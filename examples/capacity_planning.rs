//! Capacity planning with the §3 closed forms: batch-size limits
//! (Fig 2/3) and per-request serving cost (Fig 4) across SLO choices —
//! the numbers a provider would use to price SLO tiers (§3.3).
//!
//! ```sh
//! cargo run --release --example capacity_planning
//! ```

use polyserve::analysis::{
    fig2_decode_batch_series, fig3_coloc_batch_series, fig4_cost_series,
};
use polyserve::model::CostModel;

fn main() {
    let cm = CostModel::h200_llama8b();
    let tpots = [16.0, 20.0, 25.0, 30.0, 40.0, 50.0, 75.0, 100.0, 150.0];
    let configs = [(512u64, 512u64), (1000, 1000), (1000, 4000), (4000, 1000), (4000, 4000)];

    println!("== Fig 2: max decode batch vs TPOT (PD-disaggregation) ==");
    print!("{:>12}", "TPOT ms");
    for (p, d) in &configs {
        print!("{:>14}", format!("({p},{d})"));
    }
    println!();
    for (i, tpot) in tpots.iter().enumerate() {
        print!("{tpot:>12.0}");
        for (p, d) in &configs {
            let s = fig2_decode_batch_series(&cm, *p, *d, &tpots);
            print!("{:>14}", s[i].batch);
        }
        println!();
    }

    println!("\n== Fig 3: max co-located token batch vs TPOT × TTFT ==");
    for ttft in [300.0, 700.0, 2000.0] {
        println!("TTFT = {ttft} ms:");
        print!("{:>12}", "TPOT ms");
        for (p, d) in &configs {
            print!("{:>14}", format!("({p},{d})"));
        }
        println!();
        for (i, tpot) in tpots.iter().enumerate() {
            print!("{tpot:>12.0}");
            for (p, d) in &configs {
                let s = fig3_coloc_batch_series(&cm, *p, *d, ttft, &tpots);
                print!("{:>14}", s[i].batch);
            }
            println!();
        }
    }

    println!("\n== Fig 4: cost (instance·s/request) vs TPOT, TTFT=700ms ==");
    println!("{:>12} {:>12} {:>12} {:>12}", "config", "TPOT ms", "CO cost", "PD cost");
    for (p, d) in &configs {
        for pt in fig4_cost_series(&cm, *p, *d, 700.0, &[20.0, 50.0, 100.0]) {
            println!(
                "{:>12} {:>12.0} {:>12} {:>12}",
                format!("({p},{d})"),
                pt.tpot_ms,
                fmt_cost(pt.cost_coloc_s),
                fmt_cost(pt.cost_pd_s),
            );
        }
    }
    println!("\n(∞ = the SLO is infeasible for that architecture/config — see");
    println!(" EXPERIMENTS.md for the discussion of the paper's Fig 4 regime)");
}

fn fmt_cost(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "∞".to_string()
    }
}
