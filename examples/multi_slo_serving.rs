//! End-to-end driver (the repo's headline example): a real multi-SLO
//! serving run proving all three layers compose — Rust leader/worker
//! coordinator → AOT-compiled JAX model → Pallas kernels, via PJRT,
//! with Python nowhere on the request path.
//!
//! Serves a Poisson workload with two TPOT tiers (calibrated to this
//! machine's decode floor) across multiple in-process instances and
//! reports throughput, latency percentiles and DSLO attainment. The
//! run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example multi_slo_serving
//! ```

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let instances = std::env::var("POLYSERVE_INSTANCES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let requests = std::env::var("POLYSERVE_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);
    println!("multi-SLO serving: {instances} instances, {requests} requests\n");
    let report = polyserve::server::demo::run_demo(&dir, instances, requests, 0.0)?;
    println!("{report}");
    Ok(())
}
