//! Cluster-scale simulation example: reproduce one Fig-6 cell — all
//! policies on one trace across the load spectrum — and print the
//! attainment table plus goodput-at-90%.
//!
//! ```sh
//! cargo run --release --example cluster_simulation [trace] [instances]
//! ```

use polyserve::analysis::ServingMode;
use polyserve::config::{Policy, SimConfig};
use polyserve::figures::attainment_curve;
use polyserve::workload::TraceKind;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let trace = args
        .first()
        .and_then(|s| TraceKind::from_name(s))
        .unwrap_or(TraceKind::ShareGpt);
    let instances: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let fracs = [0.2, 0.4, 0.6, 0.8, 0.9, 1.0, 1.1, 1.2];
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    println!("trace {}, {instances} instances, 6000 requests/cell\n", trace.name());
    for mode in [ServingMode::PdDisaggregated, ServingMode::Colocated] {
        println!("--- {} ---", mode.name().to_uppercase());
        let mut goodputs: Vec<(String, f64, f64)> = Vec::new();
        for policy in [Policy::PolyServe, Policy::Random, Policy::Minimal, Policy::Chunk] {
            if policy == Policy::Chunk && mode == ServingMode::PdDisaggregated {
                continue; // CO-only baseline
            }
            let cfg = SimConfig {
                trace,
                policy,
                mode,
                instances,
                requests: 6_000,
                ..Default::default()
            };
            let (curve, optimal) = attainment_curve(&cfg, &fracs, threads);
            let label = policy.label(mode);
            print!("{label:>14}:");
            for (rate, att) in &curve.points {
                print!(" {:.0}rps={att:.2}", rate);
            }
            println!();
            if let Some(g) = curve.goodput_at(0.9) {
                goodputs.push((label, g, optimal));
            }
        }
        println!();
        for (label, g, opt) in &goodputs {
            println!(
                "{label:>14}: goodput@90% = {g:7.1} req/s  ({:.1}% of the closed-form optimal bound)",
                100.0 * g / opt.max(1e-9)
            );
        }
        if let (Some(ps), Some(best)) = (
            goodputs.iter().find(|(l, _, _)| l.contains("PolyServe")),
            goodputs
                .iter()
                .filter(|(l, _, _)| !l.contains("PolyServe"))
                .map(|(_, g, _)| *g)
                .max_by(|a, b| a.partial_cmp(b).unwrap()),
        ) {
            println!(
                "{:>14}  PolyServe gain over best baseline: {:.2}×\n",
                "", ps.1 / best
            );
        }
    }
}
