//! Quickstart: load the AOT model artifacts, serve a handful of
//! requests through the PJRT engine, print tokens and latencies.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use polyserve::runtime::{ArtifactStore, Engine};
use std::rc::Rc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    println!("loading artifacts from {} ...", dir.display());
    let store = Rc::new(ArtifactStore::open(&dir)?);
    println!(
        "model {} — {} layers, hidden {}, vocab {}, {} weights",
        store.model.name,
        store.model.num_layers,
        store.model.hidden,
        store.model.vocab,
        store.weights.len()
    );
    let t0 = Instant::now();
    let engine = Engine::load(Rc::clone(&store))?;
    println!(
        "compiled {} executables on '{}' in {:.1} s",
        store.executables.len(),
        engine.platform(),
        t0.elapsed().as_secs_f64()
    );

    // Serve three requests: prefill (chunked automatically), then
    // batch-decode them together — the vLLM-style continuous batch.
    let prompts: Vec<Vec<i32>> = vec![
        (1..20).collect(),
        (100..260).collect(),
        vec![7; 50],
    ];
    let mut kvs = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let mut kv = engine.new_kv();
        let t = Instant::now();
        let first = engine.prefill(&mut kv, p)?;
        println!(
            "req {i}: prompt {} tokens → first token {first} (TTFT {:.1} ms)",
            p.len(),
            t.elapsed().as_secs_f64() * 1000.0
        );
        kvs.push(kv);
    }
    print!("decoding 12 tokens per request:");
    let t = Instant::now();
    let mut streams: Vec<Vec<i32>> = kvs.iter().map(|kv| vec![kv.last_token]).collect();
    for _ in 0..12 {
        let mut refs: Vec<&mut _> = kvs.iter_mut().collect();
        let next = engine.decode_step(&mut refs)?;
        for (s, t) in streams.iter_mut().zip(&next) {
            s.push(*t);
        }
    }
    let per_tok = t.elapsed().as_secs_f64() * 1000.0 / 12.0;
    println!(" {:.1} ms/iteration (batch of 3)", per_tok);
    for (i, s) in streams.iter().enumerate() {
        println!("req {i} tokens: {s:?}");
    }
    println!("quickstart OK");
    Ok(())
}
