//! Live-server integration: a small end-to-end serving run through the
//! real PJRT engines (skipped when artifacts are missing).

use polyserve::server::demo;
use polyserve::server::{LiveServer, ServeConfig};
use polyserve::slo::{Slo, TierSet};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping server tests: run `make artifacts` first");
        None
    }
}

#[test]
fn live_server_serves_and_accounts() {
    let Some(dir) = artifacts_dir() else { return };
    let mut server = LiveServer::start(ServeConfig {
        artifacts: dir,
        instances: 1,
        chunk_tokens: 128,
        tiers: TierSet::new(vec![500, 1500]),
    })
    .expect("server start");
    let mut ids = Vec::new();
    for i in 0..6 {
        let prompt: Vec<i32> = (0..(10 + i * 13)).map(|x| (x % 500) as i32).collect();
        let tpot = if i % 2 == 0 { 500 } else { 1500 };
        ids.push(server.submit(prompt, 5, Slo::new(60_000, tpot)));
    }
    let report = server.finish().expect("finish");
    assert_eq!(report.outcomes.len(), 6);
    for o in &report.outcomes {
        assert!(o.finished.is_some(), "request {} unfinished", o.id);
        assert_eq!(o.tokens, 5, "request {} tokens", o.id);
        assert!(o.first_token.is_some());
    }
    assert!(report.total_tokens >= 30);
    assert!(report.iterations > 0);
    // Generous SLOs on an idle server: everything should attain.
    assert!(
        report.attainment() > 0.8,
        "attainment {}",
        report.attainment()
    );
}

#[test]
fn floors_measurable() {
    let Some(dir) = artifacts_dir() else { return };
    let f = demo::measure_floors(&dir).expect("floors");
    assert!(f.decode_ms > 0.0 && f.decode_ms < 10_000.0);
    assert!(f.decode_b4_ms >= f.decode_ms * 0.5);
    assert!(f.prefill128_ms > 0.0);
}
