//! Property-based tests on coordinator invariants (util::prop —
//! the in-repo proptest substitute).

use polyserve::analysis::ServingMode;
use polyserve::config::{Policy, SimConfig};
use polyserve::coordinator::admission;
use polyserve::figures::run_sim;
use polyserve::model::CostModel;
use polyserve::profile::ProfileTable;
use polyserve::sim::instance::{Instance, Role};
use polyserve::sim::SimRequest;
use polyserve::slo::{Slo, TierSet};
use polyserve::util::prop::{check, Gen, IntRange, VecOf};
use polyserve::util::rng::Rng;
use polyserve::workload::{Request, TraceKind};

fn profile() -> ProfileTable {
    ProfileTable::from_cost_model(&CostModel::h200_llama8b())
}

fn sim_requests(kvs: &[u64]) -> (Instance, Vec<SimRequest<'static>>) {
    let cm = CostModel::h200_llama8b();
    let mut inst = Instance::new(0, Role::Decode, cm.kv_capacity_tokens, cm.max_token_batch);
    let mut reqs = Vec::new();
    for (i, &kv) in kvs.iter().enumerate() {
        // Leaked immutable half: the arena borrows, never clones.
        let req: &'static Request = Box::leak(Box::new(Request {
            id: i as u64,
            arrival_ms: 0,
            prefill_len: kv as u32,
            decode_len: 10_000,
            slo: Slo::new(500, 50),
            model: 0,
        }));
        let mut r = SimRequest::new(req, 2);
        r.prefill_done = kv as u32;
        r.decoded = 1;
        r.first_token_ms = Some(0);
        r.decode_instance = Some(0);
        reqs.push(r);
        // Cache-coherent residency (direct `running` pushes would
        // desync the O(1) load counters).
        inst.push_running(i, &reqs);
    }
    (inst, reqs)
}

#[test]
fn prop_peak_kv_bounds() {
    // Peak KV prediction is bounded below by current KV and above by
    // everyone growing to the full predicted remaining length.
    let gen = VecOf {
        elem: IntRange { lo: 1, hi: 8000 },
        min_len: 1,
        max_len: 120,
    };
    check("peak_kv_bounds", &gen, |kvs| {
        let (inst, reqs) = sim_requests(kvs);
        let avg = 300.0;
        let peak = admission::peak_kv_prediction(&inst, &reqs, None, avg);
        let now: u64 = kvs.iter().map(|&k| k + 1).sum();
        let upper: u64 = kvs.iter().map(|&k| k + 1 + 300).sum();
        if peak < now.saturating_sub(kvs.len() as u64) {
            return Err(format!("peak {peak} below current {now}"));
        }
        if peak > upper {
            return Err(format!("peak {peak} above upper bound {upper}"));
        }
        Ok(())
    });
}

#[test]
fn prop_admission_monotone_in_tpot() {
    // If a server admits at TPOT t, it must admit at any looser t' > t.
    let gen = VecOf {
        elem: IntRange { lo: 100, hi: 4000 },
        min_len: 1,
        max_len: 150,
    };
    check("admission_monotone_tpot", &gen, |kvs| {
        let (inst, reqs) = sim_requests(kvs);
        let prof = profile();
        let mut prev = false;
        for tpot in [20u64, 30, 50, 100, 200] {
            let ok = admission::admit_decode(
                &inst, &reqs, &prof, tpot, 500, u64::MAX / 4, 0, 300.0, false,
            );
            if prev && !ok {
                return Err(format!("admitted at tighter TPOT but rejected at {tpot}"));
            }
            prev = prev || ok;
        }
        Ok(())
    });
}

#[test]
fn prop_max_chunk_monotone_in_load() {
    // A more loaded server can never sustain a larger prefill chunk.
    let gen = IntRange { lo: 0, hi: 400 };
    check("chunk_monotone_load", &gen, |&b| {
        let prof = profile();
        let c1 = admission::max_chunk_under(&prof, 50.0, b, b * 1000, 0.25);
        let c2 = admission::max_chunk_under(&prof, 50.0, b + 10, (b + 10) * 1000, 0.25);
        if c2 > c1 {
            return Err(format!("chunk grew with load: b={b} c1={c1} c2={c2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_tier_binning_total_and_ordered() {
    // Every TPOT bins to a tier whose TPOT covers it, and binning is
    // monotone in the request TPOT.
    let gen = VecOf {
        elem: IntRange { lo: 15, hi: 600 },
        min_len: 2,
        max_len: 64,
    };
    check("tier_binning", &gen, |tpots| {
        let tiers = TierSet::paper_default();
        let mut sorted = tpots.clone();
        sorted.sort_unstable();
        let mut last_bin = 0;
        for &t in &sorted {
            let bin = tiers.bin_for_tpot(t);
            if bin >= tiers.len() {
                return Err("bin out of range".into());
            }
            if bin < last_bin {
                return Err(format!("binning not monotone at tpot {t}"));
            }
            last_bin = bin;
        }
        Ok(())
    });
}

/// Full-simulation conservation properties on random small workloads.
#[test]
fn prop_simulation_conserves_requests() {
    struct CfgGen;
    impl Gen for CfgGen {
        type Value = (u64, u64, u64, u64);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (
                rng.range_u64(0, 7),       // trace index
                rng.range_u64(2, 10),      // instances
                rng.range_u64(30, 90),     // rate frac %
                rng.next_u64(),            // seed
            )
        }
    }
    check("sim_conserves_requests", &CfgGen, |&(t, inst, fracpct, seed)| {
        let cfg = SimConfig {
            trace: TraceKind::ALL[t as usize],
            policy: Policy::PolyServe,
            mode: if seed % 2 == 0 {
                ServingMode::PdDisaggregated
            } else {
                ServingMode::Colocated
            },
            instances: inst as usize,
            requests: 400,
            rate_frac_of_optimal: fracpct as f64 / 100.0,
            seed,
            ..Default::default()
        };
        let res = run_sim(&cfg);
        if res.unfinished != 0 {
            return Err(format!("{} unfinished requests", res.unfinished));
        }
        if res.cost.requests_served != 400 {
            return Err(format!("served {}", res.cost.requests_served));
        }
        // Tokens conservation: every outcome emitted exactly its
        // decode_len tokens.
        for o in &res.outcomes {
            if o.finish_ms.is_none() {
                return Err(format!("request {} unfinished", o.id));
            }
        }
        if res.cost.utilization() > 1.0 + 1e-9 {
            return Err(format!("utilization {} > 1", res.cost.utilization()));
        }
        Ok(())
    });
}
