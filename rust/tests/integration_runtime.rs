//! Numeric round-trip: the Rust PJRT engine must reproduce the Python
//! (JAX + Pallas) model's greedy trajectories token-for-token, and the
//! decode path must behave identically across batch buckets.
//!
//! These tests need `make artifacts` to have run; they skip (with a
//! note) when the artifact directory is absent so `cargo test` stays
//! green on a fresh checkout.

use polyserve::runtime::{ArtifactStore, Engine};
use polyserve::util::json::Json;
use std::path::PathBuf;
use std::rc::Rc;

fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("manifest.json").exists() && d.join("golden.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping runtime tests: run `make artifacts` first");
        None
    }
}

fn load_engine(dir: &PathBuf) -> Engine {
    let store = Rc::new(ArtifactStore::open(dir).expect("artifact store"));
    Engine::load(store).expect("engine")
}

struct GoldenCase {
    prompt: Vec<i32>,
    tokens: Vec<i32>,
}

fn golden_cases(dir: &PathBuf) -> Vec<GoldenCase> {
    let text = std::fs::read_to_string(dir.join("golden.json")).unwrap();
    let j = Json::parse(&text).unwrap();
    j.get("cases")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|c| GoldenCase {
            prompt: c
                .get("prompt")
                .and_then(Json::to_f64s)
                .unwrap()
                .into_iter()
                .map(|x| x as i32)
                .collect(),
            tokens: c
                .get("tokens")
                .and_then(Json::to_f64s)
                .unwrap()
                .into_iter()
                .map(|x| x as i32)
                .collect(),
        })
        .collect()
}

#[test]
fn engine_matches_python_golden_trajectories() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = load_engine(&dir);
    assert!(engine.platform().to_lowercase().contains("cpu")
        || engine.platform().to_lowercase().contains("host"));
    for (ci, case) in golden_cases(&dir).iter().enumerate() {
        let mut kv = engine.new_kv();
        let first = engine.prefill(&mut kv, &case.prompt).expect("prefill");
        assert_eq!(first, case.tokens[0], "case {ci}: first token");
        let mut got = vec![first];
        for _ in 1..case.tokens.len() {
            let mut refs = vec![&mut kv];
            let next = engine.decode_step(&mut refs).expect("decode");
            got.push(next[0]);
        }
        assert_eq!(got, case.tokens, "case {ci}: trajectory");
    }
}

#[test]
fn batched_decode_matches_single() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = load_engine(&dir);
    let cases = golden_cases(&dir);
    // Prefill three requests, decode them in one batch-of-3 (bucket 4);
    // results must equal the per-request golden trajectories.
    let mut kvs: Vec<_> = cases
        .iter()
        .map(|c| {
            let mut kv = engine.new_kv();
            engine.prefill(&mut kv, &c.prompt).unwrap();
            kv
        })
        .collect();
    for step in 1..cases[0].tokens.len() {
        let mut refs: Vec<&mut _> = kvs.iter_mut().collect();
        let next = engine.decode_step(&mut refs).unwrap();
        for (i, case) in cases.iter().enumerate() {
            assert_eq!(next[i], case.tokens[step], "req {i} step {step}");
        }
    }
}

#[test]
fn chunked_prefill_equals_whole_prefill() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = load_engine(&dir);
    let cases = golden_cases(&dir);
    let case = &cases[2]; // 150-token prompt spans chunks
    // prefill() already chunks at the max bucket; also force small
    // chunks of 64 and compare.
    let mut kv_small = engine.new_kv();
    let mut first_small = 0;
    let mut pos = 0;
    while pos < case.prompt.len() {
        let n = (case.prompt.len() - pos).min(64);
        first_small = engine
            .prefill_chunk(&mut kv_small, &case.prompt[pos..pos + n])
            .unwrap();
        pos += n;
    }
    assert_eq!(first_small, case.tokens[0]);
    let mut refs = vec![&mut kv_small];
    let next = engine.decode_step(&mut refs).unwrap();
    assert_eq!(next[0], case.tokens[1]);
}

#[test]
fn real_profiler_produces_monotone_table() {
    let Some(dir) = artifacts_dir() else { return };
    let table = polyserve::runtime::profiler::profile_real(&dir).expect("profiling");
    // Iteration time should not decrease with batch at fixed KV.
    let t1 = table.iter_ms(1, 64);
    let t8 = table.iter_ms(8, 64);
    assert!(t1 > 0.0);
    assert!(t8 >= t1 * 0.8, "t1={t1:.3} t8={t8:.3}");
}
