//! Cross-policy behavioural integration tests: the paper's qualitative
//! claims hold in simulation.

use polyserve::analysis::ServingMode;
use polyserve::config::{Features, Policy, SimConfig};
use polyserve::figures::{attainment_curve, run_sim};
use polyserve::workload::TraceKind;

fn cfg(policy: Policy, mode: ServingMode) -> SimConfig {
    SimConfig {
        trace: TraceKind::ShareGpt,
        policy,
        mode,
        instances: 20,
        requests: 6_000,
        seed: 99,
        ..Default::default()
    }
}

#[test]
fn polyserve_tier_uniformity_beats_baselines() {
    // §5.2: baselines collapse on tight-TPOT tiers; PolyServe attains
    // near-uniformly.
    let mut c_ps = cfg(Policy::PolyServe, ServingMode::PdDisaggregated);
    c_ps.rate_frac_of_optimal = 0.9;
    let mut c_rnd = c_ps.clone();
    c_rnd.policy = Policy::Random;
    let ps = run_sim(&c_ps);
    let rnd = run_sim(&c_rnd);
    assert!(
        ps.attainment.worst_tier() > rnd.attainment.worst_tier() + 0.2,
        "PolyServe worst tier {} vs Random {}",
        ps.attainment.worst_tier(),
        rnd.attainment.worst_tier()
    );
}

#[test]
fn polyserve_goodput_not_worse_and_tiers_uniform() {
    // Overall goodput@90% must not regress vs the best baseline, and
    // the per-tier uniformity (the paper's headline property) must hold
    // where the baseline collapses. (The full Fig-6 gain numbers are
    // produced by `cargo bench --bench fig6_goodput`.)
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let fracs = [0.7, 0.85, 1.0, 1.15, 1.3, 1.5];
    let mut c_ps = cfg(Policy::PolyServe, ServingMode::PdDisaggregated);
    let mut c_mn = cfg(Policy::Minimal, ServingMode::PdDisaggregated);
    c_ps.requests = 8_000;
    c_mn.requests = 8_000;
    let (ps, _) = attainment_curve(&c_ps, &fracs, threads);
    let (mn, _) = attainment_curve(&c_mn, &fracs, threads);
    let g_ps = ps.goodput_at(0.9).unwrap();
    let g_mn = mn.goodput_at(0.9).unwrap();
    assert!(
        g_ps >= g_mn * 0.97,
        "PD goodput regressed: PolyServe {g_ps:.1} vs Minimal {g_mn:.1}"
    );
}

#[test]
fn autoscaling_reduces_cost_vs_static_fleet() {
    // §5.4: with ample instances, PolyServe's auto-scaling should use
    // (and bill) far fewer instance-seconds than a static fleet.
    let mut c = cfg(Policy::PolyServe, ServingMode::Colocated);
    c.instances = 40;
    c.rate_frac_of_optimal = 0.25; // low demand
    let res = run_sim(&c);
    assert!(res.attainment.overall() > 0.9);
    let static_cost = 40.0 * res.sim_span_ms as f64 / 1000.0 / res.cost.requests_served as f64;
    let ps_cost = res.cost.cost_per_request_s();
    assert!(
        ps_cost < static_cost * 0.6,
        "auto-scaled {ps_cost:.3} vs static {static_cost:.3} inst*s/req"
    );
}

#[test]
fn burst_recovery_via_autoscaling() {
    // After a tier-mix inversion, PolyServe keeps attainment above the
    // no-autoscaling variant (static tiers can't rebalance).
    // Proxy: lazy-promotion off removes the spill mechanism.
    let mut with = cfg(Policy::PolyServe, ServingMode::PdDisaggregated);
    with.rate_frac_of_optimal = 1.0;
    let mut without = with.clone();
    without.features = Features {
        lazy_promotion: false,
        ..Features::default()
    };
    let a = run_sim(&with);
    let b = run_sim(&without);
    assert!(
        a.attainment.overall() + 0.02 >= b.attainment.overall(),
        "lazy promotion hurt: {} vs {}",
        a.attainment.overall(),
        b.attainment.overall()
    );
}

#[test]
fn chunk_budget_sweep_changes_attainment() {
    // CO-Chunk's budget matters (the paper sweeps it); ensure the knob
    // is actually wired through.
    let mut atts = Vec::new();
    for budget in [128u64, 512, 2048] {
        let mut c = cfg(Policy::Chunk, ServingMode::Colocated);
        c.chunk_budget = budget;
        c.rate_frac_of_optimal = 1.0;
        atts.push(run_sim(&c).attainment.overall());
    }
    let min = atts.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = atts.iter().cloned().fold(0.0, f64::max);
    assert!(max - min > 0.005, "budget sweep flat: {atts:?}");
}

#[test]
fn all_traces_run_all_policies_smoke() {
    for trace in TraceKind::ALL {
        for (policy, mode) in [
            (Policy::PolyServe, ServingMode::PdDisaggregated),
            (Policy::PolyServe, ServingMode::Colocated),
            (Policy::Minimal, ServingMode::PdDisaggregated),
            (Policy::Chunk, ServingMode::Colocated),
        ] {
            let c = SimConfig {
                trace,
                policy,
                mode,
                instances: 6,
                requests: 300,
                rate_frac_of_optimal: 0.5,
                seed: 1,
                ..Default::default()
            };
            let res = run_sim(&c);
            assert_eq!(res.unfinished, 0, "{trace:?} {policy:?} {mode:?}");
        }
    }
}
