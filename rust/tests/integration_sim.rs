//! End-to-end simulator integration: every (policy × mode) combination
//! must run a small workload to completion with sane metrics.

use polyserve::analysis::ServingMode;
use polyserve::config::{Policy, SimConfig};
use polyserve::figures::{run_sim, Experiment};
use polyserve::workload::TraceKind;

fn base_cfg() -> SimConfig {
    SimConfig {
        trace: TraceKind::ShareGpt,
        requests: 2_000,
        instances: 8,
        rate_frac_of_optimal: 0.6,
        seed: 42,
        ..Default::default()
    }
}

fn run(policy: Policy, mode: ServingMode, frac: f64) -> polyserve::sim::SimResult {
    let mut cfg = base_cfg();
    cfg.policy = policy;
    cfg.mode = mode;
    cfg.rate_frac_of_optimal = frac;
    run_sim(&cfg)
}

#[test]
fn all_policies_complete_all_requests_pd() {
    for policy in [Policy::PolyServe, Policy::Random, Policy::Minimal] {
        let res = run(policy, ServingMode::PdDisaggregated, 0.6);
        assert_eq!(res.unfinished, 0, "{policy:?} left requests unfinished");
        assert_eq!(res.cost.requests_served, 2_000, "{policy:?}");
        assert!(res.sim_span_ms > 0);
    }
}

#[test]
fn all_policies_complete_all_requests_coloc() {
    for policy in [Policy::PolyServe, Policy::Random, Policy::Minimal, Policy::Chunk] {
        let res = run(policy, ServingMode::Colocated, 0.6);
        assert_eq!(res.unfinished, 0, "{policy:?} left requests unfinished");
        assert_eq!(res.cost.requests_served, 2_000, "{policy:?}");
    }
}

#[test]
fn polyserve_attains_well_at_moderate_load() {
    let res = run(Policy::PolyServe, ServingMode::PdDisaggregated, 0.5);
    let att = res.attainment.overall();
    assert!(att > 0.9, "PD-PolyServe attainment at 50% load = {att}");
    let res = run(Policy::PolyServe, ServingMode::Colocated, 0.5);
    let att = res.attainment.overall();
    assert!(att > 0.85, "CO-PolyServe attainment at 50% load = {att}");
}

#[test]
fn attainment_degrades_with_load() {
    let low = run(Policy::PolyServe, ServingMode::PdDisaggregated, 0.4);
    let high = run(Policy::PolyServe, ServingMode::PdDisaggregated, 1.2);
    assert!(
        low.attainment.overall() >= high.attainment.overall(),
        "low-load attainment {} < high-load {}",
        low.attainment.overall(),
        high.attainment.overall()
    );
}

#[test]
fn polyserve_beats_random_at_high_load() {
    let ps = run(Policy::PolyServe, ServingMode::PdDisaggregated, 0.9);
    let rnd = run(Policy::Random, ServingMode::PdDisaggregated, 0.9);
    assert!(
        ps.attainment.overall() >= rnd.attainment.overall(),
        "PolyServe {} vs Random {}",
        ps.attainment.overall(),
        rnd.attainment.overall()
    );
}

#[test]
fn tpot_latencies_respect_tiers_under_polyserve() {
    let res = run(Policy::PolyServe, ServingMode::PdDisaggregated, 0.5);
    // Per-tier attainment should be reasonably uniform (the paper's
    // headline property) — no tier collapses while others are fine.
    let worst = res.attainment.worst_tier();
    let overall = res.attainment.overall();
    assert!(
        worst > overall - 0.25,
        "tier collapse: worst {worst} vs overall {overall}"
    );
}

#[test]
fn experiment_rate_tracks_optimal_fraction() {
    let mut cfg = base_cfg();
    cfg.rate_frac_of_optimal = 0.5;
    let exp = Experiment::prepare(&cfg);
    assert!(exp.optimal_rps > 0.0);
    let ratio = exp.rate_rps / exp.optimal_rps;
    assert!((ratio - 0.5).abs() < 1e-9);
    // Workload arrivals should realize roughly that rate.
    let realized = exp.workload.rate_per_s();
    assert!(
        (realized - exp.rate_rps).abs() / exp.rate_rps < 0.1,
        "realized {realized} vs requested {}",
        exp.rate_rps
    );
}

#[test]
fn outcomes_are_internally_consistent() {
    let res = run(Policy::PolyServe, ServingMode::Colocated, 0.6);
    for o in &res.outcomes {
        if let (Some(first), Some(fin)) = (o.first_token_ms, o.finish_ms) {
            assert!(first >= o.arrival_ms);
            assert!(fin >= first);
            assert!(o.tokens >= 1);
        }
        if o.attained {
            assert!(o.min_slack_ms >= 0, "attained but negative slack");
        }
    }
}

#[test]
fn cost_accounting_sane() {
    let res = run(Policy::PolyServe, ServingMode::Colocated, 0.6);
    assert!(res.cost.instance_busy_ms > 0);
    // PolyServe allocates instances on demand; allocation can't exceed
    // fleet × span.
    assert!(res.cost.instance_alloc_ms <= 8 * res.sim_span_ms);
    // Utilization within (0, 1].
    let u = res.cost.utilization();
    assert!(u > 0.0 && u <= 1.0 + 1e-9, "utilization {u}");
}

#[test]
fn deterministic_given_seed() {
    let a = run(Policy::PolyServe, ServingMode::PdDisaggregated, 0.7);
    let b = run(Policy::PolyServe, ServingMode::PdDisaggregated, 0.7);
    assert_eq!(a.attainment.overall(), b.attainment.overall());
    assert_eq!(a.sim_span_ms, b.sim_span_ms);
    assert_eq!(a.cost.instance_busy_ms, b.cost.instance_busy_ms);
}
