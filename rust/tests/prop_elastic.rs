//! Property tests for the elastic-fleet layer: `RateSchedule`
//! invariants (util::prop, the in-repo proptest substitute) and
//! whole-simulation lifecycle invariants. Lifecycle placement safety
//! (no request ever lands on a Provisioning/Draining/Retired instance)
//! is enforced by `debug_assert`s inside `Instance::push_prefill` /
//! `push_decode`, which are active in these builds — any violation
//! panics the run.

use polyserve::analysis::ServingMode;
use polyserve::config::{Policy, ScalerKind, SimConfig};
use polyserve::figures::run_sim;
use polyserve::util::prop::{check, Gen, IntRange, VecOf};
use polyserve::util::rng::Rng;
use polyserve::workload::{RateSchedule, TraceKind};

#[test]
fn prop_schedule_arrivals_strictly_increasing() {
    // Any well-formed schedule yields strictly increasing timestamps,
    // even at rates far above 1 req/ms.
    let gen = VecOf {
        elem: IntRange { lo: 1, hi: 5_000 },
        min_len: 1,
        max_len: 6,
    };
    check("arrivals_strictly_increasing", &gen, |rates| {
        let segments: Vec<(u64, f64)> = rates
            .iter()
            .enumerate()
            .map(|(i, &r)| (i as u64 * 10_000, r as f64))
            .collect();
        let s = RateSchedule { segments };
        let mut rng = Rng::new(rates.iter().sum::<u64>() ^ 0xA11);
        let arr = s.arrivals(3_000, &mut rng);
        for w in arr.windows(2) {
            if w[0] >= w[1] {
                return Err(format!("not strictly increasing: {} then {}", w[0], w[1]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rate_at_segment_boundaries() {
    // rate_at must switch exactly *at* each segment start: the new rate
    // holds at the boundary, the old rate one ms before it.
    let gen = VecOf {
        elem: IntRange { lo: 1, hi: 1_000 },
        min_len: 2,
        max_len: 12,
    };
    check("rate_at_boundaries", &gen, |gaps| {
        let mut start = 0u64;
        let mut segments = Vec::new();
        for (i, &gap) in gaps.iter().enumerate() {
            segments.push((start, (i + 1) as f64));
            start += gap;
        }
        let s = RateSchedule { segments: segments.clone() };
        for (i, &(b, rate)) in segments.iter().enumerate() {
            if s.rate_at(b) != rate {
                return Err(format!("rate_at({b}) = {} want {rate}", s.rate_at(b)));
            }
            if i > 0 {
                let before = segments[i - 1].1;
                if s.rate_at(b - 1) != before {
                    return Err(format!("rate_at({}) = {} want {before}", b - 1, s.rate_at(b - 1)));
                }
            }
        }
        // Beyond the last segment the last rate holds.
        if s.rate_at(start + 1_000_000) != segments.last().unwrap().1 {
            return Err("tail rate wrong".into());
        }
        Ok(())
    });
}

#[test]
fn prop_diurnal_integrates_to_mean() {
    struct SpecGen;
    impl Gen for SpecGen {
        type Value = (u64, u64, u64, u64);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (
                // Peak rates stay well under the 1 req/ms strict-
                // monotonicity clamp so realized rates are undistorted.
                rng.range_u64(5, 120),    // mean rate req/s
                rng.range_u64(10, 80),    // peak:trough ratio ×10 (1.0..8.0)
                rng.range_u64(60, 3600),  // period s
                rng.range_u64(4, 48),     // segments per period
            )
        }
    }
    check("diurnal_mean", &SpecGen, |&(mean, ratio10, period_s, segs)| {
        let mean = mean as f64;
        let ratio = ratio10 as f64 / 10.0;
        let period_ms = period_s * 1000;
        let s = RateSchedule::diurnal(mean, ratio, period_ms, segs as usize, 3);
        // Deterministic: the piecewise integral over full periods must
        // land within 5% of the requested mean (midpoint sampling makes
        // it exact; the tolerance guards the discretization).
        let got = s.mean_rate_over(3 * period_ms);
        if (got - mean).abs() / mean > 0.05 {
            return Err(format!("mean {got} vs requested {mean}"));
        }
        // And the realized arrival rate agrees (sampling noise bound).
        let mut rng = Rng::new(period_s ^ 0xD1);
        let n = 20_000;
        let arr = s.arrivals(n, &mut rng);
        let span_s = (*arr.last().unwrap() - arr[0]) as f64 / 1000.0;
        let realized = (n - 1) as f64 / span_s;
        // Arrivals past the 3 scheduled periods run at the last
        // segment's rate, so only check when the span stays inside.
        if *arr.last().unwrap() <= 3 * period_ms && (realized - mean).abs() / mean > 0.08 {
            return Err(format!("realized {realized} vs requested {mean}"));
        }
        Ok(())
    });
}

/// An elastic run must complete every request (no placement on
/// non-active instances — enforced by debug_asserts — and no request
/// lost across provision/drain/retire transitions), and its bill must
/// never exceed the never-shrinking upper bound.
#[test]
fn elastic_runs_complete_and_stay_bounded() {
    let cells: &[(ServingMode, ScalerKind, Policy, bool)] = &[
        (ServingMode::Colocated, ScalerKind::Gradient, Policy::PolyServe, true),
        (ServingMode::Colocated, ScalerKind::Threshold, Policy::PolyServe, false),
        (ServingMode::PdDisaggregated, ScalerKind::Gradient, Policy::PolyServe, true),
        (ServingMode::PdDisaggregated, ScalerKind::Threshold, Policy::Minimal, false),
    ];
    for &(mode, scaler, policy, diurnal) in cells {
        let mut cfg = SimConfig {
            trace: TraceKind::ShareGpt,
            policy,
            mode,
            instances: 6,
            requests: 500,
            rate_frac_of_optimal: 0.5,
            seed: 7,
            ..Default::default()
        };
        if diurnal {
            cfg.diurnal = Some(polyserve::config::DiurnalSpec {
                peak_to_trough: 3.0,
                period_s: 120.0,
            });
        }
        cfg.elastic.scaler = scaler;
        cfg.elastic.min_instances = 2;
        cfg.elastic.max_instances = 12;
        cfg.elastic.provision_delay_ms = 5_000;
        cfg.elastic.scale_eval_ms = 1_000;
        let res = run_sim(&cfg);
        let label = format!("{mode:?}/{scaler:?}/{policy:?}");
        assert_eq!(res.unfinished, 0, "{label}: unfinished requests");
        assert_eq!(res.cost.requests_served, 500, "{label}");
        assert!(!res.fleet.is_empty(), "{label}: no fleet samples");
        // The bill can never exceed every-instance-alive-for-the-run.
        let total_slots = res.fleet.samples.iter().map(|s| s.active + s.provisioning + s.draining).max().unwrap_or(0) as u64
            + 64; // retired slots; generous
        assert!(
            res.cost.active_instance_ms <= total_slots * res.sim_span_ms,
            "{label}: bill exceeds fleet-lifetime bound"
        );
        assert!(res.cost.goodput_tokens <= res.cost.tokens_total, "{label}");
    }
}

/// `max == min` (with zero provision delay) is *the* static fleet: the
/// elastic machinery must disengage entirely and reproduce the
/// fixed-fleet numbers bit-for-bit.
#[test]
fn static_bounds_reproduce_fixed_fleet_bit_for_bit() {
    let base = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 8,
        requests: 1_000,
        rate_frac_of_optimal: 0.7,
        seed: 42,
        ..Default::default()
    };
    let fixed = run_sim(&base);
    let mut static_elastic = base.clone();
    static_elastic.elastic.scaler = ScalerKind::Gradient;
    static_elastic.elastic.min_instances = 8;
    static_elastic.elastic.max_instances = 8;
    static_elastic.elastic.provision_delay_ms = 0;
    let pinned = run_sim(&static_elastic);
    assert_eq!(fixed.attainment.overall(), pinned.attainment.overall());
    assert_eq!(fixed.sim_span_ms, pinned.sim_span_ms);
    assert_eq!(fixed.cost.instance_busy_ms, pinned.cost.instance_busy_ms);
    assert_eq!(fixed.cost.instance_alloc_ms, pinned.cost.instance_alloc_ms);
    assert_eq!(fixed.cost.active_instance_ms, pinned.cost.active_instance_ms);
    assert!(pinned.fleet.is_empty(), "static bounds must schedule no ScaleEval");
}
