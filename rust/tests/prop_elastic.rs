//! Property tests for the elastic-fleet layer: `RateSchedule`
//! invariants (util::prop, the in-repo proptest substitute) and
//! whole-simulation lifecycle invariants. Lifecycle placement safety
//! (no request ever lands on a Provisioning/Draining/Retired instance)
//! is enforced by `debug_assert`s inside `Instance::push_prefill` /
//! `push_decode`, which are active in these builds — any violation
//! panics the run.

use polyserve::analysis::ServingMode;
use polyserve::config::{DiurnalSpec, Policy, ScalerKind, SimConfig};
use polyserve::coordinator::{
    make_router, Autoscaler, GradientAutoscaler, PolyServeRouter, RouteCtx, Router, ScaleAction,
};
use polyserve::figures::{run_sim, Experiment};
use polyserve::model::{CostModel, ModelRegistry};
use polyserve::profile::ProfileTable;
use polyserve::metrics::ChaosStats;
use polyserve::sim::{
    ChaosParams, Cluster, ElasticParams, FailDomain, OverloadParams, PrefillElastic, PrefillJob,
    Role, SimParams, SimRequest, SimResult, Simulation,
};
use polyserve::slo::{Slo, TimeMs};
use polyserve::util::prop::{check, Gen, IntRange, VecOf};
use polyserve::util::rng::Rng;
use polyserve::workload::{RateSchedule, Request, TraceKind, Workload};
use std::collections::HashMap;

#[test]
fn prop_schedule_arrivals_strictly_increasing() {
    // Any well-formed schedule yields strictly increasing timestamps,
    // even at rates far above 1 req/ms.
    let gen = VecOf {
        elem: IntRange { lo: 1, hi: 5_000 },
        min_len: 1,
        max_len: 6,
    };
    check("arrivals_strictly_increasing", &gen, |rates| {
        let segments: Vec<(u64, f64)> = rates
            .iter()
            .enumerate()
            .map(|(i, &r)| (i as u64 * 10_000, r as f64))
            .collect();
        let s = RateSchedule { segments };
        let mut rng = Rng::new(rates.iter().sum::<u64>() ^ 0xA11);
        let arr = s.arrivals(3_000, &mut rng);
        for w in arr.windows(2) {
            if w[0] >= w[1] {
                return Err(format!("not strictly increasing: {} then {}", w[0], w[1]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rate_at_segment_boundaries() {
    // rate_at must switch exactly *at* each segment start: the new rate
    // holds at the boundary, the old rate one ms before it.
    let gen = VecOf {
        elem: IntRange { lo: 1, hi: 1_000 },
        min_len: 2,
        max_len: 12,
    };
    check("rate_at_boundaries", &gen, |gaps| {
        let mut start = 0u64;
        let mut segments = Vec::new();
        for (i, &gap) in gaps.iter().enumerate() {
            segments.push((start, (i + 1) as f64));
            start += gap;
        }
        let s = RateSchedule { segments: segments.clone() };
        for (i, &(b, rate)) in segments.iter().enumerate() {
            if s.rate_at(b) != rate {
                return Err(format!("rate_at({b}) = {} want {rate}", s.rate_at(b)));
            }
            if i > 0 {
                let before = segments[i - 1].1;
                if s.rate_at(b - 1) != before {
                    return Err(format!("rate_at({}) = {} want {before}", b - 1, s.rate_at(b - 1)));
                }
            }
        }
        // Beyond the last segment the last rate holds.
        if s.rate_at(start + 1_000_000) != segments.last().unwrap().1 {
            return Err("tail rate wrong".into());
        }
        Ok(())
    });
}

#[test]
fn prop_diurnal_integrates_to_mean() {
    struct SpecGen;
    impl Gen for SpecGen {
        type Value = (u64, u64, u64, u64);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (
                // Peak rates stay well under the 1 req/ms strict-
                // monotonicity clamp so realized rates are undistorted.
                rng.range_u64(5, 120),    // mean rate req/s
                rng.range_u64(10, 80),    // peak:trough ratio ×10 (1.0..8.0)
                rng.range_u64(60, 3600),  // period s
                rng.range_u64(4, 48),     // segments per period
            )
        }
    }
    check("diurnal_mean", &SpecGen, |&(mean, ratio10, period_s, segs)| {
        let mean = mean as f64;
        let ratio = ratio10 as f64 / 10.0;
        let period_ms = period_s * 1000;
        let s = RateSchedule::diurnal(mean, ratio, period_ms, segs as usize, 3);
        // Deterministic: the piecewise integral over full periods must
        // land within 5% of the requested mean (midpoint sampling makes
        // it exact; the tolerance guards the discretization).
        let got = s.mean_rate_over(3 * period_ms);
        if (got - mean).abs() / mean > 0.05 {
            return Err(format!("mean {got} vs requested {mean}"));
        }
        // And the realized arrival rate agrees (sampling noise bound).
        let mut rng = Rng::new(period_s ^ 0xD1);
        let n = 20_000;
        let arr = s.arrivals(n, &mut rng);
        let span_s = (*arr.last().unwrap() - arr[0]) as f64 / 1000.0;
        let realized = (n - 1) as f64 / span_s;
        // Arrivals past the 3 scheduled periods run at the last
        // segment's rate, so only check when the span stays inside.
        if *arr.last().unwrap() <= 3 * period_ms && (realized - mean).abs() / mean > 0.08 {
            return Err(format!("realized {realized} vs requested {mean}"));
        }
        Ok(())
    });
}

/// An elastic run must complete every request (no placement on
/// non-active instances — enforced by debug_asserts — and no request
/// lost across provision/drain/retire transitions), and its bill must
/// never exceed the never-shrinking upper bound.
#[test]
fn elastic_runs_complete_and_stay_bounded() {
    let cells: &[(ServingMode, ScalerKind, Policy, bool)] = &[
        (ServingMode::Colocated, ScalerKind::Gradient, Policy::PolyServe, true),
        (ServingMode::Colocated, ScalerKind::Threshold, Policy::PolyServe, false),
        (ServingMode::Colocated, ScalerKind::Predictive, Policy::PolyServe, true),
        (ServingMode::PdDisaggregated, ScalerKind::Gradient, Policy::PolyServe, true),
        (ServingMode::PdDisaggregated, ScalerKind::Threshold, Policy::Minimal, false),
        (ServingMode::PdDisaggregated, ScalerKind::Predictive, Policy::PolyServe, true),
    ];
    for &(mode, scaler, policy, diurnal) in cells {
        let mut cfg = SimConfig {
            trace: TraceKind::ShareGpt,
            policy,
            mode,
            instances: 6,
            requests: 500,
            rate_frac_of_optimal: 0.5,
            seed: 7,
            ..Default::default()
        };
        if diurnal {
            cfg.diurnal = Some(polyserve::config::DiurnalSpec {
                peak_to_trough: 3.0,
                period_s: 120.0,
            });
        }
        cfg.elastic.scaler = scaler;
        cfg.elastic.min_instances = 2;
        cfg.elastic.max_instances = 12;
        cfg.elastic.provision_delay_ms = 5_000;
        cfg.elastic.scale_eval_ms = 1_000;
        let res = run_sim(&cfg);
        let label = format!("{mode:?}/{scaler:?}/{policy:?}");
        assert_eq!(res.unfinished, 0, "{label}: unfinished requests");
        assert_eq!(res.cost.requests_served, 500, "{label}");
        assert!(!res.fleet.is_empty(), "{label}: no fleet samples");
        // The bill can never exceed every-instance-alive-for-the-run.
        let total_slots = res.fleet.samples.iter().map(|s| s.active + s.provisioning + s.draining).max().unwrap_or(0) as u64
            + 64; // retired slots; generous
        assert!(
            res.cost.active_instance_ms <= total_slots * res.sim_span_ms,
            "{label}: bill exceeds fleet-lifetime bound"
        );
        assert!(res.cost.goodput_tokens <= res.cost.tokens_total, "{label}");
        // Only the predictive policy records a rate series.
        if scaler == ScalerKind::Predictive {
            assert!(!res.fleet.rates.is_empty(), "{label}: no rate samples");
        } else {
            assert!(res.fleet.rates.is_empty(), "{label}: unexpected rate samples");
        }
    }
}

/// `max == min` (with zero provision delay) is *the* static fleet: the
/// elastic machinery must disengage entirely and reproduce the
/// fixed-fleet numbers bit-for-bit.
#[test]
fn static_bounds_reproduce_fixed_fleet_bit_for_bit() {
    let base = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 8,
        requests: 1_000,
        rate_frac_of_optimal: 0.7,
        seed: 42,
        ..Default::default()
    };
    let fixed = run_sim(&base);
    let mut static_elastic = base.clone();
    static_elastic.elastic.scaler = ScalerKind::Gradient;
    static_elastic.elastic.min_instances = 8;
    static_elastic.elastic.max_instances = 8;
    static_elastic.elastic.provision_delay_ms = 0;
    let pinned = run_sim(&static_elastic);
    assert_eq!(fixed.attainment.overall(), pinned.attainment.overall());
    assert_eq!(fixed.sim_span_ms, pinned.sim_span_ms);
    assert_eq!(fixed.cost.instance_busy_ms, pinned.cost.instance_busy_ms);
    assert_eq!(fixed.cost.instance_alloc_ms, pinned.cost.instance_alloc_ms);
    assert_eq!(fixed.cost.active_instance_ms, pinned.cost.active_instance_ms);
    assert!(pinned.fleet.is_empty(), "static bounds must schedule no ScaleEval");
}

// ---------------------------------------------------------------------
// Regression tests for the decode-handoff timing fixes.
// ---------------------------------------------------------------------

fn decode_phase_request(id: u64, prefill: u32, decode: u32, slo: Slo) -> SimRequest<'static> {
    // Leaked immutable half: the arena borrows, never clones.
    let req: &'static Request = Box::leak(Box::new(Request {
        id,
        arrival_ms: 0,
        prefill_len: prefill,
        decode_len: decode,
        slo,
        model: 0,
    }));
    let mut r = SimRequest::new(req, 3); // paper_default tier for tpot 100
    r.prefill_done = prefill;
    r.decoded = 1;
    r.first_token_ms = Some(10);
    r
}

/// The PR-1 bug: a pended PD decode handoff was enqueued with
/// `ready = now`, skipping the KV-transfer delay the direct
/// `route_decode` path pays. Both paths must mark the handoff ready at
/// `now + kv_transfer_ms`.
#[test]
fn pended_decode_handoff_pays_kv_transfer_delay() {
    let cm = CostModel::h200_llama8b();
    let profile = ProfileTable::from_cost_model(&cm);
    let cfg = SimConfig {
        mode: ServingMode::PdDisaggregated,
        ..Default::default()
    };
    let mut router = PolyServeRouter::new(&cfg, 300.0);
    // 1 prefill + 1 decode instance; drain the decode server so the
    // handoff has nowhere to go and must pend.
    let mut cluster = Cluster::build(ServingMode::PdDisaggregated, 2, 0.5, 4, &cm, true);
    let mut reqs = vec![decode_phase_request(0, 64, 50, Slo::new(10_000, 100))];
    cluster.begin_drain(1, 0);
    let kv_transfer_ms: TimeMs = 37;
    {
        let mut ctx = RouteCtx {
            now: 10,
            cluster: &mut cluster,
            requests: &mut reqs,
            profile: &profile,
            mode: ServingMode::PdDisaggregated,
            kv_transfer_ms,
        };
        assert_eq!(router.route_decode(10, 0, &mut ctx), None, "must pend");
    }
    assert_eq!(router.stats.pends, 1);
    // Fresh capacity appears; the pended dispatch must pay the same
    // transfer delay as the direct path would.
    let id2 = cluster.provision(Role::Decode, 10, 20);
    cluster.mark_ready(id2);
    {
        let mut ctx = RouteCtx {
            now: 500,
            cluster: &mut cluster,
            requests: &mut reqs,
            profile: &profile,
            mode: ServingMode::PdDisaggregated,
            kv_transfer_ms,
        };
        router.on_tick(500, &mut ctx);
    }
    assert_eq!(
        cluster.instances[id2].decode_queue.front(),
        Some(&(0, 500 + kv_transfer_ms)),
        "pended handoff must be ready at now + kv_transfer_ms"
    );
    assert_eq!(reqs[0].decode_instance, Some(id2));
}

/// The PR-1 bug: `prefill_queue_feasible` identified the inserted job
/// by `(deadline, rem)` equality, so a queued twin made it report the
/// *earlier* job's finish time. The estimate must track the insertion
/// position: with an identical job already queued ahead, the new job's
/// finish is strictly later than on an empty queue.
#[test]
fn prefill_feasibility_tracks_inserted_job_not_its_twin() {
    let cm = CostModel::h200_llama8b();
    let profile = ProfileTable::from_cost_model(&cm);
    let cfg = SimConfig {
        mode: ServingMode::PdDisaggregated,
        ..Default::default()
    };
    let router = PolyServeRouter::new(&cfg, 300.0);
    let mut cluster = Cluster::build(ServingMode::PdDisaggregated, 2, 0.5, 4, &cm, true);
    let slo = Slo::new(5_000, 50);
    let mut reqs = vec![decode_phase_request(0, 600, 50, slo)];
    reqs[0].prefill_done = 0; // still needs its full 600-token prefill
    let empty_finish = {
        let ctx = RouteCtx {
            now: 0,
            cluster: &mut cluster,
            requests: &mut reqs,
            profile: &profile,
            mode: ServingMode::PdDisaggregated,
            kv_transfer_ms: 2,
        };
        router
            .prefill_queue_feasible(0, 0, 600, 4_950, &ctx)
            .expect("empty queue must be feasible")
    };
    // Queue a twin job: same effective deadline (5000 − tpot 50) and
    // the same 600 remaining tokens as the candidate below.
    cluster.instances[0].push_prefill(PrefillJob { req_idx: 0, deadline: 5_000 }, &reqs);
    let queued_finish = {
        let ctx = RouteCtx {
            now: 0,
            cluster: &mut cluster,
            requests: &mut reqs,
            profile: &profile,
            mode: ServingMode::PdDisaggregated,
            kv_transfer_ms: 2,
        };
        router
            .prefill_queue_feasible(0, 0, 600, 4_950, &ctx)
            .expect("two short jobs against a 5 s deadline are feasible")
    };
    assert!(
        queued_finish > empty_finish + 1e-9,
        "the new job finishes after its queued twin, not at the twin's \
         finish: empty={empty_finish} queued={queued_finish}"
    );
}

/// The PR-1 bug: releasing an empty `Pending` instance skipped the
/// `releases` diagnostic counter.
#[test]
fn pending_release_increments_stats() {
    let cm = CostModel::h200_llama8b();
    let profile = ProfileTable::from_cost_model(&cm);
    let cfg = SimConfig {
        mode: ServingMode::Colocated,
        ..Default::default()
    };
    let mut router = PolyServeRouter::new(&cfg, 300.0);
    let mut cluster = Cluster::build(ServingMode::Colocated, 2, 0.0, 4, &cm, true);
    let id = cluster.claim_for_tier(0, 0).unwrap();
    cluster.mark_pending(id);
    let mut reqs: Vec<SimRequest> = Vec::new();
    {
        let mut ctx = RouteCtx {
            now: 1_000,
            cluster: &mut cluster,
            requests: &mut reqs,
            profile: &profile,
            mode: ServingMode::Colocated,
            kv_transfer_ms: 2,
        };
        router.on_tick(1_000, &mut ctx);
    }
    assert_eq!(
        router.stats.releases, 1,
        "releasing an empty Pending instance must count as a release"
    );
    assert_eq!(cluster.best_effort_pool().count(), 2);
}

/// The PR-1 bug: `finalize` derived the span only from finished
/// requests, so a `max_sim_ms`-aborted run billed zero
/// active-instance·ms and reported 0 rps. The span must clamp to the
/// last simulated event time.
#[test]
fn aborted_run_bills_the_simulated_span() {
    let cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 4,
        requests: 400,
        rate_rps: Some(20.0), // 400 requests ≈ 20 s of arrivals
        seed: 11,
        ..Default::default()
    };
    let exp = Experiment::prepare(&cfg);
    let params = SimParams {
        mode: cfg.mode,
        max_sim_ms: 2_000, // abort long before the workload completes
        ..Default::default()
    };
    let cluster = Cluster::build(
        cfg.mode,
        cfg.instances,
        exp.cfg.prefill_frac,
        cfg.tiers.len(),
        &exp.cost_model,
        true,
    );
    let sim = Simulation::new(
        params,
        exp.cost_model.clone(),
        &exp.profile,
        &exp.workload,
        cluster,
        &cfg.tiers,
    );
    let mut router = PolyServeRouter::new(&cfg, exp.workload.avg_decode_len());
    let res = sim.run(&mut router);
    assert!(res.unfinished > 0, "the run must actually abort");
    assert!(
        res.sim_span_ms > 0 && res.sim_span_ms <= 2_000,
        "span must cover the simulated time, got {}",
        res.sim_span_ms
    );
    // A fixed 4-instance fleet is alive for the whole simulated span.
    assert_eq!(res.cost.active_instance_ms, 4 * res.sim_span_ms);
}

// ---------------------------------------------------------------------
// Scale-in KV-migration properties.
// ---------------------------------------------------------------------

/// Drains one decode server (the busiest) exactly once at `at_ms`,
/// proposing `migrate` — a deterministic harness for the drain path.
struct DrainOnce {
    at_ms: TimeMs,
    migrate: bool,
    fired: bool,
}

impl Autoscaler for DrainOnce {
    fn evaluate(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        if self.fired || now < self.at_ms {
            return Vec::new();
        }
        let target = ctx
            .cluster
            .instances
            .iter()
            .filter(|i| i.role == Role::Decode && i.lifecycle.accepts_work())
            .max_by_key(|i| i.decode_batch_now())
            .map(|i| i.id);
        match target {
            Some(inst) => {
                self.fired = true;
                vec![ScaleAction::Drain { inst, migrate: self.migrate }]
            }
            None => Vec::new(),
        }
    }

    fn name(&self) -> String {
        "drain-once".into()
    }
}

/// One controlled long-decode run: 6 requests with 3000-token outputs
/// on a 1-prefill + 2-decode fleet, the busiest decode server drained
/// at t=2 s while every request is mid-stream.
fn long_decode_drain_run(
    migration_cfg: bool,
    propose_migrate: bool,
    batching: bool,
) -> SimResult {
    let cm = CostModel::h200_llama8b();
    let profile = ProfileTable::from_cost_model(&cm);
    let cfg = SimConfig {
        mode: ServingMode::PdDisaggregated,
        ..Default::default()
    };
    let workload = Workload {
        requests: (0..6u64)
            .map(|i| Request {
                id: i,
                arrival_ms: i * 20,
                prefill_len: 256,
                decode_len: 3_000,
                slo: Slo::new(5_000, 100),
                model: 0,
            })
            .collect(),
    };
    let cluster = Cluster::build(ServingMode::PdDisaggregated, 3, 0.34, cfg.tiers.len(), &cm, true);
    let params = SimParams {
        mode: ServingMode::PdDisaggregated,
        elastic: Some(ElasticParams {
            min_instances: 1,
            max_instances: 4,
            provision_delay_ms: 1_000,
            scale_eval_ms: 500,
            migration: migration_cfg,
            migration_batching: batching,
            model_swap_delay_ms: 20_000,
            prefill: None,
        }),
        ..Default::default()
    };
    let sim = Simulation::new(params, cm.clone(), &profile, &workload, cluster, &cfg.tiers);
    let mut router = PolyServeRouter::new(&cfg, workload.avg_decode_len());
    let mut scaler = DrainOnce { at_ms: 2_000, migrate: propose_migrate, fired: false };
    sim.run_elastic(&mut router, Some(&mut scaler))
}

/// Token conservation across eviction and re-placement: every migrated
/// request still emits exactly `decode_len` tokens — none lost to the
/// eviction, none duplicated between source and destination — and the
/// drain finishes strictly sooner than waiting the residents out.
#[test]
fn migration_conserves_tokens_and_shortens_drains() {
    let off = long_decode_drain_run(false, true, false);
    let on = long_decode_drain_run(true, true, false);
    for (label, res) in [("off", &off), ("on", &on)] {
        assert_eq!(res.unfinished, 0, "migration={label}: unfinished requests");
        for o in &res.outcomes {
            assert_eq!(
                o.tokens, 3_000,
                "migration={label}: request {} emitted {} of 3000 tokens",
                o.id, o.tokens
            );
        }
        assert_eq!(res.migration.drains(), 1, "migration={label}: expected one drain");
    }
    assert!(on.migration.migrated_requests > 0, "residents must migrate");
    assert_eq!(off.migration.migrated_requests, 0);
    assert_eq!(off.migration.migrated_kv_tokens, 0);
    let (on_ms, off_ms) = (
        on.migration.mean_drain_latency_ms(),
        off.migration.mean_drain_latency_ms(),
    );
    assert!(
        on_ms < off_ms,
        "migration must shorten the drain: on={on_ms} ms vs off={off_ms} ms"
    );
}

/// `migration = "off"` is the PR-1 wait-drain path bit-for-bit: the
/// config gate alone decides — a scaler *proposing* migration must
/// change nothing while the feature is off.
#[test]
fn migration_off_reproduces_wait_drain_bit_for_bit() {
    let a = long_decode_drain_run(false, true, false); // proposal gated off
    let b = long_decode_drain_run(false, false, false); // wait-drain proposed
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.first_token_ms, y.first_token_ms);
        assert_eq!(x.finish_ms, y.finish_ms);
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.attained, y.attained);
    }
    assert_eq!(a.sim_span_ms, b.sim_span_ms);
    assert_eq!(a.cost.instance_busy_ms, b.cost.instance_busy_ms);
    assert_eq!(a.cost.active_instance_ms, b.cost.active_instance_ms);
    assert_eq!(a.migration, b.migration);
    assert_eq!(a.migration.migrated_requests, 0);
}

/// Batched per-destination transfers move exactly the same residents
/// (same eviction decisions, same KV totals) as the per-request path
/// and conserve every token — only the transfer *timing* changes (one
/// bulk stream per destination instead of a fixed delay per request).
#[test]
fn batched_migration_conserves_tokens_and_residents() {
    let per_req = long_decode_drain_run(true, true, false);
    let batched = long_decode_drain_run(true, true, true);
    for (label, res) in [("per-request", &per_req), ("batched", &batched)] {
        assert_eq!(res.unfinished, 0, "batching={label}: unfinished requests");
        for o in &res.outcomes {
            assert_eq!(
                o.tokens, 3_000,
                "batching={label}: request {} emitted {} of 3000 tokens",
                o.id, o.tokens
            );
        }
        assert_eq!(res.migration.drains(), 1, "batching={label}: expected one drain");
    }
    assert!(batched.migration.migrated_requests > 0, "residents must migrate");
    assert_eq!(
        batched.migration.migrated_requests, per_req.migration.migrated_requests,
        "batching must not change which residents are evicted"
    );
    assert_eq!(
        batched.migration.migrated_kv_tokens, per_req.migration.migrated_kv_tokens,
        "batching must not change the migrated KV volume"
    );
}

// ---------------------------------------------------------------------
// Model hot-swap properties (multi-model fleet).
// ---------------------------------------------------------------------

/// Swaps the busiest model-0 decode server to model 1 exactly once at
/// `at_ms` — the deterministic harness for the hot-swap path.
struct SwapOnce {
    at_ms: TimeMs,
    fired: bool,
}

impl Autoscaler for SwapOnce {
    fn evaluate(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        if self.fired || now < self.at_ms {
            return Vec::new();
        }
        let target = ctx
            .cluster
            .instances
            .iter()
            .filter(|i| i.role == Role::Decode && i.model == 0 && i.lifecycle.accepts_work())
            .max_by_key(|i| i.decode_batch_now())
            .map(|i| i.id);
        match target {
            Some(inst) => {
                self.fired = true;
                vec![ScaleAction::SwapModel { inst, model: 1 }]
            }
            None => Vec::new(),
        }
    }

    fn name(&self) -> String {
        "swap-once".into()
    }
}

/// Token conservation across a model hot-swap: the swapped server
/// drains (migrating its mid-stream residents to surviving model-0
/// servers), pays the weight-reload delay, and re-enters service under
/// model 1 — and every request of both models still emits exactly its
/// `decode_len` tokens, none lost to the eviction, none duplicated.
#[test]
fn model_hot_swap_conserves_tokens() {
    let registry = ModelRegistry::builtin_pair();
    let cm = registry.entry(0).cost_model.clone();
    let profile = registry.entry(0).profile.clone();
    let cfg = SimConfig {
        mode: ServingMode::PdDisaggregated,
        ..Default::default()
    };
    // 8 long-decode model-0 requests keep two decode servers busy while
    // the swap fires; 4 model-1 requests need the model-1 sub-fleet.
    let workload = Workload {
        requests: (0..12u64)
            .map(|i| Request {
                id: i,
                arrival_ms: i * 20,
                prefill_len: 256,
                decode_len: if i < 8 { 2_000 } else { 50 },
                slo: Slo::new(5_000, 100),
                model: usize::from(i >= 8),
            })
            .collect(),
    };
    // Model 0: 1 prefill + 2 decode (so the swap never empties the
    // sub-fleet); model 1: 1 prefill + 1 decode.
    let cluster = Cluster::build_models(
        ServingMode::PdDisaggregated,
        &[3, 2],
        0.34,
        cfg.tiers.len(),
        &registry.instance_caps(),
        true,
    );
    let params = SimParams {
        mode: ServingMode::PdDisaggregated,
        elastic: Some(ElasticParams {
            min_instances: 1,
            max_instances: 6,
            provision_delay_ms: 300,
            scale_eval_ms: 500,
            migration: true,
            migration_batching: false,
            model_swap_delay_ms: 700,
            prefill: None,
        }),
        ..Default::default()
    };
    let sim = Simulation::new(params, cm, &profile, &workload, cluster, &cfg.tiers)
        .with_cost_models(registry.cost_models());
    let mut router =
        PolyServeRouter::new(&cfg, workload.avg_decode_len()).with_models(registry.profiles());
    let mut scaler = SwapOnce { at_ms: 2_000, fired: false };
    let res = sim.run_elastic(&mut router, Some(&mut scaler));
    assert_eq!(res.unfinished, 0, "hot-swap run left unfinished requests");
    for o in &res.outcomes {
        let want = if o.id < 8 { 2_000 } else { 50 };
        assert_eq!(
            o.tokens, want,
            "request {} (model {}) emitted {} of {} tokens across the swap",
            o.id, o.model, o.tokens, want
        );
    }
    assert_eq!(res.migration.model_swaps, 1, "exactly one hot-swap must complete");
    assert!(
        res.migration.migrated_requests > 0,
        "the swapped server's mid-stream residents must migrate off"
    );
    // The swap rebalanced the fleet 3:2 → 2:3; billing follows the
    // *final* loaded model.
    assert_eq!(res.cost.active_instance_ms_per_model.len(), 2);
    assert!(
        res.cost.active_instance_ms_per_model[1] > res.cost.active_instance_ms_per_model[0] / 3,
        "model 1's bill must reflect the swapped-in server: {:?}",
        res.cost.active_instance_ms_per_model
    );
}

// ---------------------------------------------------------------------
// Elastic-prefill properties (PR 3).
// ---------------------------------------------------------------------

/// Drains the most-queued *prefill* server exactly once at `at_ms` —
/// the deterministic harness for the prefill-drain path.
struct DrainPrefillOnce {
    at_ms: TimeMs,
    migrate: bool,
    fired: bool,
}

impl Autoscaler for DrainPrefillOnce {
    fn evaluate(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        if self.fired || now < self.at_ms {
            return Vec::new();
        }
        let target = ctx
            .cluster
            .instances
            .iter()
            .filter(|i| i.role == Role::Prefill && i.lifecycle.accepts_work())
            .max_by_key(|i| i.queued_prefill_tokens(ctx.requests))
            .map(|i| (i.id, i.queued_prefill_tokens(ctx.requests)));
        match target {
            Some((inst, queued)) if queued > 0 => {
                self.fired = true;
                vec![ScaleAction::Drain { inst, migrate: self.migrate }]
            }
            _ => Vec::new(),
        }
    }

    fn name(&self) -> String {
        "drain-prefill-once".into()
    }
}

/// One controlled prefill-drain run: 12 requests with 4000-token
/// prompts on a 2-prefill + 2-decode fleet, the most-queued prefill
/// server drained at t=200 ms while its queue is full.
fn prefill_drain_run(migration_cfg: bool) -> SimResult {
    let cm = CostModel::h200_llama8b();
    let profile = ProfileTable::from_cost_model(&cm);
    let cfg = SimConfig {
        mode: ServingMode::PdDisaggregated,
        ..Default::default()
    };
    let workload = Workload {
        requests: (0..12u64)
            .map(|i| Request {
                id: i,
                arrival_ms: i * 5,
                prefill_len: 4_000,
                decode_len: 50,
                slo: Slo::new(8_000, 100),
                model: 0,
            })
            .collect(),
    };
    let cluster =
        Cluster::build(ServingMode::PdDisaggregated, 4, 0.5, cfg.tiers.len(), &cm, true);
    let params = SimParams {
        mode: ServingMode::PdDisaggregated,
        elastic: Some(ElasticParams {
            min_instances: 1,
            max_instances: 4,
            provision_delay_ms: 1_000,
            scale_eval_ms: 100,
            migration: migration_cfg,
            migration_batching: false,
            model_swap_delay_ms: 20_000,
            prefill: Some(PrefillElastic { min_instances: 1, max_instances: 4 }),
        }),
        ..Default::default()
    };
    let sim = Simulation::new(params, cm.clone(), &profile, &workload, cluster, &cfg.tiers);
    let mut router = PolyServeRouter::new(&cfg, workload.avg_decode_len());
    let mut scaler = DrainPrefillOnce { at_ms: 200, migrate: true, fired: false };
    sim.run_elastic(&mut router, Some(&mut scaler))
}

/// Draining a prefill server with migration re-routes its queued jobs
/// (partially-prefilled KV streams off first, progress is never applied
/// twice) and every request still emits exactly its `decode_len`
/// tokens; wait-drain finishes the queue in place, strictly slower.
#[test]
fn prefill_drain_migrates_queued_jobs_and_conserves_work() {
    let on = prefill_drain_run(true);
    let off = prefill_drain_run(false);
    for (label, res) in [("on", &on), ("off", &off)] {
        assert_eq!(res.unfinished, 0, "migration={label}: unfinished requests");
        for o in &res.outcomes {
            assert_eq!(
                o.tokens, 50,
                "migration={label}: request {} emitted {} of 50 tokens",
                o.id, o.tokens
            );
        }
        assert_eq!(res.migration.drains(), 1, "migration={label}: expected one drain");
    }
    assert!(
        on.migration.migrated_prefill_jobs > 0,
        "queued prefill jobs must be re-routed"
    );
    assert_eq!(on.migration.migrated_requests, 0, "no decode resident on a prefill server");
    assert_eq!(off.migration.migrated_prefill_jobs, 0);
    assert_eq!(off.migration.migrated_kv_tokens, 0);
    let (on_ms, off_ms) = (
        on.migration.mean_drain_latency_ms(),
        off.migration.mean_drain_latency_ms(),
    );
    assert!(
        on_ms < off_ms,
        "prefill migration must shorten the drain: on={on_ms} ms vs off={off_ms} ms"
    );
}

/// Property (3) of the predictive-scaler spec: with `prefill_elastic`
/// off, the config-driven elastic run is bit-for-bit the PR 2 path — a
/// hand-built simulation with `prefill: None` and the plain gradient
/// scaler produces identical outcomes, billing, and migration stats.
#[test]
fn prefill_elastic_off_is_bit_for_bit_pr2() {
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 6,
        requests: 400,
        rate_frac_of_optimal: 0.5,
        seed: 13,
        ..Default::default()
    };
    cfg.diurnal = Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 120.0 });
    cfg.elastic.scaler = ScalerKind::Gradient;
    cfg.elastic.min_instances = 2;
    cfg.elastic.max_instances = 10;
    cfg.elastic.provision_delay_ms = 5_000;
    cfg.elastic.scale_eval_ms = 1_000;
    cfg.elastic.migration = true;
    assert!(!cfg.elastic.prefill_elastic, "default must be off");
    let exp = Experiment::prepare(&cfg);
    let via_config = exp.run();

    // The PR 2 shape, built by hand: ElasticParams without a prefill
    // tier, gradient scaler without the prefill extension.
    let cluster = Cluster::build(
        exp.cfg.mode,
        exp.cfg.instances,
        exp.cfg.prefill_frac,
        exp.cfg.tiers.len(),
        &exp.cost_model,
        true,
    );
    let params = SimParams {
        mode: exp.cfg.mode,
        elastic: Some(ElasticParams {
            min_instances: 2,
            max_instances: 10,
            provision_delay_ms: 5_000,
            scale_eval_ms: 1_000,
            migration: true,
            migration_batching: false,
            model_swap_delay_ms: 20_000,
            prefill: None,
        }),
        ..Default::default()
    };
    let sim = Simulation::new(
        params,
        exp.cost_model.clone(),
        &exp.profile,
        &exp.workload,
        cluster,
        &exp.cfg.tiers,
    );
    let mut router = make_router(&exp.cfg, exp.workload.avg_decode_len());
    let mut scaler = GradientAutoscaler::new(exp.cfg.tiers.clone());
    let by_hand = sim.run_elastic(router.as_mut(), Some(&mut scaler));

    assert_eq!(via_config.outcomes.len(), by_hand.outcomes.len());
    for (x, y) in via_config.outcomes.iter().zip(&by_hand.outcomes) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.first_token_ms, y.first_token_ms);
        assert_eq!(x.finish_ms, y.finish_ms);
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.attained, y.attained);
    }
    assert_eq!(via_config.sim_span_ms, by_hand.sim_span_ms);
    assert_eq!(via_config.cost.instance_busy_ms, by_hand.cost.instance_busy_ms);
    assert_eq!(via_config.cost.active_instance_ms, by_hand.cost.active_instance_ms);
    assert_eq!(via_config.migration, by_hand.migration);
    assert_eq!(via_config.migration.migrated_prefill_jobs, 0);
    // The prefill tier never moved in either run.
    let pf: Vec<usize> = via_config.fleet.samples.iter().map(|s| s.active_prefill).collect();
    assert!(pf.windows(2).all(|w| w[0] == w[1]), "static prefill tier changed size");
}

/// Full-system property: a diurnal PD run under the *predictive* scaler
/// with elastic prefill and migration on completes every request with
/// exact per-request token counts, records the predicted-vs-observed
/// rate series, and never drains the prefill tier below its floor.
#[test]
fn predictive_prefill_elastic_run_completes_with_exact_tokens() {
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 8,
        requests: 600,
        rate_frac_of_optimal: 0.5,
        seed: 7,
        ..Default::default()
    };
    cfg.diurnal = Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 120.0 });
    cfg.elastic.scaler = ScalerKind::Predictive;
    cfg.elastic.min_instances = 2;
    cfg.elastic.max_instances = 12;
    cfg.elastic.provision_delay_ms = 5_000;
    cfg.elastic.scale_eval_ms = 1_000;
    cfg.elastic.migration = true;
    cfg.elastic.prefill_elastic = true;
    cfg.elastic.prefill_min = 1;
    cfg.elastic.prefill_max = 6;
    let exp = Experiment::prepare(&cfg);
    let decode_len: HashMap<u64, u32> = exp
        .workload
        .requests
        .iter()
        .map(|r| (r.id, r.decode_len))
        .collect();
    let res = exp.run();
    assert_eq!(res.unfinished, 0);
    assert_eq!(res.cost.requests_served, 600);
    for o in &res.outcomes {
        assert_eq!(
            o.tokens,
            decode_len[&o.id] as u64,
            "request {} token count drifted across migration",
            o.id
        );
    }
    assert!(!res.fleet.rates.is_empty(), "predictive run must record rate samples");
    assert!(
        res.fleet.samples.iter().all(|s| s.active_prefill >= 1),
        "prefill tier drained below its floor"
    );
}

// ---------------------------------------------------------------------
// O(1) incremental load accounting + indexed fleet views (PR 4).
// ---------------------------------------------------------------------

/// Wraps any autoscaler and re-audits the whole cluster (cached load
/// counters vs scans, membership indices + load-ordered sets vs the
/// assign vector and live keys, and the incremental unplaced-demand
/// counter vs the reconstruction scan) at every `ScaleEval` — on top
/// of the simulator's own per-event debug audit, this pins the
/// "cached == recomputed at every ScaleEval" property to an explicit,
/// countable check.
struct AuditEveryEval {
    inner: Box<dyn Autoscaler>,
    evals: usize,
}

impl Autoscaler for AuditEveryEval {
    fn evaluate(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        ctx.cluster.audit(ctx.requests);
        assert_eq!(
            ctx.cluster.unplaced_demand(),
            ctx.cluster.unplaced_demand_scan(ctx.requests, now),
            "incremental unplaced-demand counter diverged from the scan \
             oracle at ScaleEval t={now}"
        );
        // Per-(model, tier) counters and ordered sets: the `_of` views
        // must agree with a from-scratch scan re-derivation per model.
        for m in 0..ctx.cluster.num_models {
            assert_eq!(
                ctx.cluster.unplaced_demand_of(m),
                ctx.cluster.unplaced_demand_scan_of(m, ctx.requests, now),
                "per-model unplaced-demand counter diverged for model {m} \
                 at ScaleEval t={now}"
            );
            for role in [Role::Prefill, Role::Decode, Role::Coloc] {
                let by_index = ctx.cluster.with_role_of(m, role).count();
                let by_scan = ctx
                    .cluster
                    .instances
                    .iter()
                    .filter(|i| i.model == m && i.role == role && i.lifecycle.accepts_work())
                    .count();
                assert_eq!(
                    by_index, by_scan,
                    "model {m} {role:?} membership index diverged at t={now}"
                );
            }
            for k in 0..ctx.cluster.num_tiers {
                let ordered: Vec<usize> = ctx.cluster.tier_by_load_desc_of(m, k).collect();
                let mut scan: Vec<usize> = ctx.cluster.in_tier_of(m, k).collect();
                scan.sort_unstable();
                let mut resorted = ordered.clone();
                resorted.sort_unstable();
                assert_eq!(
                    resorted, scan,
                    "model {m} tier {k} ordered set lost/ghosted members at t={now}"
                );
            }
        }
        self.evals += 1;
        self.inner.evaluate(now, ctx)
    }

    fn name(&self) -> String {
        format!("audited-{}", self.inner.name())
    }

    fn take_rate_series(&mut self) -> Vec<polyserve::metrics::RateSample> {
        self.inner.take_rate_series()
    }
}

/// The full elastic + diurnal + migration + elastic-prefill sweep under
/// the predictive scaler, with the cluster audited at every ScaleEval
/// (and, in this debug build, after every simulator event): any drift
/// between a cached counter / membership index and its scan-recomputed
/// ground truth panics the run.
#[test]
fn cached_counters_match_scans_at_every_scale_eval() {
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 6,
        requests: 400,
        rate_frac_of_optimal: 0.5,
        seed: 19,
        ..Default::default()
    };
    cfg.diurnal = Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 120.0 });
    cfg.elastic.scaler = ScalerKind::Predictive;
    cfg.elastic.min_instances = 2;
    cfg.elastic.max_instances = 10;
    cfg.elastic.provision_delay_ms = 5_000;
    cfg.elastic.scale_eval_ms = 1_000;
    cfg.elastic.migration = true;
    cfg.elastic.prefill_elastic = true;
    cfg.elastic.prefill_min = 1;
    cfg.elastic.prefill_max = 5;
    let exp = Experiment::prepare(&cfg);
    let cluster = Cluster::build(
        exp.cfg.mode,
        exp.cfg.instances,
        exp.cfg.prefill_frac,
        exp.cfg.tiers.len(),
        &exp.cost_model,
        true,
    );
    let params = SimParams {
        mode: exp.cfg.mode,
        elastic: Some(ElasticParams {
            min_instances: 2,
            max_instances: 10,
            provision_delay_ms: 5_000,
            scale_eval_ms: 1_000,
            migration: true,
            migration_batching: false,
            model_swap_delay_ms: 20_000,
            prefill: Some(PrefillElastic { min_instances: 1, max_instances: 5 }),
        }),
        ..Default::default()
    };
    let sim = Simulation::new(
        params,
        exp.cost_model.clone(),
        &exp.profile,
        &exp.workload,
        cluster,
        &exp.cfg.tiers,
    );
    let mut router = make_router(&exp.cfg, exp.workload.avg_decode_len());
    let mut scaler = AuditEveryEval {
        inner: polyserve::coordinator::make_autoscaler(&exp.cfg).expect("elastic cfg"),
        evals: 0,
    };
    let res = sim.run_elastic(router.as_mut(), Some(&mut scaler));
    assert_eq!(res.unfinished, 0);
    assert!(
        scaler.evals > 10,
        "the audit must actually have run at ScaleEvals, got {}",
        scaler.evals
    );
}

/// The same audit-at-every-ScaleEval property on a two-model fleet:
/// per-(model, tier) ordered sets, per-model membership indices and
/// per-model unplaced counters are re-derived by scan at every epoch
/// while the mix planner swaps/provisions/drains across both models.
#[test]
fn multi_model_cached_counters_match_scans_at_every_scale_eval() {
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 8,
        requests: 300,
        rate_frac_of_optimal: 0.3,
        seed: 43,
        ..Default::default()
    };
    cfg.models.mix = vec![0.7, 0.3];
    cfg.models.swap_delay_ms = 2_000;
    cfg.diurnal = Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 120.0 });
    cfg.elastic.scaler = ScalerKind::Gradient;
    cfg.elastic.min_instances = 2;
    cfg.elastic.max_instances = 12;
    cfg.elastic.provision_delay_ms = 5_000;
    cfg.elastic.scale_eval_ms = 1_000;
    cfg.elastic.migration = true;
    let exp = Experiment::prepare(&cfg);
    let registry = ModelRegistry::builtin_pair();
    let counts = polyserve::figures::split_mix(cfg.instances, &cfg.models.mix);
    let cluster = Cluster::build_models(
        exp.cfg.mode,
        &counts,
        exp.cfg.prefill_frac,
        exp.cfg.tiers.len(),
        &registry.instance_caps(),
        true,
    );
    let params = SimParams {
        mode: exp.cfg.mode,
        elastic: Some(ElasticParams {
            min_instances: 2,
            max_instances: 12,
            provision_delay_ms: 5_000,
            scale_eval_ms: 1_000,
            migration: true,
            migration_batching: false,
            model_swap_delay_ms: 2_000,
            prefill: None,
        }),
        ..Default::default()
    };
    let sim = Simulation::new(
        params,
        exp.cost_model.clone(),
        &exp.profile,
        &exp.workload,
        cluster,
        &exp.cfg.tiers,
    )
    .with_cost_models(registry.cost_models());
    let profiles = registry.profiles();
    let mut router = polyserve::coordinator::make_router_with_models(
        &exp.cfg,
        exp.workload.avg_decode_len(),
        &profiles,
    );
    let mut scaler = AuditEveryEval {
        inner: polyserve::coordinator::make_autoscaler_with_models(&exp.cfg, &profiles)
            .expect("elastic cfg"),
        evals: 0,
    };
    let res = sim.run_elastic(router.as_mut(), Some(&mut scaler));
    assert_eq!(res.unfinished, 0);
    assert!(
        scaler.evals > 10,
        "the audit must actually have run at ScaleEvals, got {}",
        scaler.evals
    );
    // Both models actually served traffic through the audited run.
    let served = &res.cost.requests_served_per_model;
    assert_eq!(served.len(), 2);
    assert!(served.iter().all(|&n| n > 0), "one model served nothing: {served:?}");
}

/// Decision-identity across the full queue × index matrix: the
/// calendar-queue + load-ordered hot path must reproduce every other
/// cell's `SimResult` bit-for-bit — the index axis covers the PR-4
/// indexed path (sort-per-placement over the id indices) and the
/// scan-based pre-PR-4 path, the queue axis swaps the calendar event
/// engine for the pre-PR-6 global binary heap (`heap_reference`) —
/// in per-request outcomes, attainment, cost, fleet series, migration
/// stats, and even the processed-event count, across both serving
/// modes with the full elastic + diurnal + migration + elastic-prefill
/// machinery on, plus a `load_gradient = off` ablation cell (the
/// ordered set walked in reverse).
#[test]
fn indexed_run_reproduces_scan_reference_bit_for_bit() {
    let mut pd = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 6,
        requests: 400,
        rate_frac_of_optimal: 0.5,
        seed: 23,
        ..Default::default()
    };
    pd.diurnal = Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 120.0 });
    pd.elastic.scaler = ScalerKind::Predictive;
    pd.elastic.min_instances = 2;
    pd.elastic.max_instances = 10;
    pd.elastic.provision_delay_ms = 5_000;
    pd.elastic.scale_eval_ms = 1_000;
    pd.elastic.migration = true;
    pd.elastic.prefill_elastic = true;
    pd.elastic.prefill_min = 1;
    pd.elastic.prefill_max = 5;

    let mut co = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::Colocated,
        instances: 6,
        requests: 400,
        rate_frac_of_optimal: 0.6,
        seed: 29,
        ..Default::default()
    };
    co.diurnal = Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 120.0 });
    co.elastic.scaler = ScalerKind::Gradient;
    co.elastic.min_instances = 2;
    co.elastic.max_instances = 10;
    co.elastic.provision_delay_ms = 5_000;
    co.elastic.scale_eval_ms = 1_000;
    co.elastic.migration = true;

    let fixed = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 8,
        requests: 400,
        rate_frac_of_optimal: 0.7,
        seed: 31,
        ..Default::default()
    };

    // The load-gradient ablation walks the same ordered set in reverse
    // (ascending `(batch, kv, id)`), which must match the reference
    // paths' ascending sort bit-for-bit too.
    let mut ablated = fixed.clone();
    ablated.seed = 37;
    ablated.features.load_gradient = false;

    // Two-model registry fleet under the gradient scaler + mix planner:
    // the per-(model, tier) `_of` views, per-model pending queues and
    // swap/provision planning must themselves be engine-independent —
    // every queue × index cell replays the identical decision stream.
    let mut multi = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 8,
        requests: 300,
        rate_frac_of_optimal: 0.3,
        seed: 41,
        ..Default::default()
    };
    multi.models.mix = vec![0.7, 0.3];
    multi.models.swap_delay_ms = 2_000;
    multi.elastic.scaler = ScalerKind::Gradient;
    multi.elastic.min_instances = 2;
    multi.elastic.max_instances = 10;
    multi.elastic.provision_delay_ms = 5_000;
    multi.elastic.scale_eval_ms = 1_000;

    // The `[overload]` machinery live on a deliberately saturated fixed
    // fleet: EDF queue ordering, the arrival-edge gate and the
    // retry-with-backoff clients (seeded-jitter RNG included) are part
    // of the decision stream and must replay identically on every
    // queue × index cell — rejections, backoff re-arrivals and all.
    let mut overload = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::Colocated,
        instances: 4,
        requests: 400,
        rate_frac_of_optimal: 2.0,
        seed: 53,
        ..Default::default()
    };
    overload.overload.enabled = true;
    overload.overload.reject = true;
    overload.overload.retry = true;
    overload.overload.retry_base_ms = 200;
    overload.overload.retry_max_attempts = 2;

    // The full PR 10 recovery layer live: failure domains with a
    // correlated-kill MTBF process, periodic KV checkpoints, stepwise
    // spot price/availability curves and the chaos-adaptive predictive
    // scaler. Chaos draws, sweep order, avoid-zone re-placements and
    // the SpotPolicy hysteresis are all part of the decision stream —
    // every queue × index cell must replay them bit-for-bit.
    let mut chaos = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 6,
        requests: 300,
        rate_frac_of_optimal: 0.5,
        seed: 61,
        ..Default::default()
    };
    chaos.elastic.scaler = ScalerKind::Predictive;
    chaos.elastic.min_instances = 2;
    chaos.elastic.max_instances = 10;
    chaos.elastic.provision_delay_ms = 3_000;
    chaos.elastic.scale_eval_ms = 1_000;
    chaos.elastic.migration = true;
    chaos.elastic.prefill_elastic = true;
    chaos.elastic.prefill_min = 1;
    chaos.elastic.prefill_max = 4;
    chaos.chaos.fail_mtbf_s = 40.0;
    chaos.chaos.preempt_mtbf_s = 50.0;
    chaos.chaos.preempt_grace_ms = 5_000;
    chaos.chaos.spot_fraction = 0.5;
    chaos.chaos.spot_price_frac = 0.4;
    chaos.chaos.zones = 2;
    chaos.chaos.racks_per_zone = 2;
    chaos.chaos.domain_fail_mtbf_s = 80.0;
    chaos.chaos.checkpoint_period_ms = 1_000;
    chaos.chaos.spot_price_schedule = vec![0.0, 0.3, 60.0, 0.9];
    chaos.chaos.spot_avail_schedule = vec![0.0, 1.0, 60.0, 0.5];
    chaos.chaos.adaptive = true;

    for (label, cfg) in [
        ("pd_elastic", pd),
        ("coloc_elastic", co),
        ("pd_fixed", fixed),
        ("pd_no_gradient", ablated),
        ("pd_multi_model", multi),
        ("co_overload", overload),
        ("pd_chaos_recovery", chaos),
    ] {
        // Baseline cell: calendar queue + ordered indices (the default
        // hot path). Every other (queue, index) combination must match.
        let ordered = Experiment::prepare(&cfg).run();
        let mut cells: Vec<(String, SimResult)> = Vec::new();
        for heap in [false, true] {
            for path in ["ordered", "indexed", "scan"] {
                if !heap && path == "ordered" {
                    continue; // the baseline itself
                }
                let mut exp = Experiment::prepare(&cfg);
                exp.heap_reference = heap;
                exp.indexed_reference = path == "indexed";
                exp.scan_reference = path == "scan";
                let queue = if heap { "heap" } else { "calendar" };
                cells.push((format!("{queue}+{path}"), exp.run()));
            }
        }
        for (path, res) in cells.iter().map(|(p, r)| (p.as_str(), r)) {
            assert_eq!(
                ordered.outcomes, res.outcomes,
                "{label}/{path}: outcomes diverged"
            );
            assert_eq!(ordered.attainment, res.attainment, "{label}/{path}");
            assert_eq!(ordered.cost, res.cost, "{label}/{path}: cost diverged");
            assert_eq!(
                ordered.fleet, res.fleet,
                "{label}/{path}: fleet series diverged"
            );
            assert_eq!(ordered.migration, res.migration, "{label}/{path}");
            assert_eq!(ordered.sim_span_ms, res.sim_span_ms, "{label}/{path}");
            assert_eq!(
                ordered.throughput_rps.to_bits(),
                res.throughput_rps.to_bits(),
                "{label}/{path}"
            );
            assert_eq!(ordered.unfinished, res.unfinished, "{label}/{path}");
            assert_eq!(
                ordered.events_processed, res.events_processed,
                "{label}/{path}: event schedule diverged"
            );
            assert_eq!(ordered.chaos, res.chaos, "{label}/{path}: chaos stats diverged");
            assert_eq!(
                ordered.overload, res.overload,
                "{label}/{path}: overload stats diverged"
            );
        }
        assert_eq!(ordered.unfinished, 0, "{label}");
        if label == "pd_chaos_recovery" {
            // The chaos cell must actually exercise the recovery layer
            // — the periodic sweep is deterministic, so at least the
            // snapshots are guaranteed regardless of how the MTBF
            // draws land on this seed.
            assert!(
                ordered.chaos.checkpoints > 0,
                "{label}: the checkpoint sweep never fired: {:?}",
                ordered.chaos
            );
        } else {
            // The chaos machinery is compiled into every one of these
            // cells but `[chaos]` is disabled: the layer must stay
            // perfectly quiet — all-zero stats on every engine
            // combination.
            assert_eq!(ordered.chaos, ChaosStats::default(), "{label}: chaos must be off");
        }
        if label == "co_overload" {
            // 2× saturation on a pinned 4-instance fleet must actually
            // engage the gate, or the cell tests nothing.
            assert!(
                ordered.overload.rejected_total > 0,
                "{label}: no rejections at 2× saturation: {:?}",
                ordered.overload
            );
        } else {
            assert!(ordered.overload.rejected_total == 0, "{label}: phantom rejections");
        }
    }
}

/// Full-system property: an elastic diurnal run with the gradient
/// scaler *and* migration enabled completes every request with exact
/// per-request token counts (checked against the workload's ground
/// truth decode lengths).
#[test]
fn elastic_migration_run_completes_with_exact_token_counts() {
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 6,
        requests: 500,
        rate_frac_of_optimal: 0.5,
        seed: 7,
        ..Default::default()
    };
    cfg.diurnal = Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 120.0 });
    cfg.elastic.scaler = ScalerKind::Gradient;
    cfg.elastic.min_instances = 2;
    cfg.elastic.max_instances = 12;
    cfg.elastic.provision_delay_ms = 5_000;
    cfg.elastic.scale_eval_ms = 1_000;
    cfg.elastic.migration = true;
    let exp = Experiment::prepare(&cfg);
    let decode_len: HashMap<u64, u32> = exp
        .workload
        .requests
        .iter()
        .map(|r| (r.id, r.decode_len))
        .collect();
    let res = exp.run();
    assert_eq!(res.unfinished, 0);
    assert_eq!(res.cost.requests_served, 500);
    for o in &res.outcomes {
        assert_eq!(
            o.tokens,
            decode_len[&o.id] as u64,
            "request {} token count drifted across migration",
            o.id
        );
    }
    assert!(res.cost.goodput_tokens <= res.cost.tokens_total);
}

// ---------------------------------------------------------------------
// Fault injection & spot preemption (the `[chaos]` layer).
// ---------------------------------------------------------------------

/// The long-decode fixture under an explicit chaos schedule: 6 requests
/// with 3000-token outputs on a 1-prefill + 2-decode PD fleet (ids 0 /
/// 1, 2), no autoscaler — every lifecycle transition in the run is the
/// chaos schedule's doing.
fn chaos_fixture_run(chaos: Option<ChaosParams>, elastic: Option<ElasticParams>) -> SimResult {
    let cm = CostModel::h200_llama8b();
    let profile = ProfileTable::from_cost_model(&cm);
    let cfg = SimConfig {
        mode: ServingMode::PdDisaggregated,
        ..Default::default()
    };
    let workload = Workload {
        requests: (0..6u64)
            .map(|i| Request {
                id: i,
                arrival_ms: i * 20,
                prefill_len: 256,
                decode_len: 3_000,
                slo: Slo::new(5_000, 100),
                model: 0,
            })
            .collect(),
    };
    let cluster = Cluster::build(ServingMode::PdDisaggregated, 3, 0.34, cfg.tiers.len(), &cm, true);
    let params = SimParams {
        mode: ServingMode::PdDisaggregated,
        elastic,
        chaos,
        ..Default::default()
    };
    let sim = Simulation::new(params, cm.clone(), &profile, &workload, cluster, &cfg.tiers);
    let mut router = PolyServeRouter::new(&cfg, workload.avg_decode_len());
    sim.run_elastic(&mut router, None)
}

/// The elastic params the spot-preemption fixtures drain under —
/// migration on, so a notice's grace window evicts residents instead of
/// waiting their 3000-token outputs out.
fn chaos_elastic() -> ElasticParams {
    ElasticParams {
        min_instances: 1,
        max_instances: 4,
        provision_delay_ms: 1_000,
        scale_eval_ms: 500,
        migration: true,
        migration_batching: false,
        model_swap_delay_ms: 20_000,
        prefill: None,
    }
}

/// Token conservation across an instance failure: the hard kill at
/// t=2 s discards decode instance 2's KV mid-stream, its residents
/// re-enter placement for a full re-prefill — and every request still
/// emits exactly 3000 tokens, with the already-streamed prefix neither
/// lost nor re-emitted. The failed instance's bill stops at the failure
/// event (the satellite billing fix): the other two instances bill the
/// whole span, the dead one exactly its 2 s of life.
#[test]
fn instance_failure_conserves_tokens_and_bills_to_the_failure() {
    let res = chaos_fixture_run(
        Some(ChaosParams {
            fail_at: vec![(2_000, 2)],
            ..Default::default()
        }),
        None,
    );
    assert_eq!(res.unfinished, 0, "victims must finish on the surviving fleet");
    for o in &res.outcomes {
        assert_eq!(
            o.tokens, 3_000,
            "request {} emitted {} of 3000 tokens across the failure",
            o.id, o.tokens
        );
    }
    assert_eq!(res.chaos.failures, 1);
    assert_eq!(res.chaos.preempt_notices, 0);
    assert!(
        res.chaos.replaced_requests >= 1,
        "the killed decode server must have held residents at t=2 s"
    );
    assert!(res.chaos.lost_kv_tokens > 0, "discarded KV must be accounted");
    // Billing regression: before the force-retire fix a failed instance
    // kept billing to the end of the run.
    assert_eq!(
        res.cost.active_instance_ms,
        2 * res.sim_span_ms + 2_000,
        "failed instance must bill exactly its 2 s of life"
    );
}

/// Disabled chaos is the seed path bit-for-bit: `ChaosParams` with no
/// schedule, no MTBF process and no spot fraction constructs no runtime
/// — zero events, zero RNG draws, identical outcomes to `chaos: None`.
/// A domain *model* alone (zones/racks striping, no kill process and
/// no checkpoint period) must not enable it either: labelling the
/// fleet is free until something can actually fail.
#[test]
fn disabled_chaos_params_change_nothing() {
    let a = chaos_fixture_run(None, None);
    let cells = [
        ChaosParams {
            seed: 0xDEAD_BEEF, // an enabled run would draw from this
            ..Default::default()
        },
        ChaosParams {
            zones: 4,
            racks_per_zone: 2,
            seed: 0x5EED,
            ..Default::default()
        },
    ];
    for chaos in cells {
        let b = chaos_fixture_run(Some(chaos), None);
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.sim_span_ms, b.sim_span_ms);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(b.chaos, ChaosStats::default());
    }
}

/// Token conservation across a spot preemption that drains in time: the
/// notice at t=2 s starts a migration drain with a 30 s grace; the
/// residents' KV streams to the peer decode server, the instance
/// retires before the deadline, and the deadline event records a
/// graceful `preempt_drained` — no failure, no kill, every token
/// delivered exactly once.
#[test]
fn spot_preemption_drains_via_migration_and_conserves_tokens() {
    let res = chaos_fixture_run(
        Some(ChaosParams {
            preempt_at: vec![(2_000, 2)],
            preempt_grace_ms: 30_000,
            ..Default::default()
        }),
        Some(chaos_elastic()),
    );
    assert_eq!(res.unfinished, 0);
    for o in &res.outcomes {
        assert_eq!(
            o.tokens, 3_000,
            "request {} emitted {} of 3000 tokens across the preemption",
            o.id, o.tokens
        );
    }
    assert_eq!(res.chaos.preempt_notices, 1);
    assert_eq!(res.chaos.preempt_drained, 1, "the drain must beat the 30 s grace");
    assert_eq!(res.chaos.preempt_deadline_kills, 0);
    assert_eq!(res.chaos.failures, 0);
    assert_eq!(res.chaos.replaced_requests, 0, "a graceful drain replaces no one");
    assert!(
        res.migration.migrated_requests > 0,
        "the grace window must evict residents via migration, not wait"
    );
}

/// A preemption whose grace is hopeless (500 ms against 3000-token
/// wait-drain residents) must hit the hard deadline: the instance fails
/// at t=2.5 s, counts as both a failure and a deadline kill, and its
/// residents still finish elsewhere with exact token counts.
#[test]
fn spot_preemption_deadline_kill_replaces_residents() {
    let res = chaos_fixture_run(
        Some(ChaosParams {
            preempt_at: vec![(2_000, 2)],
            preempt_grace_ms: 500,
            ..Default::default()
        }),
        None, // no elastic config: the drain falls back to wait-drain
    );
    assert_eq!(res.unfinished, 0, "killed residents must finish on the survivor");
    for o in &res.outcomes {
        assert_eq!(
            o.tokens, 3_000,
            "request {} emitted {} of 3000 tokens across the kill",
            o.id, o.tokens
        );
    }
    assert_eq!(res.chaos.preempt_notices, 1);
    assert_eq!(res.chaos.preempt_deadline_kills, 1);
    assert_eq!(res.chaos.failures, 1, "a deadline kill is a failure");
    assert_eq!(res.chaos.preempt_drained, 0);
    assert!(res.chaos.replaced_requests >= 1);
    assert_eq!(res.migration.migrated_requests, 0, "wait-drain migrates nothing");
}

// ---------------------------------------------------------------------
// Failure domains, KV checkpoints & recovery (the PR 10 layer).
// ---------------------------------------------------------------------

/// The exact checkpoint-restore ledger: the same hard kill replayed
/// with and without periodic KV snapshots. The sweep is scheduling-
/// neutral (it only writes watermarks and stats), so both runs kill
/// the *same* victims with the *same* progress at t=2 s — which makes
/// the conservation equations exact, not statistical:
///
/// * `reprefill_on + recovered_on == reprefill_off` — the re-prefilled
///   suffix is exactly `prefill_done − checkpointed` per victim;
/// * `lost_on + recovered_on == lost_off` — every KV token is either
///   restored from a snapshot or billed as lost, never both.
///
/// With a 300 ms period against a t=2 s kill (300 ∤ 2000 — no same-ms
/// sweep/kill tie) every victim's full 256-token prompt is covered, so
/// the on-run re-prefills *nothing* and resumes decode directly.
#[test]
fn checkpoint_restore_reprefills_only_the_suffix() {
    let off = chaos_fixture_run(
        Some(ChaosParams {
            fail_at: vec![(2_000, 2)],
            ..Default::default()
        }),
        None,
    );
    let on = chaos_fixture_run(
        Some(ChaosParams {
            fail_at: vec![(2_000, 2)],
            checkpoint_period_ms: 300,
            ..Default::default()
        }),
        None,
    );
    for (label, res) in [("off", &off), ("on", &on)] {
        assert_eq!(res.unfinished, 0, "{label}: victims must finish");
        for o in &res.outcomes {
            assert_eq!(
                o.tokens, 3_000,
                "{label}: request {} emitted {} of 3000 tokens across the kill",
                o.id, o.tokens
            );
        }
        assert_eq!(res.chaos.failures, 1, "{label}");
        assert!(res.chaos.replaced_requests >= 1, "{label}: the kill must hit residents");
    }
    // Without a period the snapshot machinery never runs.
    assert_eq!(off.chaos.checkpoints, 0);
    assert_eq!(off.chaos.checkpoint_tokens, 0);
    assert_eq!(off.chaos.recovered_kv_tokens, 0);
    // With it, sweeps snapshot and bill their transfer cost.
    assert!(on.chaos.checkpoints > 0, "sweeps must find residents to snapshot");
    assert!(on.chaos.checkpoint_tokens > 0);
    assert!(on.chaos.checkpoint_cost_ms > 0, "snapshot transfer must be billed");
    // Scheduling neutrality: the same victims die either way.
    assert_eq!(on.chaos.replaced_requests, off.chaos.replaced_requests);
    // The exact conservation ledger.
    assert_eq!(
        on.chaos.reprefill_tokens + on.chaos.recovered_kv_tokens,
        off.chaos.reprefill_tokens,
        "the re-prefilled suffix must be exactly prefill_done - checkpointed"
    );
    assert_eq!(
        on.chaos.lost_kv_tokens + on.chaos.recovered_kv_tokens,
        off.chaos.lost_kv_tokens,
        "every KV token is either restored or lost, never both"
    );
    // Checkpointing must strictly help, and here it covers everything:
    // each victim's 256-token prompt was swept long before the kill, so
    // the rewind lands at the full watermark and decode resumes without
    // touching a prefill server.
    assert!(on.chaos.recovered_kv_tokens > 0);
    assert_eq!(off.chaos.reprefill_tokens, 256 * off.chaos.replaced_requests);
    assert_eq!(on.chaos.reprefill_tokens, 0, "full coverage resumes decode directly");
    assert_eq!(on.chaos.recovered_kv_tokens, 256 * on.chaos.replaced_requests);
    assert!(on.chaos.lost_kv_tokens < off.chaos.lost_kv_tokens);
}

/// A correlated rack kill through the checkpoint layer: with `zones =
/// 1, racks_per_zone = 2` the zone-first stripe puts instances {0, 2}
/// in rack (0, 0) — the fleet's only prefill server *and* one of its
/// two decode servers. The scheduled `FailDomain::Rack` draw kills
/// both in one event. The run can only finish because every victim's
/// prompt was checkpointed: with the prefill tier dead, a victim
/// needing even one token of re-prefill would strand, so completion
/// itself proves the snapshot restore (and the domain-spread fallback:
/// with a single zone the avoid-zone pass has nowhere else to go and
/// must still place on decode server 1).
#[test]
fn full_rack_kill_recovers_through_checkpoints() {
    let res = chaos_fixture_run(
        Some(ChaosParams {
            zones: 1,
            racks_per_zone: 2,
            domain_fail_at: vec![(2_000, FailDomain::Rack { zone: 0, rack: 0 })],
            checkpoint_period_ms: 500,
            ..Default::default()
        }),
        None,
    );
    assert_eq!(res.unfinished, 0, "victims must finish on the surviving decode server");
    for o in &res.outcomes {
        assert_eq!(
            o.tokens, 3_000,
            "request {} emitted {} of 3000 tokens across the rack kill",
            o.id, o.tokens
        );
    }
    assert_eq!(res.chaos.domain_kills, 1, "one correlated draw");
    assert_eq!(res.chaos.failures, 2, "the draw kills both rack members");
    assert_eq!(res.chaos.kills_per_zone, vec![2]);
    assert_eq!(res.chaos.preempt_notices, 0);
    assert!(res.chaos.replaced_requests >= 1, "decode server 2 must have held residents");
    assert_eq!(
        res.chaos.reprefill_tokens, 0,
        "full checkpoint coverage: nothing re-prefills (nothing could — prefill is dead)"
    );
    assert_eq!(
        res.chaos.recovered_kv_tokens,
        256 * res.chaos.replaced_requests,
        "every victim restores its full 256-token prompt from the snapshot"
    );
    assert!(res.chaos.lost_kv_tokens > 0, "the un-checkpointed decode suffix still dies");
}

/// The avoid-zone hint is a preference, never a filter: with the hint
/// set the gradient walk lands outside the avoided zone, and when the
/// *whole fleet* sits inside it the fallback pass still places.
#[test]
fn avoid_zone_steers_placement_without_hard_filtering() {
    let cm = CostModel::h200_llama8b();
    let profile = ProfileTable::from_cost_model(&cm);
    let cfg = SimConfig {
        mode: ServingMode::Colocated,
        ..Default::default()
    };
    let fresh_request = || {
        let req: &'static Request = Box::leak(Box::new(Request {
            id: 0,
            arrival_ms: 0,
            prefill_len: 64,
            decode_len: 50,
            slo: Slo::new(10_000, 100),
            model: 0,
        }));
        SimRequest::new(req, 3)
    };
    let build = |domains: [(u32, u32); 4]| {
        let mut cluster =
            Cluster::build(ServingMode::Colocated, 4, 0.0, cfg.tiers.len(), &cm, true);
        for (i, d) in domains.into_iter().enumerate() {
            cluster.instances[i].domain = d;
        }
        cluster
    };
    let split = [(0, 0), (0, 1), (1, 0), (1, 1)];

    // (a) Unhinted baseline: note which zone the walk picks.
    let mut router = PolyServeRouter::new(&cfg, 300.0);
    let mut cluster = build(split);
    let mut reqs = vec![fresh_request()];
    let za = {
        let mut ctx = RouteCtx {
            now: 0,
            cluster: &mut cluster,
            requests: &mut reqs,
            profile: &profile,
            mode: ServingMode::Colocated,
            kv_transfer_ms: 2,
        };
        let a = router.route_new(0, 0, &mut ctx).expect("an idle fleet must place");
        ctx.cluster.instances[a].domain.0
    };

    // (b) Same fleet, avoiding that zone: the steered walk must land in
    // the other one.
    let mut router = PolyServeRouter::new(&cfg, 300.0);
    router.set_avoid_zone(Some(za));
    let mut cluster = build(split);
    let mut reqs = vec![fresh_request()];
    {
        let mut ctx = RouteCtx {
            now: 0,
            cluster: &mut cluster,
            requests: &mut reqs,
            profile: &profile,
            mode: ServingMode::Colocated,
            kv_transfer_ms: 2,
        };
        let b = router.route_new(0, 0, &mut ctx).expect("steering must not lose placements");
        assert_ne!(
            ctx.cluster.instances[b].domain.0, za,
            "with capacity outside the blast radius the hint must steer there"
        );
    }

    // (c) Every instance inside the avoided zone: the two-pass fallback
    // still places — capacity beats the hint.
    let mut router = PolyServeRouter::new(&cfg, 300.0);
    router.set_avoid_zone(Some(0));
    let mut cluster = build([(0, 0), (0, 0), (0, 1), (0, 1)]);
    let mut reqs = vec![fresh_request()];
    {
        let mut ctx = RouteCtx {
            now: 0,
            cluster: &mut cluster,
            requests: &mut reqs,
            profile: &profile,
            mode: ServingMode::Colocated,
            kv_transfer_ms: 2,
        };
        let c = router
            .route_new(0, 0, &mut ctx)
            .expect("a fleet with capacity only inside the avoided zone must still place");
        assert_eq!(ctx.cluster.instances[c].domain.0, 0);
    }
}

/// `[overload] propagate_deadline` flips what a retry's feasibility
/// check sees. The brutal 24-request storm sheds a wave of arrivals;
/// with a 3 s retry base every backoff lands *after* the prefill queue
/// has drained — and after every original TTFT deadline has passed.
/// Re-anchored (default), the first-landing retry sees an empty queue
/// and a fresh 600 ms budget: it must be admitted. Propagated, the
/// remaining budget is already negative at re-arrival, so *every*
/// retry is re-rejected and sheds. The two runs are bit-identical up
/// to the first `RetryArrival` event (the flag is only read there), so
/// the first-wave rejection sets are the same and the totals compare
/// exactly; both runs must still conserve tokens to the ledger.
#[test]
fn propagated_deadline_rejects_what_reanchoring_admits() {
    let run = |propagate: bool| {
        let cm = CostModel::h200_llama8b();
        let profile = ProfileTable::from_cost_model(&cm);
        let cfg = SimConfig {
            mode: ServingMode::PdDisaggregated,
            ..Default::default()
        };
        let workload = Workload {
            requests: (0..24u64)
                .map(|i| Request {
                    id: i,
                    arrival_ms: i * 10,
                    prefill_len: 3_000,
                    decode_len: 50,
                    slo: Slo::new(600, 100),
                    model: 0,
                })
                .collect(),
        };
        let cluster =
            Cluster::build(ServingMode::PdDisaggregated, 3, 0.34, cfg.tiers.len(), &cm, true);
        let params = SimParams {
            mode: ServingMode::PdDisaggregated,
            overload: Some(OverloadParams {
                reject: true,
                retry: true,
                retry_base_ms: 3_000,
                retry_max_attempts: 1,
                propagate_deadline: propagate,
                seed: 0x0E71,
            }),
            ..Default::default()
        };
        let sim = Simulation::new(params, cm.clone(), &profile, &workload, cluster, &cfg.tiers);
        let mut router = PolyServeRouter::new(&cfg, workload.avg_decode_len());
        sim.run_elastic(&mut router, None)
    };
    let anchored = run(false);
    let propagated = run(true);

    for (label, res) in [("re-anchored", &anchored), ("propagated", &propagated)] {
        assert_eq!(res.unfinished, 0, "{label}: accepted requests must all finish");
        assert!(res.overload.rejected_total > 0, "{label}: the storm must shed");
        let mut served = 0u64;
        for o in &res.outcomes {
            if o.rejected {
                assert_eq!(o.tokens, 0, "{label}: rejected request {} emitted tokens", o.id);
            } else {
                assert_eq!(o.tokens, 50, "{label}: request {} lost tokens", o.id);
                served += 1;
            }
        }
        assert_eq!(res.cost.tokens_total, served * 50, "{label}: token ledger");
        assert_eq!(
            res.overload.shed_tokens,
            res.overload.rejected_total * 50,
            "{label}: shed ledger"
        );
    }
    // Re-anchored: the retries land on a drained queue with a fresh
    // budget — at least the first one is admitted late.
    let admitted_retries = |r: &SimResult| r.overload.retry_histogram.iter().sum::<u64>();
    assert!(
        admitted_retries(&anchored) > 0,
        "a re-anchored retry onto an empty queue must be admitted: {:?}",
        anchored.overload
    );
    // Propagated: every retry re-arrives past its original deadline —
    // the remaining budget is gone, so none can be admitted.
    assert_eq!(
        admitted_retries(&propagated),
        0,
        "a propagated deadline in the past must never re-admit: {:?}",
        propagated.overload
    );
    assert!(
        propagated.overload.rejected_total > anchored.overload.rejected_total,
        "propagation must shed strictly more: {} vs {}",
        propagated.overload.rejected_total,
        anchored.overload.rejected_total
    );
}

// ---------------------------------------------------------------------
// Overload admission, EDF pending queues & retry clients.
// ---------------------------------------------------------------------

/// `[overload]` off — and the FIFO reference engine — is the seed path
/// bit-for-bit: with the master switch off the BTreeSet pending queues
/// key on `(0, seq)` (insertion order, exactly the old `VecDeque`), no
/// admission gate constructs and no retry RNG is drawn; pinning
/// `fifo_reference` (with or without `enabled = "on"`, on either event
/// engine) must change nothing either.
#[test]
fn overload_off_and_fifo_reference_are_seed_path_bit_for_bit() {
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::Colocated,
        instances: 6,
        requests: 400,
        rate_frac_of_optimal: 0.6,
        seed: 59,
        ..Default::default()
    };
    cfg.diurnal = Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 120.0 });
    cfg.elastic.scaler = ScalerKind::Gradient;
    cfg.elastic.min_instances = 2;
    cfg.elastic.max_instances = 10;
    cfg.elastic.provision_delay_ms = 5_000;
    cfg.elastic.scale_eval_ms = 1_000;
    cfg.elastic.migration = true;
    let baseline = Experiment::prepare(&cfg).run();
    assert_eq!(baseline.unfinished, 0);
    assert!(
        baseline.overload.is_quiet(),
        "overload-off run must stay quiet: {:?}",
        baseline.overload
    );

    // `enabled = "on"` pinned to the FIFO reference with rejection off:
    // `edf()` gates off and no sim-side machinery constructs — provably
    // the seed path, not merely close to it.
    let mut on_cfg = cfg.clone();
    on_cfg.overload.enabled = true;
    let cells: [(&str, &SimConfig, bool, bool); 3] = [
        ("fifo_ref/overload_off", &cfg, true, false),
        ("fifo_ref/overload_on", &on_cfg, true, false),
        ("heap+fifo_ref", &cfg, true, true),
    ];
    for (label, cell_cfg, fifo, heap) in cells {
        let mut exp = Experiment::prepare(cell_cfg);
        exp.fifo_reference = fifo;
        exp.heap_reference = heap;
        let res = exp.run();
        assert_eq!(baseline.outcomes, res.outcomes, "{label}: outcomes diverged");
        assert_eq!(baseline.attainment, res.attainment, "{label}");
        assert_eq!(baseline.cost, res.cost, "{label}: cost diverged");
        assert_eq!(baseline.fleet, res.fleet, "{label}: fleet series diverged");
        assert_eq!(baseline.migration, res.migration, "{label}");
        assert_eq!(baseline.sim_span_ms, res.sim_span_ms, "{label}");
        assert_eq!(
            baseline.events_processed, res.events_processed,
            "{label}: event schedule diverged"
        );
        assert_eq!(baseline.overload, res.overload, "{label}: overload stats diverged");
    }
}

/// The admission gate composed with a mid-storm instance failure: a
/// brutally overloaded prefill tier (3000-token prompts against a
/// 600 ms TTFT, arriving every 10 ms) sheds most arrivals, and chaos
/// hard-kills decode server 2 while accepted requests stream. The books
/// must still balance exactly: every accepted request emits its full 50
/// tokens across the replacement, every rejected request emits zero
/// tokens and never bills, and the retry ledger reconciles against the
/// rejection count — no token leaks in either direction.
#[test]
fn rejection_composes_with_instance_failure_and_conserves_tokens() {
    // The fixture's retry cap, shared between the params and the
    // backoff-ledger reconciliation below.
    const RETRY_MAX: u32 = 2;
    let cm = CostModel::h200_llama8b();
    let profile = ProfileTable::from_cost_model(&cm);
    let cfg = SimConfig {
        mode: ServingMode::PdDisaggregated,
        ..Default::default()
    };
    let workload = Workload {
        requests: (0..24u64)
            .map(|i| Request {
                id: i,
                arrival_ms: i * 10,
                prefill_len: 3_000,
                decode_len: 50,
                slo: Slo::new(600, 100),
                model: 0,
            })
            .collect(),
    };
    let cluster =
        Cluster::build(ServingMode::PdDisaggregated, 3, 0.34, cfg.tiers.len(), &cm, true);
    let params = SimParams {
        mode: ServingMode::PdDisaggregated,
        chaos: Some(ChaosParams {
            fail_at: vec![(500, 2)],
            ..Default::default()
        }),
        overload: Some(OverloadParams {
            reject: true,
            retry: true,
            retry_base_ms: 100,
            retry_max_attempts: RETRY_MAX,
            propagate_deadline: false,
            seed: 0x0E71,
        }),
        ..Default::default()
    };
    let sim = Simulation::new(params, cm.clone(), &profile, &workload, cluster, &cfg.tiers);
    let mut router = PolyServeRouter::new(&cfg, workload.avg_decode_len());
    let res = sim.run_elastic(&mut router, None);

    assert_eq!(res.unfinished, 0, "accepted requests must all finish");
    assert_eq!(res.chaos.failures, 1, "the kill must land");
    let ol = &res.overload;
    assert!(ol.rejected_total > 0, "an overloaded prefill tier must shed");
    let rejected = res.outcomes.iter().filter(|o| o.rejected).count() as u64;
    assert_eq!(rejected, ol.rejected_total, "typed outcomes must match the ledger");
    let mut served = 0u64;
    for o in &res.outcomes {
        if o.rejected {
            assert_eq!(o.tokens, 0, "rejected request {} emitted tokens", o.id);
            assert!(
                o.finish_ms.is_none() && o.first_token_ms.is_none() && !o.attained,
                "rejected request {} carries service marks",
                o.id
            );
        } else {
            assert_eq!(
                o.tokens, 50,
                "request {} emitted {} of 50 tokens across the failure",
                o.id, o.tokens
            );
            served += 1;
        }
    }
    // Zero leakage either way: the bill counts exactly the accepted
    // tokens, the shed ledger exactly the rejected decode demand.
    assert_eq!(res.cost.tokens_total, served * 50);
    assert_eq!(ol.shed_tokens, ol.rejected_total * 50);
    assert_eq!(ol.rejected_per_model, vec![ol.rejected_total]);
    assert_eq!(
        ol.rejected_per_tier.iter().map(|&(_, n)| n).sum::<u64>(),
        ol.rejected_total
    );
    // Retry ledger: with retry on, every terminal shed burned exactly
    // `retry_max_attempts` backoffs before giving up, and every late
    // admit on retry `k+1` burned `k+1`.
    assert_eq!(ol.retry_exhausted, ol.rejected_total, "retry-on sheds all exhaust");
    let admitted_retries: u64 = ol
        .retry_histogram
        .iter()
        .enumerate()
        .map(|(k, &n)| (k as u64 + 1) * n)
        .sum();
    assert_eq!(
        ol.retries,
        admitted_retries + u64::from(RETRY_MAX) * ol.rejected_total,
        "the backoff ledger must reconcile"
    );
}

/// The `[models] mix` cap is lifted: a 3-model fleet splits instances
/// by largest-remainder quota, prepares the cycled builtin registry,
/// and serves every model to completion through a full elastic run.
#[test]
fn three_model_mix_splits_and_serves_every_model() {
    let counts = polyserve::figures::split_mix(12, &[0.5, 0.3, 0.2]);
    assert_eq!(counts.len(), 3);
    assert_eq!(counts.iter().sum::<usize>(), 12);
    assert!(counts.iter().all(|&c| c >= 2), "every model needs a PD pair: {counts:?}");
    assert!(counts[0] >= counts[1] && counts[1] >= counts[2], "{counts:?}");

    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        policy: Policy::PolyServe,
        mode: ServingMode::PdDisaggregated,
        instances: 12,
        requests: 300,
        rate_frac_of_optimal: 0.3,
        seed: 47,
        ..Default::default()
    };
    cfg.models.mix = vec![0.5, 0.3, 0.2];
    cfg.models.swap_delay_ms = 2_000;
    cfg.elastic.scaler = ScalerKind::Gradient;
    cfg.elastic.min_instances = 3;
    cfg.elastic.max_instances = 14;
    cfg.elastic.provision_delay_ms = 5_000;
    cfg.elastic.scale_eval_ms = 1_000;
    cfg.elastic.migration = true;
    let exp = Experiment::prepare(&cfg);
    assert_eq!(exp.models.len(), 3, "the registry must cycle to 3 models");
    let res = exp.run();
    assert_eq!(res.unfinished, 0);
    let served = &res.cost.requests_served_per_model;
    assert_eq!(served.len(), 3);
    assert!(served.iter().all(|&n| n > 0), "one model served nothing: {served:?}");
}
