//! PolyServe CLI — the Layer-3 leader entrypoint.
//!
//! Commands:
//! * `simulate` — run one cluster simulation cell and print its report.
//! * `sweep`    — attainment-vs-rate curve for a policy (Fig 6 cell).
//! * `analyze`  — print the §3 closed-form batch-limit / cost tables.
//! * `profile`  — build a profiling table (analytic, or measured from
//!   the AOT artifacts with `--real`) and save it as JSON.
//! * `serve`    — run the live multi-instance server on the AOT model
//!   artifacts and report latency/throughput.

use polyserve::analysis::{self, ServingMode};
use polyserve::config::{DiurnalSpec, Policy, ScalerKind, SimConfig};
use polyserve::figures;
use polyserve::model::CostModel;
use polyserve::profile::ProfileTable;
use polyserve::util::cli::{App, Args, Command, Parsed};
use polyserve::util::logging;
use polyserve::workload::TraceKind;
use std::path::Path;

fn main() {
    logging::init();
    let app = App::new("polyserve", "multi-SLO LLM serving at scale")
        .command(
            Command::new("simulate", "run one simulation cell")
                .opt("trace", "sharegpt", "trace name (see workload::TraceKind)")
                .opt("policy", "polyserve", "polyserve|random|minimal|chunk")
                .opt("mode", "pd", "pd|coloc")
                .opt("instances", "20", "number of serving instances")
                .opt("requests", "30000", "number of requests")
                .opt("rate-frac", "0.8", "request rate as a fraction of optimal")
                .opt("rate-rps", "", "absolute request rate (overrides rate-frac)")
                .opt("seed", "53264", "rng seed")
                .opt("config", "", "TOML config file (overrides defaults)")
                .opt("scaler", "", "fleet autoscaler: off|gradient|threshold|predictive")
                .opt("elastic-min", "", "elastic fleet floor (scalable role)")
                .opt("elastic-max", "", "elastic fleet ceiling (scalable role)")
                .opt("provision-delay-ms", "", "cold-start delay for provisioned instances")
                .opt("scale-eval-ms", "", "autoscaler evaluation period")
                .opt("provision-lead-ms", "", "predictive anticipation horizon (default: the cold-start delay)")
                .opt("prefill-min", "", "elastic PD prefill tier floor")
                .opt("prefill-max", "", "elastic PD prefill tier ceiling")
                .flag("prefill-elastic", "let TTFT pressure scale the PD prefill tier")
                .opt("diurnal-ratio", "", "diurnal peak:trough ratio (enables diurnal arrivals)")
                .opt("diurnal-period-s", "600", "diurnal period in seconds")
                .flag("migrate", "scale-in KV migration: evict drainers' decode residents")
                .flag("migrate-batch", "coalesce same-destination migration KV streams")
                .opt("model-mix", "", "comma weights, one per model (2 = built-in pair)")
                .opt("swap-delay-ms", "", "model hot-swap weight-reload delay")
                .opt("chaos-fail-mtbf-s", "", "mean time between injected instance failures")
                .opt("chaos-preempt-mtbf-s", "", "mean time between spot preemption notices")
                .opt("chaos-grace-ms", "", "drain window between preempt notice and kill")
                .opt("spot-fraction", "", "fraction of provisioned instances that are spot")
                .opt("spot-price-frac", "", "spot price as a fraction of on-demand")
                .opt("chaos-seed", "", "rng seed for the chaos schedule")
                .opt("chaos-zones", "", "failure zones the fleet is striped across")
                .opt("chaos-racks-per-zone", "", "racks inside each failure zone")
                .opt("chaos-domain-mtbf-s", "", "mean time between correlated rack/zone kills")
                .opt("checkpoint-period-ms", "", "KV-watermark snapshot period (0 = off)")
                .flag("chaos-adaptive", "scaler consumes chaos stats: churn pad + spot/on-demand split")
                .flag("overload", "EDF pending queues (the [overload] master switch)")
                .flag("overload-reject", "SLO-feasibility admission control at the arrival edge (implies --overload)")
                .flag("overload-retry", "rejected clients re-arrive after capped backoff (implies --overload-reject)")
                .opt("retry-base-ms", "", "backoff base for the first retry")
                .opt("retry-max-attempts", "", "terminal rejection after this many shed arrivals")
                .opt("overload-seed", "", "rng seed for the retry-jitter stream")
                .flag("propagate-deadline", "retries keep the original end-to-end deadline")
                .flag("verbose", "per-tier breakdown"),
        )
        .command(
            Command::new("sweep", "attainment-vs-rate curve (Fig 6 cell)")
                .opt("trace", "sharegpt", "trace name")
                .opt("policy", "polyserve", "policy")
                .opt("mode", "pd", "pd|coloc")
                .opt("instances", "20", "instances")
                .opt("requests", "10000", "requests per cell")
                .opt("fracs", "0.2,0.4,0.6,0.8,1.0,1.2", "rate fractions"),
        )
        .command(
            Command::new("analyze", "closed-form §3 batch limits and costs")
                .opt("p", "1000", "prefill length")
                .opt("d", "4000", "decode length")
                .opt("ttft", "700", "TTFT budget ms"),
        )
        .command(
            Command::new("profile", "build + save a profiling table")
                .opt("out", "artifacts/profile_h200_sim.json", "output path")
                .opt("artifacts", "artifacts", "artifact dir (for --real)")
                .flag("real", "measure from the AOT PJRT executables"),
        )
        .command(
            Command::new("serve", "live multi-instance serving demo")
                .opt("artifacts", "artifacts", "artifact dir")
                .opt("instances", "2", "in-process serving instances")
                .opt("requests", "64", "synthetic requests to serve")
                .opt("rate-rps", "0", "arrival rate (0 = auto-calibrate to ~60% capacity)"),
        );
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match app.parse(&argv) {
        Parsed::Help(h) => println!("{h}"),
        Parsed::Error(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
        Parsed::Run { command, args } => {
            let code = match command.as_str() {
                "simulate" => cmd_simulate(&args),
                "sweep" => cmd_sweep(&args),
                "analyze" => cmd_analyze(&args),
                "profile" => cmd_profile(&args),
                "serve" => cmd_serve(&args),
                _ => unreachable!(),
            };
            std::process::exit(code);
        }
    }
}

fn sim_config_from(args: &Args) -> Result<SimConfig, String> {
    let mut cfg = if !args.str_or("config", "").is_empty() {
        SimConfig::from_file(Path::new(args.str_or("config", ""))).map_err(|e| e.to_string())?
    } else {
        SimConfig::default()
    };
    if let Some(t) = args.get("trace") {
        cfg.trace = TraceKind::from_name(t).ok_or_else(|| format!("unknown trace '{t}'"))?;
    }
    if let Some(p) = args.get("policy") {
        cfg.policy = Policy::from_name(p).ok_or_else(|| format!("unknown policy '{p}'"))?;
    }
    cfg.mode = match args.str_or("mode", "pd") {
        "pd" => ServingMode::PdDisaggregated,
        "coloc" => ServingMode::Colocated,
        other => return Err(format!("unknown mode '{other}'")),
    };
    cfg.instances = args.usize_or("instances", cfg.instances);
    cfg.requests = args.usize_or("requests", cfg.requests);
    cfg.rate_frac_of_optimal = args.f64_or("rate-frac", cfg.rate_frac_of_optimal);
    if !args.str_or("rate-rps", "").is_empty() {
        cfg.rate_rps = Some(args.f64_or("rate-rps", 0.0));
    }
    cfg.seed = args.u64_or("seed", cfg.seed);
    if let Some(s) = args.get("scaler") {
        if !s.is_empty() {
            cfg.elastic.scaler =
                ScalerKind::from_name(s).ok_or_else(|| format!("unknown scaler '{s}'"))?;
        }
    }
    if !args.str_or("elastic-min", "").is_empty() {
        cfg.elastic.min_instances = args.usize_or("elastic-min", cfg.elastic.min_instances);
    }
    if !args.str_or("elastic-max", "").is_empty() {
        cfg.elastic.max_instances = args.usize_or("elastic-max", cfg.elastic.max_instances);
    }
    if !args.str_or("provision-delay-ms", "").is_empty() {
        cfg.elastic.provision_delay_ms =
            args.u64_or("provision-delay-ms", cfg.elastic.provision_delay_ms);
    }
    if !args.str_or("scale-eval-ms", "").is_empty() {
        cfg.elastic.scale_eval_ms = args.u64_or("scale-eval-ms", cfg.elastic.scale_eval_ms);
    }
    if !args.str_or("provision-lead-ms", "").is_empty() {
        cfg.elastic.provision_lead_ms = Some(args.u64_or("provision-lead-ms", 0));
    }
    if args.flag("prefill-elastic") {
        cfg.elastic.prefill_elastic = true;
    }
    if !args.str_or("prefill-min", "").is_empty() {
        cfg.elastic.prefill_min = args.usize_or("prefill-min", cfg.elastic.prefill_min);
    }
    if !args.str_or("prefill-max", "").is_empty() {
        cfg.elastic.prefill_max = args.usize_or("prefill-max", cfg.elastic.prefill_max);
    }
    if !args.str_or("diurnal-ratio", "").is_empty() {
        cfg.diurnal = Some(DiurnalSpec {
            peak_to_trough: args.f64_or("diurnal-ratio", 3.0),
            period_s: args.f64_or("diurnal-period-s", 600.0),
        });
    }
    if args.flag("migrate") {
        cfg.elastic.migration = true;
    }
    if args.flag("migrate-batch") {
        cfg.elastic.migration_batching = true;
    }
    if !args.str_or("model-mix", "").is_empty() {
        cfg.models.mix = args
            .str_or("model-mix", "")
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect();
    }
    if !args.str_or("swap-delay-ms", "").is_empty() {
        cfg.models.swap_delay_ms = args.u64_or("swap-delay-ms", cfg.models.swap_delay_ms);
    }
    if !args.str_or("chaos-fail-mtbf-s", "").is_empty() {
        cfg.chaos.fail_mtbf_s = args.f64_or("chaos-fail-mtbf-s", cfg.chaos.fail_mtbf_s);
    }
    if !args.str_or("chaos-preempt-mtbf-s", "").is_empty() {
        cfg.chaos.preempt_mtbf_s = args.f64_or("chaos-preempt-mtbf-s", cfg.chaos.preempt_mtbf_s);
    }
    if !args.str_or("chaos-grace-ms", "").is_empty() {
        cfg.chaos.preempt_grace_ms = args.u64_or("chaos-grace-ms", cfg.chaos.preempt_grace_ms);
    }
    if !args.str_or("spot-fraction", "").is_empty() {
        cfg.chaos.spot_fraction = args.f64_or("spot-fraction", cfg.chaos.spot_fraction);
    }
    if !args.str_or("spot-price-frac", "").is_empty() {
        cfg.chaos.spot_price_frac = args.f64_or("spot-price-frac", cfg.chaos.spot_price_frac);
    }
    if !args.str_or("chaos-seed", "").is_empty() {
        cfg.chaos.seed = args.u64_or("chaos-seed", cfg.chaos.seed);
    }
    if !args.str_or("chaos-zones", "").is_empty() {
        cfg.chaos.zones = args.u64_or("chaos-zones", u64::from(cfg.chaos.zones)) as u32;
    }
    if !args.str_or("chaos-racks-per-zone", "").is_empty() {
        cfg.chaos.racks_per_zone =
            args.u64_or("chaos-racks-per-zone", u64::from(cfg.chaos.racks_per_zone)) as u32;
    }
    if !args.str_or("chaos-domain-mtbf-s", "").is_empty() {
        cfg.chaos.domain_fail_mtbf_s =
            args.f64_or("chaos-domain-mtbf-s", cfg.chaos.domain_fail_mtbf_s);
    }
    if !args.str_or("checkpoint-period-ms", "").is_empty() {
        cfg.chaos.checkpoint_period_ms =
            args.u64_or("checkpoint-period-ms", cfg.chaos.checkpoint_period_ms);
    }
    if args.flag("chaos-adaptive") {
        cfg.chaos.adaptive = true;
    }
    if args.flag("overload") {
        cfg.overload.enabled = true;
    }
    if args.flag("overload-reject") {
        cfg.overload.enabled = true;
        cfg.overload.reject = true;
    }
    if args.flag("overload-retry") {
        cfg.overload.enabled = true;
        cfg.overload.reject = true;
        cfg.overload.retry = true;
    }
    if !args.str_or("retry-base-ms", "").is_empty() {
        cfg.overload.retry_base_ms = args.u64_or("retry-base-ms", cfg.overload.retry_base_ms);
    }
    if !args.str_or("retry-max-attempts", "").is_empty() {
        cfg.overload.retry_max_attempts =
            args.u64_or("retry-max-attempts", u64::from(cfg.overload.retry_max_attempts)) as u32;
    }
    if !args.str_or("overload-seed", "").is_empty() {
        cfg.overload.seed = args.u64_or("overload-seed", cfg.overload.seed);
    }
    if args.flag("propagate-deadline") {
        cfg.overload.enabled = true;
        cfg.overload.reject = true;
        cfg.overload.retry = true;
        cfg.overload.propagate_deadline = true;
    }
    cfg.validate().map_err(|e| e.to_string())?;
    Ok(cfg)
}

fn cmd_simulate(args: &Args) -> i32 {
    let cfg = match sim_config_from(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let exp = figures::Experiment::prepare(&cfg);
    println!(
        "workload: {} requests on '{}', rate {:.2} req/s ({:.0}% of optimal {:.2} req/s)",
        exp.workload.len(),
        cfg.trace.name(),
        exp.rate_rps,
        100.0 * exp.rate_rps / exp.optimal_rps.max(1e-9),
        exp.optimal_rps,
    );
    let t0 = std::time::Instant::now();
    let res = exp.run();
    println!(
        "simulated {:.1} s of cluster time in {:.2} s wall",
        res.sim_span_ms as f64 / 1000.0,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "policy {}-{}: attainment {:.3} (worst tier {:.3}), served {} ({} unfinished), throughput {:.2} req/s, cost {:.3} inst·s/req, util {:.2}",
        cfg.mode.name().to_uppercase(),
        cfg.policy.name(),
        res.attainment.overall(),
        res.attainment.worst_tier(),
        res.cost.requests_served,
        res.unfinished,
        res.throughput_rps,
        res.cost.cost_per_request_s(),
        res.cost.utilization(),
    );
    if exp.models.is_multi() {
        for (m, entry) in exp.models.entries().iter().enumerate() {
            let (total, attained) =
                res.attainment.per_model.get(m).copied().unwrap_or((0, 0));
            let served = res.cost.requests_served_per_model.get(m).copied().unwrap_or(0);
            let bill_ms = res.cost.active_instance_ms_per_model.get(m).copied().unwrap_or(0);
            let att = if total == 0 { 1.0 } else { attained as f64 / total as f64 };
            print!(
                "  model {m} ({}): attainment {att:.3} ({attained}/{total}), served {served}, bill {:.1} inst·s",
                entry.spec.name,
                bill_ms as f64 / 1000.0,
            );
            if !res.fleet.is_empty() {
                print!(
                    ", fleet mean {:.1} / peak {} / trough {}",
                    res.fleet.mean_model(m),
                    res.fleet.peak_model(m),
                    res.fleet.trough_model(m),
                );
            }
            println!();
        }
        if res.migration.model_swaps > 0 {
            println!(
                "  model hot-swaps: {} (drain + {} ms weight reload each)",
                res.migration.model_swaps, cfg.models.swap_delay_ms,
            );
        }
    }
    if !res.fleet.is_empty() {
        println!(
            "elastic fleet ({}): active mean {:.1} / peak {} / trough {}, bill {:.1} inst·s ({:.3} inst·s/req, {:.2} inst·s per 1k goodput tokens)",
            cfg.elastic.scaler.name(),
            res.fleet.mean_active(),
            res.fleet.peak_active(),
            res.fleet.trough_active(),
            res.cost.active_instance_ms as f64 / 1000.0,
            res.cost.active_cost_per_request_s(),
            res.cost.cost_per_1k_goodput_tokens_s(),
        );
        if cfg.elastic.prefill_elastic {
            println!(
                "elastic prefill: active mean {:.1} / peak {} / trough {}; {} queued jobs re-routed on drain",
                res.fleet.mean_prefill(),
                res.fleet.peak_prefill(),
                res.fleet.trough_prefill(),
                res.migration.migrated_prefill_jobs,
            );
        }
        if !res.fleet.rates.is_empty() {
            let lead = cfg
                .elastic
                .provision_lead_ms
                .unwrap_or(cfg.elastic.provision_delay_ms);
            let n = res.fleet.rates.len();
            let mean_obs =
                res.fleet.rates.iter().map(|r| r.observed_rps).sum::<f64>() / n as f64;
            let mean_pred =
                res.fleet.rates.iter().map(|r| r.predicted_rps).sum::<f64>() / n as f64;
            match res.fleet.rate_prediction_mae(lead) {
                Some(mae) => println!(
                    "predictive rate tracking: {n} epochs, mean observed {mean_obs:.2} rps, mean predicted {mean_pred:.2} rps, lead-aligned MAE {mae:.2} rps"
                ),
                None => println!(
                    "predictive rate tracking: {n} epochs, mean observed {mean_obs:.2} rps, mean predicted {mean_pred:.2} rps"
                ),
            }
        }
        if res.migration.drains() > 0 {
            println!(
                "scale-in ({}): {} drains, mean {:.0} ms / max {} ms begin_drain→retire; migrated {} requests / {} KV tokens",
                if cfg.elastic.migration { "migration" } else { "wait-drain" },
                res.migration.drains(),
                res.migration.mean_drain_latency_ms(),
                res.migration.max_drain_latency_ms(),
                res.migration.migrated_requests,
                res.migration.migrated_kv_tokens,
            );
        }
    }
    if !res.chaos.is_quiet() {
        println!(
            "chaos: {} failures, {} preempt notices ({} drained in time, {} deadline kills); {} requests re-prefilled, {} KV tokens lost",
            res.chaos.failures,
            res.chaos.preempt_notices,
            res.chaos.preempt_drained,
            res.chaos.preempt_deadline_kills,
            res.chaos.replaced_requests,
            res.chaos.lost_kv_tokens,
        );
        if res.chaos.domain_kills > 0 {
            let per_zone: Vec<String> = res
                .chaos
                .kills_per_zone
                .iter()
                .enumerate()
                .map(|(z, n)| format!("z{z}:{n}"))
                .collect();
            println!(
                "domains: {} correlated kills ({})",
                res.chaos.domain_kills,
                per_zone.join(" "),
            );
        }
        if res.chaos.checkpoints > 0 {
            println!(
                "checkpoints: {} snapshots, {} KV tokens covered ({} ms transfer); {} tokens restored on failure, {} re-prefilled",
                res.chaos.checkpoints,
                res.chaos.checkpoint_tokens,
                res.chaos.checkpoint_cost_ms,
                res.chaos.recovered_kv_tokens,
                res.chaos.reprefill_tokens,
            );
        }
        if res.cost.spot_instance_ms > 0 {
            println!(
                "spot: {:.1} of {:.1} active inst·s on spot; bill {:.1} inst·s at {:.0}% spot price",
                res.cost.spot_instance_ms as f64 / 1000.0,
                res.cost.active_instance_ms as f64 / 1000.0,
                res.cost.discounted_bill_ms(cfg.chaos.spot_price_frac) / 1000.0,
                100.0 * cfg.chaos.spot_price_frac,
            );
            if let Some(bill) = res.cost.spot_curve_bill_ms {
                println!(
                    "spot curve: bill {:.1} inst·s under the stepwise price schedule",
                    bill as f64 / 1000.0,
                );
            }
        }
    }
    if !res.overload.is_quiet() {
        let admitted_on_retry: u64 = res.overload.retry_histogram.iter().sum();
        println!(
            "overload: {} rejected ({:.1}% of {} arrivals), {} retries scheduled, {} admitted on retry, {} exhausted; {} decode tokens shed",
            res.overload.rejected_total,
            100.0 * res.overload.rejection_rate(res.outcomes.len() as u64),
            res.outcomes.len(),
            res.overload.retries,
            admitted_on_retry,
            res.overload.retry_exhausted,
            res.overload.shed_tokens,
        );
    }
    println!(
        "pending-queue aging: max wait {} ms, {} dispatches aged past patience",
        res.overload.max_pend_ms, res.overload.aged_past_patience,
    );
    if args.flag("verbose") {
        if res.overload.rejected_total > 0 {
            for &(tpot, n) in &res.overload.rejected_per_tier {
                println!("  tier {tpot:>4} ms: {n:>6} rejected");
            }
        }
        if res.migration.drains() > 0 {
            println!(
                "  drain latency histogram (1 s buckets, last = overflow): {:?}",
                res.migration.drain_latency_histogram(1_000, 8)
            );
        }
        for (tpot, total, ok) in &res.attainment.per_tier {
            println!(
                "  tier {tpot:>4} ms: {:>6}/{:<6} = {:.3}",
                ok,
                total,
                *ok as f64 / (*total).max(1) as f64
            );
        }
        let (ttft, tpot) = polyserve::metrics::latency_summary(&res.outcomes);
        if let Some(s) = ttft {
            println!("  TTFT ms: p50 {:.0} p99 {:.0}", s.p50(), s.p99());
        }
        if let Some(s) = tpot {
            println!("  mean-TPOT ms: p50 {:.1} p99 {:.1}", s.p50(), s.p99());
        }
    }
    0
}

fn cmd_sweep(args: &Args) -> i32 {
    let cfg = match sim_config_from(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let fracs: Vec<f64> = args
        .str_or("fracs", "")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let (curve, optimal) = figures::attainment_curve(&cfg, &fracs, threads);
    println!("optimal goodput: {optimal:.2} req/s");
    println!("{:>10} {:>12}", "rate", "attainment");
    for (rate, att) in &curve.points {
        println!("{rate:>10.2} {att:>12.3}");
    }
    if let Some(g) = curve.goodput_at(0.9) {
        println!(
            "goodput@90%: {g:.2} req/s ({:.1}% of optimal)",
            100.0 * g / optimal.max(1e-9)
        );
    }
    0
}

fn cmd_analyze(args: &Args) -> i32 {
    let cm = CostModel::h200_llama8b();
    let p = args.u64_or("p", 1000);
    let d = args.u64_or("d", 4000);
    let ttft = args.f64_or("ttft", 700.0);
    let tpots = [16.0, 20.0, 25.0, 30.0, 40.0, 50.0, 75.0, 100.0, 150.0];
    println!("(p, d) = ({p}, {d}), TTFT = {ttft} ms\n");
    println!(
        "{:>8} {:>10} {:>10} {:>12} {:>12}",
        "TPOT", "B_decode", "B_coloc", "cost_pd(s)", "cost_co(s)"
    );
    for pt in analysis::fig4_cost_series(&cm, p, d, ttft, &tpots) {
        let b_dc = cm.max_decode_batch(pt.tpot_ms, p + d / 2);
        let b_co = cm.max_coloc_batch(p, d, pt.tpot_ms, ttft);
        println!(
            "{:>8.0} {:>10} {:>10} {:>12.3} {:>12.3}",
            pt.tpot_ms, b_dc, b_co, pt.cost_pd_s, pt.cost_coloc_s
        );
    }
    0
}

fn cmd_profile(args: &Args) -> i32 {
    let out = args.str_or("out", "artifacts/profile_h200_sim.json");
    let table = if args.flag("real") {
        match polyserve::runtime::profiler::profile_real(Path::new(args.str_or("artifacts", "artifacts"))) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("real profiling failed: {e:#}");
                return 1;
            }
        }
    } else {
        ProfileTable::from_cost_model(&CostModel::h200_llama8b())
    };
    if let Some(dir) = Path::new(out).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match table.save(Path::new(out)) {
        Ok(()) => {
            println!("wrote {out}");
            0
        }
        Err(e) => {
            eprintln!("write failed: {e:#}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    match polyserve::server::demo::run_demo(
        Path::new(args.str_or("artifacts", "artifacts")),
        args.usize_or("instances", 2),
        args.usize_or("requests", 64),
        args.f64_or("rate-rps", 8.0),
    ) {
        Ok(report) => {
            println!("{report}");
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            1
        }
    }
}
