//! SLO tiers and deadline-based SLO (DSLO) accounting.
//!
//! The paper (§2.3) adopts deadline-based SLOs: token *i* (0-indexed,
//! token 0 = the first token produced by prefill) must be produced by
//! `arrival + TTFT + i · TPOT`. A request attains its SLO iff every
//! token met its deadline. Time is in integer milliseconds everywhere
//! (the simulator's resolution, matching the paper's 1 ms timestep).

pub mod tiers;

pub use tiers::{SloTier, TierSet, TierDistribution};

/// Milliseconds since simulation start.
pub type TimeMs = u64;

/// A request's SLO: (TTFT, TPOT) in ms. `BEST_EFFORT` uses 12 h / 12 h
/// per the paper's example.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Slo {
    /// Time-to-first-token budget, ms.
    pub ttft_ms: u64,
    /// Time-per-output-token budget, ms.
    pub tpot_ms: u64,
}

impl Slo {
    /// The best-effort marker SLO: no deadlines, excluded from attainment.
    pub const BEST_EFFORT: Slo = Slo {
        ttft_ms: 12 * 3600 * 1000,
        tpot_ms: 12 * 3600 * 1000,
    };

    /// An SLO with the given TTFT and TPOT budgets (ms).
    pub fn new(ttft_ms: u64, tpot_ms: u64) -> Slo {
        Slo { ttft_ms, tpot_ms }
    }

    /// DSLO deadline for token `i` (0-based) of a request arriving at
    /// `arrival`.
    #[inline]
    pub fn deadline(&self, arrival: TimeMs, token_index: u64) -> TimeMs {
        arrival + self.ttft_ms + token_index * self.tpot_ms
    }

    /// Is this the best-effort marker?
    pub fn is_best_effort(&self) -> bool {
        self.tpot_ms >= Slo::BEST_EFFORT.tpot_ms
    }
}

/// Tracks DSLO attainment for one request as tokens are emitted.
///
/// The paper's semantics: the request attains its SLO iff *every* token
/// is produced by its deadline. `slack_ms` reports how close calls were
/// (used by tail-latency diagnostics).
#[derive(Debug, Clone)]
pub struct DsloTracker {
    /// Arrival time the deadlines are anchored to.
    pub arrival: TimeMs,
    /// The SLO being tracked.
    pub slo: Slo,
    tokens_emitted: u64,
    violated: bool,
    /// Worst (smallest) slack over all tokens so far; deadline − emit time.
    min_slack_ms: i64,
}

impl DsloTracker {
    /// Start tracking a request that arrived at `arrival` under `slo`.
    pub fn new(arrival: TimeMs, slo: Slo) -> DsloTracker {
        DsloTracker {
            arrival,
            slo,
            tokens_emitted: 0,
            violated: false,
            min_slack_ms: i64::MAX,
        }
    }

    /// Record the emission of the next token at time `now`.
    pub fn emit_token(&mut self, now: TimeMs) {
        let deadline = self.slo.deadline(self.arrival, self.tokens_emitted);
        let slack = deadline as i64 - now as i64;
        self.min_slack_ms = self.min_slack_ms.min(slack);
        if slack < 0 {
            self.violated = true;
        }
        self.tokens_emitted += 1;
    }

    /// Tokens emitted so far.
    pub fn tokens_emitted(&self) -> u64 {
        self.tokens_emitted
    }

    /// True iff no token has missed its deadline so far.
    pub fn attained(&self) -> bool {
        !self.violated
    }

    /// Worst slack over all emitted tokens, ms (negative = violation).
    pub fn min_slack_ms(&self) -> i64 {
        if self.tokens_emitted == 0 {
            0
        } else {
            self.min_slack_ms
        }
    }

    /// Deadline of the *next* token to be emitted.
    pub fn next_deadline(&self) -> TimeMs {
        self.slo.deadline(self.arrival, self.tokens_emitted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_math() {
        let slo = Slo::new(1000, 20);
        assert_eq!(slo.deadline(500, 0), 1500);
        assert_eq!(slo.deadline(500, 1), 1520);
        assert_eq!(slo.deadline(500, 10), 1700);
    }

    #[test]
    fn tracker_attains_when_all_on_time() {
        let mut t = DsloTracker::new(0, Slo::new(100, 10));
        t.emit_token(100); // token 0 deadline 100
        t.emit_token(105); // token 1 deadline 110
        t.emit_token(120); // token 2 deadline 120 (exactly on time)
        assert!(t.attained());
        assert_eq!(t.min_slack_ms(), 0);
        assert_eq!(t.tokens_emitted(), 3);
    }

    #[test]
    fn tracker_flags_single_late_token() {
        let mut t = DsloTracker::new(0, Slo::new(100, 10));
        t.emit_token(50);
        t.emit_token(111); // deadline 110 → violation
        t.emit_token(115);
        assert!(!t.attained());
        assert_eq!(t.min_slack_ms(), -1);
    }

    #[test]
    fn dslo_allows_catching_up() {
        // A slow token followed by fast tokens still attains as long as
        // each token's own deadline is met — the paper's key flexibility.
        let mut t = DsloTracker::new(0, Slo::new(100, 20));
        t.emit_token(100); // dl 100
        t.emit_token(139); // dl 120+20*... wait: token1 dl = 100+20 = 120 → late!
        assert!(!t.attained());

        let mut t2 = DsloTracker::new(0, Slo::new(100, 20));
        t2.emit_token(90); // dl 100
        t2.emit_token(119); // dl 120: 29ms gap but within deadline
        t2.emit_token(125); // dl 140
        assert!(t2.attained());
    }

    #[test]
    fn next_deadline_advances() {
        let mut t = DsloTracker::new(1000, Slo::new(300, 50));
        assert_eq!(t.next_deadline(), 1300);
        t.emit_token(1200);
        assert_eq!(t.next_deadline(), 1350);
    }

    #[test]
    fn best_effort_is_loose() {
        assert!(Slo::BEST_EFFORT.is_best_effort());
        assert!(!Slo::new(1000, 100).is_best_effort());
    }
}
