//! SLO tier sets and the paper's evaluation tier distribution.
//!
//! §5.1: TTFT sampled uniformly from {300, 500, 1000} ms; TPOT tiers
//! {20, 30, 50, 100} ms with probabilities {10%, 20%, 30%, 40%}.
//! Requests are *binned by TPOT* (§4.2) — a tier in this codebase is a
//! TPOT level; TTFT varies per request within a tier.

use super::Slo;
use crate::util::rng::Rng;

/// One TPOT tier. Tiers are ordered tightest-first (index 0 = smallest
/// TPOT), matching the promotion direction in the paper: a request may
/// be *promoted* from tier k to tier j < k (tighter) when k is full.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTier {
    /// Index within the tier set, 0 = tightest.
    pub index: usize,
    /// The tier's TPOT budget, ms.
    pub tpot_ms: u64,
}

/// An ordered set of TPOT tiers (tightest first).
#[derive(Debug, Clone)]
pub struct TierSet {
    tpots: Vec<u64>,
}

impl TierSet {
    /// The paper's evaluation tiers: 20/30/50/100 ms.
    pub fn paper_default() -> TierSet {
        TierSet::new(vec![20, 30, 50, 100])
    }

    /// Build from TPOT values (sorted and deduped; tightest first).
    pub fn new(mut tpots: Vec<u64>) -> TierSet {
        assert!(!tpots.is_empty(), "empty tier set");
        tpots.sort_unstable();
        tpots.dedup();
        TierSet { tpots }
    }

    /// Number of tiers.
    pub fn len(&self) -> usize {
        self.tpots.len()
    }

    /// True when the set has no tiers (never, after `new`).
    pub fn is_empty(&self) -> bool {
        self.tpots.is_empty()
    }

    /// The tier at `index` (0 = tightest).
    pub fn tier(&self, index: usize) -> SloTier {
        SloTier {
            index,
            tpot_ms: self.tpots[index],
        }
    }

    /// Iterate tiers tightest-first.
    pub fn iter(&self) -> impl Iterator<Item = SloTier> + '_ {
        self.tpots
            .iter()
            .enumerate()
            .map(|(index, &tpot_ms)| SloTier { index, tpot_ms })
    }

    /// The sorted TPOT values, ms.
    pub fn tpots(&self) -> &[u64] {
        &self.tpots
    }

    /// Tier index for a request TPOT: the tightest tier whose TPOT is
    /// >= the request's (i.e. the loosest bin that still satisfies it).
    /// Requests looser than the loosest tier map to the last tier.
    pub fn bin_for_tpot(&self, tpot_ms: u64) -> usize {
        for (i, &t) in self.tpots.iter().enumerate() {
            if t >= tpot_ms {
                return i;
            }
        }
        self.tpots.len() - 1
    }

    /// Tiers tighter than `index`, nearest first — the lazy-promotion
    /// search order (§4.4: spill to the next tighter tier first).
    pub fn promotion_order(&self, index: usize) -> impl Iterator<Item = usize> {
        (0..index).rev()
    }
}

/// Sampling distribution over (TTFT, TPOT) pairs, per §5.1.
#[derive(Debug, Clone)]
pub struct TierDistribution {
    /// TTFT choices sampled uniformly, ms.
    pub ttft_choices_ms: Vec<u64>,
    /// TPOT choices, ms (parallel to `tpot_weights`).
    pub tpot_choices_ms: Vec<u64>,
    /// Sampling weight per TPOT choice.
    pub tpot_weights: Vec<f64>,
}

impl TierDistribution {
    /// §5.1 defaults.
    pub fn paper_default() -> TierDistribution {
        TierDistribution {
            ttft_choices_ms: vec![300, 500, 1000],
            tpot_choices_ms: vec![20, 30, 50, 100],
            tpot_weights: vec![0.10, 0.20, 0.30, 0.40],
        }
    }

    /// §5.3 burstiness: the inverted mix for the second half.
    pub fn paper_inverted() -> TierDistribution {
        TierDistribution {
            ttft_choices_ms: vec![300, 500, 1000],
            tpot_choices_ms: vec![20, 30, 50, 100],
            tpot_weights: vec![0.40, 0.30, 0.20, 0.10],
        }
    }

    /// Draw a (TTFT, TPOT) pair per the §5.1 distribution.
    pub fn sample(&self, rng: &mut Rng) -> Slo {
        let ttft = *rng.pick(&self.ttft_choices_ms);
        let tpot = self.tpot_choices_ms[rng.categorical(&self.tpot_weights)];
        Slo::new(ttft, tpot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiers_sorted_tightest_first() {
        let ts = TierSet::new(vec![100, 20, 50, 30]);
        assert_eq!(ts.tpots(), &[20, 30, 50, 100]);
        assert_eq!(ts.tier(0).tpot_ms, 20);
        assert_eq!(ts.tier(3).tpot_ms, 100);
    }

    #[test]
    fn binning_picks_satisfying_tier() {
        let ts = TierSet::paper_default();
        assert_eq!(ts.bin_for_tpot(20), 0);
        assert_eq!(ts.bin_for_tpot(25), 1); // needs ≤25, 30-tier can't...
        // Note: bin_for_tpot returns the first tier with tpot >= request
        // tpot; a request demanding 25ms lands in the 30ms bin only if we
        // interpret "tier tpot >= request tpot" as tier being looser.
        // The evaluation samples request TPOTs exactly from tier values,
        // so only exact matches occur in practice.
        assert_eq!(ts.bin_for_tpot(30), 1);
        assert_eq!(ts.bin_for_tpot(50), 2);
        assert_eq!(ts.bin_for_tpot(100), 3);
        assert_eq!(ts.bin_for_tpot(5000), 3);
    }

    #[test]
    fn promotion_order_is_nearest_tighter_first() {
        let ts = TierSet::paper_default();
        let order: Vec<usize> = ts.promotion_order(3).collect();
        assert_eq!(order, vec![2, 1, 0]);
        let order0: Vec<usize> = ts.promotion_order(0).collect();
        assert!(order0.is_empty());
    }

    #[test]
    fn distribution_matches_weights() {
        let dist = TierDistribution::paper_default();
        let mut rng = Rng::new(42);
        let n = 100_000;
        let mut counts = [0u32; 4];
        for _ in 0..n {
            let slo = dist.sample(&mut rng);
            let idx = dist
                .tpot_choices_ms
                .iter()
                .position(|&t| t == slo.tpot_ms)
                .unwrap();
            counts[idx] += 1;
            assert!(dist.ttft_choices_ms.contains(&slo.ttft_ms));
        }
        let fracs: Vec<f64> = counts.iter().map(|&c| c as f64 / n as f64).collect();
        for (frac, w) in fracs.iter().zip(&dist.tpot_weights) {
            assert!((frac - w).abs() < 0.01, "fracs={fracs:?}");
        }
    }

    #[test]
    fn inverted_distribution_flips_weights() {
        let a = TierDistribution::paper_default();
        let b = TierDistribution::paper_inverted();
        let mut rev = a.tpot_weights.clone();
        rev.reverse();
        assert_eq!(rev, b.tpot_weights);
    }
}
