//! Arrival processes.
//!
//! §5.2: "Requests arrive according to a Poisson process." §5.3 adds a
//! mid-run tier-mix inversion (burstiness). Helpers here produce arrival
//! timestamps; trace generators attach lengths and SLOs.

use crate::slo::TimeMs;
use crate::util::rng::Rng;

/// `n` Poisson arrival times at `rate_per_s`, in ms, starting at 0.
pub fn poisson_arrivals(n: usize, rate_per_s: f64, rng: &mut Rng) -> Vec<TimeMs> {
    assert!(rate_per_s > 0.0);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exp(rate_per_s) * 1000.0;
            t as TimeMs
        })
        .collect()
}

/// A piecewise-constant rate schedule: (start_ms, rate_per_s) segments.
/// Used for burst experiments beyond the paper's single inversion, and
/// (via [`RateSchedule::diurnal`]) as the demand curve the elastic
/// fleet's autoscaler chases.
#[derive(Debug, Clone)]
pub struct RateSchedule {
    /// (start time ms, rate req/s); must be sorted by start, first at 0.
    pub segments: Vec<(TimeMs, f64)>,
}

impl RateSchedule {
    /// A single-segment constant-rate schedule.
    pub fn constant(rate_per_s: f64) -> RateSchedule {
        RateSchedule {
            segments: vec![(0, rate_per_s)],
        }
    }

    /// A diurnal demand curve: a piecewise-constant approximation of
    /// `mean · (1 + a·sin(2πt/period))` over `periods` periods, sampled
    /// at `segments_per_period` segment midpoints. `a` is derived from
    /// the requested peak:trough ratio (`a = (r−1)/(r+1)`), so e.g.
    /// `peak_to_trough = 3` swings between 1.5× and 0.5× the mean. By
    /// midpoint symmetry the schedule integrates exactly to
    /// `mean_rate_per_s` over every full period.
    pub fn diurnal(
        mean_rate_per_s: f64,
        peak_to_trough: f64,
        period_ms: TimeMs,
        segments_per_period: usize,
        periods: usize,
    ) -> RateSchedule {
        assert!(mean_rate_per_s > 0.0);
        assert!(peak_to_trough >= 1.0, "peak:trough must be >= 1");
        assert!(segments_per_period >= 2 && periods >= 1 && period_ms >= 2);
        let a = (peak_to_trough - 1.0) / (peak_to_trough + 1.0);
        let m = segments_per_period;
        let mut segments = Vec::with_capacity(m * periods);
        for p in 0..periods {
            for i in 0..m {
                let start = p as TimeMs * period_ms + (i as TimeMs * period_ms) / m as TimeMs;
                let phase = 2.0 * std::f64::consts::PI * (i as f64 + 0.5) / m as f64;
                segments.push((start, mean_rate_per_s * (1.0 + a * phase.sin())));
            }
        }
        RateSchedule { segments }
    }

    /// The scheduled rate at time `t` (the last segment extends forever).
    pub fn rate_at(&self, t: TimeMs) -> f64 {
        let mut rate = self.segments[0].1;
        for &(start, r) in &self.segments {
            if start <= t {
                rate = r;
            } else {
                break;
            }
        }
        rate
    }

    /// Time-weighted mean rate over `[0, until)` (the last segment
    /// extends to `until`).
    pub fn mean_rate_over(&self, until: TimeMs) -> f64 {
        assert!(!self.segments.is_empty() && until > 0);
        let mut acc = 0.0;
        for (i, &(start, rate)) in self.segments.iter().enumerate() {
            if start >= until {
                break;
            }
            let end = self
                .segments
                .get(i + 1)
                .map(|&(s, _)| s.min(until))
                .unwrap_or(until);
            acc += rate * end.saturating_sub(start) as f64;
        }
        acc / until as f64
    }

    /// Generate `n` arrivals following the schedule (thinning-free:
    /// advance with the current segment's exponential gaps). Timestamps
    /// are strictly increasing — simultaneous sub-millisecond arrivals
    /// are pushed to consecutive milliseconds, matching the simulator's
    /// 1 ms resolution.
    pub fn arrivals(&self, n: usize, rng: &mut Rng) -> Vec<TimeMs> {
        assert!(!self.segments.is_empty());
        let mut t = 0.0f64;
        let mut prev: Option<TimeMs> = None;
        (0..n)
            .map(|_| {
                let rate = self.rate_at(t as TimeMs);
                t += rng.exp(rate) * 1000.0;
                let ms = match prev {
                    Some(p) => (t as TimeMs).max(p + 1),
                    None => t as TimeMs,
                };
                prev = Some(ms);
                ms
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_close() {
        let mut rng = Rng::new(3);
        let arr = poisson_arrivals(50_000, 200.0, &mut rng);
        let span_s = (*arr.last().unwrap() - arr[0]) as f64 / 1000.0;
        let rate = (arr.len() - 1) as f64 / span_s;
        assert!((rate - 200.0).abs() < 5.0, "rate={rate}");
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn poisson_cv_is_one() {
        // Exponential gaps: coefficient of variation ≈ 1.
        let mut rng = Rng::new(4);
        let arr = poisson_arrivals(20_000, 50.0, &mut rng);
        let gaps: Vec<f64> = arr.windows(2).map(|w| (w[1] - w[0]) as f64).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.1, "cv={cv}");
    }

    #[test]
    fn schedule_rate_lookup() {
        let s = RateSchedule {
            segments: vec![(0, 10.0), (1000, 50.0), (5000, 20.0)],
        };
        assert_eq!(s.rate_at(0), 10.0);
        assert_eq!(s.rate_at(999), 10.0);
        assert_eq!(s.rate_at(1000), 50.0);
        assert_eq!(s.rate_at(10_000), 20.0);
    }

    #[test]
    fn diurnal_integrates_to_mean_and_swings() {
        let mean = 60.0;
        let period = 600_000; // 10 min
        let s = RateSchedule::diurnal(mean, 3.0, period, 24, 2);
        assert_eq!(s.segments.len(), 48);
        // Exact by midpoint symmetry over full periods.
        assert!((s.mean_rate_over(2 * period) - mean).abs() / mean < 1e-9);
        // Peak and trough match the requested 3:1 ratio (a = 0.5).
        let peak = s.segments.iter().map(|&(_, r)| r).fold(0.0, f64::max);
        let trough = s.segments.iter().map(|&(_, r)| r).fold(f64::INFINITY, f64::min);
        assert!((peak / trough - 3.0).abs() < 0.1, "ratio {}", peak / trough);
        assert!(peak <= mean * 1.5 + 1e-9 && trough >= mean * 0.5 - 1e-9);
    }

    #[test]
    fn schedule_arrivals_strictly_increasing() {
        let s = RateSchedule::diurnal(400.0, 4.0, 60_000, 12, 1);
        let mut rng = Rng::new(11);
        let arr = s.arrivals(20_000, &mut rng);
        assert!(
            arr.windows(2).all(|w| w[0] < w[1]),
            "arrivals must be strictly increasing"
        );
    }

    #[test]
    fn schedule_arrivals_change_density() {
        let s = RateSchedule {
            segments: vec![(0, 10.0), (10_000, 100.0)],
        };
        let mut rng = Rng::new(5);
        let arr = s.arrivals(2000, &mut rng);
        let early = arr.iter().filter(|&&t| t < 10_000).count();
        let late_span_s = (*arr.last().unwrap() as f64 - 10_000.0) / 1000.0;
        let late_rate = (arr.len() - early) as f64 / late_span_s;
        assert!((late_rate - 100.0).abs() < 15.0, "late_rate={late_rate}");
    }
}
