//! Arrival processes.
//!
//! §5.2: "Requests arrive according to a Poisson process." §5.3 adds a
//! mid-run tier-mix inversion (burstiness). Helpers here produce arrival
//! timestamps; trace generators attach lengths and SLOs.

use crate::slo::TimeMs;
use crate::util::rng::Rng;

/// `n` Poisson arrival times at `rate_per_s`, in ms, starting at 0.
pub fn poisson_arrivals(n: usize, rate_per_s: f64, rng: &mut Rng) -> Vec<TimeMs> {
    assert!(rate_per_s > 0.0);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exp(rate_per_s) * 1000.0;
            t as TimeMs
        })
        .collect()
}

/// A piecewise-constant rate schedule: (start_ms, rate_per_s) segments.
/// Used for burst experiments beyond the paper's single inversion.
#[derive(Debug, Clone)]
pub struct RateSchedule {
    /// (start time ms, rate req/s); must be sorted by start, first at 0.
    pub segments: Vec<(TimeMs, f64)>,
}

impl RateSchedule {
    pub fn constant(rate_per_s: f64) -> RateSchedule {
        RateSchedule {
            segments: vec![(0, rate_per_s)],
        }
    }

    pub fn rate_at(&self, t: TimeMs) -> f64 {
        let mut rate = self.segments[0].1;
        for &(start, r) in &self.segments {
            if start <= t {
                rate = r;
            } else {
                break;
            }
        }
        rate
    }

    /// Generate `n` arrivals following the schedule (thinning-free:
    /// advance with the current segment's exponential gaps).
    pub fn arrivals(&self, n: usize, rng: &mut Rng) -> Vec<TimeMs> {
        assert!(!self.segments.is_empty());
        let mut t = 0.0f64;
        (0..n)
            .map(|_| {
                let rate = self.rate_at(t as TimeMs);
                t += rng.exp(rate) * 1000.0;
                t as TimeMs
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_close() {
        let mut rng = Rng::new(3);
        let arr = poisson_arrivals(50_000, 200.0, &mut rng);
        let span_s = (*arr.last().unwrap() - arr[0]) as f64 / 1000.0;
        let rate = (arr.len() - 1) as f64 / span_s;
        assert!((rate - 200.0).abs() < 5.0, "rate={rate}");
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn poisson_cv_is_one() {
        // Exponential gaps: coefficient of variation ≈ 1.
        let mut rng = Rng::new(4);
        let arr = poisson_arrivals(20_000, 50.0, &mut rng);
        let gaps: Vec<f64> = arr.windows(2).map(|w| (w[1] - w[0]) as f64).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.1, "cv={cv}");
    }

    #[test]
    fn schedule_rate_lookup() {
        let s = RateSchedule {
            segments: vec![(0, 10.0), (1000, 50.0), (5000, 20.0)],
        };
        assert_eq!(s.rate_at(0), 10.0);
        assert_eq!(s.rate_at(999), 10.0);
        assert_eq!(s.rate_at(1000), 50.0);
        assert_eq!(s.rate_at(10_000), 20.0);
    }

    #[test]
    fn schedule_arrivals_change_density() {
        let s = RateSchedule {
            segments: vec![(0, 10.0), (10_000, 100.0)],
        };
        let mut rng = Rng::new(5);
        let arr = s.arrivals(2000, &mut rng);
        let early = arr.iter().filter(|&&t| t < 10_000).count();
        let late_span_s = (*arr.last().unwrap() as f64 - 10_000.0) / 1000.0;
        let late_rate = (arr.len() - early) as f64 / late_span_s;
        assert!((late_rate - 100.0).abs() < 15.0, "late_rate={late_rate}");
    }
}
