//! Arrival processes.
//!
//! §5.2: "Requests arrive according to a Poisson process." §5.3 adds a
//! mid-run tier-mix inversion (burstiness). Helpers here produce arrival
//! timestamps; trace generators attach lengths and SLOs.

use crate::slo::TimeMs;
use crate::util::rng::Rng;

/// `n` Poisson arrival times at `rate_per_s`, in ms, starting at 0.
pub fn poisson_arrivals(n: usize, rate_per_s: f64, rng: &mut Rng) -> Vec<TimeMs> {
    assert!(rate_per_s > 0.0);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exp(rate_per_s) * 1000.0;
            t as TimeMs
        })
        .collect()
}

/// A piecewise-constant rate schedule: (start_ms, rate_per_s) segments.
/// Used for burst experiments beyond the paper's single inversion, and
/// (via [`RateSchedule::diurnal`]) as the demand curve the elastic
/// fleet's autoscaler chases.
#[derive(Debug, Clone)]
pub struct RateSchedule {
    /// (start time ms, rate req/s); must be sorted by start, first at 0.
    pub segments: Vec<(TimeMs, f64)>,
}

impl RateSchedule {
    /// A single-segment constant-rate schedule.
    pub fn constant(rate_per_s: f64) -> RateSchedule {
        RateSchedule {
            segments: vec![(0, rate_per_s)],
        }
    }

    /// A diurnal demand curve: a piecewise-constant approximation of
    /// `mean · (1 + a·sin(2πt/period))` over `periods` periods, sampled
    /// at `segments_per_period` segment midpoints. `a` is derived from
    /// the requested peak:trough ratio (`a = (r−1)/(r+1)`), so e.g.
    /// `peak_to_trough = 3` swings between 1.5× and 0.5× the mean. By
    /// midpoint symmetry the schedule integrates exactly to
    /// `mean_rate_per_s` over every full period.
    pub fn diurnal(
        mean_rate_per_s: f64,
        peak_to_trough: f64,
        period_ms: TimeMs,
        segments_per_period: usize,
        periods: usize,
    ) -> RateSchedule {
        assert!(mean_rate_per_s > 0.0);
        assert!(peak_to_trough >= 1.0, "peak:trough must be >= 1");
        assert!(segments_per_period >= 2 && periods >= 1 && period_ms >= 2);
        let a = (peak_to_trough - 1.0) / (peak_to_trough + 1.0);
        let m = segments_per_period;
        let mut segments = Vec::with_capacity(m * periods);
        for p in 0..periods {
            for i in 0..m {
                let start = p as TimeMs * period_ms + (i as TimeMs * period_ms) / m as TimeMs;
                let phase = 2.0 * std::f64::consts::PI * (i as f64 + 0.5) / m as f64;
                segments.push((start, mean_rate_per_s * (1.0 + a * phase.sin())));
            }
        }
        RateSchedule { segments }
    }

    /// A flash crowd: a `base_rate_per_s` plateau until `t_spike_ms`,
    /// an instant jump to `spike_mult × base`, then a piecewise-linear
    /// decay back to the base over `decay_ms` in `decay_steps` equal
    /// segments (each at its interval's midpoint rate, so the decay
    /// ramp integrates exactly like the continuous one). The shock the
    /// reactive scalers can only chase and the seasonal predictive
    /// term can pre-provision for.
    pub fn flash_crowd(
        base_rate_per_s: f64,
        spike_mult: f64,
        t_spike_ms: TimeMs,
        decay_ms: TimeMs,
        decay_steps: usize,
    ) -> RateSchedule {
        assert!(base_rate_per_s > 0.0);
        assert!(spike_mult >= 1.0, "spike must not dip below base");
        assert!(t_spike_ms >= 1 && decay_ms >= decay_steps as TimeMs && decay_steps >= 1);
        let mut segments = vec![(0, base_rate_per_s)];
        for i in 0..decay_steps {
            let start = t_spike_ms + (i as TimeMs * decay_ms) / decay_steps as TimeMs;
            // Midpoint of the linear spike→base ramp on this step.
            let frac = (i as f64 + 0.5) / decay_steps as f64;
            let mult = spike_mult + (1.0 - spike_mult) * frac;
            segments.push((start, base_rate_per_s * mult));
        }
        segments.push((t_spike_ms + decay_ms, base_rate_per_s));
        RateSchedule { segments }
    }

    /// A regime-switching schedule: cycle through `rates_per_s`
    /// plateaus, dwelling `dwell_ms` on each, for `switches + 1` total
    /// plateaus (the last extends forever, like every final segment).
    /// Abrupt level shifts with no ramp — the worst case for trend
    /// extrapolation.
    pub fn regime_switch(
        rates_per_s: &[f64],
        dwell_ms: TimeMs,
        switches: usize,
    ) -> RateSchedule {
        assert!(!rates_per_s.is_empty() && rates_per_s.iter().all(|r| *r > 0.0));
        assert!(dwell_ms >= 1);
        let segments = (0..=switches)
            .map(|i| (i as TimeMs * dwell_ms, rates_per_s[i % rates_per_s.len()]))
            .collect();
        RateSchedule { segments }
    }

    /// The scheduled rate at time `t` (the last segment extends forever).
    pub fn rate_at(&self, t: TimeMs) -> f64 {
        let mut rate = self.segments[0].1;
        for &(start, r) in &self.segments {
            if start <= t {
                rate = r;
            } else {
                break;
            }
        }
        rate
    }

    /// Time-weighted mean rate over `[0, until)` (the last segment
    /// extends to `until`).
    pub fn mean_rate_over(&self, until: TimeMs) -> f64 {
        assert!(!self.segments.is_empty() && until > 0);
        let mut acc = 0.0;
        for (i, &(start, rate)) in self.segments.iter().enumerate() {
            if start >= until {
                break;
            }
            let end = self
                .segments
                .get(i + 1)
                .map(|&(s, _)| s.min(until))
                .unwrap_or(until);
            acc += rate * end.saturating_sub(start) as f64;
        }
        acc / until as f64
    }

    /// Generate `n` arrivals following the schedule (thinning-free:
    /// advance with the current segment's exponential gaps). Timestamps
    /// are strictly increasing — simultaneous sub-millisecond arrivals
    /// are pushed to consecutive milliseconds, matching the simulator's
    /// 1 ms resolution.
    pub fn arrivals(&self, n: usize, rng: &mut Rng) -> Vec<TimeMs> {
        assert!(!self.segments.is_empty());
        let mut t = 0.0f64;
        let mut prev: Option<TimeMs> = None;
        (0..n)
            .map(|_| {
                let rate = self.rate_at(t as TimeMs);
                t += rng.exp(rate) * 1000.0;
                let ms = match prev {
                    Some(p) => (t as TimeMs).max(p + 1),
                    None => t as TimeMs,
                };
                prev = Some(ms);
                ms
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_close() {
        let mut rng = Rng::new(3);
        let arr = poisson_arrivals(50_000, 200.0, &mut rng);
        let span_s = (*arr.last().unwrap() - arr[0]) as f64 / 1000.0;
        let rate = (arr.len() - 1) as f64 / span_s;
        assert!((rate - 200.0).abs() < 5.0, "rate={rate}");
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn poisson_cv_is_one() {
        // Exponential gaps: coefficient of variation ≈ 1.
        let mut rng = Rng::new(4);
        let arr = poisson_arrivals(20_000, 50.0, &mut rng);
        let gaps: Vec<f64> = arr.windows(2).map(|w| (w[1] - w[0]) as f64).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.1, "cv={cv}");
    }

    #[test]
    fn schedule_rate_lookup() {
        let s = RateSchedule {
            segments: vec![(0, 10.0), (1000, 50.0), (5000, 20.0)],
        };
        assert_eq!(s.rate_at(0), 10.0);
        assert_eq!(s.rate_at(999), 10.0);
        assert_eq!(s.rate_at(1000), 50.0);
        assert_eq!(s.rate_at(10_000), 20.0);
    }

    #[test]
    fn diurnal_integrates_to_mean_and_swings() {
        let mean = 60.0;
        let period = 600_000; // 10 min
        let s = RateSchedule::diurnal(mean, 3.0, period, 24, 2);
        assert_eq!(s.segments.len(), 48);
        // Exact by midpoint symmetry over full periods.
        assert!((s.mean_rate_over(2 * period) - mean).abs() / mean < 1e-9);
        // Peak and trough match the requested 3:1 ratio (a = 0.5).
        let peak = s.segments.iter().map(|&(_, r)| r).fold(0.0, f64::max);
        let trough = s.segments.iter().map(|&(_, r)| r).fold(f64::INFINITY, f64::min);
        assert!((peak / trough - 3.0).abs() < 0.1, "ratio {}", peak / trough);
        assert!(peak <= mean * 1.5 + 1e-9 && trough >= mean * 0.5 - 1e-9);
    }

    #[test]
    fn schedule_arrivals_strictly_increasing() {
        let s = RateSchedule::diurnal(400.0, 4.0, 60_000, 12, 1);
        let mut rng = Rng::new(11);
        let arr = s.arrivals(20_000, &mut rng);
        assert!(
            arr.windows(2).all(|w| w[0] < w[1]),
            "arrivals must be strictly increasing"
        );
    }

    #[test]
    fn flash_crowd_boundaries_and_mean() {
        let s = RateSchedule::flash_crowd(10.0, 5.0, 10_000, 20_000, 10);
        // base plateau + decay_steps ramp segments + return-to-base.
        assert_eq!(s.segments.len(), 12);
        assert_eq!(s.segments[0], (0, 10.0));
        assert_eq!(s.segments[1].0, 10_000);
        assert_eq!(s.segments.last().unwrap().0, 30_000);
        // Before the spike: base. First ramp step: just under full
        // spike (midpoint of the first decay interval).
        assert_eq!(s.rate_at(9_999), 10.0);
        assert!((s.rate_at(10_000) - 48.0).abs() < 1e-9);
        // After the decay: back to base, forever.
        assert_eq!(s.rate_at(30_000), 10.0);
        assert_eq!(s.rate_at(300_000), 10.0);
        // Midpoint sampling: the ramp integrates exactly as the
        // continuous linear decay — mean over the whole window is
        // base·10s + base·(mult+1)/2·20s over 30 s.
        let expect = (10.0 * 10_000.0 + 30.0 * 20_000.0) / 30_000.0;
        assert!((s.mean_rate_over(30_000) - expect).abs() < 1e-9);
        // Rates never dip below base anywhere on the ramp.
        assert!(s.segments.iter().all(|&(_, r)| r >= 10.0 - 1e-9));
    }

    #[test]
    fn regime_switch_cycles_plateaus() {
        let s = RateSchedule::regime_switch(&[20.0, 80.0], 5_000, 4);
        assert_eq!(s.segments.len(), 5);
        assert_eq!(s.segments[0], (0, 20.0));
        assert_eq!(s.segments[1], (5_000, 80.0));
        assert_eq!(s.segments[4], (20_000, 20.0));
        assert_eq!(s.rate_at(4_999), 20.0);
        assert_eq!(s.rate_at(5_000), 80.0);
        // The last plateau extends forever.
        assert_eq!(s.rate_at(1_000_000), 20.0);
        // One full cycle averages the plateau mean exactly.
        assert!((s.mean_rate_over(10_000) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn schedule_arrivals_change_density() {
        let s = RateSchedule {
            segments: vec![(0, 10.0), (10_000, 100.0)],
        };
        let mut rng = Rng::new(5);
        let arr = s.arrivals(2000, &mut rng);
        let early = arr.iter().filter(|&&t| t < 10_000).count();
        let late_span_s = (*arr.last().unwrap() as f64 - 10_000.0) / 1000.0;
        let late_rate = (arr.len() - early) as f64 / late_span_s;
        assert!((late_rate - 100.0).abs() < 15.0, "late_rate={late_rate}");
    }
}
