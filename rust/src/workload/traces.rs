//! Trace generators matching the paper's Table 1.
//!
//! The real traces (mooncake, lmsys, sharegpt, splitwise) are not
//! shipped in this environment; per the substitution rule we synthesize
//! length distributions whose p25/p50/p75/p90/p95/p99 match Table 1 via
//! monotone piecewise-linear inverse CDFs (`PiecewiseInverseCdf`). The
//! two `uniform_*` traces are exact by construction: §5.2 names
//! uniform_512_512 and uniform_4096_1024 as uniform draws.
//!
//! Input and output lengths are sampled independently — Table 1 gives
//! only marginals, and the schedulers under test read nothing else.

use super::{Request, Workload};
use crate::slo::TierDistribution;
use crate::util::rng::{PiecewiseInverseCdf, Rng};

/// The eight traces of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TraceKind {
    /// Synthetic: uniform 4096-token prompts, 1024-token outputs.
    Uniform4096x1024,
    /// Synthetic: uniform 512-token prompts and outputs.
    Uniform512x512,
    /// Mooncake conversation trace (percentile fit).
    MooncakeConversation,
    /// Mooncake synthetic trace (percentile fit).
    MooncakeSynthetic,
    /// Mooncake tool/agent trace (percentile fit).
    MooncakeToolagent,
    /// LMSYS-Chat trace (percentile fit).
    Lmsys,
    /// ShareGPT trace (percentile fit).
    ShareGpt,
    /// Splitwise trace (percentile fit).
    Splitwise,
}

impl TraceKind {
    /// Every trace kind, in config-name order.
    pub const ALL: [TraceKind; 8] = [
        TraceKind::Uniform4096x1024,
        TraceKind::Uniform512x512,
        TraceKind::MooncakeConversation,
        TraceKind::MooncakeSynthetic,
        TraceKind::MooncakeToolagent,
        TraceKind::Lmsys,
        TraceKind::ShareGpt,
        TraceKind::Splitwise,
    ];

    /// Config/CLI name of this trace.
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Uniform4096x1024 => "uniform_4096_1024",
            TraceKind::Uniform512x512 => "uniform_512_512",
            TraceKind::MooncakeConversation => "mooncake_conversation",
            TraceKind::MooncakeSynthetic => "mooncake_synthetic",
            TraceKind::MooncakeToolagent => "mooncake_toolagent",
            TraceKind::Lmsys => "lmsys",
            TraceKind::ShareGpt => "sharegpt",
            TraceKind::Splitwise => "splitwise",
        }
    }

    /// Parse a config/CLI trace name.
    pub fn from_name(name: &str) -> Option<TraceKind> {
        TraceKind::ALL.iter().copied().find(|t| t.name() == name)
    }

    /// Table 1 input-length percentile knots (p25..p99). `None` for the
    /// uniform traces (exact by construction).
    fn input_knots(&self) -> Option<[f64; 6]> {
        match self {
            TraceKind::Uniform4096x1024 | TraceKind::Uniform512x512 => None,
            TraceKind::MooncakeConversation => {
                Some([2320.0, 6923.0, 15400.0, 27571.0, 39583.0, 85401.0])
            }
            TraceKind::MooncakeSynthetic => {
                Some([277.0, 11587.0, 23286.0, 38737.0, 49009.0, 66458.0])
            }
            TraceKind::MooncakeToolagent => {
                Some([3228.0, 6346.0, 7468.0, 16818.0, 26175.0, 61824.0])
            }
            TraceKind::Lmsys => Some([12.0, 28.0, 82.0, 301.0, 430.0, 750.0]),
            TraceKind::ShareGpt => Some([16.0, 36.0, 158.0, 818.0, 1613.0, 3421.0]),
            TraceKind::Splitwise => Some([396.0, 1019.0, 1186.0, 2735.0, 4083.0, 4142.0]),
        }
    }

    /// Table 1 output-length percentile knots (p25..p99).
    fn output_knots(&self) -> Option<[f64; 6]> {
        match self {
            TraceKind::Uniform4096x1024 | TraceKind::Uniform512x512 => None,
            TraceKind::MooncakeConversation => {
                Some([159.0, 350.0, 472.0, 597.0, 698.0, 1136.0])
            }
            TraceKind::MooncakeSynthetic => Some([10.0, 68.0, 250.0, 390.0, 522.0, 768.0]),
            TraceKind::MooncakeToolagent => Some([12.0, 30.0, 355.0, 506.0, 600.0, 890.0]),
            TraceKind::Lmsys => Some([39.0, 140.0, 338.0, 512.0, 519.0, 853.0]),
            TraceKind::ShareGpt => Some([131.0, 280.0, 445.0, 682.0, 846.0, 1001.0]),
            TraceKind::Splitwise => Some([85.0, 130.0, 395.0, 425.0, 451.0, 601.0]),
        }
    }

    /// Uniform bounds `(input_max, output_max)` for the uniform traces.
    fn uniform_bounds(&self) -> Option<(u32, u32)> {
        match self {
            TraceKind::Uniform4096x1024 => Some((8192, 2048)), // uniform [1, 2·mean]
            TraceKind::Uniform512x512 => Some((1024, 1024)),
            _ => None,
        }
    }
}

const KNOT_QS: [f64; 6] = [0.25, 0.50, 0.75, 0.90, 0.95, 0.99];

/// Samples (prefill, decode) lengths for a trace.
#[derive(Debug, Clone)]
pub struct TraceGenerator {
    /// Which trace this generator samples.
    pub kind: TraceKind,
    input_cdf: Option<PiecewiseInverseCdf>,
    output_cdf: Option<PiecewiseInverseCdf>,
    uniform: Option<(u32, u32)>,
}

impl TraceGenerator {
    /// A generator for the given trace kind.
    pub fn new(kind: TraceKind) -> TraceGenerator {
        let knots = |ks: [f64; 6]| {
            PiecewiseInverseCdf::new(KNOT_QS.iter().copied().zip(ks).collect())
        };
        TraceGenerator {
            kind,
            input_cdf: kind.input_knots().map(knots),
            output_cdf: kind.output_knots().map(knots),
            uniform: kind.uniform_bounds(),
        }
    }

    /// Sample one (prefill_len, decode_len) pair. Lengths are ≥ 1.
    pub fn sample_lengths(&self, rng: &mut Rng) -> (u32, u32) {
        if let Some((imax, omax)) = self.uniform {
            let p = rng.range_u64(1, imax as u64) as u32;
            let d = rng.range_u64(1, omax as u64) as u32;
            return (p, d);
        }
        let p = self.input_cdf.as_ref().unwrap().sample(rng).round().max(1.0) as u32;
        let d = self.output_cdf.as_ref().unwrap().sample(rng).round().max(1.0) as u32;
        (p, d)
    }

    /// Generate a full workload: `n` requests, Poisson arrivals at
    /// `rate_per_s`, SLOs drawn from `tiers` with the paper's
    /// achievability filter (§5.1: "each request is only assigned an SLO
    /// if it is achievable assuming immediate dispatch to an idle
    /// server") supplied by `achievable`.
    pub fn generate(
        &self,
        n: usize,
        rate_per_s: f64,
        tiers: &TierDistribution,
        achievable: impl Fn(u32, u32, crate::slo::Slo) -> bool,
        rng: &mut Rng,
    ) -> Workload {
        let mut requests = Vec::with_capacity(n);
        let mut t_ms = 0.0f64;
        for id in 0..n {
            t_ms += rng.exp(rate_per_s) * 1000.0;
            let (p, d) = self.sample_lengths(rng);
            let slo = draw_achievable_slo(tiers, p, d, &achievable, rng);
            requests.push(Request {
                id: id as u64,
                arrival_ms: t_ms as u64,
                prefill_len: p,
                decode_len: d,
                slo,
                model: 0,
            });
        }
        Workload { requests }
    }

    /// Generate a workload with externally supplied arrival timestamps
    /// (e.g. a diurnal [`crate::workload::RateSchedule`]); lengths and
    /// SLOs are drawn exactly as in [`TraceGenerator::generate`].
    pub fn generate_with_arrivals(
        &self,
        arrivals: &[crate::slo::TimeMs],
        tiers: &TierDistribution,
        achievable: impl Fn(u32, u32, crate::slo::Slo) -> bool,
        rng: &mut Rng,
    ) -> Workload {
        let mut requests = Vec::with_capacity(arrivals.len());
        for (id, &arrival_ms) in arrivals.iter().enumerate() {
            let (p, d) = self.sample_lengths(rng);
            let slo = draw_achievable_slo(tiers, p, d, &achievable, rng);
            requests.push(Request {
                id: id as u64,
                arrival_ms,
                prefill_len: p,
                decode_len: d,
                slo,
                model: 0,
            });
        }
        Workload { requests }
    }
}

/// §5.1 SLO assignment: resample the SLO (not the lengths) until the
/// achievability filter accepts it; give up after 32 tries and take
/// best effort. Shared by every workload generator so constant-rate
/// and scheduled arrivals get identical SLO policy.
fn draw_achievable_slo(
    tiers: &TierDistribution,
    p: u32,
    d: u32,
    achievable: &impl Fn(u32, u32, crate::slo::Slo) -> bool,
    rng: &mut Rng,
) -> crate::slo::Slo {
    let mut slo = tiers.sample(rng);
    let mut tries = 0;
    while !achievable(p, d, slo) && tries < 32 {
        slo = tiers.sample(rng);
        tries += 1;
    }
    if !achievable(p, d, slo) {
        slo = crate::slo::Slo::BEST_EFFORT;
    }
    slo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    fn lengths(kind: TraceKind, n: usize) -> (Vec<f64>, Vec<f64>) {
        let g = TraceGenerator::new(kind);
        let mut rng = Rng::new(1234);
        let mut ps = Vec::with_capacity(n);
        let mut ds = Vec::with_capacity(n);
        for _ in 0..n {
            let (p, d) = g.sample_lengths(&mut rng);
            ps.push(p as f64);
            ds.push(d as f64);
        }
        (ps, ds)
    }

    #[test]
    fn uniform_4096_1024_matches_table1() {
        // Table 1 row: input p50 ≈ 4093, output p50 ≈ 1023.
        let (ps, ds) = lengths(TraceKind::Uniform4096x1024, 100_000);
        let sp = Summary::of(&ps);
        let sd = Summary::of(&ds);
        assert!((sp.p50() - 4096.0).abs() < 100.0, "input p50 = {}", sp.p50());
        assert!((sd.p50() - 1024.0).abs() < 30.0, "output p50 = {}", sd.p50());
        assert!(sp.max <= 8192.0 && sp.min >= 1.0);
    }

    #[test]
    fn sharegpt_percentiles_match_table1() {
        let (ps, ds) = lengths(TraceKind::ShareGpt, 200_000);
        let sp = Summary::of(&ps);
        let sd = Summary::of(&ds);
        // Table 1 sharegpt input: 16/36/158/818/1613/3421
        let want_in = [16.0, 36.0, 158.0, 818.0, 1613.0, 3421.0];
        for (got, want) in sp.percentiles.iter().zip(&want_in) {
            let rel = (got - want).abs() / want;
            assert!(rel < 0.08, "input percentiles {:?} vs {want_in:?}", sp.percentiles);
        }
        let want_out = [131.0, 280.0, 445.0, 682.0, 846.0, 1001.0];
        for (got, want) in sd.percentiles.iter().zip(&want_out) {
            let rel = (got - want).abs() / want;
            assert!(rel < 0.08, "output percentiles {:?} vs {want_out:?}", sd.percentiles);
        }
    }

    #[test]
    fn mooncake_conversation_long_tail() {
        let (ps, _) = lengths(TraceKind::MooncakeConversation, 100_000);
        let s = Summary::of(&ps);
        assert!((s.percentiles[1] - 6923.0).abs() / 6923.0 < 0.08, "p50={}", s.percentiles[1]);
        assert!((s.percentiles[5] - 85401.0).abs() / 85401.0 < 0.10, "p99={}", s.percentiles[5]);
    }

    #[test]
    fn all_traces_generate_positive_lengths() {
        for kind in TraceKind::ALL {
            let (ps, ds) = lengths(kind, 2000);
            assert!(ps.iter().all(|&x| x >= 1.0), "{kind:?}");
            assert!(ds.iter().all(|&x| x >= 1.0), "{kind:?}");
        }
    }

    #[test]
    fn name_roundtrip() {
        for kind in TraceKind::ALL {
            assert_eq!(TraceKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(TraceKind::from_name("bogus"), None);
    }

    #[test]
    fn generate_workload_sorted_and_rated() {
        let g = TraceGenerator::new(TraceKind::Lmsys);
        let mut rng = Rng::new(7);
        let tiers = TierDistribution::paper_default();
        let w = g.generate(5000, 100.0, &tiers, |_, _, _| true, &mut rng);
        assert_eq!(w.len(), 5000);
        assert!(w.requests.windows(2).all(|r| r[0].arrival_ms <= r[1].arrival_ms));
        assert!((w.rate_per_s() - 100.0).abs() < 5.0, "rate={}", w.rate_per_s());
    }

    #[test]
    fn achievability_filter_falls_back_to_best_effort() {
        let g = TraceGenerator::new(TraceKind::Lmsys);
        let mut rng = Rng::new(8);
        let tiers = TierDistribution::paper_default();
        // Nothing is achievable → everything becomes best-effort.
        let w = g.generate(100, 10.0, &tiers, |_, _, _| false, &mut rng);
        assert!(w.requests.iter().all(|r| r.slo.is_best_effort()));
    }
}
