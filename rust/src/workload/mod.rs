//! Workload synthesis: requests, traces, arrival processes, SLO
//! assignment — everything §5.1 of the paper specifies.

pub mod traces;
pub mod arrivals;

pub use traces::{TraceKind, TraceGenerator};
pub use arrivals::{poisson_arrivals, RateSchedule};

use crate::model::ModelId;
use crate::slo::{Slo, TimeMs};
use crate::util::rng::Rng;

/// Unique request id.
pub type RequestId = u64;

/// A serving request as the router sees it.
#[derive(Debug, Clone)]
pub struct Request {
    /// Unique request id.
    pub id: RequestId,
    /// Arrival time, ms.
    pub arrival_ms: TimeMs,
    /// Prompt length in tokens (the paper's `p`).
    pub prefill_len: u32,
    /// Output length in tokens (the paper's `d`). Known to the
    /// *simulator* for ground truth; the router must not read it and
    /// instead predicts with the tier average (§4.5).
    pub decode_len: u32,
    /// The request's sampled SLO.
    pub slo: Slo,
    /// Which registered model this request targets. Always 0 in
    /// single-model configurations; assigned by
    /// [`Workload::assign_model_mix`] for model-mix workloads.
    pub model: ModelId,
}

impl Request {
    /// KV tokens resident at the *end* of this request's life.
    pub fn max_kv_tokens(&self) -> u64 {
        self.prefill_len as u64 + self.decode_len as u64
    }

    /// The paper's per-request average KV footprint over the decode
    /// phase: `p + d/2`.
    pub fn avg_kv_tokens(&self) -> u64 {
        self.prefill_len as u64 + self.decode_len as u64 / 2
    }
}

/// A complete workload: requests sorted by arrival time.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    /// Requests in arrival order.
    pub requests: Vec<Request>,
}

impl Workload {
    /// Number of requests.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the workload holds no requests.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Duration from first to last arrival, ms.
    pub fn span_ms(&self) -> TimeMs {
        match (self.requests.first(), self.requests.last()) {
            (Some(a), Some(b)) => b.arrival_ms - a.arrival_ms,
            _ => 0,
        }
    }

    /// Mean request rate (req/s) implied by the arrivals.
    pub fn rate_per_s(&self) -> f64 {
        if self.requests.len() < 2 || self.span_ms() == 0 {
            return 0.0;
        }
        (self.requests.len() - 1) as f64 / (self.span_ms() as f64 / 1000.0)
    }

    /// Average decode length — the router's output-length predictor
    /// (§4.5 uses the average decode length instead of per-request
    /// prediction).
    pub fn avg_decode_len(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.decode_len as f64).sum::<f64>()
            / self.requests.len() as f64
    }

    /// Average decode length of requests targeting `model` (falls back
    /// to the global average when the model has no requests) — the
    /// per-model output-length predictor for model-mix routing.
    pub fn avg_decode_len_of(&self, model: ModelId) -> f64 {
        let (mut sum, mut n) = (0.0f64, 0usize);
        for r in &self.requests {
            if r.model == model {
                sum += r.decode_len as f64;
                n += 1;
            }
        }
        if n == 0 {
            self.avg_decode_len()
        } else {
            sum / n as f64
        }
    }

    /// Request count per model id in `0..num_models`.
    pub fn model_counts(&self, num_models: usize) -> Vec<usize> {
        let mut counts = vec![0usize; num_models.max(1)];
        for r in &self.requests {
            if r.model < counts.len() {
                counts[r.model] += 1;
            }
        }
        counts
    }

    /// Assign each request a model id sampled i.i.d. from `weights`
    /// (one weight per registered model, normalized internally).
    /// Single-model configurations never call this — every request
    /// keeps the default model 0 and the workload bytes are untouched,
    /// which is what keeps those runs bit-for-bit identical.
    pub fn assign_model_mix(&mut self, weights: &[f64], rng: &mut Rng) {
        if weights.len() <= 1 {
            return;
        }
        for r in &mut self.requests {
            r.model = rng.categorical(weights);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(arrival: TimeMs, p: u32, d: u32) -> Request {
        Request {
            id: 0,
            arrival_ms: arrival,
            prefill_len: p,
            decode_len: d,
            slo: Slo::new(1000, 50),
            model: 0,
        }
    }

    #[test]
    fn kv_footprints() {
        let r = req(0, 1000, 4000);
        assert_eq!(r.max_kv_tokens(), 5000);
        assert_eq!(r.avg_kv_tokens(), 3000);
    }

    #[test]
    fn workload_rate() {
        let w = Workload {
            requests: vec![req(0, 1, 1), req(500, 1, 1), req(1000, 1, 1)],
        };
        assert_eq!(w.span_ms(), 1000);
        assert!((w.rate_per_s() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn model_mix_assignment() {
        let mut w = Workload {
            requests: (0..1000).map(|i| req(i, 1, 1)).collect(),
        };
        // No-op for a single-model mix.
        w.assign_model_mix(&[1.0], &mut Rng::new(7));
        assert!(w.requests.iter().all(|r| r.model == 0));
        w.assign_model_mix(&[0.7, 0.3], &mut Rng::new(7));
        let counts = w.model_counts(2);
        assert_eq!(counts[0] + counts[1], 1000);
        assert!((150..450).contains(&counts[1]), "{counts:?}");
        assert!((w.avg_decode_len_of(1) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn avg_decode_len() {
        let w = Workload {
            requests: vec![req(0, 1, 100), req(1, 1, 300)],
        };
        assert!((w.avg_decode_len() - 200.0).abs() < 1e-9);
    }
}
