//! AOT artifact store: the manifest + weights written by
//! `python/compile/aot.py`.
//!
//! The manifest is the ABI contract between build-time Python and the
//! serving-time Rust binary: model dims, shape buckets, executable
//! files, and the weight-tensor table (name/shape/offset into
//! `weights.bin`, f32 little-endian).

use crate::util::json::Json;
use anyhow::{ensure, Context};
use std::path::{Path, PathBuf};

/// One lowered executable (decode step or prefill chunk).
#[derive(Debug, Clone)]
pub struct ExecutableEntry {
    /// Which phase/bucket this executable serves.
    pub kind: ExecKind,
    /// Path to the serialized executable.
    pub file: PathBuf,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecKind {
    /// Decode step for a batch bucket.
    Decode { batch: usize },
    /// Prefill chunk for a chunk-size bucket.
    Prefill { chunk: usize },
}

/// A weight tensor's location in `weights.bin`.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    /// Parameter name (ABI order key).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Byte offset into the weight blob.
    pub offset: usize,
    /// Byte length in the weight blob.
    pub bytes: usize,
}

/// Model dims as recorded by the manifest (mirror of
/// `python/compile/model.py::ModelConfig`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestModel {
    /// Model name the artifacts were lowered from.
    pub name: String,
    /// Transformer layer count.
    pub num_layers: usize,
    /// Residual-stream width.
    pub hidden: usize,
    /// Query heads.
    pub num_q_heads: usize,
    /// KV heads (GQA).
    pub num_kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// FFN inner width.
    pub ffn_hidden: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Max sequence length the buckets were compiled for.
    pub max_seq_len: usize,
}

/// Parsed `artifacts/manifest.json` plus loaded weights.
#[derive(Debug)]
pub struct ArtifactStore {
    /// Artifact directory root.
    pub dir: PathBuf,
    /// Model description from the manifest.
    pub model: ManifestModel,
    /// Compiled decode batch buckets.
    pub decode_buckets: Vec<usize>,
    /// Compiled prefill chunk buckets.
    pub prefill_buckets: Vec<usize>,
    /// Every compiled executable.
    pub executables: Vec<ExecutableEntry>,
    /// Weight-blob layout entries.
    pub weights: Vec<WeightEntry>,
    /// Raw weights.bin contents (f32le, ABI order).
    pub weight_data: Vec<u8>,
}

impl ArtifactStore {
    /// Load and validate the artifact directory.
    pub fn open(dir: &Path) -> anyhow::Result<ArtifactStore> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

        let m = j.get("model").context("manifest missing 'model'")?;
        let get = |key: &str| -> anyhow::Result<usize> {
            m.get(key)
                .and_then(Json::as_usize)
                .with_context(|| format!("model.{key} missing"))
        };
        let model = ManifestModel {
            name: m
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            num_layers: get("num_layers")?,
            hidden: get("hidden")?,
            num_q_heads: get("num_q_heads")?,
            num_kv_heads: get("num_kv_heads")?,
            head_dim: get("head_dim")?,
            ffn_hidden: get("ffn_hidden")?,
            vocab: get("vocab")?,
            max_seq_len: get("max_seq_len")?,
        };

        let buckets = |key: &str| -> Vec<usize> {
            j.get(key)
                .and_then(Json::to_f64s)
                .unwrap_or_default()
                .into_iter()
                .map(|x| x as usize)
                .collect()
        };
        let decode_buckets = buckets("decode_batch_buckets");
        let prefill_buckets = buckets("prefill_chunk_buckets");
        ensure!(!decode_buckets.is_empty(), "no decode buckets in manifest");

        let mut executables = Vec::new();
        for e in j
            .get("executables")
            .and_then(Json::as_arr)
            .context("manifest missing executables")?
        {
            let file = dir.join(
                e.get("file")
                    .and_then(Json::as_str)
                    .context("executable missing file")?,
            );
            ensure!(file.exists(), "missing artifact {}", file.display());
            let kind = match e.get("kind").and_then(Json::as_str) {
                Some("decode") => ExecKind::Decode {
                    batch: e.get("batch").and_then(Json::as_usize).context("batch")?,
                },
                Some("prefill") => ExecKind::Prefill {
                    chunk: e.get("chunk").and_then(Json::as_usize).context("chunk")?,
                },
                other => anyhow::bail!("unknown executable kind {other:?}"),
            };
            executables.push(ExecutableEntry { kind, file });
        }

        let w = j.get("weights").context("manifest missing weights")?;
        let weights_file = dir.join(
            w.get("file")
                .and_then(Json::as_str)
                .context("weights.file")?,
        );
        let weight_data = std::fs::read(&weights_file)
            .with_context(|| format!("reading {}", weights_file.display()))?;
        let mut weights = Vec::new();
        for t in w
            .get("tensors")
            .and_then(Json::as_arr)
            .context("weights.tensors")?
        {
            let entry = WeightEntry {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .context("tensor name")?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::to_f64s)
                    .context("tensor shape")?
                    .into_iter()
                    .map(|x| x as usize)
                    .collect(),
                offset: t.get("offset").and_then(Json::as_usize).context("offset")?,
                bytes: t.get("bytes").and_then(Json::as_usize).context("bytes")?,
            };
            ensure!(
                entry.offset + entry.bytes <= weight_data.len(),
                "weight {} out of bounds",
                entry.name
            );
            let expect: usize = entry.shape.iter().product::<usize>() * 4;
            ensure!(
                expect == entry.bytes,
                "weight {} shape/bytes mismatch",
                entry.name
            );
            weights.push(entry);
        }
        ensure!(!weights.is_empty(), "empty weight table");

        Ok(ArtifactStore {
            dir: dir.to_path_buf(),
            model,
            decode_buckets,
            prefill_buckets,
            executables,
            weights,
            weight_data,
        })
    }

    /// Weight tensor values as f32 (copy).
    pub fn weight_f32(&self, entry: &WeightEntry) -> Vec<f32> {
        let raw = &self.weight_data[entry.offset..entry.offset + entry.bytes];
        raw.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    /// Smallest decode bucket that fits `batch` live requests.
    pub fn decode_bucket_for(&self, batch: usize) -> Option<usize> {
        self.decode_buckets.iter().copied().find(|&b| b >= batch)
    }

    /// Smallest prefill bucket that fits `chunk` tokens.
    pub fn prefill_bucket_for(&self, chunk: usize) -> Option<usize> {
        self.prefill_buckets.iter().copied().find(|&b| b >= chunk)
    }

    /// The manifest's executable entry of kind `kind`, if present.
    pub fn find_exec(&self, kind: ExecKind) -> Option<&ExecutableEntry> {
        self.executables.iter().find(|e| e.kind == kind)
    }

    /// KV-cache shape for a decode bucket:
    /// `[layers, batch, max_seq, kv_heads, head_dim]`.
    pub fn kv_shape_decode(&self, batch: usize) -> [usize; 5] {
        [
            self.model.num_layers,
            batch,
            self.model.max_seq_len,
            self.model.num_kv_heads,
            self.model.head_dim,
        ]
    }

    /// KV-cache shape for one request's prefill:
    /// `[layers, max_seq, kv_heads, head_dim]`.
    pub fn kv_shape_prefill(&self) -> [usize; 4] {
        [
            self.model.num_layers,
            self.model.max_seq_len,
            self.model.num_kv_heads,
            self.model.head_dim,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if d.join("manifest.json").exists() {
            Some(d)
        } else {
            None
        }
    }

    #[test]
    fn opens_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let store = ArtifactStore::open(&dir).unwrap();
        assert_eq!(store.model.name, "polyserve-small");
        assert_eq!(store.model.num_layers, 4);
        assert!(!store.executables.is_empty());
        // ABI: per-layer weights + final_norm + embedding.
        assert_eq!(store.weights.len(), store.model.num_layers * 9 + 2);
        // Embedding is last and shaped [vocab, hidden].
        let emb = store.weights.last().unwrap();
        assert_eq!(emb.name, "embedding");
        assert_eq!(emb.shape, vec![store.model.vocab, store.model.hidden]);
        let vals = store.weight_f32(emb);
        assert_eq!(vals.len(), store.model.vocab * store.model.hidden);
        assert!(vals.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn bucket_selection() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ArtifactStore::open(&dir).unwrap();
        assert_eq!(store.decode_bucket_for(1), Some(1));
        assert_eq!(store.decode_bucket_for(3), Some(4));
        assert_eq!(store.decode_bucket_for(8), Some(8));
        assert_eq!(store.decode_bucket_for(9), None);
        assert_eq!(store.prefill_bucket_for(10), Some(64));
        assert_eq!(store.prefill_bucket_for(65), Some(128));
    }

    #[test]
    fn missing_dir_errors() {
        assert!(ArtifactStore::open(Path::new("/nonexistent/zzz")).is_err());
    }
}
