//! Real-hardware profiling: measure (batch, KV length) → iteration time
//! on the actual PJRT executables, producing the same `ProfileTable`
//! the scheduler consumes in simulation — the live-server analogue of
//! the paper's vLLM kernel profiling (§4.5).

use super::artifacts::ArtifactStore;
use super::engine::Engine;
use crate::profile::ProfileTable;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

/// Measure a profiling table from the AOT artifacts in `dir`.
///
/// Grid: every decode batch bucket × a KV-length grid up to the model's
/// max sequence length. Each cell runs a few warmup + timed decode
/// steps with synthetic KV of the right length.
pub fn profile_real(dir: &Path) -> anyhow::Result<ProfileTable> {
    let store = Rc::new(ArtifactStore::open(dir)?);
    let engine = Engine::load(Rc::clone(&store))?;
    let max_len = store.model.max_seq_len;
    let batch_grid: Vec<u64> = store.decode_buckets.iter().map(|&b| b as u64).collect();
    let kv_grid: Vec<u64> = [1usize, max_len / 8, max_len / 4, max_len / 2, max_len - 2]
        .iter()
        .map(|&x| x.max(1) as u64)
        .collect();
    let mut times = Vec::with_capacity(batch_grid.len() * kv_grid.len());
    for &b in &batch_grid {
        for &kv_len in &kv_grid {
            times.push(measure_cell(&engine, b as usize, kv_len as usize)?);
        }
    }
    // Capacity: per-instance KV tokens = buckets_max × max_seq.
    let cap = (*store.decode_buckets.iter().max().unwrap() * max_len) as u64;
    Ok(ProfileTable::from_measurements(
        batch_grid,
        kv_grid.iter().map(|&kv| kv * 1).collect(),
        times,
        cap,
        *store.decode_buckets.iter().max().unwrap() as u64,
    ))
}

fn measure_cell(engine: &Engine, batch: usize, kv_len: usize) -> anyhow::Result<f64> {
    // Build synthetic KV states at the target length.
    let mut states: Vec<_> = (0..batch)
        .map(|i| {
            let mut kv = engine.new_kv();
            kv.kv_len = kv_len;
            kv.last_token = (i % engine.store.model.vocab) as i32;
            // Fill the valid prefix with small values so softmax is sane.
            for x in kv.k.iter_mut().take(kv_len * 64) {
                *x = 0.01;
            }
            kv
        })
        .collect();
    let warmup = 2;
    let iters = 5;
    for _ in 0..warmup {
        let mut refs: Vec<&mut _> = states.iter_mut().collect();
        engine.decode_step(&mut refs)?;
        for s in states.iter_mut() {
            s.kv_len = kv_len; // reset growth
        }
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        let mut refs: Vec<&mut _> = states.iter_mut().collect();
        engine.decode_step(&mut refs)?;
        for s in states.iter_mut() {
            s.kv_len = kv_len;
        }
    }
    Ok(t0.elapsed().as_secs_f64() * 1000.0 / iters as f64)
}
