//! PJRT execution engine: one *serving instance* backed by the AOT
//! HLO-text executables.
//!
//! Mirrors `/opt/xla-example/load_hlo`: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. One
//! compiled executable per shape bucket (decode batch ∈ {1,2,4,8},
//! prefill chunk ∈ {64,128}); the engine owns the per-request KV caches
//! host-side and slots them into the bucket's batch layout each step.
//!
//! ABI (see `python/compile/aot.py`):
//! * decode:  `(tokens[i32,B], kv_lens[i32,B], k[f32,L,B,S,H,D],
//!   v[...], weights...) -> (next_tokens[i32,B], k', v')`
//! * prefill: `(tokens[i32,T], start_pos[i32], chunk_len[i32],
//!   k[f32,L,S,H,D], v[...], weights...) -> (first_token[i32], k', v')`

use super::artifacts::{ArtifactStore, ExecKind};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::rc::Rc;

/// Per-request decoding state held by the engine (host side).
#[derive(Debug)]
pub struct KvState {
    /// `[L, S, H, D]` flattened KV for this request.
    pub k: Vec<f32>,
    /// `[L, S, H, D]` flattened V cache for this request.
    pub v: Vec<f32>,
    /// Valid prefix length (prompt + decoded so far).
    pub kv_len: usize,
    /// Last emitted token (input to the next decode step).
    pub last_token: i32,
}

/// A compiled serving instance.
pub struct Engine {
    /// The artifact store the engine executes from.
    pub store: Rc<ArtifactStore>,
    client: xla::PjRtClient,
    decode_execs: HashMap<usize, xla::PjRtLoadedExecutable>,
    prefill_execs: HashMap<usize, xla::PjRtLoadedExecutable>,
    /// Weight literals in ABI order (shared across calls).
    weight_literals: Vec<xla::Literal>,
}

impl Engine {
    /// Compile every bucket of the artifact store on the CPU PJRT client.
    pub fn load(store: Rc<ArtifactStore>) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut decode_execs = HashMap::new();
        let mut prefill_execs = HashMap::new();
        for e in &store.executables {
            let proto = xla::HloModuleProto::from_text_file(
                e.file.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", e.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exec = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", e.file.display()))?;
            match e.kind {
                ExecKind::Decode { batch } => {
                    decode_execs.insert(batch, exec);
                }
                ExecKind::Prefill { chunk } => {
                    prefill_execs.insert(chunk, exec);
                }
            }
        }
        let weight_literals = store
            .weights
            .iter()
            .map(|w| {
                let vals = store.weight_f32(w);
                let dims: Vec<i64> = w.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&vals).reshape(&dims).map_err(|e| anyhow::anyhow!("{e}"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Engine {
            store,
            client,
            decode_execs,
            prefill_execs,
            weight_literals,
        })
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fresh empty KV state for a request.
    pub fn new_kv(&self) -> KvState {
        let [l, s, h, d] = self.store.kv_shape_prefill();
        KvState {
            k: vec![0.0; l * s * h * d],
            v: vec![0.0; l * s * h * d],
            kv_len: 0,
            last_token: 0,
        }
    }

    /// Run one prefill chunk for a single request. `tokens` is the
    /// chunk slice (un-padded); the engine pads to the bucket. On the
    /// final chunk (`kv_len + tokens.len() == prompt_len`) the returned
    /// token is the request's first output token.
    pub fn prefill_chunk(&self, kv: &mut KvState, tokens: &[i32]) -> Result<i32> {
        let n = tokens.len();
        let bucket = self
            .store
            .prefill_bucket_for(n)
            .with_context(|| format!("chunk {n} exceeds buckets"))?;
        let exec = self
            .prefill_execs
            .get(&bucket)
            .with_context(|| format!("no prefill exec for bucket {bucket}"))?;
        let mut padded = vec![0i32; bucket];
        padded[..n].copy_from_slice(tokens);
        let [l, s, h, d] = self.store.kv_shape_prefill();
        let kv_dims = [l as i64, s as i64, h as i64, d as i64];

        let tok_lit = xla::Literal::vec1(&padded);
        let start_lit = xla::Literal::scalar(kv.kv_len as i32);
        let len_lit = xla::Literal::scalar(n as i32);
        let k_lit = xla::Literal::vec1(&kv.k).reshape(&kv_dims)?;
        let v_lit = xla::Literal::vec1(&kv.v).reshape(&kv_dims)?;

        let inputs = [tok_lit, start_lit, len_lit, k_lit, v_lit];
        let args: Vec<&xla::Literal> =
            inputs.iter().chain(self.weight_literals.iter()).collect();
        let result = exec.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (first_token, k_new, v_new) = result.to_tuple3()?;
        kv.k = k_new.to_vec::<f32>()?;
        kv.v = v_new.to_vec::<f32>()?;
        kv.kv_len += n;
        let t = first_token.to_vec::<i32>()?[0];
        kv.last_token = t;
        Ok(t)
    }

    /// Run one decode step for a batch of requests. Each request's KV
    /// is slotted into the bucket layout; rows beyond `reqs.len()` are
    /// dummies. Returns the next token per request and updates KV.
    pub fn decode_step(&self, reqs: &mut [&mut KvState]) -> Result<Vec<i32>> {
        let n = reqs.len();
        anyhow::ensure!(n > 0, "empty decode batch");
        let bucket = self
            .store
            .decode_bucket_for(n)
            .with_context(|| format!("batch {n} exceeds buckets"))?;
        let exec = self
            .decode_execs
            .get(&bucket)
            .with_context(|| format!("no decode exec for bucket {bucket}"))?;
        let [l, b, s, h, d] = self.store.kv_shape_decode(bucket);
        debug_assert_eq!(b, bucket);
        let row = s * h * d; // per (layer, request) KV stride

        let mut tokens = vec![0i32; bucket];
        let mut kv_lens = vec![1i32; bucket]; // dummy rows: len 1, safe
        let mut k = vec![0.0f32; l * bucket * row];
        let mut v = vec![0.0f32; l * bucket * row];
        for (i, r) in reqs.iter().enumerate() {
            tokens[i] = r.last_token;
            kv_lens[i] = r.kv_len as i32;
            for layer in 0..l {
                let dst = layer * bucket * row + i * row;
                let src = layer * row;
                k[dst..dst + row].copy_from_slice(&r.k[src..src + row]);
                v[dst..dst + row].copy_from_slice(&r.v[src..src + row]);
            }
        }
        let kv_dims = [l as i64, bucket as i64, s as i64, h as i64, d as i64];
        let inputs = [
            xla::Literal::vec1(&tokens),
            xla::Literal::vec1(&kv_lens),
            xla::Literal::vec1(&k).reshape(&kv_dims)?,
            xla::Literal::vec1(&v).reshape(&kv_dims)?,
        ];
        let args: Vec<&xla::Literal> =
            inputs.iter().chain(self.weight_literals.iter()).collect();
        let result = exec.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (next, k_new, v_new) = result.to_tuple3()?;
        let next = next.to_vec::<i32>()?;
        let k_new = k_new.to_vec::<f32>()?;
        let v_new = v_new.to_vec::<f32>()?;
        for (i, r) in reqs.iter_mut().enumerate() {
            for layer in 0..l {
                let src = layer * bucket * row + i * row;
                let dst = layer * row;
                r.k[dst..dst + row].copy_from_slice(&k_new[src..src + row]);
                r.v[dst..dst + row].copy_from_slice(&v_new[src..src + row]);
            }
            r.kv_len += 1;
            r.last_token = next[i];
        }
        Ok(next[..n].to_vec())
    }

    /// Full prefill of a prompt via chunked prefill; returns the first
    /// output token.
    pub fn prefill(&self, kv: &mut KvState, prompt: &[i32]) -> Result<i32> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt.len() + 1 < self.store.model.max_seq_len,
            "prompt too long"
        );
        let max_chunk = *self.store.prefill_buckets.iter().max().unwrap();
        let mut first = 0i32;
        let mut pos = 0;
        while pos < prompt.len() {
            let n = (prompt.len() - pos).min(max_chunk);
            first = self.prefill_chunk(kv, &prompt[pos..pos + n])?;
            pos += n;
        }
        Ok(first)
    }
}
