//! PJRT runtime: loading and executing the AOT model artifacts.
//!
//! * [`artifacts`] — manifest + weights ABI with `python/compile/aot.py`.
//! * [`engine`] — compiled per-bucket executables, KV management,
//!   prefill/decode steps.
//! * [`profiler`] — measures a real (batch, KV) → iteration-time
//!   profiling table from the compiled executables, the live-server
//!   analogue of the paper's vLLM kernel profiling.

pub mod artifacts;
pub mod engine;
pub mod profiler;

pub use artifacts::{ArtifactStore, ExecKind};
pub use engine::{Engine, KvState};
