//! One simulated serving instance: batch state, KV accounting, and the
//! iteration mechanics shared by every policy.
//!
//! Policies differ only in *where* requests are queued (routing) and
//! *how much* prefill each iteration may carry (`chunk_budget`); the
//! mechanics here are common:
//!
//! * All running decode requests generate one token per iteration
//!   (continuous batching, §2.4) — unless paused by KV pressure.
//! * The prefill queue contributes up to `budget` chunk tokens per
//!   iteration (chunked prefill); on a PD prefill server the budget is
//!   the whole token batch.
//! * Iteration duration = CostModel ground truth, quantized to 1 ms.

use super::SimRequest;
use crate::model::{CostModel, ModelId};
use crate::slo::TimeMs;
use std::collections::VecDeque;

/// Instance role in the serving architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// PD-disaggregation prefill server.
    Prefill,
    /// PD-disaggregation decode server.
    Decode,
    /// Chunked-prefill co-located server.
    Coloc,
}

/// Fleet-level lifecycle state of an instance (the elastic-fleet
/// machinery; a fixed fleet keeps every instance `Active` forever).
///
/// `Provisioning → Active → Draining → Retired`; only `Active`
/// instances accept new work. A `Draining` instance finishes its
/// resident requests (decode streams, queued prefills) and is retired
/// by the simulator once empty. `Retired` instances stay in
/// `Cluster::instances` (ids are stable indices) but are invisible to
/// every placement path and stop accruing cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    /// Cold-starting; becomes `Active` at `ready_at` (`InstanceReady`).
    Provisioning { ready_at: TimeMs },
    /// Serving normally.
    Active,
    /// Finishing resident requests; accepts nothing new.
    Draining { since: TimeMs },
    /// Decommissioned at `at`; never serves again.
    Retired { at: TimeMs },
}

impl Lifecycle {
    /// May this instance be handed *new* work?
    #[inline]
    pub fn accepts_work(&self) -> bool {
        matches!(self, Lifecycle::Active)
    }

    /// Is this instance billable fleet capacity (anything but retired)?
    #[inline]
    pub fn is_live(&self) -> bool {
        !matches!(self, Lifecycle::Retired { .. })
    }
}

/// A queued prefill job (request awaiting prompt processing here).
#[derive(Debug, Clone, Copy)]
pub struct PrefillJob {
    /// Index into the simulation's request vector.
    pub req_idx: usize,
    /// TTFT deadline (arrival + TTFT) — used for EDF ordering.
    pub deadline: TimeMs,
}

/// A decode-phase request resident on this instance.
#[derive(Debug, Clone, Copy)]
pub struct RunningReq {
    /// Index into the simulation's request vector.
    pub req_idx: usize,
    /// Paused by KV pressure this iteration (no token generated).
    pub paused: bool,
}

/// Per-iteration batch composition (what `form_batch` decided).
#[derive(Debug, Clone, Default)]
pub struct IterationBatch {
    /// Decode tokens this iteration (= active decode requests).
    pub b_decode: u64,
    /// Prefill chunk tokens this iteration.
    pub b_prefill: u64,
    /// (req_idx, tokens) prefill slices in this iteration.
    pub prefill_slices: Vec<(usize, u32)>,
    /// KV tokens resident during the iteration.
    pub kv_tokens: u64,
}

/// One serving instance.
#[derive(Debug, Clone)]
pub struct Instance {
    /// Stable instance id (index into `Cluster::instances`).
    pub id: usize,
    /// Serving role (prefill / decode / coloc).
    pub role: Role,
    /// Which registry model is loaded here. A hard placement
    /// constraint: only requests of the same model may be routed to
    /// this instance. Always 0 in single-model fleets.
    pub model: ModelId,
    /// Pending model swap: set when an autoscaler ordered this
    /// instance to reload as another model. The instance drains first;
    /// once empty the simulator calls
    /// [`crate::sim::Cluster::complete_swap`], which re-provisions it
    /// as `swap_to` after the reload delay.
    pub swap_to: Option<ModelId>,
    /// Elastic-fleet lifecycle state (`Active` for fixed fleets).
    pub lifecycle: Lifecycle,
    /// Simulated time this instance was provisioned (0 for the initial
    /// fleet) — the start of its active-instance-second billing window.
    pub born_ms: TimeMs,
    /// Spot-market capacity: bills at a discounted rate and may receive
    /// a `PreemptNotice` (deadline drain, then hard failure). Always
    /// false without a `[chaos]` spot fraction; the initial fleet is
    /// on-demand.
    pub spot: bool,
    /// Failure domain `(zone, rack)` this instance lives in. Assigned
    /// by a deterministic stride over instance ids when `[chaos]
    /// zones` > 0 (see `Simulation`); `(0, 0)` otherwise. A
    /// `ChaosFailDomain` draw kills every live instance sharing the
    /// drawn rack or zone in one event.
    pub domain: (u32, u32),
    /// Decode-phase requests resident (their KV lives here).
    pub running: Vec<RunningReq>,
    /// Requests queued for (chunked) prefill on this instance.
    pub prefill_queue: VecDeque<PrefillJob>,
    /// PD decode handoffs: (req_idx, ready_time) — KV still in flight
    /// until `ready_time`.
    pub decode_queue: VecDeque<(usize, TimeMs)>,
    /// Scale-in migration: when this drainer was told to migrate, any
    /// decode request that becomes resident later (e.g. a coloc prefill
    /// completing mid-drain) is evicted too instead of decoded here.
    pub migrate_on_drain: bool,
    /// Scale-in migration: evicted residents' KV is still streaming off
    /// this instance until then — it may not retire (or stop billing)
    /// earlier.
    pub egress_until: TimeMs,
    /// begin_drain → retire latency, recorded at retirement.
    pub drain_latency_ms: Option<u64>,
    /// Mid-iteration state.
    pub iterating: bool,
    /// When the in-flight iteration completes.
    pub busy_until: TimeMs,
    /// Composition of the in-flight iteration.
    pub current: IterationBatch,
    /// Lifetime counters.
    pub busy_ms_total: u64,
    /// Iterations completed over the instance's lifetime.
    pub iterations_total: u64,
    /// Time this instance joined / left tier allocation (for cost
    /// accounting): closed [start, end) intervals + open start.
    alloc_intervals_ms: u64,
    alloc_open_since: Option<TimeMs>,
    /// KV capacity of this instance (tokens).
    pub kv_capacity: u64,
    /// Max token batch per iteration.
    pub max_token_batch: u64,
    // ---- O(1) load accounting (the routing hot path) ----
    // Cached aggregates over the queues above, maintained at every
    // mutation point (`push_prefill`/`push_decode`/`push_running`,
    // `form_batch`, `complete_iteration`, the eviction paths) so the
    // router never rescans residents per placement. Private: direct
    // queue pushes from outside would desync them — use the push_*
    // API, and `audit_cached_load` asserts coherence in debug runs.
    /// Σ `kv_now()` over `running`.
    kv_running_tokens: u64,
    /// Σ `kv_now()` over `decode_queue` (in-flight handoffs).
    kv_handoff_tokens: u64,
    /// Σ `prefill_done` over `prefill_queue` (committed prompt KV).
    kv_prefill_done_tokens: u64,
    /// Σ remaining prompt tokens over `prefill_queue`.
    queued_prefill_rem_tokens: u64,
    /// Reference mode: load accessors recompute by scanning (the
    /// pre-cache code path) instead of reading the counters.
    scan_reference: bool,
}

impl Instance {
    /// A fresh `Active` instance (the fixed-fleet constructor).
    pub fn new(id: usize, role: Role, kv_capacity: u64, max_token_batch: u64) -> Instance {
        Instance {
            id,
            role,
            model: 0,
            swap_to: None,
            lifecycle: Lifecycle::Active,
            born_ms: 0,
            spot: false,
            domain: (0, 0),
            running: Vec::new(),
            prefill_queue: VecDeque::new(),
            decode_queue: VecDeque::new(),
            migrate_on_drain: false,
            egress_until: 0,
            drain_latency_ms: None,
            iterating: false,
            busy_until: 0,
            current: IterationBatch::default(),
            busy_ms_total: 0,
            iterations_total: 0,
            alloc_intervals_ms: 0,
            alloc_open_since: None,
            kv_capacity,
            max_token_batch,
            kv_running_tokens: 0,
            kv_handoff_tokens: 0,
            kv_prefill_done_tokens: 0,
            queued_prefill_rem_tokens: 0,
            scan_reference: false,
        }
    }

    /// Switch this instance's load accessors to the scan-based
    /// reference path (`kv_used`/`handoff_kv`/`queued_prefill_tokens`
    /// recompute instead of reading the cached counters). The counters
    /// are still maintained either way, so the switch is free to flip.
    pub fn set_scan_reference(&mut self, on: bool) {
        self.scan_reference = on;
    }

    /// A cold-starting instance for the elastic fleet: joins the
    /// cluster now, starts serving at `ready_at`.
    pub fn new_provisioning(
        id: usize,
        role: Role,
        kv_capacity: u64,
        max_token_batch: u64,
        now: TimeMs,
        ready_at: TimeMs,
    ) -> Instance {
        let mut i = Instance::new(id, role, kv_capacity, max_token_batch);
        i.lifecycle = Lifecycle::Provisioning { ready_at };
        i.born_ms = now;
        i
    }

    // ---- lifecycle transitions (elastic fleet) ----

    /// Cold start finished (`InstanceReady`).
    pub fn mark_ready(&mut self) {
        debug_assert!(
            matches!(self.lifecycle, Lifecycle::Provisioning { .. }),
            "mark_ready on non-provisioning instance {}",
            self.id
        );
        self.lifecycle = Lifecycle::Active;
    }

    /// Stop accepting new work; resident requests run to completion.
    pub fn begin_drain(&mut self, now: TimeMs) {
        debug_assert!(
            self.lifecycle.accepts_work(),
            "draining non-active instance {}",
            self.id
        );
        self.lifecycle = Lifecycle::Draining { since: now };
    }

    /// Decommission (must be empty); closes the billing window and
    /// records the drain latency (begin_drain → retire).
    pub fn retire(&mut self, now: TimeMs) {
        debug_assert!(self.is_empty(), "retiring instance {} with work", self.id);
        if let Lifecycle::Draining { since } = self.lifecycle {
            self.drain_latency_ms = Some(now.saturating_sub(since));
        }
        self.lifecycle = Lifecycle::Retired { at: now };
        self.alloc_end(now);
    }

    /// Finish a model swap: re-provision this (drained, empty)
    /// instance as `model` with the new model's per-instance caps.
    /// Records the drain latency like [`Instance::retire`] does, then
    /// re-enters `Provisioning` until `ready_at` — the cold-start-like
    /// weight-reload delay. Billing continues through the reload: the
    /// hardware is still allocated, which is exactly why swaps are not
    /// free. Cluster-level index re-keying is the caller's job
    /// ([`crate::sim::Cluster::complete_swap`]).
    pub fn complete_swap(
        &mut self,
        model: ModelId,
        kv_capacity: u64,
        max_token_batch: u64,
        now: TimeMs,
        ready_at: TimeMs,
    ) {
        debug_assert!(self.is_empty(), "swapping instance {} with work", self.id);
        debug_assert!(
            matches!(self.lifecycle, Lifecycle::Draining { .. }),
            "swapping non-draining instance {}",
            self.id
        );
        if let Lifecycle::Draining { since } = self.lifecycle {
            self.drain_latency_ms = Some(now.saturating_sub(since));
        }
        self.model = model;
        self.swap_to = None;
        self.migrate_on_drain = false;
        self.kv_capacity = kv_capacity;
        self.max_token_batch = max_token_batch;
        self.lifecycle = Lifecycle::Provisioning { ready_at };
    }

    /// Scale-in KV migration: detach every decode-phase resident — both
    /// the running batch and in-flight KV handoffs — so the caller can
    /// re-place them on surviving servers. Queued prefills stay: they
    /// have no KV worth moving yet and complete quickly here.
    ///
    /// Safe mid-iteration: an evicted request is simply absent from
    /// `running` when `complete_iteration` applies token emission, so it
    /// is never decoded both here and at its destination — tokens are
    /// conserved exactly.
    pub fn evict_residents(&mut self) -> Vec<usize> {
        debug_assert!(
            matches!(self.lifecycle, Lifecycle::Draining { .. }),
            "evicting residents of non-draining instance {}",
            self.id
        );
        self.migrate_on_drain = true;
        let mut out: Vec<usize> = self.running.drain(..).map(|s| s.req_idx).collect();
        out.extend(self.decode_queue.drain(..).map(|(r, _)| r));
        self.kv_running_tokens = 0;
        self.kv_handoff_tokens = 0;
        out
    }

    /// Prefill scale-in migration: detach every queued prefill job so
    /// the caller can re-route it to a surviving prefill server. Any
    /// chunk of an evicted job still inside the in-flight iteration is
    /// discarded (its slice is stripped from the current batch): the
    /// destination recomputes from the job's committed `prefill_done`,
    /// so prefill progress is never applied both here and there.
    pub fn evict_prefill_queue(&mut self) -> Vec<PrefillJob> {
        debug_assert!(
            matches!(self.lifecycle, Lifecycle::Draining { .. }),
            "evicting prefill queue of non-draining instance {}",
            self.id
        );
        let out: Vec<PrefillJob> = self.prefill_queue.drain(..).collect();
        if !out.is_empty() {
            self.current
                .prefill_slices
                .retain(|(r, _)| !out.iter().any(|j| j.req_idx == *r));
        }
        self.kv_prefill_done_tokens = 0;
        self.queued_prefill_rem_tokens = 0;
        out
    }

    /// Hard failure (`InstanceFail`): detach *every* resident — running
    /// decode requests, in-flight decode handoffs, and queued prefill
    /// jobs — and discard the in-flight iteration wholesale. Unlike the
    /// graceful [`Instance::evict_residents`] path there is no KV to
    /// stream anywhere: the device is gone, so the caller re-enters each
    /// victim through `route_new` for a full re-prefill.
    ///
    /// Works from any live lifecycle state (failures don't wait for a
    /// drain). Returns the victims in deterministic order — running
    /// batch, then decode handoffs, then the prefill queue — and leaves
    /// every cached load counter at zero, so the instance `is_empty()`
    /// and can be force-retired immediately.
    pub fn fail_residents(&mut self) -> Vec<usize> {
        debug_assert!(
            self.lifecycle.is_live(),
            "failing already-retired instance {}",
            self.id
        );
        let mut out: Vec<usize> = self.running.drain(..).map(|s| s.req_idx).collect();
        out.extend(self.decode_queue.drain(..).map(|(r, _)| r));
        out.extend(self.prefill_queue.drain(..).map(|j| j.req_idx));
        // The in-flight iteration dies with the device: no token
        // emission, no prefill progress is applied.
        self.iterating = false;
        self.current = IterationBatch::default();
        self.kv_running_tokens = 0;
        self.kv_handoff_tokens = 0;
        self.kv_prefill_done_tokens = 0;
        self.queued_prefill_rem_tokens = 0;
        out
    }

    /// Resident request indices, non-destructively, in the same
    /// deterministic order [`Instance::fail_residents`] would return
    /// them (running batch, decode handoffs, prefill queue). The
    /// periodic KV-checkpoint sweep walks this.
    pub fn resident_reqs(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self.running.iter().map(|s| s.req_idx).collect();
        out.extend(self.decode_queue.iter().map(|&(r, _)| r));
        out.extend(self.prefill_queue.iter().map(|j| j.req_idx));
        out
    }

    /// Billable active-instance·ms by `end`: from provisioning start to
    /// retirement (or `end` when still live).
    pub fn active_span_ms(&self, end: TimeMs) -> u64 {
        let until = match self.lifecycle {
            Lifecycle::Retired { at } => at.min(end),
            _ => end,
        };
        until.saturating_sub(self.born_ms)
    }

    // ---- queue management ----

    /// Queue a prefill job, keeping the queue EDF-ordered (§4.2).
    /// `requests` feeds the cached prompt-token counters.
    pub fn push_prefill(&mut self, job: PrefillJob, requests: &[SimRequest]) {
        debug_assert!(
            self.lifecycle.accepts_work(),
            "prefill placed on non-active instance {} ({:?})",
            self.id,
            self.lifecycle
        );
        debug_assert_eq!(
            requests[job.req_idx].req.model, self.model,
            "prefill for model {} placed on instance {} serving model {}",
            requests[job.req_idx].req.model, self.id, self.model
        );
        let r = &requests[job.req_idx];
        self.kv_prefill_done_tokens += r.prefill_done as u64;
        self.queued_prefill_rem_tokens += (r.req.prefill_len - r.prefill_done) as u64;
        // EDF order: insert by deadline (§4.2: prioritize nearest
        // deadline for prefill scheduling).
        let pos = self
            .prefill_queue
            .iter()
            .position(|j| j.deadline > job.deadline)
            .unwrap_or(self.prefill_queue.len());
        self.prefill_queue.insert(pos, job);
    }

    /// Queue a decode handoff whose KV transfer lands at `ready`.
    /// `requests` feeds the cached in-flight-KV counter.
    pub fn push_decode(&mut self, req_idx: usize, ready: TimeMs, requests: &[SimRequest]) {
        debug_assert!(
            self.lifecycle.accepts_work(),
            "decode placed on non-active instance {} ({:?})",
            self.id,
            self.lifecycle
        );
        debug_assert_eq!(
            requests[req_idx].req.model, self.model,
            "decode for model {} placed on instance {} serving model {}",
            requests[req_idx].req.model, self.id, self.model
        );
        self.kv_handoff_tokens += requests[req_idx].kv_now();
        self.decode_queue.push_back((req_idx, ready));
    }

    /// Make `req_idx` decode-resident here immediately (tests and
    /// bench fixtures; the simulator's own requests join `running`
    /// through `form_batch`/`complete_iteration`). Keeps the cached
    /// KV counters coherent — never push onto `running` directly.
    pub fn push_running(&mut self, req_idx: usize, requests: &[SimRequest]) {
        debug_assert_eq!(
            requests[req_idx].req.model, self.model,
            "resident for model {} placed on instance {} serving model {}",
            requests[req_idx].req.model, self.id, self.model
        );
        self.kv_running_tokens += requests[req_idx].kv_now();
        self.running.push(RunningReq {
            req_idx,
            paused: false,
        });
    }

    /// Drop every queued prefill job, cache-coherently (test/bench
    /// state-reset helper — the simulator never discards queued work).
    pub fn clear_prefill_queue(&mut self) {
        self.prefill_queue.clear();
        self.kv_prefill_done_tokens = 0;
        self.queued_prefill_rem_tokens = 0;
    }

    /// Drop every in-flight decode handoff, cache-coherently
    /// (test/bench state-reset helper).
    pub fn clear_decode_queue(&mut self) {
        self.decode_queue.clear();
        self.kv_handoff_tokens = 0;
    }

    /// Anything resident or queued on this instance?
    pub fn has_work(&self) -> bool {
        !self.running.is_empty()
            || !self.prefill_queue.is_empty()
            || !self.decode_queue.is_empty()
    }

    /// No work and no in-flight iteration — safe to release or retire.
    pub fn is_empty(&self) -> bool {
        !self.has_work() && !self.iterating
    }

    // ---- load metrics (what routers see) ----
    //
    // All O(1) off the cached counters; the `_scan` variants are the
    // pre-cache recomputations, kept as the audit's ground truth and as
    // the runtime-selectable reference path (`set_scan_reference`).

    /// KV tokens resident here (running decode KV + committed prompt
    /// KV of queued prefills). O(1).
    pub fn kv_used(&self, requests: &[SimRequest]) -> u64 {
        if self.scan_reference {
            return self.kv_used_scan(requests);
        }
        self.kv_running_tokens + self.kv_prefill_done_tokens
    }

    /// `kv_used` recomputed by scanning the queues (reference path).
    pub fn kv_used_scan(&self, requests: &[SimRequest]) -> u64 {
        self.running
            .iter()
            .map(|r| requests[r.req_idx].kv_now())
            .sum::<u64>()
            + self
                .prefill_queue
                .iter()
                .map(|j| requests[j.req_idx].prefill_done as u64)
                .sum::<u64>()
    }

    /// KV tokens of in-flight decode handoffs (transfer not yet
    /// landed) — the router counts them as resident-to-be. O(1).
    pub fn handoff_kv(&self, requests: &[SimRequest]) -> u64 {
        if self.scan_reference {
            return self.handoff_kv_scan(requests);
        }
        self.kv_handoff_tokens
    }

    /// `handoff_kv` recomputed by scanning (reference path).
    pub fn handoff_kv_scan(&self, requests: &[SimRequest]) -> u64 {
        self.decode_queue
            .iter()
            .map(|&(r, _)| requests[r].kv_now())
            .sum()
    }

    /// Decode batch size if an iteration started now.
    pub fn decode_batch_now(&self) -> u64 {
        self.running.len() as u64 + self.decode_queue.len() as u64
    }

    /// Remaining prefill tokens queued. O(1).
    pub fn queued_prefill_tokens(&self, requests: &[SimRequest]) -> u64 {
        if self.scan_reference {
            return self.queued_prefill_tokens_scan(requests);
        }
        self.queued_prefill_rem_tokens
    }

    /// The load-gradient ordering key the router sorts on — `(decode
    /// batch now, resident + in-flight KV)` — read straight off the
    /// cached counters. This feeds the cluster's load-ordered tier
    /// indices, *not* the router-visible accessors: the counters are
    /// maintained in scan-reference mode too, so the ordered sets stay
    /// coherent no matter which read path is active.
    pub fn load_key(&self) -> (u64, u64) {
        (
            self.decode_batch_now(),
            self.kv_running_tokens + self.kv_prefill_done_tokens + self.kv_handoff_tokens,
        )
    }

    /// The ordered pending-pool key the liveness fallback walks —
    /// `(decode batch now, queued prefill tokens remaining)`. Like
    /// [`Instance::load_key`] it reads the cached counters directly
    /// (they are maintained in every reference mode), so the cluster's
    /// ordered pending set stays coherent no matter which read path is
    /// active. Stored separately from the load key: a prefill push
    /// with no committed tokens moves this key while `(batch, kv)`
    /// stays put.
    pub fn pending_key(&self) -> (u64, u64) {
        (self.decode_batch_now(), self.queued_prefill_rem_tokens)
    }

    /// Requests resident on this instance (running, queued for prefill,
    /// or an in-flight decode handoff) — a request lives on at most one
    /// instance at a time, so summing this over the fleet counts
    /// distinct placed requests. Feeds the cluster's O(1)
    /// unplaced-demand counter.
    pub fn resident_requests(&self) -> usize {
        self.running.len() + self.prefill_queue.len() + self.decode_queue.len()
    }

    /// `queued_prefill_tokens` recomputed by scanning (reference path).
    pub fn queued_prefill_tokens_scan(&self, requests: &[SimRequest]) -> u64 {
        self.prefill_queue
            .iter()
            .map(|j| {
                let r = &requests[j.req_idx];
                (r.req.prefill_len - r.prefill_done) as u64
            })
            .sum()
    }

    /// Assert every cached load counter equals its scan-recomputed
    /// value. Called after every simulator event in debug-assertion
    /// builds (`SimParams::debug_audit`); panics on the first drift.
    pub fn audit_cached_load(&self, requests: &[SimRequest]) {
        let running: u64 = self
            .running
            .iter()
            .map(|r| requests[r.req_idx].kv_now())
            .sum();
        assert_eq!(
            self.kv_running_tokens, running,
            "inst {}: cached running KV drifted",
            self.id
        );
        assert_eq!(
            self.kv_handoff_tokens,
            self.handoff_kv_scan(requests),
            "inst {}: cached handoff KV drifted",
            self.id
        );
        let pf_done: u64 = self
            .prefill_queue
            .iter()
            .map(|j| requests[j.req_idx].prefill_done as u64)
            .sum();
        assert_eq!(
            self.kv_prefill_done_tokens, pf_done,
            "inst {}: cached prefill-done KV drifted",
            self.id
        );
        assert_eq!(
            self.queued_prefill_rem_tokens,
            self.queued_prefill_tokens_scan(requests),
            "inst {}: cached queued-prefill tokens drifted",
            self.id
        );
    }

    /// Earliest in-flight KV-handoff arrival strictly after `now`
    /// (None when no handoff is still in transit).
    pub fn next_handoff_ready_ms(&self, now: TimeMs) -> Option<TimeMs> {
        self.decode_queue
            .iter()
            .map(|&(_, ready)| ready)
            .filter(|&ready| ready > now)
            .min()
    }

    /// Wait time until the current iteration finishes (0 if idle) —
    /// the §4.6 wait-time term.
    pub fn wait_ms(&self, now: TimeMs) -> u64 {
        if self.iterating {
            self.busy_until.saturating_sub(now)
        } else {
            0
        }
    }

    // ---- allocation accounting (Fig 8 cost) ----

    /// Mark this instance as allocated to a tier (leaves the BE pool).
    pub fn alloc_start(&mut self, now: TimeMs) {
        if self.alloc_open_since.is_none() {
            self.alloc_open_since = Some(now);
        }
    }

    /// Mark return to the best-effort pool.
    pub fn alloc_end(&mut self, now: TimeMs) {
        if let Some(s) = self.alloc_open_since.take() {
            self.alloc_intervals_ms += now.saturating_sub(s);
        }
    }

    /// Total allocated instance·ms by the end of the run.
    pub fn allocated_ms(&self, end: TimeMs) -> u64 {
        self.alloc_intervals_ms
            + self
                .alloc_open_since
                .map(|s| end.saturating_sub(s))
                .unwrap_or(0)
    }

    // ---- iteration mechanics ----

    /// Form the next iteration's batch. Returns the quantized iteration
    /// duration, or None if there is no work.
    ///
    /// `budget` is the prefill-token budget this iteration (router
    /// policy); decode requests are always all scheduled (§2.4: "all
    /// current decode requests are scheduled in the next iteration").
    pub fn form_batch(
        &mut self,
        now: TimeMs,
        requests: &mut [SimRequest],
        budget: u64,
        cm: &CostModel,
    ) -> Option<TimeMs> {
        // Admit arrived decode handoffs (KV transfer complete).
        let mut di = 0;
        while di < self.decode_queue.len() {
            if self.decode_queue[di].1 <= now {
                let (req_idx, _) = self.decode_queue.remove(di).unwrap();
                // Handoff landed: its KV moves from in-flight to
                // resident in the cached accounting.
                let kv = requests[req_idx].kv_now();
                self.kv_handoff_tokens -= kv;
                self.kv_running_tokens += kv;
                self.running.push(RunningReq {
                    req_idx,
                    paused: false,
                });
            } else {
                di += 1;
            }
        }

        // KV pressure: pause newest decode requests beyond capacity.
        let mut kv: u64 = self
            .prefill_queue
            .iter()
            .map(|j| requests[j.req_idx].prefill_done as u64)
            .sum();
        // (running sorted by insertion order = arrival order at this
        // instance; oldest first keeps FCFS fairness.)
        for slot in self.running.iter_mut() {
            let need = requests[slot.req_idx].kv_now() + 1; // +1 token
            if kv + need <= self.kv_capacity {
                kv += need;
                slot.paused = false;
            } else {
                slot.paused = true;
            }
        }
        let b_decode = self.running.iter().filter(|r| !r.paused).count() as u64;

        // Prefill chunk formation under the budget and KV capacity.
        let mut b_prefill = 0u64;
        let mut slices: Vec<(usize, u32)> = Vec::new();
        let room = self
            .max_token_batch
            .saturating_sub(b_decode)
            .min(budget);
        if room > 0 {
            for job in self.prefill_queue.iter() {
                if b_prefill >= room {
                    break;
                }
                let r = &requests[job.req_idx];
                let remaining = (r.req.prefill_len - r.prefill_done) as u64;
                let take = remaining.min(room - b_prefill);
                // KV for the chunk itself must fit.
                if kv + take > self.kv_capacity {
                    break;
                }
                if take == 0 {
                    continue;
                }
                kv += take;
                b_prefill += take;
                slices.push((job.req_idx, take as u32));
            }
        }

        if b_decode == 0 && b_prefill == 0 {
            return None;
        }
        let iter_ms = cm
            .iter_ms_mixed(b_decode, b_prefill, kv)
            .ceil()
            .max(1.0) as u64;
        self.current = IterationBatch {
            b_decode,
            b_prefill,
            prefill_slices: slices,
            kv_tokens: kv,
        };
        self.iterations_total += 1;
        Some(iter_ms)
    }

    /// Apply the effects of the just-finished iteration at time `now`.
    ///
    /// Returns (requests whose prefill completed this iteration,
    /// number of requests that fully finished).
    pub fn complete_iteration(
        &mut self,
        now: TimeMs,
        requests: &mut [SimRequest],
    ) -> (Vec<usize>, usize) {
        self.iterating = false;
        let mut finished = 0usize;
        let mut completed_prefills = Vec::new();

        // 1. Prefill progress.
        for &(req_idx, take) in &self.current.prefill_slices {
            let r = &mut requests[req_idx];
            r.prefill_done += take;
            self.kv_prefill_done_tokens += take as u64;
            self.queued_prefill_rem_tokens -= take as u64;
            if r.prefill_done >= r.req.prefill_len {
                // Prefill complete → first token emitted now. A chaos
                // victim *re*-prefilling after an instance failure has
                // already emitted tokens (`decoded >= 1`) — they
                // reached the client and must not be emitted again, nor
                // the decode count clobbered; every pre-existing path
                // reaches here with `decoded == 0`, so the guard is
                // behaviour-neutral without `[chaos]`.
                if r.decoded == 0 {
                    r.tracker.emit_token(now);
                    r.first_token_ms = Some(now);
                    r.decoded = 1;
                    if r.decoded >= r.req.decode_len {
                        r.finish_ms = Some(now);
                        finished += 1;
                    }
                }
                completed_prefills.push(req_idx);
            }
        }
        // Remove finished prefills from the queue; their committed
        // prompt KV leaves the prefill-queue account with them.
        for &req_idx in &completed_prefills {
            self.kv_prefill_done_tokens -= requests[req_idx].prefill_done as u64;
        }
        self.prefill_queue.retain(|j| {
            let r = &requests[j.req_idx];
            r.prefill_done < r.req.prefill_len
        });
        // Co-location: completed prefills continue decoding here.
        if self.role == Role::Coloc {
            for &req_idx in &completed_prefills {
                if requests[req_idx].decode_remaining() > 0 {
                    requests[req_idx].decode_instance = Some(self.id);
                    self.kv_running_tokens += requests[req_idx].kv_now();
                    self.running.push(RunningReq {
                        req_idx,
                        paused: false,
                    });
                }
            }
        }

        // 2. Decode token emission.
        let mut still_running = Vec::with_capacity(self.running.len());
        for slot in self.running.drain(..) {
            // Skip requests that joined during this iteration window
            // (pushed by Coloc block above — they start next iteration)
            // by checking decoded>0 set at prefill completion; they were
            // not in `current` anyway. Paused requests emit nothing.
            let joined_this_iter = completed_prefills.contains(&slot.req_idx);
            if joined_this_iter {
                still_running.push(slot);
                continue;
            }
            let r = &mut requests[slot.req_idx];
            if slot.paused {
                still_running.push(slot);
                continue;
            }
            r.tracker.emit_token(now);
            r.decoded += 1;
            self.kv_running_tokens += 1;
            if r.decoded >= r.req.decode_len {
                r.finish_ms = Some(now);
                r.decode_instance = None;
                self.kv_running_tokens -= r.kv_now();
                finished += 1;
            } else {
                still_running.push(slot);
            }
        }
        self.running = still_running;
        self.current = IterationBatch::default();
        (completed_prefills, finished)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slo::Slo;
    use crate::workload::Request;

    fn cm() -> CostModel {
        CostModel::h200_llama8b()
    }

    fn sim_req(id: u64, p: u32, d: u32) -> SimRequest<'static> {
        // Tests leak their (tiny, fixed) request set so the arena's
        // borrowed `&'static Request` half has somewhere to point.
        let req: &'static Request = Box::leak(Box::new(Request {
            id,
            arrival_ms: 0,
            prefill_len: p,
            decode_len: d,
            slo: Slo::new(1000, 50),
            model: 0,
        }));
        SimRequest::new(req, 0)
    }

    #[test]
    fn prefill_queue_is_edf_ordered() {
        let reqs = vec![sim_req(0, 100, 5), sim_req(1, 100, 5), sim_req(2, 100, 5)];
        let mut i = Instance::new(0, Role::Prefill, 1_000_000, 2048);
        i.push_prefill(PrefillJob { req_idx: 0, deadline: 500 }, &reqs);
        i.push_prefill(PrefillJob { req_idx: 1, deadline: 100 }, &reqs);
        i.push_prefill(PrefillJob { req_idx: 2, deadline: 300 }, &reqs);
        let order: Vec<usize> = i.prefill_queue.iter().map(|j| j.req_idx).collect();
        assert_eq!(order, vec![1, 2, 0]);
        assert_eq!(i.queued_prefill_tokens(&reqs), 300);
        i.audit_cached_load(&reqs);
    }

    #[test]
    fn chunked_prefill_advances_and_completes() {
        let mut reqs = vec![sim_req(0, 1000, 5)];
        let mut i = Instance::new(0, Role::Prefill, 1_000_000, 2048);
        i.push_prefill(PrefillJob { req_idx: 0, deadline: 1000 }, &reqs);
        // Budget 512 → two chunks of 512/488.
        let t1 = i.form_batch(0, &mut reqs, 512, &cm()).unwrap();
        assert!(t1 >= 1);
        assert_eq!(i.current.b_prefill, 512);
        let (done, fin) = i.complete_iteration(t1, &mut reqs);
        assert!(done.is_empty());
        assert_eq!(fin, 0);
        assert_eq!(reqs[0].prefill_done, 512);
        let t2 = i.form_batch(t1, &mut reqs, 512, &cm()).unwrap();
        assert_eq!(i.current.b_prefill, 488);
        let (done, _) = i.complete_iteration(t1 + t2, &mut reqs);
        assert_eq!(done, vec![0]);
        assert_eq!(reqs[0].decoded, 1);
        assert_eq!(reqs[0].first_token_ms, Some(t1 + t2));
        assert!(i.prefill_queue.is_empty());
        i.audit_cached_load(&reqs);
    }

    #[test]
    fn decode_emits_one_token_per_iteration() {
        let mut reqs = vec![sim_req(0, 10, 3)];
        reqs[0].prefill_done = 10;
        reqs[0].decoded = 1; // first token emitted at prefill
        reqs[0].tracker.emit_token(0);
        let mut i = Instance::new(0, Role::Decode, 1_000_000, 2048);
        i.push_decode(0, 0, &reqs);
        let mut now = 0;
        for step in 0..2 {
            let t = i.form_batch(now, &mut reqs, 0, &cm()).unwrap();
            assert_eq!(i.current.b_decode, 1, "step {step}");
            now += t;
            let (_, fin) = i.complete_iteration(now, &mut reqs);
            i.audit_cached_load(&reqs);
            if step == 1 {
                assert_eq!(fin, 1);
            } else {
                assert_eq!(fin, 0);
            }
        }
        assert_eq!(reqs[0].decoded, 3);
        assert!(reqs[0].is_finished());
        assert!(i.is_empty());
        assert_eq!(i.kv_used(&reqs), 0, "finished request must free its KV");
    }

    #[test]
    fn decode_handoff_waits_for_kv_transfer() {
        let mut reqs = vec![sim_req(0, 10, 5)];
        reqs[0].prefill_done = 10;
        reqs[0].decoded = 1;
        let mut i = Instance::new(0, Role::Decode, 1_000_000, 2048);
        i.push_decode(0, 100, &reqs); // ready at t=100
        assert_eq!(i.handoff_kv(&reqs), 11);
        assert!(i.form_batch(50, &mut reqs, 0, &cm()).is_none());
        assert!(i.form_batch(100, &mut reqs, 0, &cm()).is_some());
        // The landed handoff's KV moved from in-flight to resident.
        assert_eq!(i.handoff_kv(&reqs), 0);
        assert_eq!(i.kv_used(&reqs), 11);
        i.audit_cached_load(&reqs);
    }

    #[test]
    fn kv_pressure_pauses_newest() {
        // Capacity for only one request's KV.
        let mut reqs = vec![sim_req(0, 400, 10), sim_req(1, 400, 10)];
        for r in reqs.iter_mut() {
            r.prefill_done = 400;
            r.decoded = 1;
        }
        let mut i = Instance::new(0, Role::Decode, 500, 2048);
        i.push_decode(0, 0, &reqs);
        i.push_decode(1, 0, &reqs);
        let _ = i.form_batch(0, &mut reqs, 0, &cm()).unwrap();
        assert_eq!(i.current.b_decode, 1);
        let paused: Vec<bool> = i.running.iter().map(|r| r.paused).collect();
        assert_eq!(paused, vec![false, true]);
        let (_, fin) = i.complete_iteration(10, &mut reqs);
        assert_eq!(fin, 0);
        // Oldest progressed, newest did not.
        assert_eq!(reqs[0].decoded, 2);
        assert_eq!(reqs[1].decoded, 1);
    }

    #[test]
    fn coloc_mixes_decode_and_prefill() {
        let mut reqs = vec![sim_req(0, 100, 5), sim_req(1, 600, 5)];
        reqs[0].prefill_done = 100;
        reqs[0].decoded = 1;
        let mut i = Instance::new(0, Role::Coloc, 1_000_000, 2048);
        i.push_running(0, &reqs);
        i.push_prefill(PrefillJob { req_idx: 1, deadline: 1000 }, &reqs);
        let _ = i.form_batch(0, &mut reqs, 512, &cm()).unwrap();
        assert_eq!(i.current.b_decode, 1);
        assert_eq!(i.current.b_prefill, 512);
        let (done, _) = i.complete_iteration(20, &mut reqs);
        assert!(done.is_empty());
        assert_eq!(reqs[0].decoded, 2);
        assert_eq!(reqs[1].prefill_done, 512);
        i.audit_cached_load(&reqs);
        // Next iteration finishes the prefill; request 1 joins decoding.
        let _ = i.form_batch(20, &mut reqs, 512, &cm()).unwrap();
        let (done, _) = i.complete_iteration(40, &mut reqs);
        assert_eq!(done, vec![1]);
        assert_eq!(i.running.len(), 2);
        // Request 1 emits its next token only in the following iteration.
        assert_eq!(reqs[1].decoded, 1);
        i.audit_cached_load(&reqs);
    }

    #[test]
    fn completed_prefill_does_not_double_emit_in_same_iteration() {
        let mut reqs = vec![sim_req(0, 64, 3)];
        let mut i = Instance::new(0, Role::Coloc, 1_000_000, 2048);
        i.push_prefill(PrefillJob { req_idx: 0, deadline: 1000 }, &reqs);
        let t = i.form_batch(0, &mut reqs, 2048, &cm()).unwrap();
        let (done, _) = i.complete_iteration(t, &mut reqs);
        assert_eq!(done, vec![0]);
        assert_eq!(reqs[0].decoded, 1); // exactly the first token
        assert_eq!(reqs[0].tracker.tokens_emitted(), 1);
    }

    #[test]
    fn allocation_accounting() {
        let mut i = Instance::new(0, Role::Decode, 1_000_000, 2048);
        i.alloc_start(100);
        i.alloc_end(400);
        i.alloc_start(600);
        assert_eq!(i.allocated_ms(1000), 300 + 400);
        // idempotent start
        i.alloc_start(700);
        assert_eq!(i.allocated_ms(1000), 700);
    }

    #[test]
    fn budget_zero_blocks_prefill_but_not_decode() {
        let mut reqs = vec![sim_req(0, 100, 5), sim_req(1, 100, 5)];
        reqs[0].prefill_done = 100;
        reqs[0].decoded = 1;
        let mut i = Instance::new(0, Role::Coloc, 1_000_000, 2048);
        i.push_running(0, &reqs);
        i.push_prefill(PrefillJob { req_idx: 1, deadline: 1000 }, &reqs);
        let _ = i.form_batch(0, &mut reqs, 0, &cm()).unwrap();
        assert_eq!(i.current.b_decode, 1);
        assert_eq!(i.current.b_prefill, 0);
    }

    #[test]
    fn lifecycle_transitions_and_billing_window() {
        let mut i = Instance::new_provisioning(3, Role::Coloc, 1_000_000, 2048, 500, 1500);
        assert!(!i.lifecycle.accepts_work());
        assert!(i.lifecycle.is_live());
        i.mark_ready();
        assert!(i.lifecycle.accepts_work());
        i.begin_drain(2000);
        assert!(!i.lifecycle.accepts_work());
        assert!(i.lifecycle.is_live());
        i.retire(3000);
        assert!(!i.lifecycle.is_live());
        // Billed from provisioning start (500) to retirement (3000).
        assert_eq!(i.active_span_ms(10_000), 2500);
        // A never-retired instance bills to the end of the run.
        let j = Instance::new(0, Role::Coloc, 1, 1);
        assert_eq!(j.active_span_ms(4000), 4000);
    }

    #[test]
    fn evict_residents_detaches_running_and_in_flight() {
        let mut reqs = vec![sim_req(0, 10, 5), sim_req(1, 10, 5), sim_req(2, 10, 5)];
        for r in reqs.iter_mut() {
            r.prefill_done = 10;
            r.decoded = 1;
        }
        let mut i = Instance::new(0, Role::Decode, 1_000_000, 2048);
        i.push_decode(0, 0, &reqs);
        i.push_decode(1, 0, &reqs);
        let t = i.form_batch(0, &mut reqs, 0, &cm()).unwrap();
        i.iterating = true;
        i.push_decode(2, 100, &reqs); // KV still in flight
        i.begin_drain(1);
        let evicted = i.evict_residents();
        assert_eq!(evicted, vec![0, 1, 2]);
        assert!(i.migrate_on_drain);
        assert_eq!(i.kv_used(&reqs) + i.handoff_kv(&reqs), 0, "evicted KV must leave");
        // The in-flight iteration emits nothing for evicted requests:
        // no token is decoded both here and at the destination.
        let (_, fin) = i.complete_iteration(t, &mut reqs);
        assert_eq!(fin, 0);
        assert_eq!(reqs[0].decoded, 1);
        assert_eq!(reqs[1].decoded, 1);
        assert!(i.is_empty());
        i.audit_cached_load(&reqs);
    }

    #[test]
    fn fail_residents_detaches_everything_and_discards_iteration() {
        let mut reqs = vec![sim_req(0, 10, 5), sim_req(1, 10, 5), sim_req(2, 200, 5)];
        for r in reqs.iter_mut().take(2) {
            r.prefill_done = 10;
            r.decoded = 1;
        }
        let mut i = Instance::new(0, Role::Coloc, 1_000_000, 2048);
        i.push_running(0, &reqs);
        i.push_decode(1, 100, &reqs); // KV still in flight
        i.push_prefill(PrefillJob { req_idx: 2, deadline: 1000 }, &reqs);
        let _ = i.form_batch(0, &mut reqs, 64, &cm()).unwrap();
        i.iterating = true;
        // Hard kill from Active: running, handoffs, and queued prefills
        // all come back, in that order; the iteration dies with them.
        let victims = i.fail_residents();
        assert_eq!(victims, vec![0, 1, 2]);
        assert!(!i.iterating);
        assert!(i.is_empty());
        assert_eq!(i.kv_used(&reqs) + i.handoff_kv(&reqs), 0);
        assert_eq!(i.queued_prefill_tokens(&reqs), 0);
        i.audit_cached_load(&reqs);
        // No token was emitted and no prefill progress applied.
        assert_eq!(reqs[0].decoded, 1);
        assert_eq!(reqs[2].prefill_done, 0);
        i.retire(50);
        assert!(!i.lifecycle.is_live());
    }

    #[test]
    fn complete_swap_reloads_with_new_caps() {
        let mut i = Instance::new(4, Role::Coloc, 900_000, 2048);
        i.begin_drain(1_000);
        i.swap_to = Some(1);
        i.complete_swap(1, 256_000, 2048, 3_500, 23_500);
        assert_eq!(i.model, 1);
        assert_eq!(i.swap_to, None);
        assert_eq!(i.kv_capacity, 256_000);
        assert_eq!(i.drain_latency_ms, Some(2_500));
        assert_eq!(i.lifecycle, Lifecycle::Provisioning { ready_at: 23_500 });
        assert!(!i.lifecycle.accepts_work());
        i.mark_ready();
        assert!(i.lifecycle.accepts_work());
        // Billing never paused: born_ms is untouched by the swap.
        assert_eq!(i.born_ms, 0);
    }

    #[test]
    fn retire_records_drain_latency() {
        let mut i = Instance::new(0, Role::Decode, 1_000_000, 2048);
        i.begin_drain(2000);
        i.retire(7500);
        assert_eq!(i.drain_latency_ms, Some(5500));
    }

    #[test]
    fn next_handoff_ready_skips_arrived_transfers() {
        let reqs = vec![sim_req(0, 10, 5), sim_req(1, 10, 5)];
        let mut i = Instance::new(0, Role::Decode, 1_000_000, 2048);
        assert_eq!(i.next_handoff_ready_ms(0), None);
        i.push_decode(0, 50, &reqs);
        i.push_decode(1, 200, &reqs);
        assert_eq!(i.next_handoff_ready_ms(0), Some(50));
        assert_eq!(i.next_handoff_ready_ms(50), Some(200));
        assert_eq!(i.next_handoff_ready_ms(200), None);
    }

    #[test]
    fn scan_reference_matches_cached_accessors() {
        let mut reqs = vec![sim_req(0, 300, 5), sim_req(1, 200, 5)];
        reqs[0].prefill_done = 300;
        reqs[0].decoded = 4;
        let mut i = Instance::new(0, Role::Coloc, 1_000_000, 2048);
        i.push_running(0, &reqs);
        i.push_prefill(PrefillJob { req_idx: 1, deadline: 1000 }, &reqs);
        let cached = (
            i.kv_used(&reqs),
            i.handoff_kv(&reqs),
            i.queued_prefill_tokens(&reqs),
        );
        i.set_scan_reference(true);
        let scanned = (
            i.kv_used(&reqs),
            i.handoff_kv(&reqs),
            i.queued_prefill_tokens(&reqs),
        );
        assert_eq!(cached, scanned);
        assert_eq!(cached.0, 304, "running kv_now = 300 prefill + 4 decoded");
        assert_eq!(cached.2, 200);
    }

    #[test]
    #[should_panic(expected = "cached running KV drifted")]
    fn audit_catches_cache_bypass() {
        // Pushing onto `running` directly (instead of `push_running`)
        // desyncs the cached counters — the audit must catch it.
        let reqs = vec![sim_req(0, 100, 5)];
        let mut i = Instance::new(0, Role::Decode, 1_000_000, 2048);
        i.running.push(RunningReq { req_idx: 0, paused: false });
        i.audit_cached_load(&reqs);
    }

    #[test]
    fn clear_helpers_keep_caches_coherent() {
        let reqs = vec![sim_req(0, 100, 5), sim_req(1, 100, 5)];
        let mut i = Instance::new(0, Role::Coloc, 1_000_000, 2048);
        i.push_prefill(PrefillJob { req_idx: 0, deadline: 100 }, &reqs);
        i.push_decode(1, 50, &reqs);
        i.clear_prefill_queue();
        i.clear_decode_queue();
        assert_eq!(i.queued_prefill_tokens(&reqs), 0);
        assert_eq!(i.handoff_kv(&reqs), 0);
        i.audit_cached_load(&reqs);
    }

    #[test]
    fn wait_ms_reflects_iteration_progress() {
        let mut i = Instance::new(0, Role::Decode, 1_000_000, 2048);
        assert_eq!(i.wait_ms(50), 0);
        i.iterating = true;
        i.busy_until = 120;
        assert_eq!(i.wait_ms(100), 20);
        assert_eq!(i.wait_ms(130), 0);
    }
}
