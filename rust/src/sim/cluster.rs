//! The fleet: instances, tier membership, best-effort pool, and the
//! elastic-fleet lifecycle (provision / drain / retire).
//!
//! Tier bookkeeping implements the paper's server states: an instance is
//! either in the best-effort pool (idle reserve), assigned to a TPOT
//! tier, or *pending* (§4.4: only lower-tier promoted requests remain on
//! it — it may join their tier if that tier scales up, else it drains to
//! the pool).
//!
//! The elastic layer sits underneath: the fleet itself can grow
//! (`provision` → cold start → `InstanceReady`) and shrink
//! (`begin_drain` → residents finish → retire). Retired instances stay
//! in `instances` so ids remain stable indices; every placement-facing
//! query (`in_tier`, `best_effort_pool`, `with_role`) returns only
//! instances whose lifecycle accepts new work.

//! **Multi-model fleets**: every index above is additionally keyed by
//! the instance's loaded [`ModelId`] — tier sets live in a flat
//! `model × tier` slot array, the best-effort / pending pools and their
//! ordered twins are per-model vectors, and the unplaced-demand
//! counters split per model. A single-model cluster (`num_models == 1`)
//! degenerates to exactly the per-tier layout of PRs 4–6: the aggregate
//! views (`in_tier`, `best_effort_pool`, …) chain the per-model sets in
//! model order, which for one model is the identical sequence — the
//! bit-for-bit identity the digest tests enforce.

use super::instance::{Instance, Lifecycle, Role};
use super::SimRequest;
use crate::analysis::ServingMode;
use crate::model::{CostModel, ModelId};
use crate::slo::TimeMs;
use std::cmp::Reverse;
use std::collections::BTreeSet;

/// Entry of a load-ordered membership set: `Reverse<(batch, kv, id)>`,
/// so ascending `BTreeSet` iteration walks members in *descending*
/// `(batch, kv, id)` order — exactly the order the router's
/// `pick_by_gradient` used to produce by sorting, including the
/// descending-id tie-break, and reverse iteration is exactly the
/// ascending sort of the `load_gradient = off` ablation.
type LoadOrdered = BTreeSet<Reverse<(u64, u64, usize)>>;

#[inline]
fn load_entry(key: (u64, u64), id: usize) -> Reverse<(u64, u64, usize)> {
    Reverse((key.0, key.1, id))
}

/// Index into `role_ids` for a role (roles never change, so the
/// per-role sets are append-only).
#[inline]
fn role_idx(role: Role) -> usize {
    match role {
        Role::Prefill => 0,
        Role::Decode => 1,
        Role::Coloc => 2,
    }
}

/// Iterator over one of the two membership paths: the indexed id sets
/// (default) or the pre-PR full-`assign` scan (reference mode).
enum ViewIter<A, B> {
    Indexed(A),
    Scan(B),
}

impl<A: Iterator<Item = usize>, B: Iterator<Item = usize>> Iterator for ViewIter<A, B> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        match self {
            ViewIter::Indexed(a) => a.next(),
            ViewIter::Scan(b) => b.next(),
        }
    }
}

/// Tier assignment state of an instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierAssign {
    /// In the best-effort pool (free to be claimed by any tier).
    BestEffort,
    /// Serving TPOT tier `k` (index into the tier set, 0 = tightest).
    Tier(usize),
    /// §4.4 pending state: no native-tier requests left, only promoted
    /// lower-tier ones; waiting to either join their tier or drain.
    Pending,
    /// Static role (baselines / prefill cluster): never rebalanced.
    Static,
}

/// The cluster under simulation.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// Every instance ever in the fleet (retired slots included).
    pub instances: Vec<Instance>,
    /// Tier assignment per instance (parallel to `instances`).
    /// Private: every write goes through [`Cluster::set_assign`] so the
    /// membership indices below can never drift from it. Read via
    /// [`Cluster::assign_of`] / [`Cluster::assignments`].
    assign: Vec<TierAssign>,
    /// Number of TPOT tiers.
    pub num_tiers: usize,
    /// Number of registry models this fleet serves (1 for every
    /// pre-registry configuration).
    pub num_models: usize,
    /// Tier-managed (PolyServe) fleet: newly provisioned instances join
    /// the best-effort pool; static fleets get `Static` assignment.
    pub managed: bool,
    /// Per-instance KV capacity for newly provisioned instances of
    /// model 0 (kept for single-model callers; multi-model provisioning
    /// reads `model_caps`).
    pub kv_capacity: u64,
    /// Per-instance max token batch for newly provisioned instances of
    /// model 0 (see `kv_capacity`).
    pub max_token_batch: u64,
    /// Per-model `(kv_capacity, max_token_batch)` instance caps — what
    /// a provision or model swap sizes the instance with.
    model_caps: Vec<(u64, u64)>,
    /// Instances the router fed while holding the ctx — the simulator
    /// must try to (re)start their iterations.
    kicked: Vec<usize>,
    // ---- indexed fleet membership (the routing hot path) ----
    // Each set mirrors `assign` exactly (lifecycle is filtered at read
    // time), so maintenance lives in `set_assign` alone. BTreeSets
    // iterate in ascending id order — identical to the old
    // enumerate-the-`assign`-vec scans, so `pick_by_gradient`'s
    // `(batch, kv, id)` tie-break and every placement outcome are
    // bit-for-bit unchanged.
    /// Ids assigned `Tier(k)`, per `(model, tier)` flat slot
    /// (`model * tiers_cap + k`).
    tier_ids: Vec<BTreeSet<usize>>,
    /// Allocated tier slots per model in the flat arrays (≥ num_tiers;
    /// grows via `ensure_tier_cap` if a policy uses a larger index).
    tiers_cap: usize,
    /// Ids assigned `BestEffort`, per model.
    be_ids: Vec<BTreeSet<usize>>,
    /// Ids assigned `Pending`, per model.
    pending_ids: Vec<BTreeSet<usize>>,
    /// Ids per role (roles are immutable: append-only).
    role_ids: [BTreeSet<usize>; 3],
    // ---- load-ordered membership (the placement hot path) ----
    // Twin sets of `tier_ids`/`be_ids` keyed by `(batch, kv, id)` in
    // descending order, so the router's §4.3 load-gradient walk is
    // plain in-order iteration with early exit — no per-placement
    // collect or sort. Re-keyed through `refresh_load` at every
    // instance-load mutation site; `audit` panics on a missed re-key.
    /// Tier members in descending `(batch, kv, id)` order, per
    /// `(model, tier)` flat slot.
    ordered_tier: Vec<LoadOrdered>,
    /// Best-effort pool in the same descending load order, per model.
    ordered_be: Vec<LoadOrdered>,
    /// Pending-state instances in *ascending* `(decode batch, queued
    /// prefill tokens, id)` order, per model — the liveness fallback's
    /// least-loaded walk (`forced_target`) as plain in-order iteration
    /// with `.next()`, no per-call min-scan.
    ordered_pending: Vec<BTreeSet<(u64, u64, usize)>>,
    /// Last key inserted into an ordered set per instance (the key a
    /// removal must use; also the audit's staleness probe).
    load_key: Vec<(u64, u64)>,
    /// Last key inserted into `ordered_pending` per instance. Stored
    /// separately from `load_key`: a prefill push with no committed
    /// tokens moves the pending key while the `(batch, kv)` load key
    /// stays put, so a load-key comparison alone would miss the re-key.
    pending_key: Vec<(u64, u64)>,
    /// Last known `resident_requests()` per instance (feeds the O(1)
    /// unplaced-demand counter below).
    resident_cnt: Vec<usize>,
    /// Σ `resident_cnt` — distinct requests resident somewhere.
    resident_total: usize,
    /// Arrival events processed (`note_arrival`).
    arrived_total: usize,
    /// Requests fully finished (`note_finished`).
    finished_total: usize,
    /// Per-model splits of the three unplaced-demand counters above
    /// (a request lives only on instances of its own model, so the
    /// per-model subtraction is exact).
    resident_per_model: Vec<usize>,
    arrived_per_model: Vec<usize>,
    finished_per_model: Vec<usize>,
    /// Instances currently `Draining` (cheap sweep short-circuit).
    draining_total: usize,
    /// Reference mode: membership views recompute by scanning.
    scan_reference: bool,
    /// Reference mode: the PR-4 path — indexed membership and cached
    /// load counters, but no ordered walk (the router materializes and
    /// sorts per placement) and scan-based unplaced demand.
    indexed_reference: bool,
}

impl Cluster {
    /// Build a cluster for `mode`:
    /// * PD: `round(prefill_frac · n)` prefill instances (Static) and
    ///   the rest decode instances.
    /// * Coloc: all instances are coloc.
    /// Tier assignment starts as given by `initial_assign` (e.g. all
    /// BestEffort for PolyServe, Static for baselines).
    pub fn build(
        mode: ServingMode,
        n: usize,
        prefill_frac: f64,
        num_tiers: usize,
        cm: &CostModel,
        polyserve_managed: bool,
    ) -> Cluster {
        Cluster::build_models(
            mode,
            &[n],
            prefill_frac,
            num_tiers,
            &[(cm.kv_capacity_tokens, cm.max_token_batch)],
            polyserve_managed,
        )
    }

    /// Build a multi-model fleet: `counts[m]` instances loaded with
    /// registry model `m`, each sized by `caps[m] = (kv_capacity,
    /// max_token_batch)` (see
    /// [`crate::model::ModelRegistry::instance_caps`]). Each model's
    /// sub-fleet is split into roles exactly as [`Cluster::build`]
    /// splits a single-model fleet (PD: `round(prefill_frac · count)`
    /// prefill instances, min 1 of each role; Coloc: all coloc), and
    /// ids are assigned model-major. With one model this *is* the old
    /// `build` — the single-model constructor delegates here.
    pub fn build_models(
        mode: ServingMode,
        counts: &[usize],
        prefill_frac: f64,
        num_tiers: usize,
        caps: &[(u64, u64)],
        polyserve_managed: bool,
    ) -> Cluster {
        assert!(!counts.is_empty() && counts.len() == caps.len());
        assert!(counts.iter().all(|&c| c >= 1), "every model needs ≥1 instance");
        let num_models = counts.len();
        let n_total: usize = counts.iter().sum();
        let mut instances = Vec::with_capacity(n_total);
        let mut assign = Vec::with_capacity(n_total);
        for (m, (&n, &(kv_cap, mtb))) in counts.iter().zip(caps.iter()).enumerate() {
            match mode {
                ServingMode::PdDisaggregated => {
                    let n_prefill = ((n as f64 * prefill_frac).round() as usize)
                        .clamp(1, n.saturating_sub(1).max(1));
                    for i in 0..n {
                        let role =
                            if i < n_prefill { Role::Prefill } else { Role::Decode };
                        let id = instances.len();
                        let mut inst = Instance::new(id, role, kv_cap, mtb);
                        inst.model = m;
                        instances.push(inst);
                        assign.push(match role {
                            Role::Prefill => TierAssign::Static,
                            _ if polyserve_managed => TierAssign::BestEffort,
                            _ => TierAssign::Static,
                        });
                    }
                }
                ServingMode::Colocated => {
                    for _ in 0..n {
                        let id = instances.len();
                        let mut inst = Instance::new(id, Role::Coloc, kv_cap, mtb);
                        inst.model = m;
                        instances.push(inst);
                        assign.push(if polyserve_managed {
                            TierAssign::BestEffort
                        } else {
                            TierAssign::Static
                        });
                    }
                }
            }
        }
        let n_built = instances.len();
        let tiers_cap = num_tiers.max(1);
        let mut cluster = Cluster {
            instances,
            assign,
            num_tiers,
            num_models,
            managed: polyserve_managed,
            kv_capacity: caps[0].0,
            max_token_batch: caps[0].1,
            model_caps: caps.to_vec(),
            kicked: Vec::new(),
            tier_ids: vec![BTreeSet::new(); num_models * tiers_cap],
            tiers_cap,
            be_ids: vec![BTreeSet::new(); num_models],
            pending_ids: vec![BTreeSet::new(); num_models],
            role_ids: [BTreeSet::new(), BTreeSet::new(), BTreeSet::new()],
            ordered_tier: vec![LoadOrdered::new(); num_models * tiers_cap],
            ordered_be: vec![LoadOrdered::new(); num_models],
            ordered_pending: vec![BTreeSet::new(); num_models],
            load_key: vec![(0, 0); n_built],
            pending_key: vec![(0, 0); n_built],
            resident_cnt: vec![0; n_built],
            resident_total: 0,
            arrived_total: 0,
            finished_total: 0,
            resident_per_model: vec![0; num_models],
            arrived_per_model: vec![0; num_models],
            finished_per_model: vec![0; num_models],
            draining_total: 0,
            scan_reference: false,
            indexed_reference: false,
        };
        for id in 0..cluster.instances.len() {
            cluster.index_add_assign(id, cluster.assign[id]);
            cluster.role_ids[role_idx(cluster.instances[id].role)].insert(id);
        }
        cluster
    }

    // ---- membership index maintenance ----

    /// Flat slot of `(model, tier)` in the tier-indexed arrays.
    #[inline]
    fn slot(&self, model: ModelId, k: usize) -> usize {
        debug_assert!(model < self.num_models && k < self.tiers_cap);
        model * self.tiers_cap + k
    }

    /// Grow the flat tier arrays so tier index `k` is addressable for
    /// every model (cold path — policies normally stay within
    /// `num_tiers`). Existing slots are moved, not re-keyed.
    fn ensure_tier_cap(&mut self, k: usize) {
        if k < self.tiers_cap {
            return;
        }
        let new_cap = k + 1;
        let mut tier_ids = vec![BTreeSet::new(); self.num_models * new_cap];
        let mut ordered = vec![LoadOrdered::new(); self.num_models * new_cap];
        for m in 0..self.num_models {
            for t in 0..self.tiers_cap {
                tier_ids[m * new_cap + t] =
                    std::mem::take(&mut self.tier_ids[m * self.tiers_cap + t]);
                ordered[m * new_cap + t] =
                    std::mem::take(&mut self.ordered_tier[m * self.tiers_cap + t]);
            }
        }
        self.tier_ids = tier_ids;
        self.ordered_tier = ordered;
        self.tiers_cap = new_cap;
    }

    fn index_add_assign(&mut self, id: usize, a: TierAssign) {
        // Entering an ordered set keys on the instance's *live*
        // counters (the stored key may predate churn outside any set).
        let key = self.instances[id].load_key();
        self.load_key[id] = key;
        let pkey = self.instances[id].pending_key();
        self.pending_key[id] = pkey;
        let m = self.instances[id].model;
        match a {
            TierAssign::Tier(k) => {
                self.ensure_tier_cap(k);
                let s = self.slot(m, k);
                self.tier_ids[s].insert(id);
                self.ordered_tier[s].insert(load_entry(key, id));
            }
            TierAssign::BestEffort => {
                self.be_ids[m].insert(id);
                self.ordered_be[m].insert(load_entry(key, id));
            }
            TierAssign::Pending => {
                self.pending_ids[m].insert(id);
                self.ordered_pending[m].insert((pkey.0, pkey.1, id));
            }
            TierAssign::Static => {}
        }
    }

    fn index_remove_assign(&mut self, id: usize, a: TierAssign) {
        // Removal must use the key the entry was inserted under — and
        // the model the instance held at insertion time, which is why
        // `complete_swap` re-indexes *around* the model change.
        let key = self.load_key[id];
        let m = self.instances[id].model;
        match a {
            TierAssign::Tier(k) => {
                if k < self.tiers_cap {
                    let s = self.slot(m, k);
                    self.tier_ids[s].remove(&id);
                    self.ordered_tier[s].remove(&load_entry(key, id));
                }
            }
            TierAssign::BestEffort => {
                self.be_ids[m].remove(&id);
                self.ordered_be[m].remove(&load_entry(key, id));
            }
            TierAssign::Pending => {
                self.pending_ids[m].remove(&id);
                let pkey = self.pending_key[id];
                self.ordered_pending[m].remove(&(pkey.0, pkey.1, id));
            }
            TierAssign::Static => {}
        }
    }

    /// Re-key instance `id` after any load mutation: updates the
    /// ordered tier / best-effort entry to the instance's live
    /// `(batch, kv)` counters and folds its residency delta into the
    /// O(1) unplaced-demand accounting.
    ///
    /// This is the ordered-index discipline: every site that mutates an
    /// instance's queues (`push_prefill`/`push_decode`/`push_running`,
    /// `form_batch`'s handoff admits, `complete_iteration`, both
    /// eviction paths, the `clear_*` helpers) must report here —
    /// threaded from the simulator event loop and the router's pended
    /// dispatch. A missed call leaves a stale key that [`Cluster::audit`]
    /// panics on in debug runs. O(1) when nothing changed, O(log m) to
    /// re-key.
    pub fn refresh_load(&mut self, id: usize) {
        let m = self.instances[id].model;
        let res = self.instances[id].resident_requests();
        let old_res = self.resident_cnt[id];
        if res != old_res {
            self.resident_total = self.resident_total + res - old_res;
            self.resident_per_model[m] = self.resident_per_model[m] + res - old_res;
            self.resident_cnt[id] = res;
        }
        // The pending key is compared independently of the load-key
        // fast path below: a prefill push with no committed tokens
        // changes the queued-token component only, so the `(batch, kv)`
        // load key stays put while the pending key moves.
        let pkey = self.instances[id].pending_key();
        if pkey != self.pending_key[id] {
            if self.assign[id] == TierAssign::Pending {
                let old = self.pending_key[id];
                self.ordered_pending[m].remove(&(old.0, old.1, id));
                self.ordered_pending[m].insert((pkey.0, pkey.1, id));
            }
            self.pending_key[id] = pkey;
        }
        let key = self.instances[id].load_key();
        let old_key = self.load_key[id];
        if key == old_key {
            return;
        }
        match self.assign[id] {
            TierAssign::Tier(k) => {
                let s = &mut self.ordered_tier[m * self.tiers_cap + k];
                s.remove(&load_entry(old_key, id));
                s.insert(load_entry(key, id));
            }
            TierAssign::BestEffort => {
                self.ordered_be[m].remove(&load_entry(old_key, id));
                self.ordered_be[m].insert(load_entry(key, id));
            }
            _ => {}
        }
        self.load_key[id] = key;
    }

    /// Tier assignment of instance `id`.
    #[inline]
    pub fn assign_of(&self, id: usize) -> TierAssign {
        self.assign[id]
    }

    /// Read-only view of the full assignment vector (parallel to
    /// `instances`).
    pub fn assignments(&self) -> &[TierAssign] {
        &self.assign
    }

    /// Set instance `id`'s tier assignment. The only write path: it
    /// keeps the per-tier / best-effort / pending id sets mirroring
    /// `assign` exactly.
    pub fn set_assign(&mut self, id: usize, a: TierAssign) {
        let old = self.assign[id];
        if old == a {
            return;
        }
        self.index_remove_assign(id, old);
        self.assign[id] = a;
        self.index_add_assign(id, a);
    }

    /// Route every membership view (and each instance's load
    /// accessors) through the pre-PR full scans instead of the indices
    /// and cached counters — the A/B reference path for
    /// decision-identity tests and perf baselines. Indices and counters
    /// are still maintained, so the switch can flip at any time.
    pub fn set_scan_reference(&mut self, on: bool) {
        self.scan_reference = on;
        for i in &mut self.instances {
            i.set_scan_reference(on);
        }
    }

    /// Is the scan-based reference path active?
    pub fn is_scan_reference(&self) -> bool {
        self.scan_reference
    }

    /// Run the PR-4 *indexed* reference path: membership comes from the
    /// id indices and loads from the cached counters (both as today),
    /// but the router bypasses the load-ordered sets — it materializes
    /// each tier and sorts per placement — and unplaced demand is
    /// reconstructed by scan. The A/B baseline for measuring what the
    /// ordered indices alone buy. Ordered sets are still maintained, so
    /// the switch can flip at any time.
    pub fn set_indexed_reference(&mut self, on: bool) {
        self.indexed_reference = on;
    }

    /// Is the PR-4 indexed (sort-per-placement) reference path active?
    pub fn is_indexed_reference(&self) -> bool {
        self.indexed_reference
    }

    // ---- O(1) unplaced-demand accounting ----

    /// Simulator: a request's arrival event fired for `model`. Feeds
    /// [`Cluster::unplaced_demand`] and its per-model split.
    pub fn note_arrival(&mut self, model: ModelId) {
        self.arrived_total += 1;
        self.arrived_per_model[model] += 1;
    }

    /// Arrival events processed so far. The audit uses this to reconcile
    /// the O(1) counter with the reconstruction scan *mid-timestamp*:
    /// between two same-millisecond arrivals, the scan already counts
    /// the unprocessed one (its `arrival_ms <= now`) while the counter —
    /// correctly — does not.
    pub fn arrived_total(&self) -> usize {
        self.arrived_total
    }

    /// Simulator: `n` requests of `model` fully finished this event.
    /// Feeds [`Cluster::unplaced_demand`] and its per-model split.
    pub fn note_finished(&mut self, model: ModelId, n: usize) {
        self.finished_total += n;
        self.finished_per_model[model] += n;
    }

    /// Arrival events processed so far for `model` (the per-model twin
    /// of [`Cluster::arrived_total`], for the same mid-timestamp
    /// reconciliation).
    pub fn arrived_total_of(&self, model: ModelId) -> usize {
        self.arrived_per_model[model]
    }

    /// Arrived, unfinished requests resident on *no* instance — the
    /// demand the router is holding in its pending queues (or in-flight
    /// migrations). O(1): `arrived − finished − resident`, where every
    /// term is an incremental counter (`note_arrival`/`note_finished`/
    /// the residency delta folded in by `refresh_load`). Finished
    /// requests are never resident and residents have always arrived,
    /// so the subtraction counts exactly the scan's set; the per-event
    /// debug audit asserts equality with [`Cluster::unplaced_demand_scan`].
    pub fn unplaced_demand(&self) -> usize {
        self.arrived_total
            .saturating_sub(self.finished_total)
            .saturating_sub(self.resident_total)
    }

    /// Per-model [`Cluster::unplaced_demand`]: arrived, unfinished
    /// `model` requests resident on no instance. Exact for the same
    /// reason the global counter is — a request only ever resides on
    /// instances of its own model (the hard placement constraint).
    pub fn unplaced_demand_of(&self, model: ModelId) -> usize {
        self.arrived_per_model[model]
            .saturating_sub(self.finished_per_model[model])
            .saturating_sub(self.resident_per_model[model])
    }

    /// The pre-PR unplaced-demand reconstruction: scan every instance's
    /// queues to mark resident requests, then count the arrived,
    /// unfinished, unmarked ones (admission-shed requests are excluded:
    /// they were never counted as arrived and are demand the fleet
    /// deliberately refused). O(total requests + residents) per
    /// call — kept as the debug-audit oracle for the O(1) counter and
    /// as the reference-mode path.
    pub fn unplaced_demand_scan(&self, requests: &[SimRequest], now: TimeMs) -> usize {
        let mut placed = vec![false; requests.len()];
        for i in &self.instances {
            for j in &i.prefill_queue {
                placed[j.req_idx] = true;
            }
            for &(r, _) in &i.decode_queue {
                placed[r] = true;
            }
            for s in &i.running {
                placed[s.req_idx] = true;
            }
        }
        requests
            .iter()
            .enumerate()
            .filter(|(idx, r)| {
                r.req.arrival_ms <= now && r.finish_ms.is_none() && !r.shed && !placed[*idx]
            })
            .count()
    }

    /// Per-model twin of [`Cluster::unplaced_demand_scan`] — the
    /// debug-audit oracle for [`Cluster::unplaced_demand_of`].
    pub fn unplaced_demand_scan_of(
        &self,
        model: ModelId,
        requests: &[SimRequest],
        now: TimeMs,
    ) -> usize {
        let mut placed = vec![false; requests.len()];
        for i in &self.instances {
            for j in &i.prefill_queue {
                placed[j.req_idx] = true;
            }
            for &(r, _) in &i.decode_queue {
                placed[r] = true;
            }
            for s in &i.running {
                placed[s.req_idx] = true;
            }
        }
        requests
            .iter()
            .enumerate()
            .filter(|(idx, r)| {
                r.req.model == model
                    && r.req.arrival_ms <= now
                    && r.finish_ms.is_none()
                    && !r.shed
                    && !placed[*idx]
            })
            .count()
    }

    /// Total instance slots, retired included (ids are stable indices).
    pub fn len(&self) -> usize {
        self.instances.len()
    }

    /// True when the cluster has no instance slots at all.
    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// Instance ids with a given role that accept new work (placement
    /// candidates; provisioning / draining / retired are excluded).
    /// Ascending id order, O(role size) off the role index.
    pub fn with_role(&self, role: Role) -> impl Iterator<Item = usize> + '_ {
        if self.scan_reference {
            ViewIter::Scan(
                self.instances
                    .iter()
                    .filter(move |i| i.role == role && i.lifecycle.accepts_work())
                    .map(|i| i.id),
            )
        } else {
            ViewIter::Indexed(
                self.role_ids[role_idx(role)]
                    .iter()
                    .copied()
                    .filter(move |&id| self.instances[id].lifecycle.accepts_work()),
            )
        }
    }

    /// Per-model [`Cluster::with_role`]: `model` instances of `role`
    /// that accept work, ascending id order.
    pub fn with_role_of(
        &self,
        model: ModelId,
        role: Role,
    ) -> impl Iterator<Item = usize> + '_ {
        self.with_role(role)
            .filter(move |&id| self.instances[id].model == model)
    }

    /// Checked flat slot of `(model, tier)`: `None` when `k` was never
    /// allocated (so an out-of-range tier index can never alias into
    /// another model's slot range).
    #[inline]
    fn slot_checked(&self, model: ModelId, k: usize) -> Option<usize> {
        (k < self.tiers_cap && model < self.num_models)
            .then(|| model * self.tiers_cap + k)
    }

    /// Instance ids currently assigned to tier `k` and accepting work,
    /// chained in model order (for a single-model fleet this *is* the
    /// plain ascending-id tier view of PRs 4–6). O(tier size) off the
    /// per-(model, tier) indices.
    pub fn in_tier(&self, k: usize) -> impl Iterator<Item = usize> + '_ {
        (0..self.num_models).flat_map(move |m| self.in_tier_of(m, k))
    }

    /// Per-model tier membership: `model` instances assigned `Tier(k)`
    /// that accept work, ascending id order. The hard placement
    /// constraint's routing view — a `model`-tagged request may only
    /// land on ids from here.
    pub fn in_tier_of(
        &self,
        model: ModelId,
        k: usize,
    ) -> impl Iterator<Item = usize> + '_ {
        if self.scan_reference {
            ViewIter::Scan(
                self.assign
                    .iter()
                    .enumerate()
                    .filter(move |(i, a)| {
                        **a == TierAssign::Tier(k)
                            && self.instances[*i].model == model
                            && self.instances[*i].lifecycle.accepts_work()
                    })
                    .map(|(i, _)| i),
            )
        } else {
            ViewIter::Indexed(
                self.slot_checked(model, k)
                    .map(|s| &self.tier_ids[s])
                    .into_iter()
                    .flat_map(|s| s.iter().copied())
                    .filter(move |&id| self.instances[id].lifecycle.accepts_work()),
            )
        }
    }

    /// Tier-`k` members accepting work, in descending `(batch, kv, id)`
    /// load order — the §4.3 load-gradient walk as plain in-order
    /// iteration off the ordered index. Bit-for-bit the sequence the
    /// router's old materialize-and-sort produced (including the
    /// descending-id tie-break), but with no per-placement allocation
    /// or sort: the cost moved to an O(log m) re-key per load mutation
    /// (`refresh_load`). Reference modes must not use this — the router
    /// falls back to collect+sort over [`Cluster::in_tier`] there.
    pub fn tier_by_load_desc(&self, k: usize) -> impl Iterator<Item = usize> + '_ {
        (0..self.num_models).flat_map(move |m| self.tier_by_load_desc_of(m, k))
    }

    /// Per-model [`Cluster::tier_by_load_desc`]: the model-aware
    /// router's §4.3 gradient walk over `model`'s tier-`k` members.
    pub fn tier_by_load_desc_of(
        &self,
        model: ModelId,
        k: usize,
    ) -> impl Iterator<Item = usize> + '_ {
        self.slot_checked(model, k)
            .map(|s| &self.ordered_tier[s])
            .into_iter()
            .flat_map(|s| s.iter())
            .map(|&Reverse((_, _, id))| id)
            .filter(move |&id| self.instances[id].lifecycle.accepts_work())
    }

    /// Ascending twin of [`Cluster::tier_by_load_desc`] — the same
    /// ordered set walked in reverse, which is exactly the ascending
    /// `(batch, kv, id)` sort of the `load_gradient = off` ablation.
    pub fn tier_by_load_asc(&self, k: usize) -> impl Iterator<Item = usize> + '_ {
        (0..self.num_models).flat_map(move |m| self.tier_by_load_asc_of(m, k))
    }

    /// Per-model [`Cluster::tier_by_load_asc`].
    pub fn tier_by_load_asc_of(
        &self,
        model: ModelId,
        k: usize,
    ) -> impl Iterator<Item = usize> + '_ {
        self.slot_checked(model, k)
            .map(|s| &self.ordered_tier[s])
            .into_iter()
            .flat_map(|s| s.iter().rev())
            .map(|&Reverse((_, _, id))| id)
            .filter(move |&id| self.instances[id].lifecycle.accepts_work())
    }

    /// The best-effort pool's load-ordered twin: active pool members in
    /// descending `(batch, kv, id)` order. Maintained by the same
    /// re-key discipline as the tier sets (and covered by the audit);
    /// `claim_for_tier` keeps claiming by lowest id for decision
    /// identity, so this view is for policies that want the pool by
    /// load — reverse it for least-loaded-first.
    pub fn best_effort_by_load(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.num_models).flat_map(move |m| self.best_effort_by_load_of(m))
    }

    /// Per-model [`Cluster::best_effort_by_load`].
    pub fn best_effort_by_load_of(
        &self,
        model: ModelId,
    ) -> impl Iterator<Item = usize> + '_ {
        self.ordered_be[model]
            .iter()
            .map(|&Reverse((_, _, id))| id)
            .filter(move |&id| self.instances[id].lifecycle.accepts_work())
    }

    /// Instance ids in the best-effort pool (claimable: active only),
    /// chained in model order — plain ascending-id for a single-model
    /// fleet. O(pool size) off the per-model pool indices.
    pub fn best_effort_pool(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.num_models).flat_map(move |m| self.best_effort_pool_of(m))
    }

    /// Per-model best-effort pool: claimable `model` instances,
    /// ascending id order.
    pub fn best_effort_pool_of(
        &self,
        model: ModelId,
    ) -> impl Iterator<Item = usize> + '_ {
        if self.scan_reference {
            ViewIter::Scan(
                self.assign
                    .iter()
                    .enumerate()
                    .filter(move |(i, a)| {
                        **a == TierAssign::BestEffort
                            && self.instances[*i].model == model
                            && self.instances[*i].lifecycle.accepts_work()
                    })
                    .map(|(i, _)| i),
            )
        } else {
            ViewIter::Indexed(
                self.be_ids[model]
                    .iter()
                    .copied()
                    .filter(move |&id| self.instances[id].lifecycle.accepts_work()),
            )
        }
    }

    /// Instance ids in the §4.4 pending state that accept work, chained
    /// in model order — plain ascending-id for a single-model fleet.
    /// O(pending size) off the per-model pending indices.
    pub fn pending_pool(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.num_models).flat_map(move |m| self.pending_pool_of(m))
    }

    /// Per-model pending pool: `model` instances in the §4.4 pending
    /// state that accept work, ascending id order.
    pub fn pending_pool_of(&self, model: ModelId) -> impl Iterator<Item = usize> + '_ {
        if self.scan_reference {
            ViewIter::Scan(
                self.assign
                    .iter()
                    .enumerate()
                    .filter(move |(i, a)| {
                        **a == TierAssign::Pending
                            && self.instances[*i].model == model
                            && self.instances[*i].lifecycle.accepts_work()
                    })
                    .map(|(i, _)| i),
            )
        } else {
            ViewIter::Indexed(
                self.pending_ids[model]
                    .iter()
                    .copied()
                    .filter(move |&id| self.instances[id].lifecycle.accepts_work()),
            )
        }
    }

    /// The pending pool's ordered twin: pending-state instances that
    /// accept work, in *ascending* `(decode batch, queued prefill
    /// tokens, id)` order. `.next()` is exactly the least-loaded
    /// min-scan `forced_target` used to run over
    /// [`Cluster::pending_pool`] (`min_by_key` over an ascending-id
    /// view returns the lexicographic `(batch, tokens, id)` minimum),
    /// so the fallback's pick is bit-for-bit unchanged. Maintained by
    /// the same re-key discipline as the tier sets — via the separate
    /// pending key, since this ordering can move without the load key
    /// moving — and covered by the audit. Reference modes must not use
    /// this — the router keeps the min-scan there.
    pub fn pending_by_load(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.num_models).flat_map(move |m| self.pending_by_load_of(m))
    }

    /// Per-model [`Cluster::pending_by_load`].
    pub fn pending_by_load_of(
        &self,
        model: ModelId,
    ) -> impl Iterator<Item = usize> + '_ {
        self.ordered_pending[model]
            .iter()
            .map(|&(_, _, id)| id)
            .filter(move |&id| self.instances[id].lifecycle.accepts_work())
    }

    /// Ids holding a `Tier(_)` or `Pending` assignment, any lifecycle,
    /// ascending — the candidate set of the router's autoscale-down
    /// sweep (every other assignment is a no-op there, so visiting only
    /// these is decision-identical to sweeping the whole fleet).
    pub fn assigned_ids(&self) -> Vec<usize> {
        if self.scan_reference {
            return (0..self.assign.len())
                .filter(|&i| {
                    matches!(self.assign[i], TierAssign::Tier(_) | TierAssign::Pending)
                })
                .collect();
        }
        let mut ids: Vec<usize> = self
            .tier_ids
            .iter()
            .flat_map(|s| s.iter().copied())
            .chain(self.pending_ids.iter().flat_map(|s| s.iter().copied()))
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Claim an instance from the BE pool for tier `k` (§4.3: "joining a
    /// particular SLO tier simply requires ... reconfiguring"; instant).
    /// Returns the claimed id. Single-model shorthand for
    /// [`Cluster::claim_for_tier_of`] on model 0 — bit-identical to the
    /// pre-registry claim, since a single-model pool *is* the model-0
    /// pool.
    pub fn claim_for_tier(&mut self, k: usize, now: TimeMs) -> Option<usize> {
        self.claim_for_tier_of(0, k, now)
    }

    /// Claim a `model` instance from its per-model BE pool for
    /// `(model, tier k)`. Lowest id first (decision identity with the
    /// single-model claim); the claimed instance lands in the
    /// per-(model, tier) membership slot.
    pub fn claim_for_tier_of(
        &mut self,
        model: ModelId,
        k: usize,
        now: TimeMs,
    ) -> Option<usize> {
        let id = self.best_effort_pool_of(model).next()?;
        self.set_assign(id, TierAssign::Tier(k));
        self.instances[id].alloc_start(now);
        Some(id)
    }

    /// Move a pending instance into tier `k` (it already holds promoted
    /// requests of that tier).
    pub fn adopt_pending(&mut self, id: usize, k: usize) {
        debug_assert_eq!(self.assign[id], TierAssign::Pending);
        self.set_assign(id, TierAssign::Tier(k));
        // alloc interval already open from its previous tier stint.
    }

    /// Mark an instance pending (§4.4).
    pub fn mark_pending(&mut self, id: usize) {
        self.set_assign(id, TierAssign::Pending);
    }

    /// Release an instance to the best-effort pool.
    pub fn release(&mut self, id: usize, now: TimeMs) {
        debug_assert!(self.instances[id].is_empty(), "releasing a busy instance");
        self.set_assign(id, TierAssign::BestEffort);
        self.instances[id].alloc_end(now);
    }

    // ---- elastic fleet lifecycle ----

    /// Add a cold-starting instance to the fleet; it accepts no work
    /// until `ready_at` (the simulator fires `InstanceReady` then).
    /// Returns the new instance id.
    ///
    /// Tier assignment mirrors [`Cluster::build`]: prefill servers are
    /// always `Static` — a provisioned prefill instance must never
    /// enter the best-effort pool, or `claim_for_tier` would hand a
    /// prefill server to a TPOT tier (the role-confusion bug exposed by
    /// making the prefill tier elastic).
    pub fn provision(&mut self, role: Role, now: TimeMs, ready_at: TimeMs) -> usize {
        self.provision_model(0, role, now, ready_at)
    }

    /// Provision a cold-starting instance pre-loaded with registry
    /// model `model`, sized by that model's `(kv_capacity,
    /// max_token_batch)` caps. [`Cluster::provision`] is the model-0
    /// shorthand; assignment rules are identical.
    pub fn provision_model(
        &mut self,
        model: ModelId,
        role: Role,
        now: TimeMs,
        ready_at: TimeMs,
    ) -> usize {
        let id = self.instances.len();
        let (kv_cap, mtb) = self.model_caps[model];
        let mut inst = Instance::new_provisioning(id, role, kv_cap, mtb, now, ready_at);
        inst.model = model;
        inst.set_scan_reference(self.scan_reference);
        self.instances.push(inst);
        let a = match role {
            Role::Prefill => TierAssign::Static,
            _ if self.managed => TierAssign::BestEffort,
            _ => TierAssign::Static,
        };
        self.assign.push(a);
        self.load_key.push((0, 0));
        self.pending_key.push((0, 0));
        self.resident_cnt.push(0);
        self.index_add_assign(id, a);
        self.role_ids[role_idx(role)].insert(id);
        id
    }

    /// Cold start finished: the instance joins the serving fleet.
    pub fn mark_ready(&mut self, id: usize) {
        self.instances[id].mark_ready();
    }

    /// Start draining `id`: it accepts nothing new and is retired once
    /// its resident requests finish.
    pub fn begin_drain(&mut self, id: usize, now: TimeMs) {
        self.instances[id].begin_drain(now);
        self.draining_total += 1;
    }

    /// Retire `id` if it is draining, has no work left, and any
    /// migrated-out KV has finished streaming off it (`egress_until`).
    /// Returns true if it retired. A drain that is really a model swap
    /// (`swap_to` set) never retires here — the simulator routes it to
    /// [`Cluster::complete_swap`] instead.
    pub fn retire_if_drained(&mut self, id: usize, now: TimeMs) -> bool {
        if matches!(self.instances[id].lifecycle, Lifecycle::Draining { .. })
            && self.instances[id].swap_to.is_none()
            && self.instances[id].is_empty()
            && self.instances[id].egress_until <= now
        {
            self.instances[id].retire(now);
            self.draining_total -= 1;
            return true;
        }
        false
    }

    /// Hard-kill `id` (`InstanceFail`): detach every resident request
    /// ([`Instance::fail_residents`]) and force-retire the instance
    /// *now* — regardless of lifecycle state, in-flight KV egress
    /// (`egress_until`), or a pending model swap, none of which can
    /// complete on a dead device. Billing stops at the failure event
    /// (the retire timestamp caps `active_span_ms`), unlike a graceful
    /// drain which bills until its last migrated-out transfer has left.
    ///
    /// Returns the detached victims in deterministic order (running
    /// batch, decode handoffs, prefill queue); the caller re-routes
    /// each through `route_new` for a full re-prefill — their KV died
    /// with the instance. Keeps every counter audit-coherent: the
    /// draining count drops if the victim was mid-drain and the
    /// residency/load keys are refreshed before returning.
    pub fn fail(&mut self, id: usize, now: TimeMs) -> Vec<usize> {
        let victims = self.instances[id].fail_residents();
        if matches!(self.instances[id].lifecycle, Lifecycle::Draining { .. }) {
            self.draining_total -= 1;
        }
        self.instances[id].swap_to = None;
        self.instances[id].retire(now);
        self.refresh_load(id);
        victims
    }

    /// Live instance ids inside failure zone `zone` — and, when `rack`
    /// is set, only the instances on that rack. Ascending id order (the
    /// deterministic kill order for a `ChaosFailDomain` draw).
    pub fn live_in_domain(&self, zone: u32, rack: Option<u32>) -> Vec<usize> {
        self.instances
            .iter()
            .filter(|i| {
                i.lifecycle.is_live()
                    && i.domain.0 == zone
                    && rack.map(|r| i.domain.1 == r).unwrap_or(true)
            })
            .map(|i| i.id)
            .collect()
    }

    // ---- model hot-swap lifecycle ----

    /// Start swapping `id` to registry model `target`: the instance
    /// drains (accepts nothing new, residents finish or migrate off)
    /// and, once empty with egress done, [`Cluster::complete_swap`]
    /// reloads it. Billing never pauses — the instance stays in the
    /// fleet for cost accounting throughout the swap.
    pub fn begin_swap(&mut self, id: usize, target: ModelId, now: TimeMs) {
        debug_assert!(target < self.num_models, "swap target outside the registry");
        debug_assert_ne!(
            self.instances[id].model, target,
            "swapping inst {id} to the model it already serves"
        );
        self.instances[id].swap_to = Some(target);
        if !matches!(self.instances[id].lifecycle, Lifecycle::Draining { .. }) {
            self.begin_drain(id, now);
        }
    }

    /// The model `id` is draining toward, if its drain is a swap.
    pub fn swap_pending(&self, id: usize) -> Option<ModelId> {
        self.instances[id].swap_to
    }

    /// True when a swap-draining instance has emptied out (no residents,
    /// egress done) and is ready for [`Cluster::complete_swap`].
    pub fn swap_ready(&self, id: usize, now: TimeMs) -> bool {
        self.instances[id].swap_to.is_some()
            && matches!(self.instances[id].lifecycle, Lifecycle::Draining { .. })
            && self.instances[id].is_empty()
            && self.instances[id].egress_until <= now
    }

    /// Finish a model swap on a fully drained instance: re-key every
    /// membership index around the model change (removal under the
    /// *old* model, insertion under the *new* — see
    /// `index_remove_assign`), reload the instance with the target
    /// model's caps, and put it back through the cold-start path
    /// (`Provisioning` until `ready_at`; the simulator fires
    /// `InstanceReady` then). Returns the model it reloaded to.
    pub fn complete_swap(&mut self, id: usize, now: TimeMs, ready_at: TimeMs) -> ModelId {
        let target = self.instances[id]
            .swap_to
            .expect("complete_swap without begin_swap");
        let old_assign = self.assign[id];
        self.index_remove_assign(id, old_assign);
        let (kv_cap, mtb) = self.model_caps[target];
        self.instances[id].complete_swap(target, kv_cap, mtb, now, ready_at);
        self.draining_total -= 1;
        // Reset assignment to the provision default for its role; a
        // tier stint it held under the old model ends here.
        let a = match self.instances[id].role {
            Role::Prefill => TierAssign::Static,
            _ if self.managed => TierAssign::BestEffort,
            _ => TierAssign::Static,
        };
        if matches!(old_assign, TierAssign::Tier(_) | TierAssign::Pending) {
            self.instances[id].alloc_end(now);
        }
        self.assign[id] = a;
        self.index_add_assign(id, a);
        target
    }

    /// Any instance currently draining? O(1) — lets the housekeeping
    /// tick skip its retire sweep on the (common) all-steady fleet.
    pub fn draining_any(&self) -> bool {
        self.draining_total > 0
    }

    /// Count instances of `role` in lifecycle states selected by `f`.
    fn count_lifecycle(&self, role: Role, f: impl Fn(&Lifecycle) -> bool) -> usize {
        self.instances
            .iter()
            .filter(|i| i.role == role && f(&i.lifecycle))
            .count()
    }

    /// Serving instances of `role` (lifecycle Active).
    pub fn active_count(&self, role: Role) -> usize {
        self.count_lifecycle(role, Lifecycle::accepts_work)
    }

    /// Committed capacity of `role`: active + still cold-starting
    /// (drainers are on their way out and do not count).
    pub fn committed_count(&self, role: Role) -> usize {
        self.count_lifecycle(role, |l| {
            matches!(l, Lifecycle::Active | Lifecycle::Provisioning { .. })
        })
    }

    /// Per-model [`Cluster::active_count`]: serving `model` instances
    /// of `role`.
    pub fn active_count_of(&self, model: ModelId, role: Role) -> usize {
        self.instances
            .iter()
            .filter(|i| i.model == model && i.role == role && i.lifecycle.accepts_work())
            .count()
    }

    /// Per-model [`Cluster::committed_count`]: active + cold-starting
    /// `model` instances of `role`, **plus** instances of any model
    /// currently swap-draining *toward* `model` — capacity already on
    /// its way, so a sizing pass never double-issues the swap.
    pub fn committed_count_of(&self, model: ModelId, role: Role) -> usize {
        self.instances
            .iter()
            .filter(|i| {
                i.role == role
                    && ((i.model == model
                        && matches!(
                            i.lifecycle,
                            Lifecycle::Active | Lifecycle::Provisioning { .. }
                        ))
                        || i.swap_to == Some(model))
            })
            .count()
    }

    /// Instances of `role` currently provisioning.
    pub fn provisioning_count(&self, role: Role) -> usize {
        self.count_lifecycle(role, |l| matches!(l, Lifecycle::Provisioning { .. }))
    }

    /// Instances of `role` currently draining.
    pub fn draining_count(&self, role: Role) -> usize {
        self.count_lifecycle(role, |l| matches!(l, Lifecycle::Draining { .. }))
    }

    /// Router-side: mark that `inst` received work and may need its
    /// iteration (re)started by the simulator.
    pub fn mark_kicked(&mut self, inst: usize) {
        self.kicked.push(inst);
    }

    /// Simulator side: drain the list of router-fed instances to restart.
    pub fn take_kicked(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.kicked)
    }

    /// Assert the membership indices mirror `assign` exactly, the
    /// load-ordered sets hold every keyed member under its *live*
    /// `(batch, kv)` counters (a stale key means a mutation site
    /// skipped [`Cluster::refresh_load`]), the residency and draining
    /// counters match their scans, and every instance's cached load
    /// counters equal their scan-recomputed values. Runs after every
    /// simulator event in debug-assertion builds
    /// (`SimParams::debug_audit`); panics on the first drift.
    pub fn audit(&self, requests: &[SimRequest]) {
        for (id, &a) in self.assign.iter().enumerate() {
            let model = self.instances[id].model;
            // Membership is keyed by (model, assignment): the id must
            // appear in exactly its own model's slot/pool and in no
            // other model's — the per-model re-derivation of satellite
            // audits (a swap that skipped the re-key discipline leaves
            // the id stranded under its old model and trips this).
            let expect_slot = match a {
                TierAssign::Tier(k) => self.slot_checked(model, k),
                _ => None,
            };
            for (s, set) in self.tier_ids.iter().enumerate() {
                assert_eq!(
                    set.contains(&id),
                    expect_slot == Some(s),
                    "inst {id} (model {model}): tier slot {s} disagrees with assign {a:?}"
                );
            }
            for (m, set) in self.be_ids.iter().enumerate() {
                assert_eq!(
                    set.contains(&id),
                    a == TierAssign::BestEffort && m == model,
                    "inst {id} (model {model}): be_ids[{m}] disagrees with assign {a:?}"
                );
            }
            for (m, set) in self.pending_ids.iter().enumerate() {
                assert_eq!(
                    set.contains(&id),
                    a == TierAssign::Pending && m == model,
                    "inst {id} (model {model}): pending_ids[{m}] disagrees with assign {a:?}"
                );
            }
            assert!(
                self.role_ids[role_idx(self.instances[id].role)].contains(&id),
                "inst {id}: missing from its role index"
            );
            // Re-key discipline: the stored key must equal the live
            // counters, and the keyed sets must hold exactly that entry.
            let live = self.instances[id].load_key();
            assert_eq!(
                self.load_key[id], live,
                "inst {id}: load key stale — a mutation site skipped refresh_load"
            );
            assert_eq!(
                self.resident_cnt[id],
                self.instances[id].resident_requests(),
                "inst {id}: resident count stale — a mutation site skipped refresh_load"
            );
            let pend_live = self.instances[id].pending_key();
            assert_eq!(
                self.pending_key[id], pend_live,
                "inst {id}: pending key stale — a mutation site skipped refresh_load"
            );
            match a {
                TierAssign::Tier(k) => assert!(
                    self.ordered_tier[self.slot(model, k)]
                        .contains(&load_entry(live, id)),
                    "inst {id}: missing from ordered tier ({model}, {k}) under its live key"
                ),
                TierAssign::BestEffort => assert!(
                    self.ordered_be[model].contains(&load_entry(live, id)),
                    "inst {id}: missing from model {model}'s ordered best-effort set"
                ),
                TierAssign::Pending => assert!(
                    self.ordered_pending[model]
                        .contains(&(pend_live.0, pend_live.1, id)),
                    "inst {id}: missing from model {model}'s ordered pending set"
                ),
                TierAssign::Static => {}
            }
        }
        let sets_total: usize = self.tier_ids.iter().map(|s| s.len()).sum::<usize>()
            + self.be_ids.iter().map(|s| s.len()).sum::<usize>()
            + self.pending_ids.iter().map(|s| s.len()).sum::<usize>();
        let assigned = self
            .assign
            .iter()
            .filter(|a| **a != TierAssign::Static)
            .count();
        assert_eq!(sets_total, assigned, "stale ids left in a membership set");
        let ordered_total: usize = self.ordered_tier.iter().map(|s| s.len()).sum::<usize>()
            + self.ordered_be.iter().map(|s| s.len()).sum::<usize>();
        let keyed = self
            .assign
            .iter()
            .filter(|a| matches!(a, TierAssign::Tier(_) | TierAssign::BestEffort))
            .count();
        assert_eq!(ordered_total, keyed, "stale entries left in a load-ordered set");
        assert_eq!(
            self.ordered_pending.iter().map(|s| s.len()).sum::<usize>(),
            self.pending_ids.iter().map(|s| s.len()).sum::<usize>(),
            "stale entries left in the ordered pending set"
        );
        assert_eq!(
            self.resident_total,
            self.instances.iter().map(Instance::resident_requests).sum::<usize>(),
            "incremental residency counter drifted"
        );
        // Per-model unplaced-demand split: each residency counter must
        // equal the scan over its own model's instances, and the splits
        // must sum to the totals.
        for m in 0..self.num_models {
            assert_eq!(
                self.resident_per_model[m],
                self.instances
                    .iter()
                    .filter(|i| i.model == m)
                    .map(Instance::resident_requests)
                    .sum::<usize>(),
                "per-model residency counter drifted for model {m}"
            );
        }
        assert_eq!(
            self.arrived_per_model.iter().sum::<usize>(),
            self.arrived_total,
            "per-model arrival split drifted"
        );
        assert_eq!(
            self.finished_per_model.iter().sum::<usize>(),
            self.finished_total,
            "per-model finished split drifted"
        );
        assert_eq!(
            self.draining_total,
            self.instances
                .iter()
                .filter(|i| matches!(i.lifecycle, Lifecycle::Draining { .. }))
                .count(),
            "draining counter drifted"
        );
        for i in &self.instances {
            i.audit_cached_load(requests);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::h200_llama8b()
    }

    #[test]
    fn pd_build_splits_roles() {
        let c = Cluster::build(ServingMode::PdDisaggregated, 20, 0.35, 4, &cm(), true);
        let prefill = c.with_role(Role::Prefill).count();
        let decode = c.with_role(Role::Decode).count();
        assert_eq!(prefill, 7);
        assert_eq!(decode, 13);
        // prefill static, decode in BE pool (PolyServe-managed)
        assert_eq!(c.best_effort_pool().count(), 13);
    }

    #[test]
    fn coloc_build_all_coloc() {
        let c = Cluster::build(ServingMode::Colocated, 8, 0.35, 4, &cm(), false);
        assert_eq!(c.with_role(Role::Coloc).count(), 8);
        assert_eq!(c.best_effort_pool().count(), 0); // static for baselines
    }

    #[test]
    fn claim_and_release_lifecycle() {
        let mut c = Cluster::build(ServingMode::Colocated, 4, 0.0, 2, &cm(), true);
        let id = c.claim_for_tier(1, 100).unwrap();
        assert_eq!(c.assign_of(id), TierAssign::Tier(1));
        assert_eq!(c.in_tier(1).count(), 1);
        assert_eq!(c.best_effort_pool().count(), 3);
        c.mark_pending(id);
        assert_eq!(c.in_tier(1).count(), 0);
        c.adopt_pending(id, 0);
        assert_eq!(c.in_tier(0).count(), 1);
        c.mark_pending(id);
        c.release(id, 500);
        assert_eq!(c.best_effort_pool().count(), 4);
        assert_eq!(c.instances[id].allocated_ms(1000), 400);
    }

    #[test]
    fn claim_exhausts_pool() {
        let mut c = Cluster::build(ServingMode::Colocated, 2, 0.0, 1, &cm(), true);
        assert!(c.claim_for_tier(0, 0).is_some());
        assert!(c.claim_for_tier(0, 0).is_some());
        assert!(c.claim_for_tier(0, 0).is_none());
    }

    #[test]
    fn kicked_roundtrip() {
        let mut c = Cluster::build(ServingMode::Colocated, 2, 0.0, 1, &cm(), true);
        c.mark_kicked(1);
        c.mark_kicked(0);
        assert_eq!(c.take_kicked(), vec![1, 0]);
        assert!(c.take_kicked().is_empty());
    }

    #[test]
    fn provision_drain_retire_lifecycle() {
        let mut c = Cluster::build(ServingMode::Colocated, 2, 0.0, 2, &cm(), true);
        assert_eq!(c.active_count(Role::Coloc), 2);
        // Provision a third instance with a 5 s cold start.
        let id = c.provision(Role::Coloc, 1000, 6000);
        assert_eq!(id, 2);
        assert_eq!(c.len(), 3);
        // Not claimable while provisioning.
        assert_eq!(c.best_effort_pool().count(), 2);
        assert_eq!(c.committed_count(Role::Coloc), 3);
        assert_eq!(c.provisioning_count(Role::Coloc), 1);
        c.mark_ready(id);
        assert_eq!(c.best_effort_pool().count(), 3);
        assert_eq!(c.active_count(Role::Coloc), 3);
        // Drain it: leaves every placement view immediately.
        c.begin_drain(id, 8000);
        assert_eq!(c.best_effort_pool().count(), 2);
        assert_eq!(c.with_role(Role::Coloc).count(), 2);
        assert_eq!(c.draining_count(Role::Coloc), 1);
        assert_eq!(c.committed_count(Role::Coloc), 2);
        // Empty, so it retires right away.
        assert!(c.retire_if_drained(id, 9000));
        assert!(!c.retire_if_drained(id, 9000));
        assert_eq!(c.len(), 3, "retired instances keep their slot");
        assert_eq!(c.active_count(Role::Coloc), 2);
        assert_eq!(c.instances[id].active_span_ms(20_000), 8000);
    }

    #[test]
    fn fail_force_retires_from_any_live_state() {
        let mut c = Cluster::build(ServingMode::Colocated, 3, 0.0, 2, &cm(), true);
        // Fail an Active tier member with in-flight egress: billing and
        // membership end at the failure, egress notwithstanding.
        let id = c.claim_for_tier(0, 0).unwrap();
        c.instances[id].egress_until = 99_999;
        let victims = c.fail(id, 4_000);
        assert!(victims.is_empty());
        assert!(!c.instances[id].lifecycle.is_live());
        assert_eq!(c.instances[id].active_span_ms(50_000), 4_000);
        assert_eq!(c.in_tier(0).count(), 0);
        // Fail a Draining instance: the draining counter stays coherent.
        let other = c.best_effort_pool().next().unwrap();
        c.begin_drain(other, 5_000);
        assert!(c.draining_any());
        c.fail(other, 6_000);
        assert!(!c.draining_any());
        c.audit(&[]);
    }

    #[test]
    fn draining_tier_member_leaves_tier_view() {
        let mut c = Cluster::build(ServingMode::Colocated, 3, 0.0, 2, &cm(), true);
        let id = c.claim_for_tier(0, 0).unwrap();
        assert_eq!(c.in_tier(0).count(), 1);
        c.begin_drain(id, 100);
        assert_eq!(c.in_tier(0).count(), 0, "draining member must be unroutable");
    }

    #[test]
    fn provisioned_prefill_stays_out_of_the_tier_pool() {
        // The PR 1 role-confusion bug: a provisioned Prefill instance
        // entered the BE pool of a managed fleet, where claim_for_tier
        // could hand it to a TPOT tier.
        let mut c = Cluster::build(ServingMode::PdDisaggregated, 4, 0.5, 2, &cm(), true);
        let be_before = c.best_effort_pool().count();
        let id = c.provision(Role::Prefill, 0, 100);
        c.mark_ready(id);
        assert_eq!(c.assign_of(id), TierAssign::Static);
        assert_eq!(c.best_effort_pool().count(), be_before);
        assert_eq!(c.with_role(Role::Prefill).count(), 3);
        // Decode provisioning still joins the pool.
        let id2 = c.provision(Role::Decode, 0, 100);
        c.mark_ready(id2);
        assert_eq!(c.best_effort_pool().count(), be_before + 1);
    }

    #[test]
    fn single_instance_pd_keeps_one_decode() {
        let c = Cluster::build(ServingMode::PdDisaggregated, 2, 0.5, 1, &cm(), true);
        assert_eq!(c.with_role(Role::Prefill).count(), 1);
        assert_eq!(c.with_role(Role::Decode).count(), 1);
    }

    /// Every view must yield the exact sequence (values *and* order) the
    /// pre-PR scans produced, across assignment and lifecycle churn.
    #[test]
    fn indexed_views_match_scan_reference_exactly() {
        let mut c = Cluster::build(ServingMode::PdDisaggregated, 10, 0.3, 4, &cm(), true);
        // Churn: claims, pending, drains, provisions.
        let a = c.claim_for_tier(0, 0).unwrap();
        let b = c.claim_for_tier(2, 0).unwrap();
        c.claim_for_tier(2, 0).unwrap();
        c.mark_pending(b);
        c.begin_drain(a, 10);
        let p = c.provision(Role::Decode, 10, 50);
        c.mark_ready(p);
        c.provision(Role::Prefill, 10, 50); // still provisioning

        let snapshot = |c: &Cluster| {
            let mut v: Vec<Vec<usize>> = Vec::new();
            for k in 0..c.num_tiers {
                v.push(c.in_tier(k).collect());
            }
            v.push(c.best_effort_pool().collect());
            v.push(c.pending_pool().collect());
            v.push(c.with_role(Role::Prefill).collect());
            v.push(c.with_role(Role::Decode).collect());
            v.push(c.assigned_ids());
            v
        };
        let indexed = snapshot(&c);
        c.set_scan_reference(true);
        assert!(c.is_scan_reference());
        let scanned = snapshot(&c);
        assert_eq!(indexed, scanned);
        c.set_scan_reference(false);
        c.audit(&[]);
    }

    #[test]
    fn assigned_ids_cover_tiered_and_pending_any_lifecycle() {
        let mut c = Cluster::build(ServingMode::Colocated, 5, 0.0, 2, &cm(), true);
        let a = c.claim_for_tier(0, 0).unwrap();
        let b = c.claim_for_tier(1, 0).unwrap();
        c.mark_pending(b);
        // A draining tier member stays a sweep candidate (the router
        // may still release it mid-drain, closing its alloc window).
        c.begin_drain(a, 5);
        assert_eq!(c.assigned_ids(), vec![a, b]);
        assert!(c.draining_any());
        assert!(c.retire_if_drained(a, 10));
        assert!(!c.draining_any());
        // Retired keeps its Tier assignment until released; still listed.
        assert_eq!(c.assigned_ids(), vec![a, b]);
        c.audit(&[]);
    }

    fn sim_req(id: u64, p: u32, decoded: u32) -> SimRequest<'static> {
        use crate::slo::Slo;
        use crate::workload::Request;
        // Leak the immutable half: the arena borrows, never clones.
        let req: &'static Request = Box::leak(Box::new(Request {
            id,
            arrival_ms: 0,
            prefill_len: p,
            decode_len: 500,
            slo: Slo::new(1000, 50),
            model: 0,
        }));
        let mut r = SimRequest::new(req, 0);
        r.prefill_done = p;
        r.decoded = decoded;
        r.first_token_ms = Some(1);
        r
    }

    /// The ordered tier walk must track load re-keys: descending
    /// `(batch, kv, id)` forward (the gradient walk, descending-id
    /// ties), ascending in reverse (the ablation walk), draining
    /// members filtered out.
    #[test]
    fn ordered_tier_walk_tracks_rekeys() {
        let mut c = Cluster::build(ServingMode::Colocated, 4, 0.0, 2, &cm(), true);
        let reqs = vec![sim_req(0, 100, 4), sim_req(1, 200, 4)];
        for id in 0..3 {
            assert_eq!(c.claim_for_tier(0, 0), Some(id));
        }
        // All keys (0, 0): descending-id ties, ascending twin reversed.
        assert_eq!(c.tier_by_load_desc(0).collect::<Vec<_>>(), vec![2, 1, 0]);
        assert_eq!(c.tier_by_load_asc(0).collect::<Vec<_>>(), vec![0, 1, 2]);
        // Load instance 1: it must move to the front of the walk.
        c.instances[1].push_running(0, &reqs);
        c.refresh_load(1);
        assert_eq!(c.tier_by_load_desc(0).collect::<Vec<_>>(), vec![1, 2, 0]);
        assert_eq!(c.tier_by_load_asc(0).collect::<Vec<_>>(), vec![0, 2, 1]);
        // Heavier KV on instance 0 at the same batch depth: kv breaks it.
        c.instances[0].push_running(1, &reqs);
        c.refresh_load(0);
        assert_eq!(c.tier_by_load_desc(0).collect::<Vec<_>>(), vec![0, 1, 2]);
        // Draining members leave the walk (lifecycle filtered at read).
        c.begin_drain(2, 10);
        assert_eq!(c.tier_by_load_desc(0).collect::<Vec<_>>(), vec![0, 1]);
        c.audit(&reqs);
    }

    /// The best-effort twin is maintained through claims, releases and
    /// load churn under the same re-key discipline.
    #[test]
    fn ordered_best_effort_twin_stays_coherent() {
        let mut c = Cluster::build(ServingMode::Colocated, 3, 0.0, 1, &cm(), true);
        let reqs = vec![sim_req(0, 100, 4)];
        assert_eq!(c.best_effort_by_load().collect::<Vec<_>>(), vec![2, 1, 0]);
        c.instances[1].push_running(0, &reqs);
        c.refresh_load(1);
        assert_eq!(c.best_effort_by_load().collect::<Vec<_>>(), vec![1, 2, 0]);
        // Claim by lowest id (decision identity) — the twin follows.
        let id = c.claim_for_tier(0, 0).unwrap();
        assert_eq!(id, 0);
        assert_eq!(c.best_effort_by_load().collect::<Vec<_>>(), vec![1, 2]);
        c.audit(&reqs);
    }

    /// The pending pool's ordered twin walks least-loaded first and
    /// tracks re-keys — including the case that motivates the separate
    /// pending key: a queued prefill with no committed tokens moves
    /// `(batch, queued tokens)` while the `(batch, kv)` load key stays
    /// put.
    #[test]
    fn ordered_pending_twin_walks_least_loaded_first() {
        use super::super::instance::PrefillJob;
        let mut c = Cluster::build(ServingMode::Colocated, 4, 0.0, 1, &cm(), true);
        let mut reqs = vec![sim_req(0, 100, 4), sim_req(1, 100, 4)];
        reqs[1].prefill_done = 0;
        for id in 0..3 {
            assert_eq!(c.claim_for_tier(0, 0), Some(id));
            c.mark_pending(id);
        }
        // All keys (0, 0): ascending-id walk.
        assert_eq!(c.pending_by_load().collect::<Vec<_>>(), vec![0, 1, 2]);
        // A decode resident on 0 pushes it behind its peers.
        c.instances[0].push_running(0, &reqs);
        c.refresh_load(0);
        assert_eq!(c.pending_by_load().collect::<Vec<_>>(), vec![1, 2, 0]);
        // Queued prefill with prefill_done = 0: the load key of 1 is
        // unchanged but its pending key grows — the twin must re-key.
        c.instances[1].push_prefill(PrefillJob { req_idx: 1, deadline: 500 }, &reqs);
        c.refresh_load(1);
        assert_eq!(c.pending_by_load().collect::<Vec<_>>(), vec![2, 1, 0]);
        // Draining members leave the walk (lifecycle filtered at read);
        // adoption removes the entry under its stored key.
        c.begin_drain(2, 10);
        assert_eq!(c.pending_by_load().collect::<Vec<_>>(), vec![1, 0]);
        c.adopt_pending(1, 0);
        assert_eq!(c.pending_by_load().collect::<Vec<_>>(), vec![0]);
        c.audit(&reqs);
    }

    /// Mutating an instance's load without reporting through
    /// `refresh_load` must be caught by the audit — the mechanical
    /// check behind the re-key discipline.
    #[test]
    #[should_panic(expected = "load key stale")]
    fn audit_catches_missed_rekey() {
        let mut c = Cluster::build(ServingMode::Colocated, 2, 0.0, 1, &cm(), true);
        let reqs = vec![sim_req(0, 100, 4)];
        let id = c.claim_for_tier(0, 0).unwrap();
        c.instances[id].push_running(0, &reqs); // no refresh_load: drift
        c.audit(&reqs);
    }

    /// The O(1) unplaced-demand counter equals the reconstruction scan.
    #[test]
    fn unplaced_demand_counter_matches_scan() {
        let mut c = Cluster::build(ServingMode::Colocated, 2, 0.0, 1, &cm(), true);
        let mut reqs = vec![sim_req(0, 100, 4), sim_req(1, 100, 4), sim_req(2, 100, 4)];
        let id = c.claim_for_tier(0, 0).unwrap();
        for _ in 0..3 {
            c.note_arrival(0);
        }
        // req 0 resident, req 1 finished, req 2 unplaced.
        c.instances[id].push_running(0, &reqs);
        c.refresh_load(id);
        reqs[1].finish_ms = Some(50);
        c.note_finished(0, 1);
        assert_eq!(c.unplaced_demand(), 1);
        assert_eq!(c.unplaced_demand(), c.unplaced_demand_scan(&reqs, 100));
        assert_eq!(c.unplaced_demand_of(0), 1);
        assert_eq!(c.unplaced_demand_of(0), c.unplaced_demand_scan_of(0, &reqs, 100));
        c.audit(&reqs);
    }

    /// Two-model fleets lay instances out model-major, key every
    /// membership view by model, and the hard placement constraint
    /// shows in the per-model views.
    #[test]
    fn build_models_keys_views_per_model() {
        let caps = [(900_000u64, 2048u64), (256_000u64, 2048u64)];
        let mut c = Cluster::build_models(
            ServingMode::PdDisaggregated,
            &[6, 4],
            0.25,
            2,
            &caps,
            true,
        );
        assert_eq!(c.num_models, 2);
        assert_eq!(c.len(), 10);
        // Model-major ids: 0..6 model 0 (round(6·0.25)=2 prefill),
        // 6..10 model 1 (round(4·0.25)=1 prefill).
        assert!(c.instances[..6].iter().all(|i| i.model == 0));
        assert!(c.instances[6..].iter().all(|i| i.model == 1));
        assert_eq!(c.instances[7].kv_capacity, 256_000);
        assert_eq!(c.with_role_of(0, Role::Prefill).count(), 2);
        assert_eq!(c.with_role_of(1, Role::Prefill).count(), 1);
        assert_eq!(c.best_effort_pool_of(0).count(), 4);
        assert_eq!(c.best_effort_pool_of(1).count(), 3);
        // Aggregate = chained per-model sequences.
        assert_eq!(
            c.best_effort_pool().collect::<Vec<_>>(),
            vec![2, 3, 4, 5, 7, 8, 9]
        );
        // Claims are model-keyed: each model's tier slot fills from its
        // own pool only.
        let a = c.claim_for_tier_of(0, 1, 0).unwrap();
        let b = c.claim_for_tier_of(1, 1, 0).unwrap();
        assert_eq!((a, b), (2, 7));
        assert_eq!(c.in_tier_of(0, 1).collect::<Vec<_>>(), vec![2]);
        assert_eq!(c.in_tier_of(1, 1).collect::<Vec<_>>(), vec![7]);
        assert_eq!(c.in_tier(1).collect::<Vec<_>>(), vec![2, 7]);
        assert_eq!(c.tier_by_load_desc_of(1, 1).collect::<Vec<_>>(), vec![7]);
        // Scan reference agrees with the indexed per-model views.
        let indexed: Vec<usize> = c.in_tier(1).collect();
        let pool: Vec<usize> = c.best_effort_pool().collect();
        c.set_scan_reference(true);
        assert_eq!(c.in_tier(1).collect::<Vec<_>>(), indexed);
        assert_eq!(c.best_effort_pool().collect::<Vec<_>>(), pool);
        c.set_scan_reference(false);
        // Model-aware provision sizes by the target model's caps.
        let p = c.provision_model(1, Role::Decode, 0, 100);
        assert_eq!(c.instances[p].model, 1);
        assert_eq!(c.instances[p].kv_capacity, 256_000);
        c.mark_ready(p);
        assert_eq!(c.best_effort_pool_of(1).count(), 3);
        c.audit(&[]);
    }

    /// The swap lifecycle: drain with `swap_to` set never retires, and
    /// `complete_swap` re-keys the indices around the model change,
    /// reloads caps, and re-enters via Provisioning.
    #[test]
    fn model_swap_drains_reloads_and_rekeys() {
        let caps = [(900_000u64, 2048u64), (256_000u64, 1024u64)];
        let mut c = Cluster::build_models(
            ServingMode::Colocated,
            &[2, 1],
            0.0,
            2,
            &caps,
            true,
        );
        let id = c.claim_for_tier_of(0, 0, 10).unwrap();
        assert_eq!(c.in_tier_of(0, 0).count(), 1);
        c.begin_swap(id, 1, 100);
        assert!(c.draining_any());
        assert_eq!(c.swap_pending(id), Some(1));
        // Unroutable while swap-draining; never plain-retires.
        assert_eq!(c.in_tier_of(0, 0).count(), 0);
        assert!(!c.retire_if_drained(id, 200));
        assert!(c.swap_ready(id, 200));
        // Swap capacity is already committed to the target model.
        let committed_before = c.committed_count_of(1, Role::Coloc);
        assert_eq!(committed_before, 2, "swap target counts as committed");
        let target = c.complete_swap(id, 200, 20_200);
        assert_eq!(target, 1);
        assert!(!c.draining_any());
        assert_eq!(c.instances[id].model, 1);
        assert_eq!(c.instances[id].kv_capacity, 256_000);
        assert_eq!(c.instances[id].max_token_batch, 1024);
        assert_eq!(c.swap_pending(id), None);
        // Cold-starting under the new model: committed but not active.
        assert_eq!(c.committed_count_of(1, Role::Coloc), 2);
        assert_eq!(c.active_count_of(1, Role::Coloc), 1);
        assert_eq!(c.best_effort_pool_of(1).count(), 1);
        c.mark_ready(id);
        assert_eq!(c.best_effort_pool_of(1).collect::<Vec<_>>(), vec![id, 2]);
        assert_eq!(c.best_effort_pool_of(0).collect::<Vec<_>>(), vec![1]);
        // Tier alloc window (opened at the claim, t=10) closed at swap
        // time (t=200).
        assert_eq!(c.instances[id].allocated_ms(1_000), 190);
        c.audit(&[]);
    }

    #[test]
    fn set_assign_keeps_indices_coherent() {
        let mut c = Cluster::build(ServingMode::Colocated, 3, 0.0, 2, &cm(), true);
        c.set_assign(0, TierAssign::Tier(1));
        c.set_assign(1, TierAssign::Static);
        c.set_assign(2, TierAssign::Pending);
        c.audit(&[]);
        assert_eq!(c.in_tier(1).collect::<Vec<_>>(), vec![0]);
        assert_eq!(c.best_effort_pool().count(), 0);
        assert_eq!(c.pending_pool().collect::<Vec<_>>(), vec![2]);
        c.set_assign(0, TierAssign::BestEffort);
        c.audit(&[]);
        assert_eq!(c.in_tier(1).count(), 0);
        assert_eq!(c.best_effort_pool().collect::<Vec<_>>(), vec![0]);
    }
}
