//! The simulator's event queue: a calendar queue (bucketed timing
//! wheel) with a binary-heap reference implementation behind one
//! [`EventQueue`] dispatch enum.
//!
//! The simulation runs at 1 ms resolution and orders events by the
//! total order `(t, seq)` — `seq` is a globally unique, monotonically
//! increasing push counter, so the key payload never decides a
//! comparison. A binary heap pays O(log n) per push/pop on that order;
//! the calendar queue makes both amortized O(1) by exploiting the fixed
//! tick granularity:
//!
//! * **Slots** — [`SLOTS`] one-millisecond buckets cover the *current
//!   generation* (`gen = t >> SLOT_BITS`, a [`SLOTS`]-ms window). An
//!   event due in the current generation lands in slot `t & SLOT_MASK`;
//!   each slot is a FIFO, so same-timestamp events drain in push (= seq)
//!   order. A 16-word occupancy bitmask finds the next non-empty slot
//!   with a couple of `trailing_zeros` scans instead of walking 1024
//!   `Vec`s.
//! * **Overflow ring** — events beyond the current generation (cold
//!   starts, migration streams, far ticks) wait in one of [`RING`]
//!   per-generation buckets indexed `gen & RING_MASK`. Rotating into a
//!   generation drains its bucket into the slots, filtering by exact
//!   generation: an event more than `RING` generations out simply stays
//!   in the bucket for a later lap (bucket order is preserved, so the
//!   seq order of a timestamp's events survives any number of laps).
//!
//! # The cursor and bounded pops
//!
//! `cursor` is the earliest timestamp the wheel has *not* fully drained;
//! every queued event satisfies `t >= cursor`, and pushes behind the
//! cursor are a bug ([`debug_assert`]ed). The simulator merges sorted
//! workload arrivals against this queue with
//! [`EventQueue::pop_earlier_than`]`(bound)`, which pops the earliest
//! event with `t` strictly `< bound` and otherwise returns `None`
//! **without scanning past the bound** — the cursor (and the wheel
//! rotation) stop at `bound`, so events the arrival handler then pushes
//! at `t >= bound` still land ahead of the cursor. A plain
//! [`EventQueue::pop`] is the unbounded special case.
//!
//! Decision identity with the heap is exact: both implementations drain
//! any push/pop interleaving in identical `(t, seq)` order (property-
//! tested below), which is what lets `SimParams::heap_reference` swap
//! the engines at runtime for A/B digest runs.

use crate::slo::TimeMs;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// log2 of the slot count: one generation spans `2^SLOT_BITS` ms.
const SLOT_BITS: u32 = 10;
/// One-millisecond slots per generation (the wheel's span).
const SLOTS: usize = 1 << SLOT_BITS;
/// Mask extracting the slot index from a timestamp.
const SLOT_MASK: TimeMs = (SLOTS as TimeMs) - 1;
/// Overflow-ring buckets (generations); must be a power of two.
const RING: usize = 1024;
/// Mask extracting the ring bucket from a generation number.
const RING_MASK: u64 = (RING as u64) - 1;

/// One queued event: `(time, seq, key)`. `seq` is unique and
/// monotonically increasing across pushes, so `(t, seq)` is a total
/// order and `K` never decides a comparison.
type Entry<K> = (TimeMs, u64, K);

/// The calendar queue proper (reached through [`EventQueue`]; the
/// fields and methods stay private). See the module docs for the
/// invariants; `len` counts every queued event across slots and ring.
pub struct Calendar<K> {
    /// FIFO buckets for the current generation's timestamps.
    slots: Vec<VecDeque<Entry<K>>>,
    /// Occupancy bitmask over `slots` (bit i set ⇔ slot i non-empty).
    occ: [u64; SLOTS / 64],
    /// Per-generation overflow buckets, indexed `gen & RING_MASK`.
    ring: Vec<Vec<Entry<K>>>,
    /// The generation the slots currently cover (`t >> SLOT_BITS`).
    gen: u64,
    /// Earliest timestamp not yet fully drained; every queued event has
    /// `t >= cursor`. May transiently equal the generation's end.
    cursor: TimeMs,
    len: usize,
}

impl<K> Calendar<K> {
    fn new() -> Calendar<K> {
        Calendar {
            slots: (0..SLOTS).map(|_| VecDeque::new()).collect(),
            occ: [0; SLOTS / 64],
            ring: (0..RING).map(|_| Vec::new()).collect(),
            gen: 0,
            cursor: 0,
            len: 0,
        }
    }

    fn push(&mut self, t: TimeMs, seq: u64, key: K) {
        debug_assert!(
            t >= self.cursor,
            "event pushed at t={t} behind the cursor {}",
            self.cursor
        );
        self.len += 1;
        if t >> SLOT_BITS == self.gen {
            let slot = (t & SLOT_MASK) as usize;
            self.slots[slot].push_back((t, seq, key));
            self.occ[slot >> 6] |= 1u64 << (slot & 63);
        } else {
            debug_assert!(t >> SLOT_BITS > self.gen, "past generation");
            self.ring[((t >> SLOT_BITS) & RING_MASK) as usize].push((t, seq, key));
        }
    }

    /// Lowest occupied slot index `>= from`, if any.
    fn next_occupied(&self, from: usize) -> Option<usize> {
        if from >= SLOTS {
            return None;
        }
        let mut word = from >> 6;
        let mut bits = self.occ[word] & (!0u64 << (from & 63));
        loop {
            if bits != 0 {
                return Some((word << 6) + bits.trailing_zeros() as usize);
            }
            word += 1;
            if word >= SLOTS / 64 {
                return None;
            }
            bits = self.occ[word];
        }
    }

    /// Advance to the next generation and drain its overflow bucket
    /// into the slots. Events of a *later* lap (more than `RING`
    /// generations out at push time) go back into the bucket, order
    /// preserved — seq order within a timestamp survives any lap count.
    fn rotate(&mut self) {
        self.gen += 1;
        self.cursor = self.gen << SLOT_BITS;
        let idx = (self.gen & RING_MASK) as usize;
        if self.ring[idx].is_empty() {
            return;
        }
        let bucket = std::mem::take(&mut self.ring[idx]);
        for (t, seq, key) in bucket {
            if t >> SLOT_BITS == self.gen {
                let slot = (t & SLOT_MASK) as usize;
                self.slots[slot].push_back((t, seq, key));
                self.occ[slot >> 6] |= 1u64 << (slot & 63);
            } else {
                self.ring[idx].push((t, seq, key));
            }
        }
    }

    /// Pop the earliest event with `t < bound` (no bound: the global
    /// minimum). The scan — and the cursor — never advance past the
    /// bound, so events pushed later at `t >= bound` stay ahead of the
    /// cursor.
    fn pop_earlier_than(&mut self, bound: Option<TimeMs>) -> Option<Entry<K>> {
        if self.len == 0 {
            // Empty wheel: fast-forward straight to the bound instead
            // of rotating through empty generations next time.
            if let Some(b) = bound {
                if b > self.cursor {
                    self.cursor = b;
                    self.gen = b >> SLOT_BITS;
                }
            }
            return None;
        }
        loop {
            let gen_start = self.gen << SLOT_BITS;
            let gen_end = gen_start + SLOTS as TimeMs;
            debug_assert!(self.cursor >= gen_start && self.cursor <= gen_end);
            let from = (self.cursor - gen_start) as usize;
            if let Some(slot) = self.next_occupied(from) {
                let t = gen_start + slot as TimeMs;
                if let Some(b) = bound {
                    if t >= b {
                        // Earliest queued event is at/after the bound:
                        // stop the cursor *at the bound*, not at t.
                        self.cursor = self.cursor.max(b);
                        return None;
                    }
                }
                self.cursor = t;
                let q = &mut self.slots[slot];
                let ev = q.pop_front().expect("occupied slot was empty");
                if q.is_empty() {
                    self.occ[slot >> 6] &= !(1u64 << (slot & 63));
                }
                self.len -= 1;
                debug_assert_eq!(ev.0, t, "slot held a foreign timestamp");
                return Some(ev);
            }
            // Generation exhausted. Rotate — unless the bound lies
            // inside it, in which case everything `< bound` is drained.
            if let Some(b) = bound {
                if b <= gen_end {
                    self.cursor = self.cursor.max(b);
                    return None;
                }
            }
            self.rotate();
        }
    }
}

/// The simulator's event queue: calendar-queue hot path or binary-heap
/// reference, selected at construction (`SimParams::heap_reference`).
/// Both drain any interleaving in identical `(t, seq)` order.
pub enum EventQueue<K> {
    /// O(1)-amortized bucketed timing wheel (the default engine).
    Calendar(Box<Calendar<K>>),
    /// The pre-calendar binary heap, kept as a runtime reference mode.
    Heap(BinaryHeap<Reverse<Entry<K>>>),
}

impl<K: Ord> EventQueue<K> {
    /// A calendar-queue engine (the default hot path).
    pub fn calendar() -> EventQueue<K> {
        EventQueue::Calendar(Box::new(Calendar::new()))
    }

    /// A binary-heap engine (the `heap_reference` A/B mode).
    pub fn heap() -> EventQueue<K> {
        EventQueue::Heap(BinaryHeap::new())
    }

    /// Queued events.
    pub fn len(&self) -> usize {
        match self {
            EventQueue::Calendar(c) => c.len,
            EventQueue::Heap(h) => h.len(),
        }
    }

    /// True when no event is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Queue an event. `seq` must be unique and monotonically
    /// increasing across pushes, and `t` must not lie behind any
    /// previously popped time or `pop_earlier_than` bound.
    pub fn push(&mut self, t: TimeMs, seq: u64, key: K) {
        match self {
            EventQueue::Calendar(c) => c.push(t, seq, key),
            EventQueue::Heap(h) => h.push(Reverse((t, seq, key))),
        }
    }

    /// Pop the globally earliest event in `(t, seq)` order.
    pub fn pop(&mut self) -> Option<Entry<K>> {
        self.pop_earlier_than(None)
    }

    /// Pop the earliest event with `t` strictly `< bound`; `None` if no
    /// such event (or no bound and the queue is empty). The calendar's
    /// internal scan never advances past the bound, so callers may keep
    /// pushing events at `t >= bound` between bounded pops — the merge
    /// primitive behind the simulator's sorted-arrival cursor.
    pub fn pop_earlier_than(&mut self, bound: Option<TimeMs>) -> Option<Entry<K>> {
        match self {
            EventQueue::Calendar(c) => c.pop_earlier_than(bound),
            EventQueue::Heap(h) => match bound {
                None => h.pop().map(|Reverse(e)| e),
                Some(b) => {
                    if h.peek().is_some_and(|Reverse((t, _, _))| *t < b) {
                        h.pop().map(|Reverse(e)| e)
                    } else {
                        None
                    }
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn both() -> [EventQueue<u32>; 2] {
        [EventQueue::calendar(), EventQueue::heap()]
    }

    #[test]
    fn same_timestamp_fifo_by_seq() {
        // Events sharing a timestamp must drain in push (= seq) order —
        // the key payload must never decide, even when it sorts the
        // other way.
        for mut q in both() {
            q.push(50, 0, 9);
            q.push(50, 1, 3);
            q.push(10, 2, 7);
            q.push(50, 3, 1);
            assert_eq!(q.pop(), Some((10, 2, 7)));
            // Interleaved push at the same timestamp lands behind the
            // earlier seqs.
            q.push(50, 4, 0);
            assert_eq!(q.pop(), Some((50, 0, 9)));
            assert_eq!(q.pop(), Some((50, 1, 3)));
            assert_eq!(q.pop(), Some((50, 3, 1)));
            assert_eq!(q.pop(), Some((50, 4, 0)));
            assert_eq!(q.pop(), None);
            assert!(q.is_empty());
        }
    }

    #[test]
    fn overflow_ring_rotation_across_span_boundaries() {
        // Events beyond the current SLOTS-ms window wait in the ring
        // and surface exactly at their generation — including a bucket
        // shared by two generations ("laps") RING generations apart,
        // whose far event must survive the first rotation.
        let span = SLOTS as TimeMs;
        let lap = span * RING as TimeMs;
        for mut q in both() {
            let near = span + 5; // generation 1
            let far = near + lap; // generation 1 + RING: same bucket
            let mid = 3 * span + 2; // generation 3
            q.push(far, 0, 1);
            q.push(mid, 1, 2);
            q.push(near, 2, 3);
            q.push(7, 3, 4); // current generation
            assert_eq!(q.pop(), Some((7, 3, 4)));
            assert_eq!(q.pop(), Some((near, 2, 3)));
            assert_eq!(q.pop(), Some((mid, 1, 2)));
            // The far lap twin is still queued, not lost to rotation.
            assert_eq!(q.len(), 1);
            assert_eq!(q.pop(), Some((far, 0, 1)));
            assert_eq!(q.pop(), None);
        }
    }

    #[test]
    fn far_future_deadline_lands_in_the_overflow_ring_and_keeps_order() {
        // A `PreemptNotice`'s hard deadline is scheduled a whole grace
        // window ahead of `now` — with a generous grace that lands far
        // beyond the SLOTS-ms wheel, in the overflow ring. The deadline
        // must neither surface early (killing an instance still inside
        // its grace) nor be dropped by ring rotation, and near-term
        // events pushed *after* it (iteration ends, migration arrivals)
        // must all drain first while the queue keeps advancing.
        let span = SLOTS as TimeMs;
        for mut q in both() {
            let notice_at = 2_000;
            let deadline = notice_at + 30 * span; // grace ≫ the wheel
            q.push(notice_at, 0, 1); // the notice itself
            q.push(deadline, 1, 2); // its far-future kill
            assert_eq!(q.pop(), Some((notice_at, 0, 1)));
            // The drain the notice started: a spread of nearer events
            // pushed after the deadline was already queued.
            for i in 0..20u64 {
                q.push(notice_at + (i + 1) * span, 2 + i, 3);
            }
            for i in 0..20u64 {
                assert_eq!(q.pop(), Some((notice_at + (i + 1) * span, 2 + i, 3)));
                // The deadline never surfaces before its time.
                assert_eq!(q.len() as u64, 20 - i, "deadline lost or duplicated");
            }
            assert_eq!(q.pop(), Some((deadline, 1, 2)));
            assert_eq!(q.pop(), None);
        }
    }

    #[test]
    fn bounded_pop_is_strict_and_resumable() {
        for mut q in both() {
            q.push(10, 0, 1);
            // Strictly-less-than: an event *at* the bound stays queued.
            assert_eq!(q.pop_earlier_than(Some(10)), None);
            assert_eq!(q.len(), 1);
            assert_eq!(q.pop_earlier_than(Some(11)), Some((10, 0, 1)));
            // The bounded miss must not have scanned past the bound:
            // a later push at exactly the bound time still pops.
            q.push(10, 1, 2);
            q.push(10_000, 2, 3); // far event, forces no early drain
            assert_eq!(q.pop_earlier_than(Some(2_000)), Some((10, 1, 2)));
            assert_eq!(q.pop_earlier_than(Some(2_000)), None);
            // And the cursor parked at the bound accepts pushes there.
            q.push(2_000, 3, 4);
            assert_eq!(q.pop(), Some((2_000, 3, 4)));
            assert_eq!(q.pop(), Some((10_000, 2, 3)));
        }
    }

    #[test]
    fn empty_queue_fast_forward_keeps_accepting() {
        // Bounded pops on an empty queue fast-forward the calendar's
        // cursor; pushes at/after each bound must stay legal and drain
        // correctly across the jumped generations.
        for mut q in both() {
            assert_eq!(q.pop_earlier_than(Some(5_000_000)), None);
            q.push(5_000_000, 0, 1);
            q.push(5_000_000 + 3 * SLOTS as TimeMs, 1, 2);
            assert_eq!(q.pop(), Some((5_000_000, 0, 1)));
            assert_eq!(q.pop(), Some((5_000_000 + 3 * SLOTS as TimeMs, 1, 2)));
            assert_eq!(q.pop(), None);
        }
    }

    /// Property test: a randomized push / pop / bounded-pop
    /// interleaving — delays from same-millisecond to multi-lap —
    /// drains bit-identically from the calendar and the heap.
    #[test]
    fn randomized_interleaving_drains_identically() {
        for trial in 0..20u64 {
            let mut rng = Rng::new(0xE0_0E + trial);
            let mut cal: EventQueue<u32> = EventQueue::calendar();
            let mut heap: EventQueue<u32> = EventQueue::heap();
            let mut now: TimeMs = 0;
            let mut seq = 0u64;
            for _ in 0..4_000 {
                match rng.range_u64(0, 100) {
                    // Push: mostly near-future, sometimes cross-
                    // generation, rarely beyond a full ring lap.
                    0..=59 => {
                        let delta = match rng.range_u64(0, 10) {
                            0..=6 => rng.range_u64(0, 40),
                            7 | 8 => rng.range_u64(0, 5 * SLOTS as u64),
                            _ => rng.range_u64(0, (RING as u64 + 2) * SLOTS as u64),
                        };
                        let t = now + delta;
                        let key = rng.range_u64(0, 4) as u32; // collisions on purpose
                        cal.push(t, seq, key);
                        heap.push(t, seq, key);
                        seq += 1;
                    }
                    // Unbounded pop.
                    60..=79 => {
                        let (a, b) = (cal.pop(), heap.pop());
                        assert_eq!(a, b, "trial {trial}: pop diverged");
                        if let Some((t, _, _)) = a {
                            now = now.max(t);
                        }
                    }
                    // Bounded pop: the simulator's arrival merge. On a
                    // miss the clock jumps to the bound (the arrival
                    // is processed at `bound`), as in the event loop.
                    _ => {
                        let bound = now + rng.range_u64(0, 3 * SLOTS as u64);
                        let (a, b) = (
                            cal.pop_earlier_than(Some(bound)),
                            heap.pop_earlier_than(Some(bound)),
                        );
                        assert_eq!(a, b, "trial {trial}: bounded pop diverged");
                        now = match a {
                            Some((t, _, _)) => now.max(t),
                            None => now.max(bound),
                        };
                    }
                }
                assert_eq!(cal.len(), heap.len(), "trial {trial}: len diverged");
            }
            // Full drain must agree to the last event.
            loop {
                let (a, b) = (cal.pop(), heap.pop());
                assert_eq!(a, b, "trial {trial}: drain diverged");
                if a.is_none() {
                    break;
                }
            }
        }
    }
}
