//! Discrete-event cluster simulator.
//!
//! Mirrors the paper's evaluation methodology (§5.1): serving instances
//! are simulated at 1 ms resolution using profiling-derived iteration
//! times; the router under test makes every scheduling decision.
//!
//! Architecture:
//!
//! * [`instance`] — one serving instance: running decode batch, prefill
//!   queue, KV accounting, iteration mechanics (batch formation,
//!   completion processing).
//! * [`cluster`] — the fleet: tier membership, best-effort pool,
//!   cost accounting.
//! * this module — the event loop ([`Simulation`]): request arrivals,
//!   iteration completions, router callbacks, outcome collection.
//!
//! Ground truth iteration times come from [`CostModel`] (the simulated
//! hardware); the router only sees a [`ProfileTable`] — mirroring the
//! paper's profiling-driven scheduler, including its prediction error.
//!
//! # Decode-handoff timing
//!
//! Every PD prefill→decode handoff pays `kv_transfer_ms` before the
//! destination may schedule the request, *regardless of the path that
//! placed it*: the simulator's direct `route_decode` dispatch and the
//! router's pended dispatch (`RouteCtx::kv_transfer_ms`) mark the
//! handoff ready at `now + kv_transfer_ms` identically. An idle
//! destination wakes exactly when the earliest in-flight transfer
//! lands (a `Wake` event), not at the next housekeeping tick.
//!
//! # Scale-in KV migration
//!
//! With `[elastic] migration = "on"`, a `Drain` action whose scaler
//! judged the surviving fleet able to absorb the residents
//! ([`crate::coordinator::migration_feasible`]) evicts the drainer's
//! decode requests instead of waiting them out. Each evicted request
//! pays an end-to-end transfer of `max(kv_transfer_ms,
//! kv_now / MIGRATION_TOKENS_PER_MS)`: the bulk stream beyond the
//! final handoff hop is the `MigrationArrive` event delay, the
//! hop itself is the ordinary `kv_transfer_ms` placement pays. The
//! request re-enters placement through the router's ordinary
//! `route_decode`/pending machinery — destination residents stay
//! protected by the same admission checks as any other handoff — and
//! the source may not retire (it keeps billing) until its last
//! transfer has left. Tokens are conserved exactly: an evicted request
//! is absent from the drainer's batch from the eviction on, so every
//! one of its `decode_len` tokens is emitted exactly once, here or
//! there.
//!
//! # Fault injection & spot preemption (the `[chaos]` layer)
//!
//! With [`SimParams::chaos`] enabled, the run carries adversarial
//! stressors alongside the workload:
//!
//! * **`InstanceFail`** — a hard kill: the instance force-retires at
//!   the event (billing stops there, unlike a drain, which bills until
//!   its last egress transfer leaves), its residents' KV dies with the
//!   device, and every victim re-enters placement through the router's
//!   ordinary `route_new` with `prefill_done` rewound to zero — a full
//!   re-prefill, in contrast to migration's graceful KV handoff.
//!   Emitted decode tokens are *kept* (they already reached the
//!   client), so each of a victim's `decode_len` tokens is still
//!   emitted exactly once — the conservation property tests pin this.
//!   Kills come from an explicit `(t_ms, instance)` list and/or a
//!   seeded exponential MTBF process over the live fleet.
//! * **`PreemptNotice`** — spot-market reclamation: the instance
//!   begins an ordinary drain *now* (with KV migration when `[elastic]
//!   migration` is on and feasible) and a hard `InstanceFail` is
//!   scheduled `preempt_grace_ms` later. Drained in time → clean exit
//!   (`preempt_drained`); still alive at the deadline → deadline kill
//!   with full KV loss (`preempt_deadline_kills`). Only `Active` spot
//!   instances receive notices. Spot instances are assigned
//!   deterministically at provision time by `spot_fraction` and bill
//!   at `spot_price_frac` of the on-demand rate
//!   ([`crate::metrics::CostAccount::discounted_bill_ms`]) — or over a
//!   stepwise `spot_price_schedule` into
//!   [`crate::metrics::CostAccount::spot_curve_bill_ms`] when a curve
//!   is declared; a `spot_avail_schedule` scales the preempt-MTBF gaps
//!   the same way (scarcer capacity → faster reclamation).
//! * **`DomainFail` / `ChaosFailDomain`** — correlated kills: with
//!   `[chaos] zones` set, every instance carries a deterministic
//!   `(zone, rack)` failure domain ([`domain_of`]) and one draw kills
//!   every live instance in a rack (or, rarer, a whole zone) at once.
//!   Victim re-placement steers away from the blast radius: the router
//!   is handed the failed zone ([`crate::coordinator::Router::set_avoid_zone`])
//!   and prefers survivors outside it, falling back to the full fleet.
//! * **`Checkpoint`** — periodic KV snapshots (`[chaos]
//!   checkpoint_period_ms`): every resident's committed prefill
//!   watermark is checkpointed (billing the delta tokens as transfer
//!   time, [`crate::metrics::ChaosStats::checkpoint_cost_ms`]), and an
//!   `InstanceFail` rewinds victims to the last checkpoint instead of
//!   zero — re-prefill pays only the suffix, never re-emitting decoded
//!   tokens.
//!
//! A disabled `[chaos]` block schedules zero events and draws zero
//! RNG, so the machinery's presence is bit-for-bit invisible — the
//! digest-identity tests run the full queue × index matrix against the
//! chaos-free path. In-flight outbound migration transfers survive a
//! source failure: the stream carries a KV snapshot, not live device
//! references.
//!
//! # Overload admission & retry (the `[overload]` layer)
//!
//! With [`SimParams::overload`] set, every arrival is first priced
//! against the router's arrival-edge feasibility gate
//! ([`Router::admit_at_arrival`]): an infeasible request is *rejected*
//! — a typed [`RequestOutcome::rejected`] outcome billed zero tokens,
//! never a silent drop — or, with `[overload] retry`, re-arrives
//! through the ordinary event queue after capped exponential backoff
//! with seeded jitter. A retry re-arrival re-anchors the request's SLO
//! clock at the re-arrival time (the client resubmitted; the backoff
//! wait is not held against the new deadlines) — every deadline the
//! scheduler prices thereafter comes from
//! [`SimRequest::ttft_deadline`], which keys on the *effective*
//! arrival. `None` params (overload off) constructs no runtime,
//! schedules no events and draws no RNG — bit-for-bit the seed path,
//! exactly like a disabled `[chaos]`.
//!
//! # Load-ordered fleet indices and the re-key discipline
//!
//! The cluster keeps every tier (and the best-effort pool) in a
//! load-ordered `BTreeSet` keyed by the router's §4.3 sort tuple
//! `(decode batch, resident+in-flight KV, id)` in descending order, so
//! a placement is an in-order walk with early exit instead of a
//! per-request collect+sort. The invariant that makes this
//! decision-identical — every member's stored key equals its live
//! cached counters — is maintained by calling
//! [`Cluster::refresh_load`] after **every** instance-load mutation
//! this event loop performs: arrival/pended/handoff `push_*`,
//! `form_batch`, `complete_iteration`, and both migration evictions.
//! The same hook folds each instance's residency delta into the O(1)
//! unplaced-demand counter (`note_arrival`/`note_finished` supply the
//! other two terms). In debug builds the per-event audit re-derives
//! the ordered sets and the counter by scan and panics on the first
//! missed re-key.
//!
//! # Elastic prefill tier
//!
//! With `ElasticParams::prefill` set (config `[elastic]
//! prefill_elastic = "on"`), `Role::Prefill` instances get the same
//! Provisioning/Active/Draining/Retired lifecycle as the scalable
//! role, bounded by their own `prefill_min`/`prefill_max` — the
//! simulator enforces bounds *per role*, so a scaler's `Provision`/
//! `Drain` on a prefill server is never checked against the decode
//! bounds (and with `prefill: None`, prefill actions are ignored
//! outright: the PR 2 static-prefill path is reproduced bit-for-bit).
//! Draining a prefill server with migration on re-routes its queued
//! prefill jobs through the router's ordinary `route_new` placement;
//! a partially-prefilled job's KV streams off the source first (same
//! `MigrationArrive` machinery and egress billing as decode KV), while
//! its in-flight chunk on the source is discarded — the destination
//! recomputes from the job's committed `prefill_done`, so prefill work
//! is never applied twice.
//!
//! # Multi-model fleets and hot swaps
//!
//! Every instance is tagged with the registry [`ModelId`] it has
//! loaded; a request only ever lands on instances of its own model
//! (the hard placement constraint, `debug_assert`ed at every `push_*`).
//! Ground-truth iteration times come from the per-model cost models
//! (`with_cost_models`); a single-model run uses exactly the one
//! [`CostModel`] it always did. A `SwapModel` scale action drains the
//! instance (same machinery as scale-in, including KV migration when
//! enabled), then — once empty with egress done — reloads it with the
//! target model's weights and caps: `Cluster::complete_swap` re-keys
//! the membership indices around the model change and the instance
//! re-enters through the ordinary cold-start path after
//! `model_swap_delay_ms`. Billing never pauses across a swap.
//!
//! # Event engine: calendar queue + arrival cursor
//!
//! Events live in an [`equeue::EventQueue`] — a calendar queue
//! (bucketed timing wheel at the 1 ms tick granularity, with an
//! overflow ring for far-future events) that makes push/pop amortized
//! O(1) while preserving the exact `(t, seq)` total order of the old
//! binary heap; `SimParams::heap_reference` swaps the heap back in at
//! runtime for A/B digest runs.
//!
//! **Arrival-cursor invariant.** The queue is *not* seeded with the
//! workload's N arrival events. `Workload::requests` is arrival-sorted
//! (asserted at construction), so the loop merges `arrival_cursor` —
//! the index of the next unprocessed arrival — against the queue head
//! via `pop_earlier_than(next_arrival)`: a queued event pops only if it
//! is *strictly* earlier, otherwise the arrival is synthesized.
//! Arrivals therefore win every timestamp tie, exactly as in the seeded
//! ordering, where all N arrival seqs preceded every runtime-scheduled
//! event's; and because the bounded pop never scans past the bound,
//! events the handlers push at `t >= now` always land ahead of the
//! wheel's cursor. The queue's live size drops from O(total requests)
//! to O(in-flight events).
//!
//! **Arena invariant.** `requests` is a dense arena of per-request
//! *mutable* tracker state ([`SimRequest`]), indexed by the same
//! `req_idx` the events carry. The immutable prompt/SLO data stays in
//! the borrowed [`Workload`] (`SimRequest::req` is a `&Request`, never
//! a clone); nothing on the simulation side ever writes through it.

pub mod cluster;
pub mod equeue;
pub mod instance;

pub use cluster::{Cluster, TierAssign};
pub use equeue::EventQueue;
pub use instance::{Instance, Lifecycle, PrefillJob, Role};

use std::collections::BTreeSet;

use crate::analysis::ServingMode;
use crate::coordinator::{
    migration_feasible, prefill_migration_feasible, Autoscaler, RouteCtx, Router, ScaleAction,
};
use crate::metrics::{
    AttainmentReport, ChaosStats, CostAccount, FleetSample, FleetSeries, MigrationStats,
    OverloadStats, RequestOutcome,
};
use crate::model::{CostModel, ModelId};
use crate::profile::ProfileTable;
use crate::slo::{DsloTracker, TimeMs};
use crate::util::rng::Rng;
use crate::workload::Workload;

/// Scale-in KV-migration streaming rate, tokens per ms. Sized for
/// RDMA-class interconnect on the simulated hardware: ≈0.125 MB of KV
/// per token (8B-class GQA model, fp16) at ~50 GB/s ≈ 400 tokens/ms.
/// The per-request transfer time is `max(kv_transfer_ms, kv_now / this)`.
pub const MIGRATION_TOKENS_PER_MS: u64 = 400;

/// Deterministic failure-domain stride: instance `id` lands in
/// `(id mod zones, (id / zones) mod racks)`. Zone-first striping means
/// consecutive ids spread across zones before doubling up on a rack —
/// any contiguous id range is maximally blast-radius-diverse.
pub fn domain_of(id: usize, zones: u32, racks_per_zone: u32) -> (u32, u32) {
    let z = zones.max(1) as usize;
    let r = racks_per_zone.max(1) as usize;
    ((id % z) as u32, ((id / z) % r) as u32)
}

/// Value of a stepwise `(t, value)` schedule at time `t`: the last
/// step at or before `t`, or `before_first` ahead of the first step.
fn schedule_value_at(sched: &[(TimeMs, f64)], t: TimeMs, before_first: f64) -> f64 {
    let mut v = before_first;
    for &(tk, vk) in sched {
        if tk <= t {
            v = vk;
        } else {
            break;
        }
    }
    v
}

/// Integrate a stepwise price curve over `[start, end)` ms: the sum of
/// `segment_ms * price` over the curve's steps, with `flat` as the
/// price ahead of the first step. Returns price-weighted milliseconds.
fn integrate_spot_price(sched: &[(TimeMs, f64)], flat: f64, start: TimeMs, end: TimeMs) -> f64 {
    if end <= start {
        return 0.0;
    }
    let mut total = 0.0;
    let mut t = start;
    let mut price = flat;
    for &(tk, pk) in sched {
        if tk <= t {
            price = pk;
            continue;
        }
        if tk >= end {
            break;
        }
        total += (tk - t) as f64 * price;
        t = tk;
        price = pk;
    }
    total + (end - t) as f64 * price
}

/// Simulator-side request state: the mutable half of the request
/// arena. The immutable prompt/SLO data is only *borrowed* from the
/// workload (`'w`) — `Simulation::new` clones nothing per request.
#[derive(Debug, Clone)]
pub struct SimRequest<'w> {
    /// The underlying workload request (borrowed, immutable).
    pub req: &'w crate::workload::Request,
    /// TPOT tier bin (index into the tier set).
    pub tier: usize,
    /// Per-token DSLO deadline tracker.
    pub tracker: DsloTracker,
    /// Prompt tokens prefilled so far.
    pub prefill_done: u32,
    /// Output tokens emitted (token 0 comes from prefill completion).
    pub decoded: u32,
    /// First-token emission time (`None` until prefill completes).
    pub first_token_ms: Option<TimeMs>,
    /// Completion time (`None` while decoding).
    pub finish_ms: Option<TimeMs>,
    /// Instance currently hosting the request's decode phase.
    pub decode_instance: Option<usize>,
    /// Committed prefill watermark as of the last KV checkpoint
    /// (`[chaos] checkpoint_period_ms`): an `InstanceFail` rewinds
    /// `prefill_done` here instead of to zero, so re-prefill pays only
    /// the un-checkpointed suffix. Stays 0 (the PR 8 cold-restart
    /// semantics) with checkpointing off. Monotone, never past
    /// `prefill_done`.
    pub checkpointed: u32,
    /// Arrival time the SLO clock is anchored at: the workload arrival,
    /// until an `[overload] retry` re-arrival re-anchors it (the client
    /// resubmitted — the backoff wait is not held against the new
    /// deadlines).
    pub effective_arrival_ms: TimeMs,
    /// Shed by admission control (`[overload] reject`): never placed,
    /// zero tokens, reported as a typed `Rejected` outcome. Always
    /// false with overload off.
    pub shed: bool,
}

impl<'w> SimRequest<'w> {
    /// Fresh tracker state over a borrowed workload request.
    pub fn new(req: &'w crate::workload::Request, tier: usize) -> SimRequest<'w> {
        SimRequest {
            req,
            tier,
            tracker: DsloTracker::new(req.arrival_ms, req.slo),
            prefill_done: 0,
            decoded: 0,
            first_token_ms: None,
            finish_ms: None,
            decode_instance: None,
            checkpointed: 0,
            effective_arrival_ms: req.arrival_ms,
            shed: false,
        }
    }

    /// The TTFT deadline every scheduling decision prices — keyed on
    /// the *effective* arrival, so a retry re-arrival shifts it with
    /// the re-anchored SLO clock.
    pub fn ttft_deadline(&self) -> TimeMs {
        self.effective_arrival_ms + self.req.slo.ttft_ms
    }

    /// Has the request emitted its full output?
    pub fn is_finished(&self) -> bool {
        self.finish_ms.is_some()
    }

    /// Total KV footprint right now (prefilled + decoded tokens).
    pub fn kv_now(&self) -> u64 {
        self.prefill_done as u64 + self.decoded as u64
    }

    /// Remaining decode tokens (including any in flight).
    pub fn decode_remaining(&self) -> u32 {
        self.req.decode_len.saturating_sub(self.decoded)
    }
}

/// Result of a full simulation run.
#[derive(Debug)]
pub struct SimResult {
    /// Per-request outcomes.
    pub outcomes: Vec<RequestOutcome>,
    /// Aggregated DSLO attainment.
    pub attainment: AttainmentReport,
    /// Instance·second cost accounting.
    pub cost: CostAccount,
    /// Per-tier fleet-size time series (empty for fixed-fleet runs).
    pub fleet: FleetSeries,
    /// Scale-in drain latencies + KV-migration counters.
    pub migration: MigrationStats,
    /// Wall-clock simulated, ms.
    pub sim_span_ms: TimeMs,
    /// Completed requests per second of simulated time.
    pub throughput_rps: f64,
    /// Requests never finished (stuck/dropped) — should be 0.
    pub unfinished: usize,
    /// Simulator events processed (arrivals, iteration ends, wakes,
    /// ticks, lifecycle + migration events) — the denominator of the
    /// `sim_perf` events/sec throughput metric.
    pub events_processed: u64,
    /// Fault-injection counters; all-zeros unless [`SimParams::chaos`]
    /// was enabled (the digest-identity tests pin this).
    pub chaos: ChaosStats,
    /// Overload accounting (rejections, retries, shed tokens, queue
    /// aging). The rejection/retry counters stay zero unless
    /// [`SimParams::overload`] was set; the aging counters move on any
    /// run that ever pended a request.
    pub overload: OverloadStats,
}

/// Per-role bounds for the elastic PD prefill tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillElastic {
    /// Never drain the prefill cluster below this (≥ 1: the PD router
    /// requires at least one active prefill server).
    pub min_instances: usize,
    /// Never provision prefill above this (active + cold-starting).
    pub max_instances: usize,
}

/// Fleet-elasticity mechanics (bounds and delays; *when* to scale is
/// the [`Autoscaler`]'s decision). `min`/`max` bound the scalable
/// role — decode servers under PD, coloc servers under co-location;
/// the PD prefill cluster stays static unless [`ElasticParams::prefill`]
/// gives it bounds of its own.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ElasticParams {
    /// Never drain below this many scalable instances.
    pub min_instances: usize,
    /// Never provision above this many (active + cold-starting).
    pub max_instances: usize,
    /// Cold-start delay: provision → `InstanceReady`.
    pub provision_delay_ms: TimeMs,
    /// Period of the `ScaleEval` event.
    pub scale_eval_ms: TimeMs,
    /// Scale-in KV migration: evict a drainer's decode residents to
    /// surviving servers instead of waiting for them to finish. `false`
    /// reproduces the PR 1 wait-drain path bit-for-bit.
    pub migration: bool,
    /// Elastic PD prefill tier bounds; `None` = static prefill cluster
    /// (scaler actions on `Role::Prefill` are ignored — the PR 2
    /// behaviour bit-for-bit).
    pub prefill: Option<PrefillElastic>,
    /// Coalesce a drain's same-`(source, destination)` KV migration
    /// streams into one bulk transfer per destination: residents are
    /// routed at drain time, grouped by destination, and each group
    /// pays a single `max(kv_transfer_ms, Σkv / MIGRATION_TOKENS_PER_MS)`
    /// stream instead of one `MigrationArrive` round-trip each. `false`
    /// reproduces the per-request transfer path bit-for-bit.
    pub migration_batching: bool,
    /// Model hot-swap reload delay: drain-complete → `InstanceReady`
    /// under the new model (weight load + warmup). Irrelevant (and
    /// unread) while the fleet serves a single model.
    pub model_swap_delay_ms: TimeMs,
}

/// A correlated-failure blast radius: one `ChaosFailDomain` draw (or
/// an explicit [`ChaosParams::domain_fail_at`] entry) hard-kills every
/// live instance inside it in a single event. Domains are assigned to
/// instances by a deterministic stride at build/provision time when
/// `[chaos] zones` > 0 (see [`Instance::domain`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum FailDomain {
    /// One rack inside a zone — the common blast radius (top-of-rack
    /// switch or PDU loss).
    Rack {
        /// The zone the rack lives in.
        zone: u32,
        /// Rack index inside the zone.
        rack: u32,
    },
    /// A whole zone — the rare, wide outage (every rack in it dies).
    Zone {
        /// The zone that goes dark.
        zone: u32,
    },
}

/// Fault-injection and spot-preemption schedule (the `[chaos]` layer;
/// see the module docs). `Default` is fully disabled —
/// [`ChaosParams::enabled`] is `false` and the simulation constructs
/// no chaos runtime at all.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChaosParams {
    /// Explicit hard kills: `(t_ms, instance id)`. Ids out of range or
    /// already retired at fire time are skipped.
    pub fail_at: Vec<(TimeMs, usize)>,
    /// Mean time between seeded random hard kills, drawn exponentially
    /// and aimed uniformly at the live fleet. 0 disables the process.
    pub fail_mtbf_ms: u64,
    /// Explicit spot-preemption notices: `(t_ms, instance id)`. A
    /// notice on a non-`Active` instance is dropped.
    pub preempt_at: Vec<(TimeMs, usize)>,
    /// Mean time between seeded random preemption notices, aimed
    /// uniformly at `Active` spot instances. 0 disables the process.
    pub preempt_mtbf_ms: u64,
    /// Grace window between a `PreemptNotice` and its hard deadline
    /// kill, ms.
    pub preempt_grace_ms: TimeMs,
    /// Fraction of *elastically provisioned* instances assigned to the
    /// spot class, by deterministic stride at provision time (the
    /// initial fleet is always on-demand). 0 = no spot capacity.
    pub spot_fraction: f64,
    /// Spot price as a fraction of the on-demand rate, reported through
    /// [`crate::metrics::CostAccount::discounted_bill_ms`].
    pub spot_price_frac: f64,
    /// Failure-domain zones the fleet is striped across (`(zone, rack)`
    /// by deterministic stride over instance ids). 0 = no domain model:
    /// every instance stays in `(0, 0)` and correlated kills are
    /// unavailable.
    pub zones: u32,
    /// Racks per zone (the inner stripe); must be >= 1 when `zones > 0`.
    pub racks_per_zone: u32,
    /// Explicit correlated kills: `(t_ms, domain)` — every live
    /// instance inside the domain fails at `t` in one event.
    pub domain_fail_at: Vec<(TimeMs, FailDomain)>,
    /// Mean time between seeded correlated domain kills, ms: each draw
    /// picks a uniform zone, then either one of its racks or (one draw
    /// in `racks_per_zone + 1`) the whole zone. 0 disables the process;
    /// needs `zones > 0` to have a target.
    pub domain_fail_mtbf_ms: u64,
    /// KV checkpoint period, ms: snapshot every resident request's
    /// committed prefill watermark so an `InstanceFail` rewinds there
    /// instead of to zero (suffix-only re-prefill). Each snapshot bills
    /// its delta tokens over [`MIGRATION_TOKENS_PER_MS`] into
    /// [`crate::metrics::ChaosStats::checkpoint_cost_ms`]. 0 = off.
    pub checkpoint_period_ms: u64,
    /// Stepwise spot price curve: `(t_ms, price_frac)` steps, times
    /// strictly increasing; the flat `spot_price_frac` applies before
    /// the first step. Empty = flat pricing only (bit-for-bit the
    /// single-step default; `spot_curve_bill_ms` stays `None`).
    pub spot_price_schedule: Vec<(TimeMs, f64)>,
    /// Stepwise spot availability curve: `(t_ms, multiplier)` steps
    /// scaling the preempt-MTBF inter-event gap (multiplier < 1 =
    /// scarcer capacity, preemptions come faster). The RNG draw stream
    /// is unchanged — only the drawn gap is scaled. Empty = off.
    pub spot_avail_schedule: Vec<(TimeMs, f64)>,
    /// Seed of the MTBF processes' dedicated RNG stream.
    pub seed: u64,
}

impl ChaosParams {
    /// Does this schedule inject anything at all? `false` means the
    /// run schedules zero chaos events and draws zero RNG — bit-for-bit
    /// the chaos-free path. Zone striping alone (`zones > 0` with no
    /// injection and no checkpointing) does not enable: it only labels
    /// instances.
    pub fn enabled(&self) -> bool {
        !self.fail_at.is_empty()
            || !self.preempt_at.is_empty()
            || self.fail_mtbf_ms > 0
            || self.preempt_mtbf_ms > 0
            || self.spot_fraction > 0.0
            || !self.domain_fail_at.is_empty()
            || self.domain_fail_mtbf_ms > 0
            || self.checkpoint_period_ms > 0
    }
}

/// Arrival-edge admission control and client retry behaviour (the
/// `[overload]` layer; see the module docs). `None` on
/// [`SimParams::overload`] is the gate-free seed path bit-for-bit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OverloadParams {
    /// Shed infeasible arrivals with a typed `Rejected` outcome.
    pub reject: bool,
    /// Rejected clients resubmit after capped exponential backoff.
    pub retry: bool,
    /// Backoff base: retry `k` waits `base·2^(k-1) + jitter(base)` ms.
    pub retry_base_ms: u64,
    /// Give up (shed for good) after this many rejections.
    pub retry_max_attempts: u32,
    /// Client-side deadline propagation: a retry re-arrives with the
    /// *remaining* end-to-end budget — the SLO clock stays anchored at
    /// the original arrival instead of re-anchoring at the re-arrival.
    /// `false` is the PR 9 reset-clock behaviour bit-for-bit.
    pub propagate_deadline: bool,
    /// Seed of the retry-jitter RNG stream.
    pub seed: u64,
}

/// Environment knobs (not policy).
#[derive(Debug, Clone)]
pub struct SimParams {
    /// Serving architecture simulated.
    pub mode: ServingMode,
    /// KV-transfer latency prefill→decode for PD (paper assumes RDMA).
    pub kv_transfer_ms: TimeMs,
    /// Housekeeping tick period.
    pub tick_ms: TimeMs,
    /// Abort the run if simulated time exceeds this (safety valve).
    pub max_sim_ms: TimeMs,
    /// Elastic-fleet mechanics; `None` = fixed fleet (seed behaviour:
    /// no lifecycle events are ever scheduled).
    pub elastic: Option<ElasticParams>,
    /// Run the cache/index coherence audit (`Cluster::audit`) after
    /// every event in debug-assertion builds. Default on; the
    /// `sim_perf` timing cells turn it off — with it the bench would
    /// measure the audit's own full scans, not the hot path.
    pub debug_audit: bool,
    /// Schedule events on the pre-calendar binary heap instead of the
    /// calendar queue — a runtime reference mode (like the cluster's
    /// `scan_reference`/`indexed_reference`) for A/B digest-identity
    /// runs; decisions are bit-for-bit identical by construction.
    pub heap_reference: bool,
    /// Fault-injection schedule; `None` or a disabled schedule is the
    /// chaos-free seed path bit-for-bit.
    pub chaos: Option<ChaosParams>,
    /// Arrival-edge admission control + client retries; `None` is the
    /// gate-free seed path bit-for-bit.
    pub overload: Option<OverloadParams>,
}

impl Default for SimParams {
    fn default() -> SimParams {
        SimParams {
            mode: ServingMode::PdDisaggregated,
            kv_transfer_ms: 2,
            tick_ms: 100,
            max_sim_ms: 48 * 3600 * 1000,
            elastic: None,
            debug_audit: true,
            heap_reference: false,
            chaos: None,
            overload: None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum EventKey {
    Arrival(usize),
    IterEnd(usize),
    /// Retry starting an iteration (e.g. a KV handoff becomes ready).
    Wake(usize),
    Tick,
    /// A provisioned instance finished its cold start.
    InstanceReady(usize),
    /// Periodic autoscaler evaluation (elastic fleets only).
    ScaleEval,
    /// A migrated request's KV finished streaming off its drained
    /// source; re-enter decode placement now.
    MigrationArrive(usize),
    /// Hard kill: force-retire the instance, resident KV is lost
    /// (`[chaos]` only — never scheduled otherwise).
    InstanceFail(usize),
    /// Spot reclamation warning: drain now against a hard deadline
    /// (`[chaos]` only).
    PreemptNotice(usize),
    /// Self-rescheduling MTBF hard-kill process (`[chaos]` only).
    ChaosFail,
    /// Self-rescheduling MTBF spot-preemption process (`[chaos]` only).
    ChaosPreempt,
    /// A rejected client's backoff expired: the request re-arrives with
    /// a re-anchored SLO clock (`[overload] retry` only).
    RetryArrival(usize),
    /// Explicit correlated kill: every live instance in the domain
    /// fails in one event (`[chaos]` only).
    DomainFail(FailDomain),
    /// Self-rescheduling MTBF correlated-kill process (`[chaos]` only).
    ChaosFailDomain,
    /// Self-rescheduling KV checkpoint sweep: snapshot every resident
    /// request's committed prefill watermark (`[chaos]
    /// checkpoint_period_ms` only).
    Checkpoint,
}

/// Live fault-injection state: the schedule, its dedicated RNG stream,
/// the accumulated counters, and the set of instances inside a
/// preemption grace window. Constructed only when
/// [`ChaosParams::enabled`] — its absence is what makes the chaos-off
/// path bit-for-bit identical to the seed.
struct ChaosRuntime {
    /// The schedule this runtime executes.
    params: ChaosParams,
    /// MTBF processes' RNG; untouched unless an MTBF knob is set.
    rng: Rng,
    /// Counters surfaced on [`SimResult::chaos`].
    stats: ChaosStats,
    /// Instances holding a `PreemptNotice` whose deadline
    /// `InstanceFail` has not fired yet.
    preempt_pending: BTreeSet<usize>,
    /// Elastic provisions seen so far — the deterministic spot-class
    /// stride counter.
    provisioned: u64,
    /// Chaos-adaptive spot policy: when a scaler's `SpotPolicy` action
    /// judged realized churn to be eating the spot discount, new
    /// provisions skip the spot stride (the counter still advances) —
    /// until a later action restores it. Never set without
    /// `[chaos] adaptive`.
    force_on_demand: bool,
}

impl ChaosRuntime {
    fn new(params: ChaosParams) -> ChaosRuntime {
        ChaosRuntime {
            rng: Rng::new(params.seed),
            stats: ChaosStats::default(),
            preempt_pending: BTreeSet::new(),
            provisioned: 0,
            force_on_demand: false,
            params,
        }
    }

    /// Next exponential inter-event gap of an MTBF process, clamped to
    /// the 1 ms event resolution.
    fn next_gap(&mut self, mtbf_ms: u64) -> TimeMs {
        debug_assert!(mtbf_ms > 0, "gap drawn from a disabled MTBF process");
        self.rng.exp(1.0 / mtbf_ms as f64).max(1.0) as TimeMs
    }
}

/// Live overload-admission state: the knobs, the retry-jitter RNG
/// stream, and per-request rejection counts. Constructed only when
/// [`SimParams::overload`] is set — its absence is what keeps the
/// overload-off path bit-for-bit identical to the seed (no gate calls,
/// no RNG draws, no events).
struct OverloadRuntime {
    params: OverloadParams,
    /// Retry-jitter RNG; drawn only when a retry is scheduled.
    rng: Rng,
    /// `attempts[i]` = times request `i` was refused at the arrival
    /// edge (0 = admitted on first contact).
    attempts: Vec<u32>,
}

impl OverloadRuntime {
    fn new(params: OverloadParams, n_requests: usize) -> OverloadRuntime {
        OverloadRuntime {
            rng: Rng::new(params.seed),
            attempts: vec![0; n_requests],
            params,
        }
    }
}

/// The event-driven simulation.
pub struct Simulation<'a> {
    /// Environment knobs.
    pub params: SimParams,
    /// Ground-truth iteration times (the simulated hardware) for
    /// registry model 0 — the only model unless
    /// [`Simulation::with_cost_models`] installs more.
    pub cost_model: CostModel,
    /// Per-model ground truth, indexed by [`ModelId`]; entry 0 is
    /// always `cost_model`.
    cost_models: Vec<CostModel>,
    /// The table the router sees (§4.5 profiling stand-in).
    pub profile: &'a ProfileTable,
    /// The request arena: per-request mutable state, indexed by the
    /// event queue's `req_idx`; immutable data borrowed from the
    /// workload.
    pub requests: Vec<SimRequest<'a>>,
    /// The fleet under simulation.
    pub cluster: Cluster,
    events: EventQueue<EventKey>,
    seq: u64,
    /// Index of the next workload arrival not yet fed into the run
    /// (the queue is not seeded with arrivals; see the module docs).
    arrival_cursor: usize,
    now: TimeMs,
    fleet: FleetSeries,
    migration: MigrationStats,
    events_processed: u64,
    /// Reused by the Tick safety sweep instead of reallocating a fresh
    /// `Vec` every 100 ms.
    tick_scratch: Vec<usize>,
    /// Fault-injection runtime; `None` whenever `[chaos]` is absent or
    /// disabled — then no chaos event is ever scheduled and no RNG is
    /// ever drawn.
    chaos: Option<ChaosRuntime>,
    /// Overload-admission runtime; `None` whenever `[overload]` is
    /// absent — then the gate is never consulted and no RNG is drawn.
    overload: Option<OverloadRuntime>,
    /// Overload accounting, always present: the rejection/retry fields
    /// stay zero without a runtime, the queue-aging fields are copied
    /// from the router at finalization on every run.
    ol_stats: OverloadStats,
}

impl<'a> Simulation<'a> {
    /// Build a simulation over `workload` on `cluster`. Arrivals are
    /// *not* seeded as events: the run feeds the (arrival-sorted)
    /// workload through a cursor merged against the queue head, so only
    /// the first housekeeping tick is queued up front.
    pub fn new(
        params: SimParams,
        cost_model: CostModel,
        profile: &'a ProfileTable,
        workload: &'a Workload,
        cluster: Cluster,
        tiers: &crate::slo::TierSet,
    ) -> Simulation<'a> {
        assert!(
            workload
                .requests
                .windows(2)
                .all(|w| w[0].arrival_ms <= w[1].arrival_ms),
            "workload must be sorted by arrival time: the simulator \
             feeds arrivals through a cursor, not pre-seeded events"
        );
        let requests: Vec<SimRequest<'a>> = workload
            .requests
            .iter()
            .map(|r| SimRequest::new(r, tiers.bin_for_tpot(r.slo.tpot_ms)))
            .collect();
        let events = if params.heap_reference {
            EventQueue::heap()
        } else {
            EventQueue::calendar()
        };
        let tick = params.tick_ms;
        let cost_models = vec![cost_model.clone()];
        let chaos = params
            .chaos
            .clone()
            .filter(|c| c.enabled())
            .map(ChaosRuntime::new);
        let overload = params
            .overload
            .clone()
            .map(|p| OverloadRuntime::new(p, requests.len()));
        let mut sim = Simulation {
            params,
            cost_model,
            cost_models,
            profile,
            requests,
            cluster,
            events,
            seq: 0,
            arrival_cursor: 0,
            now: 0,
            fleet: FleetSeries::default(),
            migration: MigrationStats::default(),
            events_processed: 0,
            tick_scratch: Vec::new(),
            chaos,
            overload,
            ol_stats: OverloadStats::default(),
        };
        // Failure-domain striping over the built fleet: instance id
        // striding `(id mod zones, (id div zones) mod racks)` spreads
        // adjacent ids across zones first, then racks — so any
        // contiguous slice of the fleet is maximally domain-diverse.
        // Elastic provisions get the same stride in `apply_provision`.
        if let Some((zones, racks)) = sim.chaos.as_ref().and_then(|ch| {
            (ch.params.zones > 0).then_some((ch.params.zones, ch.params.racks_per_zone.max(1)))
        }) {
            for i in &mut sim.cluster.instances {
                i.domain = domain_of(i.id, zones, racks);
            }
        }
        sim.push_event(tick, EventKey::Tick);
        sim
    }

    /// Install the full per-model ground-truth cost models (from
    /// [`crate::model::ModelRegistry::cost_models`]). Entry 0 must be
    /// the model the simulation was built with; a single-entry vector
    /// leaves behaviour untouched.
    pub fn with_cost_models(mut self, cost_models: Vec<CostModel>) -> Simulation<'a> {
        assert!(!cost_models.is_empty());
        assert_eq!(
            cost_models[0], self.cost_model,
            "registry model 0 must match the simulation's base cost model"
        );
        self.cost_models = cost_models;
        self
    }

    fn push_event(&mut self, t: TimeMs, key: EventKey) {
        self.events.push(t, self.seq, key);
        self.seq += 1;
    }

    fn ctx(&mut self) -> RouteCtx<'_, 'a> {
        RouteCtx {
            now: self.now,
            cluster: &mut self.cluster,
            requests: &mut self.requests,
            profile: self.profile,
            mode: self.params.mode,
            kv_transfer_ms: self.params.kv_transfer_ms,
        }
    }

    /// Run to completion under `router` with a fixed fleet.
    pub fn run(self, router: &mut dyn Router) -> SimResult {
        self.run_elastic(router, None)
    }

    /// Run to completion under `router`, with an optional fleet
    /// autoscaler (requires `params.elastic`); returns outcomes and
    /// metrics. With `scaler == None` this is byte-identical to the
    /// fixed-fleet path: no lifecycle event is ever scheduled.
    pub fn run_elastic(
        mut self,
        router: &mut dyn Router,
        mut scaler: Option<&mut dyn Autoscaler>,
    ) -> SimResult {
        let mut completed = 0usize;
        let total = self.requests.len();
        // Hoisted once for the whole run: the ScaleEval arm borrows
        // this instead of cloning `ElasticParams` on every evaluation.
        let elastic = self.params.elastic.clone();
        if let (Some(ep), true) = (elastic.as_ref(), scaler.is_some()) {
            self.sample_fleet();
            self.push_event(ep.scale_eval_ms.max(1), EventKey::ScaleEval);
        }
        // Seed the fault-injection schedule: the explicit kill/preempt
        // lists plus the first draw of each MTBF process, in a fixed
        // order so seq numbering is deterministic. A disabled `[chaos]`
        // constructed no runtime — zero pushes, zero RNG draws, and the
        // seq stream matches the chaos-free path exactly.
        let mut chaos_seed: Vec<(TimeMs, EventKey)> = Vec::new();
        if let Some(ch) = self.chaos.as_mut() {
            for &(t, inst) in &ch.params.fail_at {
                chaos_seed.push((t, EventKey::InstanceFail(inst)));
            }
            for &(t, inst) in &ch.params.preempt_at {
                chaos_seed.push((t, EventKey::PreemptNotice(inst)));
            }
            let fail_mtbf = ch.params.fail_mtbf_ms;
            if fail_mtbf > 0 {
                let gap = ch.next_gap(fail_mtbf);
                chaos_seed.push((gap, EventKey::ChaosFail));
            }
            let preempt_mtbf = ch.params.preempt_mtbf_ms;
            if preempt_mtbf > 0 {
                let gap = ch.next_gap(preempt_mtbf);
                chaos_seed.push((gap, EventKey::ChaosPreempt));
            }
            // PR 10 additions append strictly after the PR 8 seeds, so
            // a schedule without them reproduces the old seq stream
            // bit-for-bit.
            for &(t, d) in &ch.params.domain_fail_at {
                chaos_seed.push((t, EventKey::DomainFail(d)));
            }
            let domain_mtbf = ch.params.domain_fail_mtbf_ms;
            if domain_mtbf > 0 {
                let gap = ch.next_gap(domain_mtbf);
                chaos_seed.push((gap, EventKey::ChaosFailDomain));
            }
            let ckpt = ch.params.checkpoint_period_ms;
            if ckpt > 0 {
                chaos_seed.push((ckpt, EventKey::Checkpoint));
            }
        }
        for (t, key) in chaos_seed {
            self.push_event(t, key);
        }
        loop {
            // Merge the sorted-workload arrival cursor against the
            // queue head. Arrivals win timestamp ties (in the old
            // seeded ordering every arrival seq preceded every
            // runtime-scheduled event's), which the strictly-less-than
            // bound encodes; the bounded pop never scans the wheel
            // past the bound, so this event's own pushes stay legal.
            let next_arrival = self
                .requests
                .get(self.arrival_cursor)
                .map(|r| r.req.arrival_ms);
            let (t, key) = match self.events.pop_earlier_than(next_arrival) {
                Some((t, _, key)) => (t, key),
                None => match next_arrival {
                    Some(t) => {
                        let idx = self.arrival_cursor;
                        self.arrival_cursor += 1;
                        (t, EventKey::Arrival(idx))
                    }
                    // Queue drained and no arrivals left.
                    None => break,
                },
            };
            debug_assert!(t >= self.now, "time went backwards");
            if t > self.params.max_sim_ms {
                // Abort *before* advancing the clock: `self.now` stays
                // the last simulated event time, which `finalize` bills.
                log::warn!("simulation exceeded max_sim_ms; aborting");
                break;
            }
            self.now = t;
            self.events_processed += 1;
            match key {
                // Both arrival flavours count terminal rejections into
                // `completed`: a shed request will never finish, so the
                // loop must not wait for it.
                EventKey::Arrival(idx) => {
                    completed += self.handle_arrival(idx, router);
                }
                EventKey::RetryArrival(idx) => {
                    completed += self.handle_retry_arrival(idx, router);
                }
                EventKey::IterEnd(inst) => {
                    // Chaos-gated stale guard: a hard kill mid-iteration
                    // leaves this event in the queue; the dead instance
                    // must not complete the discarded batch. Gated on
                    // the runtime so the chaos-free control flow (and
                    // router call sequence) is untouched.
                    if self.chaos.is_some()
                        && !self.cluster.instances[inst].lifecycle.is_live()
                    {
                        // dropped: instance was hard-killed mid-iteration
                    } else {
                        completed += self.handle_iter_end(inst, router);
                    }
                }
                EventKey::Wake(inst) => {
                    if self.chaos.is_some()
                        && !self.cluster.instances[inst].lifecycle.is_live()
                    {
                        // stale wake for a hard-killed instance
                    } else {
                        self.maybe_start_iteration(inst, router);
                        // A migrating drainer's wake may be its egress
                        // deadline — it retires (or completes its model
                        // swap) here if truly done.
                        self.finish_drain(inst);
                    }
                }
                EventKey::InstanceReady(inst) => {
                    if self.chaos.is_some()
                        && !self.cluster.instances[inst].lifecycle.is_live()
                    {
                        // killed during its cold start / swap reload
                    } else {
                        self.cluster.mark_ready(inst);
                        // Fresh capacity may unblock pending work at once.
                        router.on_tick(self.now, &mut self.ctx());
                        self.restart_fed_instances(router);
                    }
                }
                EventKey::InstanceFail(inst) => {
                    self.handle_instance_fail(inst, router);
                }
                EventKey::PreemptNotice(inst) => {
                    self.handle_preempt_notice(inst, router);
                }
                EventKey::ChaosFail => self.handle_chaos_fail(router),
                EventKey::ChaosPreempt => self.handle_chaos_preempt(router),
                EventKey::DomainFail(d) => self.handle_domain_fail(d, router),
                EventKey::ChaosFailDomain => self.handle_chaos_domain_fail(router),
                EventKey::Checkpoint => self.handle_checkpoint(),
                EventKey::MigrationArrive(req_idx) => {
                    debug_assert!(
                        !self.requests[req_idx].is_finished(),
                        "migrated request {req_idx} finished while in flight"
                    );
                    // Phase dispatch: a request evicted off a draining
                    // prefill server is still prefill-phase; decode
                    // evictions always carry a completed prefill.
                    if self.requests[req_idx].prefill_done
                        < self.requests[req_idx].req.prefill_len
                    {
                        self.place_prefill_handoff(req_idx, router);
                    } else {
                        self.place_decode_handoff(req_idx, router);
                    }
                    self.restart_fed_instances(router);
                }
                EventKey::ScaleEval => {
                    if completed < total {
                        if let (Some(sc), Some(ep)) =
                            (scaler.as_deref_mut(), elastic.as_ref())
                        {
                            self.handle_scale_eval(sc, ep, router);
                            self.push_event(
                                self.now + ep.scale_eval_ms.max(1),
                                EventKey::ScaleEval,
                            );
                        }
                    }
                }
                EventKey::Tick => {
                    if completed < total {
                        router.on_tick(self.now, &mut self.ctx());
                        self.restart_fed_instances(router);
                        // Safety sweep: restart any idle instance that
                        // still holds work (e.g. queued by a router path
                        // that forgot to kick it). The scratch Vec is
                        // reused across ticks instead of reallocated.
                        let mut idle = std::mem::take(&mut self.tick_scratch);
                        idle.clear();
                        idle.extend(
                            self.cluster
                                .instances
                                .iter()
                                .filter(|i| !i.iterating && i.has_work())
                                .map(|i| i.id),
                        );
                        for &inst in &idle {
                            self.maybe_start_iteration(inst, router);
                        }
                        self.tick_scratch = idle;
                        // Retire (or swap-reload) drainers that emptied
                        // outside their own iteration path (e.g.
                        // released by the router) — skipped outright
                        // while nothing is draining.
                        if self.cluster.draining_any() {
                            for id in 0..self.cluster.instances.len() {
                                self.finish_drain(id);
                            }
                        }
                        if log::log_enabled!(log::Level::Trace) && self.now % 1000 == 0 {
                            self.log_timeline();
                        }
                        let next = self.now + self.params.tick_ms;
                        self.push_event(next, EventKey::Tick);
                    }
                }
            }
            // Coherence audit (debug builds): cached load counters,
            // membership indices, the load-ordered sets, and the O(1)
            // unplaced-demand counter must equal their scan-recomputed
            // ground truth after *every* event.
            if cfg!(debug_assertions) && self.params.debug_audit {
                self.cluster.audit(&self.requests);
                // The scan oracle counts every request with
                // `arrival_ms <= now` — including same-millisecond
                // arrivals whose events are still queued behind this
                // one — while the counter (correctly) counts only
                // processed arrivals. Reconcile by the number of
                // pending same-time arrivals, which are always
                // unfinished and unresident: counter + pending == scan
                // exactly, with no request-ordering assumptions.
                let arrived_scan = self
                    .requests
                    .iter()
                    .filter(|r| r.req.arrival_ms <= self.now)
                    .count();
                // Admission-gated requests never called `note_arrival`:
                // shed ones are also filtered out of the scan oracle
                // (debug-only recount here), while retry-waiting ones
                // appear on both sides of the equation and cancel.
                let shed_count = if self.overload.is_some() {
                    self.requests.iter().filter(|r| r.shed).count()
                } else {
                    0
                };
                assert!(
                    self.cluster.arrived_total() + shed_count <= arrived_scan,
                    "arrival counter overran the workload"
                );
                let pending_arrivals =
                    arrived_scan - self.cluster.arrived_total() - shed_count;
                assert_eq!(
                    self.cluster.unplaced_demand() + pending_arrivals,
                    self.cluster.unplaced_demand_scan(&self.requests, self.now),
                    "incremental unplaced-demand counter drifted from the scan oracle"
                );
            }
            if completed == total {
                break;
            }
        }
        // Attach the predicted-vs-observed arrival-rate series (empty
        // for non-predictive scalers) before outcome collection.
        if let Some(sc) = scaler.as_deref_mut() {
            self.fleet.rates = sc.take_rate_series();
        }
        // Queue-aging diagnostics come from the router (policies
        // without a pending queue report `None` and leave the zeros).
        if let Some((aged, max_pend)) = router.queue_aging() {
            self.ol_stats.aged_past_patience = aged;
            self.ol_stats.max_pend_ms = max_pend;
        }
        self.finalize(completed)
    }

    /// Apply one autoscaler evaluation: bounds-checked provision/drain
    /// plus a fleet-size sample. Bounds are *per role* — the scalable
    /// role uses `min_instances`/`max_instances`, `Role::Prefill` its
    /// own `ElasticParams::prefill` bounds (actions on a static prefill
    /// cluster are dropped, reproducing the PR 2 path bit-for-bit).
    fn handle_scale_eval(
        &mut self,
        scaler: &mut dyn Autoscaler,
        ep: &ElasticParams,
        router: &mut dyn Router,
    ) {
        // Chaos telemetry feed (only when a chaos runtime exists, so
        // the chaos-free control flow is untouched): the scaler sees
        // the realized kill/preempt counters and the *current* spot
        // price before it plans. The default hook is a no-op; only a
        // chaos-adaptive scaler acts on it.
        if self.chaos.is_some() {
            let spot_active = self
                .cluster
                .instances
                .iter()
                .filter(|i| i.spot && i.lifecycle.is_live())
                .count();
            let ch = self.chaos.as_ref().expect("checked above");
            let price = schedule_value_at(
                &ch.params.spot_price_schedule,
                self.now,
                ch.params.spot_price_frac,
            );
            scaler.observe_chaos(self.now, &ch.stats, spot_active, price);
        }
        let actions = scaler.evaluate(self.now, &mut self.ctx());
        for action in actions {
            match action {
                ScaleAction::Provision { role } => {
                    self.apply_provision(0, role, ep);
                }
                ScaleAction::ProvisionModel { model, role } => {
                    self.apply_provision(model, role, ep);
                }
                ScaleAction::Drain { inst, migrate } => {
                    let role = self.cluster.instances[inst].role;
                    let floor = match role {
                        Role::Prefill => match &ep.prefill {
                            Some(p) => p.min_instances.max(1),
                            None => {
                                log::debug!(
                                    "t={} dropping prefill drain: prefill tier is static",
                                    self.now
                                );
                                continue;
                            }
                        },
                        _ => ep.min_instances,
                    };
                    if self.cluster.instances[inst].lifecycle.accepts_work()
                        && self.cluster.active_count(role) > floor
                    {
                        self.cluster.begin_drain(inst, self.now);
                        if ep.migration && migrate {
                            // Wait-free drain: move the residents out
                            // instead of waiting for them to finish.
                            match role {
                                Role::Prefill => self.migrate_prefill_queue(inst),
                                _ => self.migrate_residents(inst, router),
                            }
                        }
                        // Empty drainers retire on the spot.
                        self.cluster.retire_if_drained(inst, self.now);
                        log::debug!("t={} scale-in: drain inst {inst} ({role:?})", self.now);
                    }
                }
                ScaleAction::SwapModel { inst, model } => {
                    let role = self.cluster.instances[inst].role;
                    // A swap is both a scale-in (of the old model) and a
                    // scale-out (of the new): it needs an active, not
                    // already-swapping instance, must not strand the old
                    // model's last server, and counts against the
                    // target's committed capacity like a provision.
                    let old = self.cluster.instances[inst].model;
                    if model != old
                        && model < self.cluster.num_models
                        && self.cluster.instances[inst].lifecycle.accepts_work()
                        && self.cluster.active_count_of(old, role) > 1
                    {
                        self.cluster.begin_swap(inst, model, self.now);
                        if ep.migration {
                            match role {
                                Role::Prefill => self.migrate_prefill_queue(inst),
                                _ => self.migrate_residents(inst, router),
                            }
                        }
                        log::debug!(
                            "t={} hot-swap: inst {inst} ({role:?}) model {old} -> {model}",
                            self.now
                        );
                        // Already empty: reload starts immediately.
                        self.finish_drain(inst);
                    }
                }
                ScaleAction::SpotPolicy { on_demand } => {
                    // Chaos-adaptive spot/on-demand shift: subsequent
                    // provisions skip (or resume) the spot stride. Only
                    // ever emitted by a chaos-adaptive scaler, so the
                    // knobs-off path never reaches here.
                    if let Some(ch) = self.chaos.as_mut() {
                        if ch.force_on_demand != on_demand {
                            ch.force_on_demand = on_demand;
                            log::debug!(
                                "t={} chaos-adaptive: provisions now {}",
                                self.now,
                                if on_demand { "on-demand" } else { "spot-eligible" }
                            );
                        }
                    }
                }
            }
        }
        self.sample_fleet();
    }

    /// Bounds-checked provision of a `model`-loaded instance (the
    /// shared body of `Provision` ≡ model 0 and `ProvisionModel`).
    fn apply_provision(&mut self, model: ModelId, role: Role, ep: &ElasticParams) {
        if model >= self.cluster.num_models {
            log::debug!("t={} dropping provision of unknown model {model}", self.now);
            return;
        }
        let cap = match role {
            Role::Prefill => match &ep.prefill {
                Some(p) => p.max_instances,
                None => {
                    log::debug!(
                        "t={} dropping prefill provision: prefill tier is static",
                        self.now
                    );
                    return;
                }
            },
            _ => ep.max_instances,
        };
        if self.cluster.committed_count(role) < cap {
            let ready = self.now + ep.provision_delay_ms;
            let id = self.cluster.provision_model(model, role, self.now, ready);
            // Deterministic spot-class stride over elastic provisions:
            // provision k is spot iff the running spot quota
            // `floor(k·spot_fraction)` steps up at k+1. No RNG — the
            // class assignment is reproducible across digest runs.
            if let Some(ch) = self.chaos.as_mut() {
                let frac = ch.params.spot_fraction;
                if frac > 0.0 {
                    let k = ch.provisioned as f64;
                    ch.provisioned += 1;
                    // The stride counter advances even under a
                    // `SpotPolicy` on-demand hold, so lifting the hold
                    // resumes the original class sequence.
                    if ((k + 1.0) * frac).floor() > (k * frac).floor() && !ch.force_on_demand {
                        self.cluster.instances[id].spot = true;
                    }
                }
                if ch.params.zones > 0 {
                    self.cluster.instances[id].domain =
                        domain_of(id, ch.params.zones, ch.params.racks_per_zone.max(1));
                }
            }
            self.push_event(ready, EventKey::InstanceReady(id));
            log::debug!(
                "t={} scale-out: provision inst {id} (model {model}, {role:?}), ready at {ready}",
                self.now
            );
        }
    }

    /// A draining instance emptied out: either finish its model swap
    /// (reload + cold start under the new model) or retire it. Every
    /// drain-completion site funnels through here, so a swap can
    /// complete wherever a retire could.
    fn finish_drain(&mut self, inst: usize) {
        if self.cluster.swap_ready(inst, self.now) {
            let delay = self
                .params
                .elastic
                .as_ref()
                .map(|e| e.model_swap_delay_ms)
                .unwrap_or(0);
            let ready = self.now + delay;
            let target = self.cluster.complete_swap(inst, self.now, ready);
            self.migration.model_swaps += 1;
            self.push_event(ready, EventKey::InstanceReady(inst));
            log::debug!(
                "t={} hot-swap: inst {inst} reloading as model {target}, ready at {ready}",
                self.now
            );
        } else {
            self.cluster.retire_if_drained(inst, self.now);
        }
    }

    /// Hard-kill `inst` (`[chaos]` only): force-retire it on the spot —
    /// billing stops here, unlike a drain — and re-enter every resident
    /// through `route_new` for a full re-prefill (the device's KV died
    /// with it; already-emitted decode tokens are kept, so token
    /// conservation holds exactly). Also the deadline arm of a spot
    /// preemption: if the instance drained away inside its grace window
    /// this records a clean exit instead.
    fn handle_instance_fail(&mut self, inst: usize, router: &mut dyn Router) {
        let live = inst < self.cluster.instances.len()
            && self.cluster.instances[inst].lifecycle.is_live();
        let was_preempt = match self.chaos.as_mut() {
            Some(ch) => ch.preempt_pending.remove(&inst),
            // Never scheduled without a runtime; tolerate anyway.
            None => return,
        };
        if !live {
            if was_preempt {
                // Drained (and retired) before the deadline: the spot
                // reclamation cost nothing beyond the drain itself.
                if let Some(ch) = self.chaos.as_mut() {
                    ch.stats.preempt_drained += 1;
                }
            }
            return;
        }
        if let Some(ch) = self.chaos.as_mut() {
            ch.stats.failures += 1;
            if was_preempt {
                ch.stats.preempt_deadline_kills += 1;
            }
        }
        let victims = self.cluster.fail(inst, self.now);
        log::debug!(
            "t={} chaos: inst {inst} failed, {} residents lost their KV",
            self.now,
            victims.len()
        );
        for &req_idx in &victims {
            let (kv, ckpt, reprefill) = {
                let r = &self.requests[req_idx];
                debug_assert!(r.checkpointed <= r.prefill_done, "checkpoint past the watermark");
                (
                    r.kv_now(),
                    r.checkpointed as u64,
                    (r.prefill_done - r.checkpointed) as u64,
                )
            };
            if let Some(ch) = self.chaos.as_mut() {
                // Only the un-checkpointed suffix of the KV dies with
                // the device; the checkpointed prefix restores from the
                // snapshot. Without checkpointing `ckpt` is 0 and this
                // is exactly the PR 8 full-loss accounting.
                ch.stats.lost_kv_tokens += kv.saturating_sub(ckpt);
                ch.stats.recovered_kv_tokens += ckpt;
                ch.stats.reprefill_tokens += reprefill;
                ch.stats.replaced_requests += 1;
            }
            // Rewind to the last checkpoint (zero without checkpointing
            // — the PR 8 cold restart): only the suffix re-prefills.
            // `decoded` (and the tracker) keep the tokens the client
            // already received — they are never re-emitted.
            let r = &mut self.requests[req_idx];
            r.prefill_done = r.checkpointed;
            r.decode_instance = None;
        }
        // Re-placement only after the dead instance is `Retired`, so
        // `route_new` can never choose it. With a domain model, steer
        // the router away from the victim's zone for the replacement
        // placements (two-pass: survivors outside the blast radius are
        // preferred, with the full fleet as fallback).
        let avoid = self
            .chaos
            .as_ref()
            .and_then(|ch| (ch.params.zones > 0).then_some(self.cluster.instances[inst].domain.0));
        if avoid.is_some() {
            router.set_avoid_zone(avoid);
        }
        for &req_idx in &victims {
            // A checkpoint at the full prompt resumes decode directly —
            // there is nothing left to re-prefill.
            if self.requests[req_idx].prefill_done < self.requests[req_idx].req.prefill_len {
                self.place_prefill_handoff(req_idx, router);
            } else {
                self.place_decode_handoff(req_idx, router);
            }
        }
        if avoid.is_some() {
            router.set_avoid_zone(None);
        }
        self.restart_fed_instances(router);
    }

    /// Correlated kill (`[chaos]` only): hard-fail every live instance
    /// inside `domain` in one event — the rack/zone blast radius. Each
    /// victim goes through the ordinary [`Simulation::handle_instance_fail`]
    /// path (checkpoint rewind, domain-avoiding re-placement), in
    /// ascending instance-id order.
    fn handle_domain_fail(&mut self, domain: FailDomain, router: &mut dyn Router) {
        let (zone, rack) = match domain {
            FailDomain::Rack { zone, rack } => (zone, Some(rack)),
            FailDomain::Zone { zone } => (zone, None),
        };
        let victims = self.cluster.live_in_domain(zone, rack);
        if victims.is_empty() {
            return;
        }
        if let Some(ch) = self.chaos.as_mut() {
            ch.stats.domain_kills += 1;
            let z = zone as usize;
            if ch.stats.kills_per_zone.len() <= z {
                ch.stats.kills_per_zone.resize(z + 1, 0);
            }
            ch.stats.kills_per_zone[z] += victims.len() as u64;
        }
        log::debug!(
            "t={} chaos: domain {domain:?} failed, {} instances down",
            self.now,
            victims.len()
        );
        for inst in victims {
            self.handle_instance_fail(inst, router);
        }
    }

    /// One firing of the MTBF correlated-kill process: draw a uniform
    /// zone, then either one of its racks or — one draw in
    /// `racks_per_zone + 1` — the whole zone, and reschedule with a
    /// fresh exponential gap. The draw sequence depends only on the
    /// seed (fixed three draws per firing, targets or not).
    fn handle_chaos_domain_fail(&mut self, router: &mut dyn Router) {
        let (domain, gap) = {
            let Some(ch) = self.chaos.as_mut() else { return };
            let zones = ch.params.zones.max(1);
            let racks = ch.params.racks_per_zone.max(1);
            let zone = ch.rng.below(zones as u64) as u32;
            let r = ch.rng.below(racks as u64 + 1) as u32;
            let domain = if r == racks {
                FailDomain::Zone { zone }
            } else {
                FailDomain::Rack { zone, rack: r }
            };
            let mtbf = ch.params.domain_fail_mtbf_ms;
            (domain, ch.next_gap(mtbf))
        };
        self.handle_domain_fail(domain, router);
        self.push_event(self.now + gap, EventKey::ChaosFailDomain);
    }

    /// One firing of the periodic KV-checkpoint sweep (`[chaos]
    /// checkpoint_period_ms` only): snapshot every live instance's
    /// residents' committed prefill watermarks, bill each snapshot's
    /// delta tokens as transfer time over the migration interconnect
    /// rate, and reschedule. Snapshots are asynchronous — they never
    /// stall the instance — so the cost lands in
    /// [`crate::metrics::ChaosStats::checkpoint_cost_ms`], not in the
    /// iteration timeline.
    fn handle_checkpoint(&mut self) {
        let period = match self.chaos.as_ref() {
            Some(ch) if ch.params.checkpoint_period_ms > 0 => ch.params.checkpoint_period_ms,
            _ => return,
        };
        let mut snaps = 0u64;
        let mut toks = 0u64;
        for id in 0..self.cluster.instances.len() {
            if !self.cluster.instances[id].lifecycle.is_live() {
                continue;
            }
            for req_idx in self.cluster.instances[id].resident_reqs() {
                let r = &mut self.requests[req_idx];
                let delta = r.prefill_done.saturating_sub(r.checkpointed);
                if delta > 0 {
                    r.checkpointed = r.prefill_done;
                    snaps += 1;
                    toks += delta as u64;
                }
            }
        }
        if let Some(ch) = self.chaos.as_mut() {
            if snaps > 0 {
                ch.stats.checkpoints += snaps;
                ch.stats.checkpoint_tokens += toks;
                ch.stats.checkpoint_cost_ms += toks.div_ceil(MIGRATION_TOKENS_PER_MS);
            }
        }
        self.push_event(self.now + period, EventKey::Checkpoint);
    }

    /// Spot reclamation notice (`[chaos]` only): start an ordinary
    /// drain *now* — with KV migration when `[elastic] migration` is on
    /// and the role-matched feasibility gate passes — and schedule the
    /// hard deadline kill `preempt_grace_ms` out. Only `Active`
    /// instances take notices (a drainer is already leaving).
    fn handle_preempt_notice(&mut self, inst: usize, router: &mut dyn Router) {
        let grace = match self.chaos.as_ref() {
            Some(ch) => ch.params.preempt_grace_ms,
            None => return,
        };
        if inst >= self.cluster.instances.len()
            || !self.cluster.instances[inst].lifecycle.accepts_work()
        {
            return;
        }
        if let Some(ch) = self.chaos.as_mut() {
            ch.stats.preempt_notices += 1;
            ch.preempt_pending.insert(inst);
        }
        let role = self.cluster.instances[inst].role;
        // Gate while still Active, exactly as the autoscalers do (the
        // gates skip the source via `id != inst`).
        let migrate = self.params.elastic.as_ref().is_some_and(|e| e.migration) && {
            let ctx = self.ctx();
            match role {
                Role::Prefill => prefill_migration_feasible(&ctx, inst),
                _ => migration_feasible(&ctx, inst),
            }
        };
        self.cluster.begin_drain(inst, self.now);
        if migrate {
            match role {
                Role::Prefill => self.migrate_prefill_queue(inst),
                _ => self.migrate_residents(inst, router),
            }
        }
        // Already empty (or fully migrated with egress done): clean exit
        // on the spot; the deadline event then finds it retired.
        self.cluster.retire_if_drained(inst, self.now);
        self.push_event(self.now + grace, EventKey::InstanceFail(inst));
        log::debug!(
            "t={} chaos: preempt notice for inst {inst} ({role:?}), deadline in {grace} ms",
            self.now
        );
    }

    /// One firing of the MTBF hard-kill process: kill a uniformly
    /// chosen live instance and reschedule with a fresh exponential
    /// gap. Fires (and keeps billing RNG draws) even when the fleet has
    /// no live target, so the draw sequence depends only on the seed.
    fn handle_chaos_fail(&mut self, router: &mut dyn Router) {
        let live: Vec<usize> = self
            .cluster
            .instances
            .iter()
            .filter(|i| i.lifecycle.is_live())
            .map(|i| i.id)
            .collect();
        let (victim, gap) = {
            let Some(ch) = self.chaos.as_mut() else { return };
            let victim = if live.is_empty() {
                None
            } else {
                Some(live[ch.rng.below(live.len() as u64) as usize])
            };
            let mtbf = ch.params.fail_mtbf_ms;
            (victim, ch.next_gap(mtbf))
        };
        if let Some(v) = victim {
            self.handle_instance_fail(v, router);
        }
        self.push_event(self.now + gap, EventKey::ChaosFail);
    }

    /// One firing of the MTBF spot-preemption process: notice a
    /// uniformly chosen `Active` spot instance and reschedule. No-op
    /// (beyond the rescheduling draw) while no spot capacity is up.
    fn handle_chaos_preempt(&mut self, router: &mut dyn Router) {
        let spot: Vec<usize> = self
            .cluster
            .instances
            .iter()
            .filter(|i| i.spot && i.lifecycle.accepts_work())
            .map(|i| i.id)
            .collect();
        let now = self.now;
        let (victim, gap) = {
            let Some(ch) = self.chaos.as_mut() else { return };
            let victim = if spot.is_empty() {
                None
            } else {
                Some(spot[ch.rng.below(spot.len() as u64) as usize])
            };
            let mtbf = ch.params.preempt_mtbf_ms;
            let mut gap = ch.next_gap(mtbf);
            // Spot availability curve: scale the *drawn* gap (the RNG
            // stream itself is untouched — an empty schedule is
            // bit-for-bit the flat path). Multiplier < 1 means scarcer
            // capacity: the next preemption comes sooner.
            if !ch.params.spot_avail_schedule.is_empty() {
                let mult = schedule_value_at(&ch.params.spot_avail_schedule, now, 1.0);
                gap = ((gap as f64) * mult).max(1.0) as TimeMs;
            }
            (victim, gap)
        };
        if let Some(v) = victim {
            self.handle_preempt_notice(v, router);
        }
        self.push_event(self.now + gap, EventKey::ChaosPreempt);
    }

    /// Evict `inst`'s decode residents and schedule their KV transfers.
    /// The end-to-end cost per request is `max(kv_transfer_ms,
    /// kv_now / MIGRATION_TOKENS_PER_MS)`: the `MigrationArrive` delay
    /// covers the bulk stream *beyond* the final `kv_transfer_ms`
    /// handoff hop, which placement itself pays (so nothing is paid
    /// twice). The source may not retire — and keeps billing — until
    /// its last transfer has left (`egress_until`).
    ///
    /// With `ElasticParams::migration_batching` on, residents are
    /// instead routed *now* and coalesced into one bulk transfer per
    /// `(source, destination)` pair: the whole group lands when its
    /// single `max(kv_transfer_ms, Σkv / MIGRATION_TOKENS_PER_MS)`
    /// stream completes (one stream setup instead of per-request
    /// round-trips). Requests the router pends fall back to the
    /// per-request `MigrationArrive` path unchanged.
    fn migrate_residents(&mut self, inst: usize, router: &mut dyn Router) {
        let batching = self
            .params
            .elastic
            .as_ref()
            .is_some_and(|e| e.migration_batching);
        let evicted = self.cluster.instances[inst].evict_residents();
        self.cluster.refresh_load(inst);
        let kv_transfer_ms = self.params.kv_transfer_ms;
        let mut egress_until = self.cluster.instances[inst].egress_until;
        if !batching {
            for req_idx in evicted {
                let kv = self.requests[req_idx].kv_now();
                self.requests[req_idx].decode_instance = None;
                let stream =
                    (kv / MIGRATION_TOKENS_PER_MS.max(1)).saturating_sub(kv_transfer_ms);
                self.migration.migrated_requests += 1;
                self.migration.migrated_kv_tokens += kv;
                egress_until = egress_until.max(self.now + stream);
                self.push_event(self.now + stream, EventKey::MigrationArrive(req_idx));
                log::debug!(
                    "t={} migrate: req {req_idx} ({kv} KV tokens) off inst {inst}, lands in {stream} ms",
                    self.now
                );
            }
            self.cluster.instances[inst].egress_until = egress_until;
            if egress_until > self.now {
                // Retire exactly when the last transfer departs, not at
                // the next housekeeping tick.
                self.push_event(egress_until, EventKey::Wake(inst));
            }
            return;
        }
        // Batched path: place every evictee immediately, then group the
        // placed ones by destination into one bulk stream each.
        let mut groups: Vec<(usize, Vec<usize>, u64)> = Vec::new();
        for req_idx in evicted {
            let kv = self.requests[req_idx].kv_now();
            self.requests[req_idx].decode_instance = None;
            self.migration.migrated_requests += 1;
            self.migration.migrated_kv_tokens += kv;
            match router.route_decode(self.now, req_idx, &mut self.ctx()) {
                Some(d) => match groups.iter_mut().find(|g| g.0 == d) {
                    Some(g) => {
                        g.1.push(req_idx);
                        g.2 += kv;
                    }
                    None => groups.push((d, vec![req_idx], kv)),
                },
                None => {
                    // Pended by the router: per-request fallback.
                    let stream = (kv / MIGRATION_TOKENS_PER_MS.max(1))
                        .saturating_sub(kv_transfer_ms);
                    egress_until = egress_until.max(self.now + stream);
                    self.push_event(self.now + stream, EventKey::MigrationArrive(req_idx));
                }
            }
        }
        for (d, reqs, total_kv) in groups {
            // One bulk stream end-to-end: the handoff-ready time *is*
            // the stream completion, so the per-request hop is folded
            // into (not added on top of) the bulk transfer.
            let stream =
                (total_kv / MIGRATION_TOKENS_PER_MS.max(1)).max(kv_transfer_ms);
            let ready = self.now + stream;
            egress_until = egress_until.max(ready);
            self.migration.batched_transfers += 1;
            log::debug!(
                "t={} migrate: bulk {}x reqs ({total_kv} KV tokens) inst {inst} -> {d}, lands in {stream} ms",
                self.now,
                reqs.len()
            );
            for req_idx in reqs {
                self.requests[req_idx].decode_instance = Some(d);
                self.cluster.instances[d].push_decode(req_idx, ready, &self.requests);
            }
            self.cluster.refresh_load(d);
            self.maybe_start_iteration(d, router);
        }
        self.cluster.instances[inst].egress_until = egress_until;
        if egress_until > self.now {
            self.push_event(egress_until, EventKey::Wake(inst));
        }
        self.restart_fed_instances(router);
    }

    /// Evict a draining prefill server's queued jobs and re-route them
    /// to surviving prefill servers. An unstarted job re-enters
    /// placement immediately (it has no KV to move); a
    /// partially-prefilled job's committed KV streams off the source
    /// first, paying the same `max(kv_transfer_ms,
    /// kv/MIGRATION_TOKENS_PER_MS)` end-to-end cost as a decode
    /// migration — entirely as the `MigrationArrive` delay, because
    /// prefill re-queueing (unlike a decode handoff) has no
    /// destination-side transfer hop to cover the final
    /// `kv_transfer_ms`. The source keeps billing until its last
    /// transfer departs (`egress_until`), exactly like decode.
    fn migrate_prefill_queue(&mut self, inst: usize) {
        let jobs = self.cluster.instances[inst].evict_prefill_queue();
        self.cluster.refresh_load(inst);
        if jobs.is_empty() {
            return;
        }
        let kv_transfer_ms = self.params.kv_transfer_ms;
        let mut egress_until = self.cluster.instances[inst].egress_until;
        for job in jobs {
            let kv = self.requests[job.req_idx].prefill_done as u64;
            let stream = if kv == 0 {
                0
            } else {
                (kv / MIGRATION_TOKENS_PER_MS.max(1)).max(kv_transfer_ms)
            };
            self.migration.migrated_prefill_jobs += 1;
            self.migration.migrated_kv_tokens += kv;
            egress_until = egress_until.max(self.now + stream);
            self.push_event(self.now + stream, EventKey::MigrationArrive(job.req_idx));
            log::debug!(
                "t={} migrate: prefill job {} ({kv} KV tokens done) off inst {inst}, lands in {stream} ms",
                self.now,
                job.req_idx
            );
        }
        self.cluster.instances[inst].egress_until = egress_until;
        if egress_until > self.now {
            self.push_event(egress_until, EventKey::Wake(inst));
        }
    }

    /// Record the current fleet composition (overall and per role —
    /// the prefill column makes the elastic-prefill series visible).
    fn sample_fleet(&mut self) {
        let per_tier: Vec<usize> = (0..self.cluster.num_tiers)
            .map(|k| self.cluster.in_tier(k).count())
            .collect();
        let mut sample = FleetSample {
            t_ms: self.now,
            per_tier,
            per_model: vec![0; self.cluster.num_models],
            best_effort: self.cluster.best_effort_pool().count(),
            active: 0,
            active_prefill: 0,
            provisioning: 0,
            draining: 0,
        };
        for i in &self.cluster.instances {
            match i.lifecycle {
                Lifecycle::Active => {
                    sample.active += 1;
                    sample.per_model[i.model] += 1;
                    if i.role == Role::Prefill {
                        sample.active_prefill += 1;
                    }
                }
                Lifecycle::Provisioning { .. } => sample.provisioning += 1,
                Lifecycle::Draining { .. } => sample.draining += 1,
                Lifecycle::Retired { .. } => {}
            }
        }
        self.fleet.samples.push(sample);
    }

    /// Process an arrival (or a retry re-arrival). Returns 1 iff the
    /// request was terminally shed by the admission gate — it then
    /// counts as completed for loop accounting, since it will never
    /// finish.
    fn handle_arrival(&mut self, idx: usize, router: &mut dyn Router) -> usize {
        // Arrival-edge admission gate (`[overload] reject`): consult
        // the router's feasibility check *before* the request is
        // counted as arrived — a rejected request never touches the
        // unplaced-demand counter, pends nowhere, and bills nothing.
        if self.overload.as_ref().is_some_and(|o| o.params.reject) {
            let now = self.now;
            let admitted = router.admit_at_arrival(now, idx, &self.ctx());
            if !admitted {
                return self.reject_arrival(idx);
            }
        }
        // Feed the O(1) unplaced-demand counter before routing: the
        // request exists (and may pend) from this event on.
        self.cluster.note_arrival(self.requests[idx].req.model);
        let chosen = router.route_new(self.now, idx, &mut self.ctx());
        if let Some(inst) = chosen {
            let deadline = self.requests[idx].ttft_deadline();
            self.cluster.instances[inst]
                .push_prefill(PrefillJob { req_idx: idx, deadline }, &self.requests);
            self.cluster.refresh_load(inst);
            self.maybe_start_iteration(inst, router);
        }
        self.restart_fed_instances(router);
        // None: the router holds it pending and dispatches later.
        0
    }

    /// A rejected client's backoff expired: re-anchor the SLO clock at
    /// the re-arrival (the client resubmitted — deadlines restart from
    /// now, not from the original arrival) and run the ordinary arrival
    /// path, admission gate included. With `[overload]
    /// propagate_deadline`, the re-anchor is skipped: the clock stays
    /// at the original arrival, so the retry carries only the
    /// *remaining* end-to-end budget into every feasibility check.
    fn handle_retry_arrival(&mut self, idx: usize, router: &mut dyn Router) -> usize {
        debug_assert!(
            !self.requests[idx].shed && !self.requests[idx].is_finished(),
            "retry re-arrival for a settled request"
        );
        let propagate = self
            .overload
            .as_ref()
            .is_some_and(|o| o.params.propagate_deadline);
        if !propagate {
            let r = &mut self.requests[idx];
            r.effective_arrival_ms = self.now;
            r.tracker = DsloTracker::new(self.now, r.req.slo);
        }
        self.handle_arrival(idx, router)
    }

    /// The admission gate refused `idx`: schedule a client retry
    /// (capped exponential backoff with seeded jitter) while attempts
    /// remain, else shed the request for good with a typed `Rejected`
    /// outcome. Returns 1 on the terminal shed.
    fn reject_arrival(&mut self, idx: usize) -> usize {
        let (attempt, retry, base, max_attempts) = {
            let ol = self
                .overload
                .as_mut()
                .expect("admission gate fired without an overload runtime");
            ol.attempts[idx] += 1;
            (
                ol.attempts[idx],
                ol.params.retry,
                ol.params.retry_base_ms,
                ol.params.retry_max_attempts,
            )
        };
        if retry && attempt <= max_attempts {
            let jitter = self
                .overload
                .as_mut()
                .expect("checked above")
                .rng
                .below(base.max(1));
            let backoff = base
                .saturating_mul(1u64 << u64::from(attempt - 1).min(16))
                .saturating_add(jitter)
                .max(1);
            self.ol_stats.retries += 1;
            self.push_event(self.now + backoff, EventKey::RetryArrival(idx));
            log::debug!(
                "t={} overload: reject req {idx} (attempt {attempt}), retry in {backoff} ms",
                self.now
            );
            0
        } else {
            self.requests[idx].shed = true;
            log::debug!(
                "t={} overload: shed req {idx} after {attempt} rejection(s)",
                self.now
            );
            1
        }
    }

    /// Start an iteration on `inst` if it's idle and has work.
    pub fn maybe_start_iteration(&mut self, inst: usize, router: &mut dyn Router) {
        if self.cluster.instances[inst].iterating {
            return;
        }
        let budget = router.chunk_budget(self.now, inst, &mut self.ctx());
        let now = self.now;
        // Disjoint field borrows: the instance is mutated while the
        // cost model is only read — no clone needed on this hot path.
        // Ground truth is the cost model of the model *this instance*
        // has loaded (entry 0 for every single-model run).
        let cm = &self.cost_models[self.cluster.instances[inst].model];
        let iter = self.cluster.instances[inst].form_batch(
            now,
            &mut self.requests,
            budget,
            cm,
        );
        // Handoff admits inside form_batch are key-neutral (in-flight
        // KV becomes resident, batch and residency unchanged) — the
        // re-key hook's compare-and-skip makes reporting them free, and
        // keeps this site honest if that ever changes.
        self.cluster.refresh_load(inst);
        let Some(iter_ms) = iter else {
            // Idle with KV handoffs still in flight: wake exactly when
            // the earliest transfer lands, instead of waiting for the
            // next housekeeping tick to notice.
            if let Some(ready) = self.cluster.instances[inst].next_handoff_ready_ms(now) {
                self.push_event(ready, EventKey::Wake(inst));
            }
            return;
        };
        let i = &mut self.cluster.instances[inst];
        i.iterating = true;
        i.busy_until = now + iter_ms;
        i.busy_ms_total += iter_ms;
        self.push_event(now + iter_ms, EventKey::IterEnd(inst));
    }

    /// Process an iteration completion; returns #requests finished.
    fn handle_iter_end(&mut self, inst: usize, router: &mut dyn Router) -> usize {
        let now = self.now;
        let (completed_prefills, finished) = {
            let i = &mut self.cluster.instances[inst];
            i.complete_iteration(now, &mut self.requests)
        };
        // Token emission / prefill progress / completions all moved the
        // load key: re-key before the router sees the fleet again.
        self.cluster.refresh_load(inst);
        // Everything resident here shares the instance's model (the
        // hard placement constraint), so the whole batch of finishes
        // books against it.
        let model = self.cluster.instances[inst].model;
        self.cluster.note_finished(model, finished);
        // Completed prefills → decode placement.
        for req_idx in completed_prefills {
            match self.params.mode {
                ServingMode::Colocated => { /* stays on the same instance */ }
                ServingMode::PdDisaggregated => {
                    if self.requests[req_idx].decode_remaining() == 0 {
                        continue; // output fully emitted at prefill
                    }
                    self.place_decode_handoff(req_idx, router);
                }
            }
        }
        // A migrating drainer never decodes: requests that became
        // decode-resident after the eviction sweep (a coloc prefill
        // completing mid-drain) are evicted the same way.
        if self.cluster.instances[inst].migrate_on_drain
            && self.cluster.instances[inst].decode_batch_now() > 0
        {
            self.migrate_residents(inst, router);
        }
        router.on_iter_end(now, inst, &mut self.ctx());
        self.maybe_start_iteration(inst, router);
        self.restart_fed_instances(router);
        // A draining instance whose last resident just finished leaves
        // the fleet (or completes its model swap) here.
        self.finish_drain(inst);
        finished
    }

    /// Route a decode-phase request (a completed PD prefill, or a
    /// request migrated off a drainer) and enqueue the KV handoff. Both
    /// callers pay the same `kv_transfer_ms` before the destination may
    /// schedule it; `None` from the router means it pended the request
    /// and will dispatch it later through the same-delay `enqueue_on`
    /// path.
    fn place_decode_handoff(&mut self, req_idx: usize, router: &mut dyn Router) {
        let now = self.now;
        let target = router.route_decode(now, req_idx, &mut self.ctx());
        if let Some(d) = target {
            let ready = now + self.params.kv_transfer_ms;
            self.requests[req_idx].decode_instance = Some(d);
            self.cluster.instances[d].push_decode(req_idx, ready, &self.requests);
            self.cluster.refresh_load(d);
            // If the destination stays idle until `ready`,
            // maybe_start_iteration schedules the wake at exactly that
            // time via `next_handoff_ready_ms`.
            self.maybe_start_iteration(d, router);
        }
    }

    /// Re-route a prefill-phase request migrated off a draining prefill
    /// server, through the router's ordinary arrival placement
    /// (`route_new` — PD routers place prefills synchronously; `None`
    /// means the router pended it and dispatches it itself). The job
    /// keeps its original TTFT deadline.
    fn place_prefill_handoff(&mut self, req_idx: usize, router: &mut dyn Router) {
        let chosen = router.route_new(self.now, req_idx, &mut self.ctx());
        if let Some(inst) = chosen {
            let deadline = self.requests[req_idx].ttft_deadline();
            self.cluster.instances[inst]
                .push_prefill(PrefillJob { req_idx, deadline }, &self.requests);
            self.cluster.refresh_load(inst);
            self.maybe_start_iteration(inst, router);
        }
    }

    /// Restart any instance the router fed while holding the ctx.
    fn restart_fed_instances(&mut self, router: &mut dyn Router) {
        loop {
            let kicked = self.cluster.take_kicked();
            if kicked.is_empty() {
                break;
            }
            for k in kicked {
                self.maybe_start_iteration(k, router);
            }
        }
    }

    /// Per-second cluster state dump (trace level) for debugging
    /// scheduling dynamics.
    fn log_timeline(&self) {
        use std::fmt::Write as _;
        let mut line = format!("t={:>7}ms", self.now);
        for k in 0..self.cluster.num_tiers {
            let ids: Vec<usize> = self.cluster.in_tier(k).collect();
            let batch: u64 = ids
                .iter()
                .map(|&i| self.cluster.instances[i].decode_batch_now())
                .sum();
            let _ = write!(line, " | T{k}: {}inst b={batch}", ids.len());
        }
        let be = self.cluster.best_effort_pool().count();
        let pending_assign = self
            .cluster
            .assignments()
            .iter()
            .filter(|a| **a == TierAssign::Pending)
            .count();
        let pf_queue: u64 = self
            .cluster
            .instances
            .iter()
            .filter(|i| i.role == Role::Prefill)
            .map(|i| i.queued_prefill_tokens(&self.requests))
            .sum();
        let _ = write!(line, " | BE={be} Pend={pending_assign} pfq={pf_queue}");
        log::trace!("{line}");
    }

    fn finalize(mut self, completed: usize) -> SimResult {
        let mut outcomes = Vec::with_capacity(self.requests.len());
        // Billing span: finished requests set the floor, and the clock
        // (last simulated event) clamps it up — a `max_sim_ms`-aborted
        // run still bills the active-instance time it simulated instead
        // of reporting a zero-length run.
        let mut span: TimeMs = if completed < self.requests.len() {
            self.now
        } else {
            0
        };
        for r in &self.requests {
            let attained = r.is_finished() && r.tracker.attained();
            outcomes.push(RequestOutcome {
                id: r.req.id,
                model: r.req.model,
                slo: r.req.slo,
                arrival_ms: r.req.arrival_ms,
                first_token_ms: r.first_token_ms,
                finish_ms: r.finish_ms,
                tokens: r.tracker.tokens_emitted(),
                attained,
                min_slack_ms: r.tracker.min_slack_ms(),
                rejected: r.shed,
            });
            if let Some(f) = r.finish_ms {
                span = span.max(f);
            }
        }
        let attainment = AttainmentReport::from_outcomes(&outcomes);
        let mut cost = CostAccount {
            requests_served: outcomes.iter().filter(|o| o.finish_ms.is_some()).count() as u64,
            active_instance_ms_per_model: vec![0; self.cluster.num_models],
            requests_served_per_model: vec![0; self.cluster.num_models],
            ..Default::default()
        };
        for o in &outcomes {
            if o.finish_ms.is_none() {
                continue; // partial tokens of unfinished requests don't bill
            }
            cost.requests_served_per_model[o.model] += 1;
            cost.tokens_total += o.tokens;
            if o.attained {
                cost.goodput_tokens += o.tokens;
            }
        }
        for i in &self.cluster.instances {
            cost.instance_busy_ms += i.busy_ms_total;
            // Statically-assigned instances (baselines, the PD prefill
            // cluster) are allocated for their whole lifetime (= the
            // whole run on a fixed fleet); tier-managed instances count
            // their tier-allocation intervals.
            cost.instance_alloc_ms += match self.cluster.assign_of(i.id) {
                TierAssign::Static => i.active_span_ms(span),
                _ => i.allocated_ms(span),
            };
            // Elastic-fleet billing: an instance costs money from the
            // moment it is provisioned until it retires, busy or not.
            // The per-model split bills an instance's whole existence
            // to the model it ended the run loaded with (hot swaps
            // reassign the bill; see `CostAccount`).
            cost.active_instance_ms += i.active_span_ms(span);
            cost.active_instance_ms_per_model[i.model] += i.active_span_ms(span);
            // The spot slice of the same bill, for discounted-cost
            // reporting. A failed instance's span ends at its failure
            // (`Retired { at }` caps `active_span_ms`) — dead devices
            // stop billing at the kill, not at span end.
            if i.spot {
                cost.spot_instance_ms += i.active_span_ms(span);
            }
        }
        // Spot price *curve* billing: only when the run declared a
        // stepwise schedule (`None` otherwise — the flat-discount
        // default path is untouched). The on-demand slice bills at full
        // rate; each spot instance's active span is integrated over the
        // stepwise price, with the flat `spot_price_frac` applying
        // ahead of the first step.
        if let Some(ch) = self.chaos.as_ref() {
            if !ch.params.spot_price_schedule.is_empty() {
                let sched = &ch.params.spot_price_schedule;
                let flat = ch.params.spot_price_frac;
                let mut bill = (cost.active_instance_ms - cost.spot_instance_ms) as f64;
                for i in &self.cluster.instances {
                    if !i.spot {
                        continue;
                    }
                    let start = i.born_ms;
                    let end = start + i.active_span_ms(span);
                    bill += integrate_spot_price(sched, flat, start, end);
                }
                cost.spot_curve_bill_ms = Some(bill.round() as u64);
            }
        }
        // Drain latencies: recorded at retirement; drains still open at
        // the end of the run are censored at the span (they cost at
        // least that long — keeps wait-drain tails honest).
        for i in &self.cluster.instances {
            match i.lifecycle {
                Lifecycle::Retired { .. } => {
                    if let Some(d) = i.drain_latency_ms {
                        self.migration.drain_latency_ms.push(d);
                    }
                }
                Lifecycle::Draining { since } => {
                    self.migration.drain_latency_ms.push(span.saturating_sub(since));
                }
                _ => {}
            }
        }
        let throughput_rps = if span > 0 {
            cost.requests_served as f64 / (span as f64 / 1000.0)
        } else {
            0.0
        };
        // Overload accounting: terminal sheds by tier (keyed by the
        // request's own TPOT) and model, the would-have-been decode
        // demand, and the retry fate of every gated request. All-zero
        // (and `is_quiet`) without a runtime — the aging fields were
        // copied from the router before finalization either way.
        let mut ol = std::mem::take(&mut self.ol_stats);
        ol.rejected_per_model = vec![0; self.cluster.num_models];
        for r in &self.requests {
            if !r.shed {
                continue;
            }
            ol.rejected_total += 1;
            ol.rejected_per_model[r.req.model] += 1;
            ol.shed_tokens += r.req.decode_len as u64;
            let key = r.req.slo.tpot_ms;
            match ol.rejected_per_tier.binary_search_by_key(&key, |&(t, _)| t) {
                Ok(i) => ol.rejected_per_tier[i].1 += 1,
                Err(i) => ol.rejected_per_tier.insert(i, (key, 1)),
            }
        }
        if let Some(rt) = &self.overload {
            for (i, &a) in rt.attempts.iter().enumerate() {
                if a == 0 {
                    continue;
                }
                if self.requests[i].shed {
                    // Shed with >1 rejection ⇒ its retries ran out
                    // (a == 1 is a plain no-retry shed).
                    if a > 1 {
                        ol.retry_exhausted += 1;
                    }
                } else {
                    // Admitted after `a` rejections ⇒ on retry `a`.
                    let k = (a - 1) as usize;
                    if ol.retry_histogram.len() <= k {
                        ol.retry_histogram.resize(k + 1, 0);
                    }
                    ol.retry_histogram[k] += 1;
                }
            }
        }
        ol.served_tokens = cost.goodput_tokens;
        SimResult {
            unfinished: outcomes.len() - completed.min(outcomes.len()),
            outcomes,
            attainment,
            cost,
            fleet: self.fleet,
            migration: self.migration,
            sim_span_ms: span,
            throughput_rps,
            events_processed: self.events_processed,
            chaos: self.chaos.map(|c| c.stats).unwrap_or_default(),
            overload: ol,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn domain_stride_is_zone_first() {
        // zones=2, racks=2 over 8 ids: zone alternates, rack doubles.
        let d: Vec<(u32, u32)> = (0..8).map(|id| domain_of(id, 2, 2)).collect();
        assert_eq!(
            d,
            vec![(0, 0), (1, 0), (0, 1), (1, 1), (0, 0), (1, 0), (0, 1), (1, 1)]
        );
        // Degenerate inputs clamp to a single (0, 0) domain.
        assert_eq!(domain_of(7, 0, 0), (0, 0));
        // zones=1: the rack stripe is plain id % racks.
        assert_eq!(domain_of(2, 1, 2), (0, 0));
        assert_eq!(domain_of(3, 1, 2), (0, 1));
    }

    #[test]
    fn schedule_value_steps_hold_until_the_next_edge() {
        let sched = [(1_000, 0.3), (5_000, 0.9)];
        assert_eq!(schedule_value_at(&sched, 0, 0.5), 0.5);
        assert_eq!(schedule_value_at(&sched, 999, 0.5), 0.5);
        assert_eq!(schedule_value_at(&sched, 1_000, 0.5), 0.3);
        assert_eq!(schedule_value_at(&sched, 4_999, 0.5), 0.3);
        assert_eq!(schedule_value_at(&sched, 5_000, 0.5), 0.9);
        assert_eq!(schedule_value_at(&sched, u64::MAX, 0.5), 0.9);
        assert_eq!(schedule_value_at(&[], 123, 0.5), 0.5);
    }

    #[test]
    fn spot_price_integral_matches_piecewise_sum() {
        let sched = [(1_000, 0.2), (3_000, 1.0)];
        // [0, 4000): 1000 ms flat 0.5 + 2000 ms at 0.2 + 1000 ms at 1.0.
        let got = integrate_spot_price(&sched, 0.5, 0, 4_000);
        assert!((got - (500.0 + 400.0 + 1_000.0)).abs() < 1e-9, "{got}");
        // A window entirely past the last step bills at the last price.
        let tail = integrate_spot_price(&sched, 0.5, 10_000, 12_000);
        assert!((tail - 2_000.0).abs() < 1e-9, "{tail}");
        // Empty window bills nothing.
        assert_eq!(integrate_spot_price(&sched, 0.5, 4_000, 4_000), 0.0);
        // The flat-price satellite guarantee: a single step at t=0 with
        // the flat price is bit-for-bit the flat bill.
        let frac = 0.4;
        let single = integrate_spot_price(&[(0, frac)], frac, 2_345, 9_876);
        let flat = integrate_spot_price(&[], frac, 2_345, 9_876);
        assert_eq!(single.to_bits(), flat.to_bits());
    }
}
