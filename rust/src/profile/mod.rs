//! Profiling table: (batch size, KV size) → iteration time.
//!
//! §4.5: "Through profiling, PolyServe builds a map of (batch size, KV
//! cache size) to execution time." The router consumes *only* this table
//! (never the analytic closed form directly), mirroring the paper's
//! architecture. Tables come from two sources:
//!
//! * [`ProfileTable::from_cost_model`] — sampled from the H200-calibrated
//!   analytic model for simulation (the paper's vLLM profiling data
//!   stand-in);
//! * `polyserve profile --real` (see `runtime::profiler`) — measured from
//!   the actual AOT-compiled PJRT executables, for the live server.
//!
//! Lookup is bilinear interpolation over the grid with clamping at the
//! edges; the grid is dense enough (configurable) that interpolation
//! error is ≪ the 1 ms simulator resolution.

use crate::model::CostModel;
use crate::util::json::Json;
use std::path::Path;

/// A (batch, kv) → iteration-time-ms grid.
#[derive(Debug, Clone)]
pub struct ProfileTable {
    /// Strictly increasing batch-size grid points.
    batch_grid: Vec<u64>,
    /// Strictly increasing KV-token grid points.
    kv_grid: Vec<u64>,
    /// Row-major `[batch][kv]` iteration times, ms.
    times_ms: Vec<f64>,
    /// KV capacity (tokens) of the profiled instance.
    pub kv_capacity_tokens: u64,
    /// Max schedulable token batch of the profiled instance.
    pub max_token_batch: u64,
}

impl ProfileTable {
    /// Build by sampling a cost model on a log-ish grid.
    pub fn from_cost_model(cm: &CostModel) -> ProfileTable {
        let batch_grid = default_batch_grid(cm.max_token_batch);
        let kv_grid = default_kv_grid(cm.kv_capacity_tokens);
        let mut times_ms = Vec::with_capacity(batch_grid.len() * kv_grid.len());
        for &b in &batch_grid {
            for &kv in &kv_grid {
                times_ms.push(cm.iter_ms(b, kv));
            }
        }
        ProfileTable {
            batch_grid,
            kv_grid,
            times_ms,
            kv_capacity_tokens: cm.kv_capacity_tokens,
            max_token_batch: cm.max_token_batch,
        }
    }

    /// Build from explicit measurements (used by the real-PJRT profiler).
    /// `samples[(bi, ki)]` must cover the full grid, row-major.
    pub fn from_measurements(
        batch_grid: Vec<u64>,
        kv_grid: Vec<u64>,
        times_ms: Vec<f64>,
        kv_capacity_tokens: u64,
        max_token_batch: u64,
    ) -> ProfileTable {
        assert_eq!(times_ms.len(), batch_grid.len() * kv_grid.len());
        assert!(batch_grid.windows(2).all(|w| w[0] < w[1]));
        assert!(kv_grid.windows(2).all(|w| w[0] < w[1]));
        ProfileTable {
            batch_grid,
            kv_grid,
            times_ms,
            kv_capacity_tokens,
            max_token_batch,
        }
    }

    #[inline]
    fn at(&self, bi: usize, ki: usize) -> f64 {
        self.times_ms[bi * self.kv_grid.len() + ki]
    }

    /// Predicted iteration time (ms) for token batch `b` and `kv` resident
    /// KV tokens. Bilinear interpolation, clamped at grid edges.
    pub fn iter_ms(&self, b: u64, kv: u64) -> f64 {
        if b == 0 {
            return 0.0;
        }
        let (bi, bt) = bracket(&self.batch_grid, b);
        let (ki, kt) = bracket(&self.kv_grid, kv);
        let b0 = self.at(bi, ki) * (1.0 - kt) + self.at(bi, ki + 1) * kt;
        let b1 = self.at(bi + 1, ki) * (1.0 - kt) + self.at(bi + 1, ki + 1) * kt;
        b0 * (1.0 - bt) + b1 * bt
    }

    /// Iteration time rounded up to whole ms (simulator resolution).
    pub fn iter_ms_quantized(&self, b: u64, kv: u64) -> u64 {
        self.iter_ms(b, kv).ceil() as u64
    }

    /// Largest batch whose predicted time stays under `budget_ms` at the
    /// given per-request KV footprint. Binary search over the predictor.
    pub fn max_batch_under(&self, budget_ms: f64, kv_per_req: u64) -> u64 {
        let mut lo = 0u64;
        let mut hi = self.max_token_batch;
        if kv_per_req > 0 {
            hi = hi.min(self.kv_capacity_tokens / kv_per_req);
        }
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if self.iter_ms(mid, mid * kv_per_req) < budget_ms {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }

    // ---- persistence ----

    /// Serialize the table to its JSON representation.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "batch_grid",
            Json::from_f64s(&self.batch_grid.iter().map(|&x| x as f64).collect::<Vec<_>>()),
        )
        .set(
            "kv_grid",
            Json::from_f64s(&self.kv_grid.iter().map(|&x| x as f64).collect::<Vec<_>>()),
        )
        .set("times_ms", Json::from_f64s(&self.times_ms))
        .set("kv_capacity_tokens", Json::Num(self.kv_capacity_tokens as f64))
        .set("max_token_batch", Json::Num(self.max_token_batch as f64));
        o
    }

    /// Parse a table from the JSON representation.
    pub fn from_json(j: &Json) -> anyhow::Result<ProfileTable> {
        let get_u64s = |key: &str| -> anyhow::Result<Vec<u64>> {
            Ok(j.get(key)
                .and_then(Json::to_f64s)
                .ok_or_else(|| anyhow::anyhow!("profile table missing {key}"))?
                .into_iter()
                .map(|x| x as u64)
                .collect())
        };
        let batch_grid = get_u64s("batch_grid")?;
        let kv_grid = get_u64s("kv_grid")?;
        let times_ms = j
            .get("times_ms")
            .and_then(Json::to_f64s)
            .ok_or_else(|| anyhow::anyhow!("profile table missing times_ms"))?;
        anyhow::ensure!(
            times_ms.len() == batch_grid.len() * kv_grid.len(),
            "profile table shape mismatch"
        );
        Ok(ProfileTable {
            batch_grid,
            kv_grid,
            times_ms,
            kv_capacity_tokens: j
                .get("kv_capacity_tokens")
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as u64,
            max_token_batch: j.get("max_token_batch").and_then(Json::as_f64).unwrap_or(0.0)
                as u64,
        })
    }

    /// Write the table as pretty JSON to `path`.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }

    /// Read a table previously written by [`ProfileTable::save`].
    pub fn load(path: &Path) -> anyhow::Result<ProfileTable> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        ProfileTable::from_json(&j)
    }
}

/// Index `i` and fraction `t` such that `grid[i] + t·(grid[i+1]-grid[i])`
/// brackets `x`, clamped to the grid.
fn bracket(grid: &[u64], x: u64) -> (usize, f64) {
    debug_assert!(grid.len() >= 2);
    if x <= grid[0] {
        return (0, 0.0);
    }
    if x >= grid[grid.len() - 1] {
        return (grid.len() - 2, 1.0);
    }
    // binary search for upper bound
    let mut lo = 0usize;
    let mut hi = grid.len() - 1;
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if grid[mid] <= x {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let t = (x - grid[lo]) as f64 / (grid[hi] - grid[lo]) as f64;
    (lo, t)
}

/// Batch grid: 1,2,4,...,knee region densified, up to max batch.
fn default_batch_grid(max_batch: u64) -> Vec<u64> {
    let mut g = vec![1u64, 2, 4, 8, 16, 24, 32, 48, 64, 80, 96, 128, 160, 192, 256, 384, 512, 768, 1024, 1536, 2048];
    g.retain(|&b| b <= max_batch);
    if *g.last().unwrap() != max_batch {
        g.push(max_batch);
    }
    g
}

/// KV grid: 0 to capacity, log-spaced with a dense low end.
fn default_kv_grid(capacity: u64) -> Vec<u64> {
    let mut g = vec![0u64, 1_000, 5_000, 10_000, 25_000, 50_000, 100_000, 150_000, 250_000, 400_000, 600_000, 900_000, 1_200_000];
    g.retain(|&kv| kv <= capacity);
    if *g.last().unwrap() != capacity {
        g.push(capacity);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;

    fn table() -> ProfileTable {
        ProfileTable::from_cost_model(&CostModel::h200_llama8b())
    }

    #[test]
    fn interpolation_matches_model_on_grid() {
        let cm = CostModel::h200_llama8b();
        let t = table();
        for &b in &[1u64, 16, 64, 256, 2048] {
            for &kv in &[0u64, 10_000, 150_000, 900_000] {
                let want = cm.iter_ms(b, kv);
                let got = t.iter_ms(b, kv);
                assert!(
                    (got - want).abs() < 1e-9,
                    "grid point b={b} kv={kv}: got {got} want {want}"
                );
            }
        }
    }

    #[test]
    fn interpolation_error_off_grid_small() {
        let cm = CostModel::h200_llama8b();
        let t = table();
        // worst case near the GEMM knee; must stay well under 1 ms.
        for b in [3u64, 50, 77, 100, 300, 1000] {
            for kv in [500u64, 42_000, 333_333, 777_777] {
                let want = cm.iter_ms(b, kv);
                let got = t.iter_ms(b, kv);
                assert!(
                    (got - want).abs() < 0.8,
                    "b={b} kv={kv}: got {got:.3} want {want:.3}"
                );
            }
        }
    }

    #[test]
    fn clamps_at_edges() {
        let t = table();
        assert_eq!(t.iter_ms(0, 0), 0.0);
        let over = t.iter_ms(1_000_000, 10_000_000);
        let edge = t.iter_ms(t.max_token_batch, t.kv_capacity_tokens);
        assert!((over - edge).abs() < 1e-9);
    }

    #[test]
    fn max_batch_under_matches_cost_model() {
        let cm = CostModel::h200_llama8b();
        let t = table();
        for tpot in [20.0, 30.0, 50.0, 100.0] {
            let want = cm.max_decode_batch(tpot, 3000);
            let got = t.max_batch_under(tpot, 3000);
            let diff = (want as i64 - got as i64).abs();
            assert!(diff <= 3, "tpot={tpot}: table {got} vs model {want}");
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = table();
        let j = t.to_json();
        let t2 = ProfileTable::from_json(&Json::parse(&j.pretty()).unwrap()).unwrap();
        assert_eq!(t2.kv_capacity_tokens, t.kv_capacity_tokens);
        for &b in &[1u64, 100, 2048] {
            for &kv in &[0u64, 123_456] {
                assert!((t.iter_ms(b, kv) - t2.iter_ms(b, kv)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let t = table();
        let dir = std::env::temp_dir().join("polyserve_test_profile");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("table.json");
        t.save(&path).unwrap();
        let t2 = ProfileTable::load(&path).unwrap();
        assert!((t.iter_ms(333, 44_444) - t2.iter_ms(333, 44_444)).abs() < 1e-9);
    }

    #[test]
    fn bracket_basics() {
        let g = vec![0u64, 10, 100];
        assert_eq!(bracket(&g, 0), (0, 0.0));
        let (i, t) = bracket(&g, 5);
        assert_eq!(i, 0);
        assert!((t - 0.5).abs() < 1e-9);
        let (i, t) = bracket(&g, 55);
        assert_eq!(i, 1);
        assert!((t - 0.5).abs() < 1e-9);
        assert_eq!(bracket(&g, 1000), (1, 1.0));
    }
}
