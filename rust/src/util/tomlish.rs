//! A TOML-subset parser for experiment/serve configs.
//!
//! Supports what `configs/*.toml` use: `[table]` and `[table.sub]`
//! headers, `key = value` with string / bool / integer / float / arrays
//! of scalars, `#` comments, and bare or quoted keys. Values land in a
//! flat `"table.sub.key" -> Value` map, which the typed config layer
//! (`crate::config`) consumes.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A string.
    Str(String),
    /// A boolean.
    Bool(bool),
    /// A number.
    Num(f64),
    /// An array.
    Arr(Vec<Value>),
}

impl Value {
    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }
    /// Numeric value as `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
    /// All elements as `f64`, if numeric.
    pub fn to_f64s(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(Value::as_f64).collect())
    }
    /// All elements as strings, if this is a string array.
    pub fn to_strs(&self) -> Option<Vec<String>> {
        self.as_arr().map(|v| {
            v.iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect()
        })
    }
}

/// Parsed document: flat dotted-key map.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    map: BTreeMap<String, Value>,
}

impl Doc {
    /// Dotted-key lookup (`section.key`).
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// String at `key`, or `default`.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    /// Number at `key`, or `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    /// Number at `key` as `usize`, or `default`.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    /// Bool at `key`, or `default`.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// All keys in the document, dotted.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }

    /// All keys under a table prefix (e.g. `"slo."`).
    pub fn under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = (&'a str, &'a Value)> {
        self.map
            .iter()
            .filter(move |(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.as_str(), v))
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    /// 1-based line of the error.
    pub line: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc, TomlError> {
    let mut doc = Doc::default();
    let mut table = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError {
            line: lineno + 1,
            msg: msg.to_string(),
        };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated table header"))?;
            let name = name.trim();
            if name.is_empty() {
                return Err(err("empty table name"));
            }
            table = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
        let key = line[..eq].trim().trim_matches('"');
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
        let full = if table.is_empty() {
            key.to_string()
        } else {
            format!("{table}.{key}")
        };
        doc.map.insert(full, val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(unescape(inner)?));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(Vec::new()));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Value::Arr(items));
    }
    // number: allow underscores as separators
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value: {s:?}"))
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape: \\{other:?}")),
        }
    }
    Ok(out)
}

/// Split on commas not inside quotes or nested brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_document() {
        let doc = parse(
            r#"
# experiment config
name = "fig6"
requests = 30_000

[cluster]
instances = 20
mode = "pd"     # pd | coloc

[slo]
tpot_ms = [20, 30, 50, 100]
tpot_weights = [0.1, 0.2, 0.3, 0.4]
strict = true
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "fig6");
        assert_eq!(doc.usize_or("requests", 0), 30_000);
        assert_eq!(doc.usize_or("cluster.instances", 0), 20);
        assert_eq!(doc.str_or("cluster.mode", ""), "pd");
        assert_eq!(
            doc.get("slo.tpot_ms").unwrap().to_f64s().unwrap(),
            vec![20.0, 30.0, 50.0, 100.0]
        );
        assert!(doc.bool_or("slo.strict", false));
    }

    #[test]
    fn comment_inside_string_preserved() {
        let doc = parse(r##"s = "a # b" # real comment"##).unwrap();
        assert_eq!(doc.str_or("s", ""), "a # b");
    }

    #[test]
    fn nested_arrays() {
        let doc = parse("a = [[1, 2], [3, 4]]").unwrap();
        let arr = doc.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].to_f64s().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("[unclosed").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn string_escapes() {
        let doc = parse(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(doc.str_or("s", ""), "a\nb\t\"c\"");
    }

    #[test]
    fn under_prefix_iteration() {
        let doc = parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        let keys: Vec<&str> = doc.under("a.").map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["a.x", "a.y"]);
    }
}
