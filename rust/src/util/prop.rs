//! Mini property-based testing framework (proptest substitute).
//!
//! Provides seeded generators and a `check` runner with iterative
//! shrinking: on failure, the runner repeatedly asks the generator for
//! "smaller" variants of the failing case (via [`Gen::shrink`]) and
//! reports the smallest reproduction plus the seed to replay it.
//!
//! Used by `rust/tests/prop_coordinator.rs` to check router/batcher
//! invariants over random request populations.

use crate::util::rng::Rng;

/// A generator of values of type `T` with optional shrinking.
pub trait Gen {
    /// The type of generated values.
    type Value: Clone + std::fmt::Debug;

    /// Draw one value.
    fn generate(&self, rng: &mut Rng) -> Self::Value;

    /// Candidate "smaller" values; default: no shrinking.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform integer range (inclusive), shrinking toward `lo`.
pub struct IntRange {
    /// Inclusive lower bound.
    pub lo: u64,
    /// Inclusive upper bound.
    pub hi: u64,
}

impl Gen for IntRange {
    type Value = u64;

    fn generate(&self, rng: &mut Rng) -> u64 {
        rng.range_u64(self.lo, self.hi)
    }

    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform float range, shrinking toward `lo`.
pub struct FloatRange {
    /// Inclusive lower bound.
    pub lo: f64,
    /// Exclusive upper bound.
    pub hi: f64,
}

impl Gen for FloatRange {
    type Value = f64;

    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.range_f64(self.lo, self.hi)
    }

    fn shrink(&self, v: &f64) -> Vec<f64> {
        if *v > self.lo {
            vec![self.lo, self.lo + (*v - self.lo) / 2.0]
        } else {
            Vec::new()
        }
    }
}

/// Vector of values from an element generator, with length in
/// `[min_len, max_len]`. Shrinks by halving length, dropping single
/// elements, and shrinking individual elements.
pub struct VecOf<G: Gen> {
    /// Element generator.
    pub elem: G,
    /// Minimum length.
    pub min_len: usize,
    /// Maximum length.
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;

    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = rng.range_u64(self.min_len as u64, self.max_len as u64) as usize;
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }

    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // halve
            let half = v[..(v.len() / 2).max(self.min_len)].to_vec();
            out.push(half);
            // drop last
            out.push(v[..v.len() - 1].to_vec());
            // drop first
            out.push(v[1..].to_vec());
        }
        // shrink one element (first shrinkable, to bound the search)
        for (i, e) in v.iter().enumerate() {
            let shrunk = self.elem.shrink(e);
            if let Some(s) = shrunk.into_iter().next() {
                let mut copy = v.clone();
                copy[i] = s;
                out.push(copy);
                break;
            }
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairOf<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Map a generator through a function (no shrinking through the map).
pub struct MapGen<G: Gen, T, F: Fn(G::Value) -> T> {
    /// Inner generator.
    pub inner: G,
    /// Mapping function.
    pub f: F,
    /// Carries the output type.
    pub _marker: std::marker::PhantomData<T>,
}

impl<G: Gen, T: Clone + std::fmt::Debug, F: Fn(G::Value) -> T> Gen for MapGen<G, T, F> {
    type Value = T;

    fn generate(&self, rng: &mut Rng) -> T {
        (self.f)(self.inner.generate(rng))
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum CheckResult<V> {
    /// Every case passed.
    Pass { cases: usize },
    /// A case failed (shrunk as far as possible).
    Fail {
        seed: u64,
        case: V,
        shrunk_steps: usize,
        message: String,
    },
}

/// Configuration for the runner.
pub struct Config {
    /// Cases to run.
    pub cases: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Shrink-iteration cap.
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Config {
        let seed = std::env::var("POLYSERVE_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        let cases = std::env::var("POLYSERVE_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(256);
        Config {
            cases,
            seed,
            max_shrink_steps: 512,
        }
    }
}

/// Run `prop` on `cases` generated values; on failure shrink and return
/// the smallest failing case found.
pub fn check_with<G, P>(cfg: &Config, gen: &G, prop: P) -> CheckResult<G::Value>
where
    G: Gen,
    P: Fn(&G::Value) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let value = gen.generate(&mut case_rng);
        if let Err(msg) = prop(&value) {
            // shrink
            let mut best = value;
            let mut best_msg = msg;
            let mut steps = 0usize;
            'outer: while steps < cfg.max_shrink_steps {
                let candidates = gen.shrink(&best);
                if candidates.is_empty() {
                    break;
                }
                for cand in candidates {
                    steps += 1;
                    if steps >= cfg.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer; // restart from new best
                    }
                }
                break; // no candidate fails: minimal
            }
            let _ = case_idx;
            return CheckResult::Fail {
                seed: case_seed,
                case: best,
                shrunk_steps: steps,
                message: best_msg,
            };
        }
    }
    CheckResult::Pass { cases: cfg.cases }
}

/// Assert-style wrapper: panics with a replay seed on failure.
pub fn check<G, P>(name: &str, gen: &G, prop: P)
where
    G: Gen,
    P: Fn(&G::Value) -> Result<(), String>,
{
    let cfg = Config::default();
    match check_with(&cfg, gen, prop) {
        CheckResult::Pass { .. } => {}
        CheckResult::Fail {
            seed,
            case,
            shrunk_steps,
            message,
        } => {
            panic!(
                "property '{name}' failed after {shrunk_steps} shrink steps\n\
                 seed: {seed} (set POLYSERVE_PROP_SEED to replay)\n\
                 case: {case:?}\n\
                 error: {message}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let gen = IntRange { lo: 0, hi: 1000 };
        let r = check_with(&Config::default(), &gen, |&x| {
            if x <= 1000 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
        assert!(matches!(r, CheckResult::Pass { .. }));
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let gen = IntRange { lo: 0, hi: 100_000 };
        // Fails for x >= 37; shrinking should land on or near 37.
        let r = check_with(&Config::default(), &gen, |&x| {
            if x < 37 {
                Ok(())
            } else {
                Err(format!("{x} >= 37"))
            }
        });
        match r {
            CheckResult::Fail { case, .. } => {
                assert!(case >= 37 && case <= 74, "shrunk case = {case}");
            }
            _ => panic!("expected failure"),
        }
    }

    #[test]
    fn vec_generator_respects_bounds() {
        let gen = VecOf {
            elem: IntRange { lo: 1, hi: 9 },
            min_len: 2,
            max_len: 20,
        };
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let v = gen.generate(&mut rng);
            assert!((2..=20).contains(&v.len()));
            assert!(v.iter().all(|&x| (1..=9).contains(&x)));
        }
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        let gen = VecOf {
            elem: IntRange { lo: 0, hi: 100 },
            min_len: 0,
            max_len: 50,
        };
        // Property: no vector contains an element > 10. Shrinker should
        // find a small counterexample.
        let r = check_with(&Config::default(), &gen, |v| {
            if v.iter().all(|&x| x <= 10) {
                Ok(())
            } else {
                Err("element > 10".into())
            }
        });
        match r {
            CheckResult::Fail { case, .. } => {
                assert!(case.len() <= 8, "shrunk to len {}", case.len());
            }
            _ => panic!("expected failure"),
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = IntRange { lo: 0, hi: 1 << 40 };
        let cfg = Config {
            cases: 16,
            seed: 1234,
            max_shrink_steps: 16,
        };
        let f = |r: CheckResult<u64>| match r {
            CheckResult::Fail { case, .. } => case,
            _ => panic!(),
        };
        let a = f(check_with(&cfg, &gen, |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("big".into())
            }
        }));
        let b = f(check_with(&cfg, &gen, |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("big".into())
            }
        }));
        assert_eq!(a, b);
    }
}
