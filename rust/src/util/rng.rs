//! Deterministic PRNG and sampling distributions.
//!
//! `rand`/`rand_distr` are unavailable offline, so this module provides a
//! small, fast, reproducible generator (xoshiro256++) plus every
//! distribution the workload layer needs. All simulation results in
//! EXPERIMENTS.md are reproducible from the seeds recorded there.

/// xoshiro256++ — fast, high-quality, 256-bit state.
///
/// Seeding runs the seed through SplitMix64 per the reference
/// implementation so that even small seeds (0, 1, 2, ...) produce
/// well-mixed state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-thread / per-trace use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range_u64: lo > hi");
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean `1/lambda`). Inter-arrival
    /// times of a Poisson process.
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        // 1 - f64() is in (0, 1], so ln is finite.
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Standard normal via Box–Muller (polar form avoided for simplicity;
    /// tails beyond ~8 sigma don't matter for workload synthesis).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Lognormal: exp(Normal(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Poisson-distributed count with mean `lambda`.
    ///
    /// Knuth's method for small lambda; normal approximation above 64
    /// (we only use counts for sanity checks, not arrival synthesis —
    /// arrivals use [`Rng::exp`] inter-arrival gaps).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda > 64.0 {
            let x = self.normal(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0f64;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Sample an index from unnormalised weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Sampler over a monotone piecewise-linear inverse CDF given as
/// (percentile, value) knots — used to match the paper's Table 1 trace
/// statistics exactly at every published percentile.
#[derive(Debug, Clone)]
pub struct PiecewiseInverseCdf {
    /// (quantile in [0,1], value) knots, strictly increasing in both.
    knots: Vec<(f64, f64)>,
}

impl PiecewiseInverseCdf {
    /// Build from `(quantile, value)` knots. Adds implicit endpoints at
    /// q=0 (value scaled 60% of first knot, floor 1) and q=1 (extends the
    /// last segment's slope) when not supplied.
    pub fn new(mut knots: Vec<(f64, f64)>) -> Self {
        assert!(!knots.is_empty());
        knots.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in knots.windows(2) {
            assert!(w[0].0 < w[1].0, "duplicate quantile knot");
            assert!(w[0].1 <= w[1].1, "inverse CDF must be monotone");
        }
        if knots[0].0 > 0.0 {
            let v0 = (knots[0].1 * 0.6).max(1.0);
            knots.insert(0, (0.0, v0.min(knots[0].1)));
        }
        let last = *knots.last().unwrap();
        if last.0 < 1.0 {
            // Extend with the slope of the final segment, capped at 1.4x.
            let prev = knots[knots.len() - 2];
            let slope = if last.0 > prev.0 {
                (last.1 - prev.1) / (last.0 - prev.0)
            } else {
                0.0
            };
            let v1 = (last.1 + slope * (1.0 - last.0)).min(last.1 * 1.4).max(last.1);
            knots.push((1.0, v1));
        }
        PiecewiseInverseCdf { knots }
    }

    /// Value at quantile `q` in [0,1].
    pub fn quantile(&self, q: f64) -> f64 {
        let q = q.clamp(0.0, 1.0);
        let k = &self.knots;
        let mut i = 0;
        while i + 1 < k.len() && k[i + 1].0 < q {
            i += 1;
        }
        let (q0, v0) = k[i];
        let (q1, v1) = k[(i + 1).min(k.len() - 1)];
        if q1 <= q0 {
            return v0;
        }
        v0 + (v1 - v0) * (q - q0) / (q1 - q0)
    }

    /// Draw a sample.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        self.quantile(rng.f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(11);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.range_u64(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean={mean}");
        assert!((var - 9.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(8);
        for &lam in &[0.5, 4.0, 100.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.poisson(lam)).sum::<u64>() as f64 / n as f64;
            assert!((mean - lam).abs() < lam.max(1.0) * 0.05, "lam={lam} mean={mean}");
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(9);
        let w = [1.0, 2.0, 3.0, 4.0];
        let mut counts = [0u32; 4];
        for _ in 0..100_000 {
            counts[r.categorical(&w)] += 1;
        }
        for i in 0..4 {
            let frac = counts[i] as f64 / 100_000.0;
            let expect = w[i] / 10.0;
            assert!((frac - expect).abs() < 0.01, "i={i} frac={frac}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn piecewise_inverse_cdf_matches_knots() {
        let cdf = PiecewiseInverseCdf::new(vec![
            (0.25, 100.0),
            (0.50, 200.0),
            (0.75, 400.0),
            (0.90, 800.0),
            (0.99, 1600.0),
        ]);
        assert!((cdf.quantile(0.25) - 100.0).abs() < 1e-9);
        assert!((cdf.quantile(0.50) - 200.0).abs() < 1e-9);
        assert!((cdf.quantile(0.99) - 1600.0).abs() < 1e-9);
        // interpolation between knots
        let mid = cdf.quantile(0.375);
        assert!(mid > 100.0 && mid < 200.0);
    }

    #[test]
    fn piecewise_sampling_reproduces_percentiles() {
        let cdf = PiecewiseInverseCdf::new(vec![
            (0.25, 16.0),
            (0.50, 36.0),
            (0.75, 158.0),
            (0.90, 818.0),
            (0.95, 1613.0),
            (0.99, 3421.0),
        ]);
        let mut r = Rng::new(12);
        let mut xs: Vec<f64> = (0..200_000).map(|_| cdf.sample(&mut r)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p = |q: f64| xs[(q * (xs.len() - 1) as f64) as usize];
        assert!((p(0.50) - 36.0).abs() / 36.0 < 0.05, "p50={}", p(0.50));
        assert!((p(0.90) - 818.0).abs() / 818.0 < 0.05, "p90={}", p(0.90));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
