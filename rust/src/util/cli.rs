//! Declarative command-line parsing (clap substitute).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`
//! options with defaults, and auto-generated `--help` text. Only what
//! `rust/src/main.rs` needs.

use std::collections::BTreeMap;

/// One option specification.
#[derive(Debug, Clone)]
pub struct Opt {
    /// Option name (without `--`).
    pub name: &'static str,
    /// Help text.
    pub help: &'static str,
    /// Default value (`None` for flags).
    pub default: Option<&'static str>,
    /// Is this a boolean flag?
    pub is_flag: bool,
}

/// Parsed arguments for one subcommand.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional arguments after options.
    pub positional: Vec<String>,
}

impl Args {
    /// The raw value of option `name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }
    /// Option value, or `default` when absent.
    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
    /// Option value parsed as `f64`, or `default`.
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    /// Option value parsed as `usize`, or `default`.
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    /// Option value parsed as `u64`, or `default`.
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    /// Was the boolean flag passed?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// A subcommand with its options.
pub struct Command {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line description.
    pub about: &'static str,
    /// Declared options and flags.
    pub opts: Vec<Opt>,
}

impl Command {
    /// A new subcommand with a one-line description.
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    /// Declare a valued option with a default and help text.
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean flag with help text.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        for opt in &self.opts {
            if let Some(d) = opt.default {
                args.values.insert(opt.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline_val) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name} for '{}'", self.name))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{name} is a flag and takes no value"));
                    }
                    args.flags.push(name.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    args.values.insert(name.to_string(), val);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    fn help(&self) -> String {
        let mut s = format!("  {:<12} {}\n", self.name, self.about);
        for o in &self.opts {
            let tail = if o.is_flag {
                String::new()
            } else {
                format!(" (default: {})", o.default.unwrap_or("-"))
            };
            s.push_str(&format!("      --{:<20} {}{}\n", o.name, o.help, tail));
        }
        s
    }
}

/// The top-level application.
pub struct App {
    /// Binary name.
    pub name: &'static str,
    /// One-line description.
    pub about: &'static str,
    /// Registered subcommands.
    pub commands: Vec<Command>,
}

/// Result of parsing: which subcommand and its args.
pub enum Parsed {
    /// A subcommand invocation with parsed arguments.
    Run { command: String, args: Args },
    /// Help text to print.
    Help(String),
    /// A usage error to report.
    Error(String),
}

impl App {
    /// A new CLI application.
    pub fn new(name: &'static str, about: &'static str) -> App {
        App {
            name,
            about,
            commands: Vec::new(),
        }
    }

    /// Register a subcommand.
    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    /// Render the top-level usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE: {} <command> [options]\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&c.help());
        }
        s
    }

    /// Parse argv into a command invocation, help request, or error.
    pub fn parse(&self, argv: &[String]) -> Parsed {
        let Some(cmd_name) = argv.first() else {
            return Parsed::Help(self.usage());
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Parsed::Help(self.usage());
        }
        let Some(cmd) = self.commands.iter().find(|c| c.name == cmd_name) else {
            return Parsed::Error(format!(
                "unknown command '{cmd_name}'\n\n{}",
                self.usage()
            ));
        };
        if argv.iter().any(|a| a == "--help" || a == "-h") {
            return Parsed::Help(cmd.help());
        }
        match cmd.parse(&argv[1..]) {
            Ok(args) => Parsed::Run {
                command: cmd_name.clone(),
                args,
            },
            Err(e) => Parsed::Error(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("polyserve", "test").command(
            Command::new("simulate", "run a simulation")
                .opt("trace", "sharegpt", "trace name")
                .opt("rate", "1.0", "request rate")
                .opt("instances", "20", "server count")
                .flag("verbose", "chatty output"),
        )
    }

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_overrides() {
        let p = app().parse(&argv(&["simulate", "--rate", "2.5", "--verbose"]));
        match p {
            Parsed::Run { command, args } => {
                assert_eq!(command, "simulate");
                assert_eq!(args.str_or("trace", ""), "sharegpt");
                assert_eq!(args.f64_or("rate", 0.0), 2.5);
                assert_eq!(args.usize_or("instances", 0), 20);
                assert!(args.flag("verbose"));
            }
            _ => panic!("expected Run"),
        }
    }

    #[test]
    fn equals_syntax() {
        let p = app().parse(&argv(&["simulate", "--rate=3.0"]));
        match p {
            Parsed::Run { args, .. } => assert_eq!(args.f64_or("rate", 0.0), 3.0),
            _ => panic!(),
        }
    }

    #[test]
    fn unknown_command_and_option() {
        assert!(matches!(app().parse(&argv(&["bogus"])), Parsed::Error(_)));
        assert!(matches!(
            app().parse(&argv(&["simulate", "--bogus", "1"])),
            Parsed::Error(_)
        ));
    }

    #[test]
    fn help_paths() {
        assert!(matches!(app().parse(&argv(&[])), Parsed::Help(_)));
        assert!(matches!(app().parse(&argv(&["--help"])), Parsed::Help(_)));
        assert!(matches!(
            app().parse(&argv(&["simulate", "--help"])),
            Parsed::Help(_)
        ));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(matches!(
            app().parse(&argv(&["simulate", "--rate"])),
            Parsed::Error(_)
        ));
    }
}
