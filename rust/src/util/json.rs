//! Minimal JSON value model, writer and parser.
//!
//! serde is unavailable offline; this covers what the repo needs:
//! profile tables (`artifacts/profile_*.json`), the AOT artifact manifest
//! written by `python/compile/aot.py`, and machine-readable results under
//! `results/`. Full RFC 8259 input is accepted except for `\u` surrogate
//! pairs outside the BMP (not needed for our own files).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is
/// deterministic and diffs are stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// A boolean.
    Bool(bool),
    /// A number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty JSON object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics on non-objects (programming error).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element lookup.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value as `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `obj.path(&["a", "b"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// A numeric JSON array.
    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// All elements as `f64`, if this is a numeric array.
    pub fn to_f64s(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).collect())
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte position of the error.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            // fast path: run of plain bytes
            while let Some(c) = self.peek() {
                if c == b'"' || c == b'\\' || c < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            s.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| self.err("invalid utf-8"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("surrogate \\u escape"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => return Err(self.err("control character in string")),
                None => return Err(self.err("eof in string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.25", "1e3", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2, "src={src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.5e-2}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            v.path(&["a"]).unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x\ny")
        );
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn integers_printed_without_decimal() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(-0.5).dump(), "-0.5");
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"\\q\""] {
            assert!(Json::parse(src).is_err(), "src={src:?}");
        }
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éЖ""#).unwrap();
        assert_eq!(v.as_str(), Some("éЖ"));
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("name", Json::Str("polyserve".into()))
            .set("xs", Json::from_f64s(&[1.0, 2.0, 3.0]));
        let parsed = Json::parse(&o.pretty()).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str(), Some("polyserve"));
        assert_eq!(parsed.get("xs").unwrap().to_f64s(), Some(vec![1.0, 2.0, 3.0]));
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
    }
}
