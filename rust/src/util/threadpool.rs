//! A small fixed-size thread pool with a parallel-map helper.
//!
//! Used by the figure harnesses to sweep (policy × trace × rate) grids in
//! parallel — each cell is an independent deterministic simulation, so
//! results are bitwise-reproducible regardless of scheduling order.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `n` workers (min 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("polyserve-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
        }
    }

    /// Pool sized to available parallelism.
    pub fn default_size() -> ThreadPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ThreadPool::new(n)
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker pool hung up");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel map preserving input order. Each invocation of `f` gets the
/// item index, so callers can derive deterministic per-item RNG streams.
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(usize, T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let f = Arc::new(f);
    let pool = ThreadPool::new(threads);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    for (i, item) in items.into_iter().enumerate() {
        let tx = tx.clone();
        let f = Arc::clone(&f);
        pool.execute(move || {
            let r = f(i, item);
            // receiver alive until we've collected all results
            let _ = tx.send((i, r));
        });
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    out.into_iter().map(|r| r.expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop waits for completion.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..200).collect();
        let out = par_map(items, 8, |_, x| x * 2);
        assert_eq!(out, (0..200).map(|x| x * 2).collect::<Vec<u64>>());
    }

    #[test]
    fn par_map_single_thread_path() {
        let out = par_map(vec![1, 2, 3], 1, |i, x| i as i32 + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<u32> = par_map(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }
}
