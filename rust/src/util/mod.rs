//! Self-contained substrates.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! (rand, serde, criterion, proptest, tokio, clap) are unavailable. Each
//! submodule here is a small, tested, purpose-built replacement:
//!
//! * [`rng`] — deterministic PRNG + the distributions the workload
//!   generators need (uniform, exponential, Poisson, categorical,
//!   lognormal).
//! * [`stats`] — percentiles, moments, histograms.
//! * [`json`] — a JSON writer/parser for profile tables and results.
//! * [`tomlish`] — a TOML-subset parser for experiment configs.
//! * [`logging`] — a `log`-crate backend with env-controlled level.
//! * [`threadpool`] — a scoped thread pool for parallel simulation sweeps.
//! * [`prop`] — a mini property-based-testing framework (proptest
//!   substitute) with seeded generators and iterative shrinking.
//! * [`benchkit`] — a criterion-substitute micro-benchmark harness used
//!   by every `cargo bench` target.
//! * [`cli`] — a small declarative command-line parser (clap substitute).

pub mod rng;
pub mod stats;
pub mod json;
pub mod tomlish;
pub mod logging;
pub mod threadpool;
pub mod prop;
pub mod benchkit;
pub mod cli;
