//! Summary statistics: percentiles, moments, online accumulators and
//! fixed-width histograms. Used by the workload generators (Table 1),
//! the metrics layer (attainment/goodput curves) and benchkit.

/// Percentile of a sorted slice using linear interpolation between
/// closest ranks (the same convention as `numpy.percentile`).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    let q = q.clamp(0.0, 100.0) / 100.0;
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Percentile of an unsorted slice (copies + sorts).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// The percentile set the paper's Table 1 reports.
pub const TABLE1_PERCENTILES: [f64; 6] = [25.0, 50.0, 75.0, 90.0, 95.0, 99.0];

/// Summary of a sample: count, mean, std, min/max and Table-1 percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub count: usize,
    /// Sample mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// p25, p50, p75, p90, p95, p99
    pub percentiles: [f64; 6],
}

impl Summary {
    /// Summarize a sample (percentiles by nearest rank on a sorted copy).
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty sample");
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len() as f64;
        let mean = v.iter().sum::<f64>() / n;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        let mut percentiles = [0.0; 6];
        for (i, q) in TABLE1_PERCENTILES.iter().enumerate() {
            percentiles[i] = percentile_sorted(&v, *q);
        }
        Summary {
            count: v.len(),
            mean,
            std: var.sqrt(),
            min: v[0],
            max: *v.last().unwrap(),
            percentiles,
        }
    }

    /// Median.
    pub fn p50(&self) -> f64 {
        self.percentiles[1]
    }
    /// 99th percentile.
    pub fn p99(&self) -> f64 {
        self.percentiles[5]
    }
}

/// Online mean/variance accumulator (Welford). O(1) memory — used in the
/// simulator where samples number in the millions.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    /// An empty accumulator.
    pub fn new() -> Online {
        Online {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold in one observation (Welford update).
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observations so far.
    pub fn count(&self) -> u64 {
        self.n
    }
    /// Sample mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Sample variance (0 with fewer than two observations).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    /// Smallest observation seen.
    pub fn min(&self) -> f64 {
        self.min
    }
    /// Largest observation seen.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold another accumulator into this one (parallel merge).
    pub fn merge(&mut self, other: &Online) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bin histogram over `[lo, hi)` with overflow/underflow buckets.
/// Quantiles are approximate (bin-midpoint) — fine for latency
/// distributions at the 1 ms simulator resolution.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    under: u64,
    over: u64,
    count: u64,
}

impl Histogram {
    /// A histogram over `[lo, hi)` with `nbins` equal buckets.
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Histogram {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            under: 0,
            over: 0,
            count: 0,
        }
    }

    /// Count one observation (clamped into the edge buckets).
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let i = ((x - self.lo) / (self.hi - self.lo) * self.bins.len() as f64) as usize;
            let i = i.min(self.bins.len() - 1);
            self.bins[i] += 1;
        }
    }

    /// Total observations counted.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Approximate quantile (bin midpoint).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!(self.count > 0);
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = self.under;
        if acc >= target && self.under > 0 {
            return self.lo;
        }
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        for (i, &b) in self.bins.iter().enumerate() {
            acc += b;
            if acc >= target {
                return self.lo + (i as f64 + 0.5) * w;
            }
        }
        self.hi
    }

    /// Fraction of samples at or below `x` (bin-granular).
    pub fn cdf(&self, x: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if x < self.lo {
            return 0.0;
        }
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        let k = (((x - self.lo) / w) as usize).min(self.bins.len());
        let acc: u64 = self.under + self.bins[..k].iter().sum::<u64>();
        acc as f64 / self.count as f64
    }
}

/// Linear interpolation helper: y at `x` on the polyline `(xs, ys)`;
/// clamps outside the domain. Used for attainment-vs-rate goodput
/// crossovers (rate at 90% attainment).
pub fn interp(xs: &[f64], ys: &[f64], x: f64) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    if x <= xs[0] {
        return ys[0];
    }
    if x >= xs[xs.len() - 1] {
        return ys[ys.len() - 1];
    }
    for i in 0..xs.len() - 1 {
        if x <= xs[i + 1] {
            let t = (x - xs[i]) / (xs[i + 1] - xs[i]);
            return ys[i] * (1.0 - t) + ys[i + 1] * t;
        }
    }
    ys[ys.len() - 1]
}

/// x where the decreasing polyline `(xs, ys)` crosses `level`, by linear
/// interpolation; `None` if it never does. Used for "goodput at 90%
/// attainment": xs = request rates, ys = attainment.
pub fn crossing_down(xs: &[f64], ys: &[f64], level: f64) -> Option<f64> {
    assert_eq!(xs.len(), ys.len());
    if ys.is_empty() || ys[0] < level {
        return if ys.first().copied().unwrap_or(0.0) >= level {
            Some(xs[0])
        } else {
            None
        };
    }
    for i in 0..ys.len() - 1 {
        if ys[i] >= level && ys[i + 1] < level {
            let t = (ys[i] - level) / (ys[i] - ys[i + 1]);
            return Some(xs[i] + t * (xs[i + 1] - xs[i]));
        }
    }
    // never drops below level within the measured range
    Some(xs[xs.len() - 1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 25.0) - 25.75).abs() < 1e-9);
    }

    #[test]
    fn summary_moments() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let s = Summary::of(&xs);
        assert!((s.mean - 5.0).abs() < 1e-9);
        assert!((s.std - 2.0).abs() < 1e-9);
        assert_eq!(s.count, 8);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0 + 3.0).collect();
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((o.mean() - mean).abs() < 1e-9);
        assert!((o.var() - var).abs() < 1e-6);
    }

    #[test]
    fn online_merge_matches_single() {
        let xs: Vec<f64> = (0..500).map(|i| i as f64 * 0.1).collect();
        let ys: Vec<f64> = (0..300).map(|i| 100.0 - i as f64).collect();
        let mut a = Online::new();
        let mut b = Online::new();
        let mut all = Online::new();
        for &x in &xs {
            a.push(x);
            all.push(x);
        }
        for &y in &ys {
            b.push(y);
            all.push(y);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-6);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(0.0, 100.0, 1000);
        for i in 0..10_000 {
            h.push((i % 100) as f64);
        }
        assert!((h.quantile(0.5) - 50.0).abs() < 1.0);
        assert!((h.quantile(0.99) - 99.0).abs() < 1.0);
        assert!((h.cdf(50.0) - 0.5).abs() < 0.02);
    }

    #[test]
    fn histogram_overflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-5.0);
        h.push(500.0);
        h.push(5.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.cdf(-1.0), 0.0);
        assert!((h.cdf(10.0) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn interp_and_crossing() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [1.0, 0.95, 0.80, 0.40];
        assert!((interp(&xs, &ys, 2.5) - 0.875).abs() < 1e-9);
        let x90 = crossing_down(&xs, &ys, 0.90).unwrap();
        assert!((x90 - (2.0 + (0.05 / 0.15))).abs() < 1e-9);
        // never attains level
        assert_eq!(crossing_down(&xs, &ys, 1.5), None);
        // always above level
        assert_eq!(crossing_down(&xs, &ys, 0.1), Some(4.0));
    }
}
