//! Micro-benchmark harness (criterion substitute).
//!
//! Every `cargo bench` target in this repo is `harness = false` and uses
//! this module. Two kinds of benches coexist:
//!
//! 1. **Timing benches** ([`Bench::time`]) — warmup, then timed
//!    iterations with mean / p50 / p99 / throughput, printed as an
//!    aligned table. Used for §5.6 scheduler efficiency and the perf
//!    pass.
//! 2. **Figure/table benches** ([`Bench::table`]) — regenerate a paper
//!    table or figure's data series and print the rows (and write CSV
//!    under `results/`). Matching the paper is about the *values*, not
//!    the wallclock, so these run once.
//!
//! `POLYSERVE_FULL=1` switches figure benches to paper-scale request
//! counts (300 k) — the default is a scaled run for CI-fast iteration.

use std::fmt::Write as _;
use std::time::{Duration, Instant};

/// Is a paper-scale (full) run requested?
pub fn full_scale() -> bool {
    std::env::var("POLYSERVE_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Is a CI smoke run requested (`POLYSERVE_SMOKE=1`)? Figure benches
/// shrink to a tiny workload and enforce their invariants with
/// assertions, so a regression fails the build instead of only skewing
/// a CSV.
pub fn smoke_scale() -> bool {
    std::env::var("POLYSERVE_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Timing statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    /// Benchmark name.
    pub name: String,
    /// Iterations timed.
    pub iters: usize,
    /// Mean iteration time.
    pub mean: Duration,
    /// Median iteration time.
    pub p50: Duration,
    /// 99th-percentile iteration time.
    pub p99: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl Timing {
    /// Items per second (`None` without an items-per-iteration count).
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.mean.as_secs_f64())
    }
}

/// Benchmark runner for one bench binary.
pub struct Bench {
    suite: String,
    timings: Vec<Timing>,
    csv_rows: Vec<(String, String)>, // (file, row)
    csv_headers: Vec<(String, String)>,
}

impl Bench {
    /// Start a bench suite (prints the suite header immediately).
    pub fn new(suite: &str) -> Bench {
        println!("\n=== bench suite: {suite} ===");
        Bench {
            suite: suite.to_string(),
            timings: Vec::new(),
            csv_rows: Vec::new(),
            csv_headers: Vec::new(),
        }
    }

    /// Time `f`, which performs one iteration per call. `items` is the
    /// number of logical operations per iteration (for ops/s).
    pub fn time<F: FnMut()>(&mut self, name: &str, items: Option<f64>, mut f: F) -> &Timing {
        // Warmup: run until 0.2 s or 10 iterations, whichever first.
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_iters < 10 && warm_start.elapsed() < Duration::from_millis(200) {
            f();
            warm_iters += 1;
        }
        // Choose iteration count targeting ~1 s of measurement,
        // clamped to [10, 10_000].
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((1.0 / per_iter.max(1e-9)) as usize).clamp(10, 10_000);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        samples.sort();
        let mean = samples.iter().sum::<Duration>() / iters as u32;
        let t = Timing {
            name: name.to_string(),
            iters,
            mean,
            p50: samples[iters / 2],
            p99: samples[(iters * 99) / 100],
            min: samples[0],
            items_per_iter: items,
        };
        self.print_timing(&t);
        self.timings.push(t);
        self.timings.last().unwrap()
    }

    fn print_timing(&self, t: &Timing) {
        let mut line = format!(
            "  {:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            t.name,
            t.iters,
            fmt_dur(t.mean),
            fmt_dur(t.p50),
            fmt_dur(t.p99),
        );
        if let Some(tput) = t.throughput() {
            let _ = write!(line, "  {:>14}/s", fmt_count(tput));
        }
        println!("{line}");
    }

    /// Print a figure/table data block and queue it for CSV output.
    /// `headers` are column names; each row is a Vec of cells.
    pub fn table(&mut self, name: &str, headers: &[&str], rows: &[Vec<String>]) {
        println!("\n--- {name} ---");
        let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
        for row in rows {
            for (i, cell) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(cell.len());
                }
            }
        }
        let mut head = String::from(" ");
        for (h, w) in headers.iter().zip(&widths) {
            let _ = write!(head, " {h:>w$}");
        }
        println!("{head}");
        for row in rows {
            let mut line = String::from(" ");
            for (cell, w) in row.iter().zip(&widths) {
                let _ = write!(line, " {cell:>w$}");
            }
            println!("{line}");
        }
        // CSV
        let file = format!("{}_{}.csv", self.suite, sanitize(name));
        self.csv_headers.push((file.clone(), headers.join(",")));
        for row in rows {
            self.csv_rows.push((file.clone(), row.join(",")));
        }
    }

    /// Write queued CSVs under `results/` and a summary line. Call last.
    pub fn finish(self) {
        let dir = std::path::Path::new("results");
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let mut files: std::collections::BTreeMap<String, String> = Default::default();
        for (file, header) in &self.csv_headers {
            files.entry(file.clone()).or_insert_with(|| format!("{header}\n"));
        }
        for (file, row) in &self.csv_rows {
            if let Some(buf) = files.get_mut(file) {
                buf.push_str(row);
                buf.push('\n');
            }
        }
        for (file, buf) in files {
            let path = dir.join(&file);
            if std::fs::write(&path, buf).is_ok() {
                println!("  [csv] wrote results/{file}");
            }
        }
        println!("=== suite {} done ===", self.suite);
    }
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
        .collect()
}

/// Human duration: ns/µs/ms/s with 3 significant digits.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Human count: 12.3k, 4.56M ...
pub fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.1}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Format a float with fixed decimals (table helper).
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_runs_and_reports() {
        let mut b = Bench::new("selftest");
        let t = b.time("noop-ish", Some(100.0), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(t.iters >= 10);
        assert!(t.mean >= t.min);
        assert!(t.throughput().unwrap() > 0.0);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
        assert_eq!(fmt_count(1234.0), "1.2k");
        assert_eq!(f(1.23456, 2), "1.23");
    }

    #[test]
    fn sanitize_names() {
        assert_eq!(sanitize("Fig 6 (goodput)"), "fig_6__goodput_");
    }
}
