//! Evaluation metrics: per-request outcomes, DSLO attainment (overall
//! and per TPOT tier), goodput, and instance·second cost accounting.

use crate::model::ModelId;
use crate::slo::{Slo, TimeMs};
use crate::util::stats::{crossing_down, Summary};

/// Outcome of one finished (or dropped) request. `PartialEq` so the
/// decision-identity tests can compare whole runs bit-for-bit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestOutcome {
    /// Workload request id.
    pub id: u64,
    /// Registry model the request was served by (0 on single-model
    /// fleets).
    pub model: ModelId,
    /// The request's SLO.
    pub slo: Slo,
    /// Arrival time, ms.
    pub arrival_ms: TimeMs,
    /// First-token emission time, ms (`None` = never).
    pub first_token_ms: Option<TimeMs>,
    /// Completion time, ms (`None` = unfinished).
    pub finish_ms: Option<TimeMs>,
    /// Output tokens emitted.
    pub tokens: u64,
    /// Every token met its DSLO deadline.
    pub attained: bool,
    /// Worst slack over all tokens (ms; negative = violation).
    pub min_slack_ms: i64,
    /// Shed by admission control (`[overload] reject`): the request
    /// was never served, billed zero tokens, and is excluded from
    /// attainment denominators. Always `false` with overload off.
    pub rejected: bool,
}

impl RequestOutcome {
    /// Time to first token, ms (`None` if no token was emitted).
    pub fn ttft_ms(&self) -> Option<u64> {
        self.first_token_ms.map(|t| t - self.arrival_ms)
    }

    /// Mean TPOT over the decode stream (ms/token).
    pub fn mean_tpot_ms(&self) -> Option<f64> {
        match (self.first_token_ms, self.finish_ms) {
            (Some(first), Some(fin)) if self.tokens > 1 => {
                Some((fin - first) as f64 / (self.tokens - 1) as f64)
            }
            _ => None,
        }
    }
}

/// Aggregated attainment report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AttainmentReport {
    /// SLO-carrying requests counted.
    pub total: usize,
    /// How many attained every token deadline.
    pub attained: usize,
    /// (tpot_ms, total, attained) per tier, sorted by tpot.
    pub per_tier: Vec<(u64, usize, usize)>,
    /// (total, attained) per registry model, indexed by [`ModelId`]
    /// (one entry on single-model fleets; same BE exclusion as the
    /// overall counts).
    pub per_model: Vec<(usize, usize)>,
}

impl AttainmentReport {
    /// Aggregate per-request outcomes into overall + per-tier +
    /// per-model attainment.
    pub fn from_outcomes(outcomes: &[RequestOutcome]) -> AttainmentReport {
        let mut per_tier: Vec<(u64, usize, usize)> = Vec::new();
        let num_models = outcomes.iter().map(|o| o.model + 1).max().unwrap_or(0);
        let mut per_model = vec![(0usize, 0usize); num_models];
        let mut total = 0usize;
        let mut attained = 0usize;
        for o in outcomes {
            if o.slo.is_best_effort() {
                continue; // BE requests don't count toward SLO attainment
            }
            if o.rejected {
                continue; // shed at admission: attainment counts accepted work
            }
            total += 1;
            per_model[o.model].0 += 1;
            if o.attained {
                attained += 1;
                per_model[o.model].1 += 1;
            }
            match per_tier.binary_search_by_key(&o.slo.tpot_ms, |e| e.0) {
                Ok(i) => {
                    per_tier[i].1 += 1;
                    if o.attained {
                        per_tier[i].2 += 1;
                    }
                }
                Err(i) => {
                    per_tier.insert(i, (o.slo.tpot_ms, 1, usize::from(o.attained)));
                }
            }
        }
        AttainmentReport {
            total,
            attained,
            per_tier,
            per_model,
        }
    }

    /// Attainment fraction of registry model `m` (`None` if the run
    /// never finished a request of that model).
    pub fn model_attainment(&self, m: ModelId) -> Option<f64> {
        self.per_model.get(m).map(|&(t, a)| {
            if t == 0 {
                1.0
            } else {
                a as f64 / t as f64
            }
        })
    }

    /// Overall DSLO attainment fraction in [0, 1].
    pub fn overall(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.attained as f64 / self.total as f64
        }
    }

    /// Attainment of the tier with TPOT `tpot_ms` (`None` if absent).
    pub fn tier_attainment(&self, tpot_ms: u64) -> Option<f64> {
        self.per_tier
            .iter()
            .find(|e| e.0 == tpot_ms)
            .map(|e| if e.1 == 0 { 1.0 } else { e.2 as f64 / e.1 as f64 })
    }

    /// Worst tier attainment — PolyServe's claim is near-uniform
    /// attainment across tiers, so this is the discriminating number.
    pub fn worst_tier(&self) -> f64 {
        self.per_tier
            .iter()
            .map(|e| if e.1 == 0 { 1.0 } else { e.2 as f64 / e.1 as f64 })
            .fold(1.0, f64::min)
    }
}

/// An attainment-vs-rate curve for goodput extraction (Fig 6 / Fig 7:
/// "goodput at 90% attainment").
#[derive(Debug, Clone, Default)]
pub struct AttainmentCurve {
    /// (request rate req/s, overall attainment in [0,1]).
    pub points: Vec<(f64, f64)>,
}

impl AttainmentCurve {
    /// Insert a measured (rate, attainment) point, keeping the curve sorted.
    pub fn push(&mut self, rate_rps: f64, attainment: f64) {
        self.points.push((rate_rps, attainment));
        self.points
            .sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    }

    /// Goodput: the largest rate at which attainment ≥ `level`
    /// (linear interpolation between measured rates).
    pub fn goodput_at(&self, level: f64) -> Option<f64> {
        if self.points.is_empty() {
            return None;
        }
        let xs: Vec<f64> = self.points.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = self.points.iter().map(|p| p.1).collect();
        crossing_down(&xs, &ys, level)
    }
}

/// Cost accounting: instance·seconds (§3.3 "we define the cost as
/// instance · second").
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CostAccount {
    /// Total instance·ms spent iterating.
    pub instance_busy_ms: u64,
    /// Total instance·ms the fleet was *allocated* (busy or idle but
    /// reserved to a tier) — the number Fig 8 divides by requests.
    pub instance_alloc_ms: u64,
    /// Total instance·ms the fleet *existed* (provision → retire):
    /// what a cloud bill charges. On a fixed fleet this is
    /// `n × sim_span`; an elastic fleet makes it load-dependent.
    pub active_instance_ms: u64,
    /// Requests that finished.
    pub requests_served: u64,
    /// Output tokens emitted across all finished requests.
    pub tokens_total: u64,
    /// Output tokens from SLO-attaining requests only — the "goodput
    /// tokens" an operator is actually paid for.
    pub goodput_tokens: u64,
    /// `active_instance_ms` split by registry model, indexed by
    /// [`ModelId`]. An instance's whole existence bills against the
    /// model it *ended* the run loaded with (hot swaps reassign the
    /// bill, matching how a cloud invoice lists the final deployment);
    /// one entry on single-model fleets.
    pub active_instance_ms_per_model: Vec<u64>,
    /// `requests_served` split by registry model.
    pub requests_served_per_model: Vec<u64>,
    /// The slice of `active_instance_ms` billed by spot instances
    /// (provision → retire/fail). 0 unless `[chaos]` provisioned spot
    /// capacity.
    pub spot_instance_ms: u64,
    /// On-demand-equivalent bill (ms, rounded) with the spot slice
    /// priced by the stepwise `[chaos] spot_price_schedule` instead of
    /// the flat `spot_price_frac`. `None` unless the run declared a
    /// price curve — flat-discount runs keep using
    /// [`CostAccount::discounted_bill_ms`].
    pub spot_curve_bill_ms: Option<u64>,
}

impl CostAccount {
    /// Allocated instance·seconds per served request (Fig 8's metric).
    pub fn cost_per_request_s(&self) -> f64 {
        if self.requests_served == 0 {
            return f64::INFINITY;
        }
        self.instance_alloc_ms as f64 / 1000.0 / self.requests_served as f64
    }

    /// Fleet bill per request (elastic accounting), instance·s.
    pub fn active_cost_per_request_s(&self) -> f64 {
        if self.requests_served == 0 {
            return f64::INFINITY;
        }
        self.active_instance_ms as f64 / 1000.0 / self.requests_served as f64
    }

    /// Fleet bill per 1000 goodput tokens, instance·s — the
    /// load-dependent unit economics number.
    pub fn cost_per_1k_goodput_tokens_s(&self) -> f64 {
        if self.goodput_tokens == 0 {
            return f64::INFINITY;
        }
        self.active_instance_ms as f64 / self.goodput_tokens as f64
    }

    /// Busy fraction of allocated instance time.
    pub fn utilization(&self) -> f64 {
        if self.instance_alloc_ms == 0 {
            0.0
        } else {
            self.instance_busy_ms as f64 / self.instance_alloc_ms as f64
        }
    }

    /// Cloud bill in on-demand-equivalent instance·ms, with the spot
    /// slice discounted to `spot_price_frac` of the on-demand rate
    /// (1.0 = no discount; equals `active_instance_ms` when the run
    /// provisioned no spot capacity).
    pub fn discounted_bill_ms(&self, spot_price_frac: f64) -> f64 {
        let on_demand = self.active_instance_ms - self.spot_instance_ms;
        on_demand as f64 + self.spot_instance_ms as f64 * spot_price_frac
    }
}

/// One snapshot of fleet composition, taken at every `ScaleEval`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetSample {
    /// Simulated time of the snapshot.
    pub t_ms: TimeMs,
    /// Active instances assigned to each TPOT tier (tightest first).
    pub per_tier: Vec<usize>,
    /// Active instances loaded with each registry model, indexed by
    /// [`ModelId`] (the per-model fleet series; one entry on
    /// single-model fleets).
    pub per_model: Vec<usize>,
    /// Active instances idling in the best-effort pool.
    pub best_effort: usize,
    /// All active instances (any role / assignment).
    pub active: usize,
    /// Active `Role::Prefill` instances (the elastic-prefill series;
    /// constant on runs where the prefill tier is static, 0 on coloc).
    pub active_prefill: usize,
    /// Instances cold-starting at the snapshot.
    pub provisioning: usize,
    /// Instances draining at the snapshot.
    pub draining: usize,
}

/// One predicted-vs-observed arrival-rate sample, recorded by the
/// predictive autoscaler at every `ScaleEval` epoch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RateSample {
    /// Simulated time of the evaluation epoch.
    pub t_ms: TimeMs,
    /// Raw arrival rate over the last epoch window (req/s).
    pub observed_rps: f64,
    /// EWMA-smoothed rate estimate (req/s).
    pub smoothed_rps: f64,
    /// Rate projected `provision_lead_ms` ahead — what the fleet was
    /// sized for (req/s).
    pub predicted_rps: f64,
}

/// Per-tier fleet-size time series for an elastic run (empty on fixed
/// fleets).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetSeries {
    /// Fleet-composition snapshots, one per `ScaleEval`.
    pub samples: Vec<FleetSample>,
    /// Predicted-vs-observed arrival-rate samples (empty unless the
    /// run used the predictive autoscaler).
    pub rates: Vec<RateSample>,
}

impl FleetSeries {
    /// True when the run recorded no fleet snapshots (fixed fleet).
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Largest active fleet observed.
    pub fn peak_active(&self) -> usize {
        self.samples.iter().map(|s| s.active).max().unwrap_or(0)
    }

    /// Smallest active fleet observed.
    pub fn trough_active(&self) -> usize {
        self.samples.iter().map(|s| s.active).min().unwrap_or(0)
    }

    /// Time-weighted mean active fleet size over the sampled span.
    pub fn mean_active(&self) -> f64 {
        self.time_weighted_mean(|s| s.active)
    }

    /// Largest active prefill tier observed.
    pub fn peak_prefill(&self) -> usize {
        self.samples.iter().map(|s| s.active_prefill).max().unwrap_or(0)
    }

    /// Smallest active prefill tier observed.
    pub fn trough_prefill(&self) -> usize {
        self.samples.iter().map(|s| s.active_prefill).min().unwrap_or(0)
    }

    /// Time-weighted mean active prefill-tier size.
    pub fn mean_prefill(&self) -> f64 {
        self.time_weighted_mean(|s| s.active_prefill)
    }

    /// Time-weighted mean active instances loaded with model `m` (0.0
    /// when the series never sampled that model).
    pub fn mean_model(&self, m: ModelId) -> f64 {
        self.time_weighted_mean(|s| s.per_model.get(m).copied().unwrap_or(0))
    }

    /// Largest active sub-fleet observed for model `m`.
    pub fn peak_model(&self, m: ModelId) -> usize {
        self.samples
            .iter()
            .map(|s| s.per_model.get(m).copied().unwrap_or(0))
            .max()
            .unwrap_or(0)
    }

    /// Smallest active sub-fleet observed for model `m`.
    pub fn trough_model(&self, m: ModelId) -> usize {
        self.samples
            .iter()
            .map(|s| s.per_model.get(m).copied().unwrap_or(0))
            .min()
            .unwrap_or(0)
    }

    fn time_weighted_mean(&self, f: impl Fn(&FleetSample) -> usize) -> f64 {
        if self.samples.len() < 2 {
            return self.samples.first().map(|s| f(s) as f64).unwrap_or(0.0);
        }
        let mut weighted = 0.0;
        let mut span = 0.0;
        for w in self.samples.windows(2) {
            let dt = (w[1].t_ms - w[0].t_ms) as f64;
            weighted += f(&w[0]) as f64 * dt;
            span += dt;
        }
        if span == 0.0 {
            f(&self.samples[0]) as f64
        } else {
            weighted / span
        }
    }

    /// Mean absolute error between the predicted rate and the observed
    /// rate of the epoch nearest `t + lead_ms` — how well the
    /// predictive scaler anticipated the curve it was chasing. `None`
    /// without rate samples.
    pub fn rate_prediction_mae(&self, lead_ms: TimeMs) -> Option<f64> {
        if self.rates.is_empty() {
            return None;
        }
        let mut err = 0.0f64;
        let mut n = 0usize;
        for r in &self.rates {
            let target_t = r.t_ms + lead_ms;
            let Some(actual) = self
                .rates
                .iter()
                .min_by_key(|o| o.t_ms.abs_diff(target_t))
                .filter(|o| o.t_ms.abs_diff(target_t) <= (lead_ms / 2).max(1))
            else {
                continue;
            };
            err += (r.predicted_rps - actual.observed_rps).abs();
            n += 1;
        }
        if n == 0 {
            None
        } else {
            Some(err / n as f64)
        }
    }
}

/// Scale-in drain + KV-migration accounting. Drain latencies are
/// recorded for every drained instance (migration on or off) so the
/// two policies are directly comparable; the migrated counters stay
/// zero unless `[elastic] migration = "on"` evicted residents.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MigrationStats {
    /// Decode requests detached from drainers and re-placed elsewhere.
    pub migrated_requests: u64,
    /// Queued prefill jobs re-routed off draining prefill servers
    /// (elastic-prefill scale-in; 0 with a static prefill tier).
    pub migrated_prefill_jobs: u64,
    /// KV tokens in flight across all migrations (resident KV at
    /// eviction time; includes partially-prefilled KV of migrated
    /// prefill jobs).
    pub migrated_kv_tokens: u64,
    /// Per-drain begin_drain→retire latency (ms). Instances still
    /// draining when the run ends are censored at the simulated span.
    pub drain_latency_ms: Vec<u64>,
    /// Model hot-swaps completed (drain → reload under a new model).
    pub model_swaps: u64,
    /// Bulk same-`(source, dest)` migration transfers issued by the
    /// batched scale-in path (0 unless `migration_batching` is on).
    pub batched_transfers: u64,
}

impl MigrationStats {
    /// Number of recorded drains.
    pub fn drains(&self) -> usize {
        self.drain_latency_ms.len()
    }

    /// Mean begin_drain→retire latency, ms (0 with no drains).
    pub fn mean_drain_latency_ms(&self) -> f64 {
        if self.drain_latency_ms.is_empty() {
            return 0.0;
        }
        self.drain_latency_ms.iter().sum::<u64>() as f64 / self.drain_latency_ms.len() as f64
    }

    /// Worst begin_drain→retire latency, ms.
    pub fn max_drain_latency_ms(&self) -> u64 {
        self.drain_latency_ms.iter().copied().max().unwrap_or(0)
    }

    /// Fixed-width drain-latency histogram: `buckets` counts of width
    /// `bucket_ms`, with everything past the last edge clamped into the
    /// final bucket.
    pub fn drain_latency_histogram(&self, bucket_ms: u64, buckets: usize) -> Vec<usize> {
        let mut hist = vec![0usize; buckets.max(1)];
        let last = hist.len() - 1;
        for &d in &self.drain_latency_ms {
            let b = (d / bucket_ms.max(1)) as usize;
            hist[b.min(last)] += 1;
        }
        hist
    }
}

/// Fault-injection accounting: instance failures, spot preemptions,
/// and the re-prefill work they force. All zeros unless the run
/// enabled a `[chaos]` schedule — the digest-identity tests pin that.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosStats {
    /// Instances hard-killed (explicit schedule, MTBF process, or a
    /// spot preemption that blew its drain deadline).
    pub failures: u64,
    /// Spot preemption notices delivered (each starts a deadline
    /// drain).
    pub preempt_notices: u64,
    /// Preempted instances that were still alive when the grace
    /// window expired and were hard-killed.
    pub preempt_deadline_kills: u64,
    /// Preempted instances that drained cleanly (migrate/wait-drain)
    /// before their deadline.
    pub preempt_drained: u64,
    /// Requests whose resident KV died with a failed instance and
    /// that re-entered placement for a full re-prefill.
    pub replaced_requests: u64,
    /// KV tokens (prefill-done + decoded context) lost to failures —
    /// the prefill slice of it is recomputed from scratch. With
    /// checkpointing on, only the *un*-checkpointed suffix counts here;
    /// the protected prefix lands in `recovered_kv_tokens`.
    pub lost_kv_tokens: u64,
    /// Correlated domain kills executed (one per `DomainFail` draw that
    /// hit ≥ 0 live instances — rack and zone kills both count once).
    pub domain_kills: u64,
    /// Instances killed per zone by domain-correlated draws, indexed by
    /// zone id (empty unless `[chaos] zones` partitioned the fleet).
    pub kills_per_zone: Vec<u64>,
    /// KV-watermark snapshots taken by the periodic checkpointer.
    pub checkpoints: u64,
    /// Prefill tokens newly covered by snapshots (sum of per-snapshot
    /// watermark deltas — the transfer volume billed to the
    /// interconnect).
    pub checkpoint_tokens: u64,
    /// Total snapshot transfer time billed, ms (`checkpoint_tokens`
    /// over the migration interconnect rate, per snapshot).
    pub checkpoint_cost_ms: u64,
    /// Checkpointed prefill tokens restored instead of recomputed when
    /// their instance failed — KV the snapshots saved.
    pub recovered_kv_tokens: u64,
    /// Prefill tokens actually recomputed after failures
    /// (`prefill_done − checkpointed` summed over victims; equals the
    /// victims' full `prefill_done` when checkpointing is off).
    pub reprefill_tokens: u64,
}

impl ChaosStats {
    /// True when the run injected no faults at all.
    pub fn is_quiet(&self) -> bool {
        self == &ChaosStats::default()
    }
}

/// Overload accounting: admission-control rejections, retry traffic,
/// shed vs. served tokens, and pending-queue aging. The rejection and
/// retry counters stay zero unless `[overload]` enabled them — the
/// digest-identity tests pin that; the aging counters move on any run
/// that ever pended a request.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OverloadStats {
    /// Requests shed with a typed `Rejected` outcome (final — retry
    /// re-arrivals that were later admitted don't count).
    pub rejected_total: u64,
    /// Final rejections per SLO tier, sorted by TPOT:
    /// `(tpot_ms, rejected)`.
    pub rejected_per_tier: Vec<(u64, u64)>,
    /// Final rejections per registry model, indexed by [`ModelId`].
    pub rejected_per_model: Vec<u64>,
    /// Retry re-arrivals scheduled through the calendar queue.
    pub retries: u64,
    /// Retried-then-admitted requests by rejection count:
    /// `retry_histogram[k]` = requests admitted after exactly `k+1`
    /// rejections (i.e. on their `k+1`-th retry re-arrival). Requests
    /// admitted on first contact never appear.
    pub retry_histogram: Vec<u64>,
    /// Requests that exhausted `retry_max_attempts` and were shed for
    /// good.
    pub retry_exhausted: u64,
    /// Output tokens the shed requests *would* have decoded — demand
    /// deliberately not served.
    pub shed_tokens: u64,
    /// Output tokens from SLO-attaining served requests (mirrors
    /// `CostAccount::goodput_tokens` for the shed-vs-served ratio).
    pub served_tokens: u64,
    /// Pended requests that waited past the router's relaxed-admission
    /// patience before dispatch (queue-aging signal; moves on normal
    /// runs too).
    pub aged_past_patience: u64,
    /// Longest observed pend, ms (0 if nothing ever pended).
    pub max_pend_ms: u64,
}

impl OverloadStats {
    /// True when admission control never shed, retried, or deferred
    /// anything — the aging counters are *excluded*, since FIFO pend
    /// queues age under plain load with `[overload]` off.
    pub fn is_quiet(&self) -> bool {
        self.rejected_total == 0
            && self.retries == 0
            && self.retry_exhausted == 0
            && self.shed_tokens == 0
            && self.rejected_per_tier.is_empty()
            && self.rejected_per_model.iter().all(|&r| r == 0)
            && self.retry_histogram.is_empty()
    }

    /// Fraction of all arrivals that ended shed (0.0 when nothing
    /// arrived).
    pub fn rejection_rate(&self, arrivals: u64) -> f64 {
        if arrivals == 0 {
            0.0
        } else {
            self.rejected_total as f64 / arrivals as f64
        }
    }
}

/// Latency summary across outcomes (TTFT and mean-TPOT distributions).
pub fn latency_summary(outcomes: &[RequestOutcome]) -> (Option<Summary>, Option<Summary>) {
    let ttfts: Vec<f64> = outcomes
        .iter()
        .filter_map(|o| o.ttft_ms().map(|t| t as f64))
        .collect();
    let tpots: Vec<f64> = outcomes.iter().filter_map(|o| o.mean_tpot_ms()).collect();
    (
        if ttfts.is_empty() { None } else { Some(Summary::of(&ttfts)) },
        if tpots.is_empty() { None } else { Some(Summary::of(&tpots)) },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(tpot: u64, attained: bool) -> RequestOutcome {
        RequestOutcome {
            id: 0,
            model: 0,
            slo: Slo::new(500, tpot),
            arrival_ms: 0,
            first_token_ms: Some(100),
            finish_ms: Some(1100),
            tokens: 101,
            attained,
            min_slack_ms: if attained { 5 } else { -3 },
            rejected: false,
        }
    }

    #[test]
    fn report_aggregates_tiers() {
        let outcomes = vec![
            outcome(20, true),
            outcome(20, false),
            outcome(50, true),
            outcome(50, true),
        ];
        let r = AttainmentReport::from_outcomes(&outcomes);
        assert_eq!(r.total, 4);
        assert_eq!(r.attained, 3);
        assert!((r.overall() - 0.75).abs() < 1e-9);
        assert_eq!(r.tier_attainment(20), Some(0.5));
        assert_eq!(r.tier_attainment(50), Some(1.0));
        assert_eq!(r.tier_attainment(100), None);
        assert!((r.worst_tier() - 0.5).abs() < 1e-9);
        assert_eq!(r.per_model, vec![(4, 3)]);
        assert_eq!(r.model_attainment(0), Some(0.75));
        assert_eq!(r.model_attainment(1), None);
    }

    #[test]
    fn report_splits_per_model() {
        let mut o1 = outcome(20, true);
        o1.model = 1;
        let outcomes = vec![outcome(20, false), o1];
        let r = AttainmentReport::from_outcomes(&outcomes);
        assert_eq!(r.per_model, vec![(1, 0), (1, 1)]);
        assert_eq!(r.model_attainment(0), Some(0.0));
        assert_eq!(r.model_attainment(1), Some(1.0));
    }

    #[test]
    fn best_effort_excluded() {
        let mut o = outcome(20, true);
        o.slo = Slo::BEST_EFFORT;
        let r = AttainmentReport::from_outcomes(&[o]);
        assert_eq!(r.total, 0);
        assert_eq!(r.overall(), 1.0);
    }

    #[test]
    fn goodput_extraction() {
        let mut c = AttainmentCurve::default();
        c.push(10.0, 1.0);
        c.push(30.0, 0.80);
        c.push(20.0, 0.95);
        let g = c.goodput_at(0.90).unwrap();
        assert!(g > 20.0 && g < 30.0, "goodput={g}");
    }

    #[test]
    fn cost_account() {
        let c = CostAccount {
            instance_busy_ms: 5_000,
            instance_alloc_ms: 10_000,
            active_instance_ms: 20_000,
            requests_served: 5,
            tokens_total: 4_000,
            goodput_tokens: 2_000,
            active_instance_ms_per_model: vec![20_000],
            requests_served_per_model: vec![5],
            spot_instance_ms: 8_000,
            spot_curve_bill_ms: None,
        };
        assert!((c.cost_per_request_s() - 2.0).abs() < 1e-9);
        assert!((c.active_cost_per_request_s() - 4.0).abs() < 1e-9);
        assert!((c.cost_per_1k_goodput_tokens_s() - 10.0).abs() < 1e-9);
        assert!((c.utilization() - 0.5).abs() < 1e-9);
        // 12 000 on-demand ms + 8 000 spot ms at 30% of the rate.
        assert!((c.discounted_bill_ms(0.3) - 14_400.0).abs() < 1e-9);
        assert!((c.discounted_bill_ms(1.0) - 20_000.0).abs() < 1e-9);
        let empty = CostAccount::default();
        assert!(empty.cost_per_request_s().is_infinite());
        assert!(empty.active_cost_per_request_s().is_infinite());
        assert!(empty.cost_per_1k_goodput_tokens_s().is_infinite());
        assert_eq!(empty.discounted_bill_ms(0.3), 0.0);
    }

    #[test]
    fn rejected_excluded_from_attainment() {
        let mut shed = outcome(20, false);
        shed.rejected = true;
        shed.first_token_ms = None;
        shed.finish_ms = None;
        shed.tokens = 0;
        let outcomes = vec![outcome(20, true), outcome(20, false), shed];
        let r = AttainmentReport::from_outcomes(&outcomes);
        assert_eq!(r.total, 2);
        assert_eq!(r.attained, 1);
        assert_eq!(r.tier_attainment(20), Some(0.5));
        assert_eq!(r.per_model, vec![(2, 1)]);
    }

    #[test]
    fn overload_stats_quiet() {
        assert!(OverloadStats::default().is_quiet());
        // Aging moves on plain runs — it must not break quietness.
        let aged = OverloadStats {
            aged_past_patience: 7,
            max_pend_ms: 1234,
            ..OverloadStats::default()
        };
        assert!(aged.is_quiet());
        let shedding = OverloadStats {
            rejected_total: 1,
            ..OverloadStats::default()
        };
        assert!(!shedding.is_quiet());
        assert!((shedding.rejection_rate(4) - 0.25).abs() < 1e-9);
        assert_eq!(OverloadStats::default().rejection_rate(0), 0.0);
    }

    #[test]
    fn chaos_stats_quiet() {
        assert!(ChaosStats::default().is_quiet());
        let noisy = ChaosStats {
            failures: 1,
            ..ChaosStats::default()
        };
        assert!(!noisy.is_quiet());
    }

    #[test]
    fn fleet_series_summaries() {
        let sample = |t_ms, active| FleetSample {
            t_ms,
            per_tier: vec![active / 2, active - active / 2],
            per_model: vec![active],
            best_effort: 0,
            active,
            active_prefill: active / 4,
            provisioning: 0,
            draining: 0,
        };
        let s = FleetSeries {
            samples: vec![sample(0, 4), sample(1000, 8), sample(3000, 2)],
            rates: Vec::new(),
        };
        assert_eq!(s.peak_active(), 8);
        assert_eq!(s.trough_active(), 2);
        // Time-weighted: 4 for 1 s, 8 for 2 s over 3 s = 20/3.
        assert!((s.mean_active() - 20.0 / 3.0).abs() < 1e-9);
        // Prefill column: 1 for 1 s, 2 for 2 s over 3 s = 5/3.
        assert_eq!(s.peak_prefill(), 2);
        assert_eq!(s.trough_prefill(), 0);
        assert!((s.mean_prefill() - 5.0 / 3.0).abs() < 1e-9);
        assert!(FleetSeries::default().is_empty());
        assert_eq!(FleetSeries::default().peak_active(), 0);
        assert_eq!(FleetSeries::default().rate_prediction_mae(1000), None);
    }

    #[test]
    fn rate_prediction_mae_aligns_by_lead() {
        // Predictions made at t are for t+1000; observed rates step up
        // by 10 each epoch and every prediction is 2 high.
        let rates: Vec<RateSample> = (0..5u64)
            .map(|i| RateSample {
                t_ms: i * 1000,
                observed_rps: 10.0 * i as f64,
                smoothed_rps: 10.0 * i as f64,
                predicted_rps: 10.0 * (i + 1) as f64 + 2.0,
            })
            .collect();
        let s = FleetSeries { samples: Vec::new(), rates };
        let mae = s.rate_prediction_mae(1000).unwrap();
        assert!((mae - 2.0).abs() < 1e-9, "mae={mae}");
    }

    #[test]
    fn migration_stats_summaries() {
        let m = MigrationStats {
            migrated_requests: 3,
            migrated_prefill_jobs: 0,
            migrated_kv_tokens: 4_500,
            drain_latency_ms: vec![100, 900, 2_500, 40_000],
            model_swaps: 0,
            batched_transfers: 0,
        };
        assert_eq!(m.drains(), 4);
        assert!((m.mean_drain_latency_ms() - 10_875.0).abs() < 1e-9);
        assert_eq!(m.max_drain_latency_ms(), 40_000);
        // 1 s buckets × 4: [0,1s) → 2, [1s,2s) → 0, [2s,3s) → 1, rest → 1.
        assert_eq!(m.drain_latency_histogram(1_000, 4), vec![2, 0, 1, 1]);
        let empty = MigrationStats::default();
        assert_eq!(empty.drains(), 0);
        assert_eq!(empty.mean_drain_latency_ms(), 0.0);
        assert_eq!(empty.max_drain_latency_ms(), 0);
    }

    #[test]
    fn outcome_latencies() {
        let o = outcome(20, true);
        assert_eq!(o.ttft_ms(), Some(100));
        assert!((o.mean_tpot_ms().unwrap() - 10.0).abs() < 1e-9);
    }
}
