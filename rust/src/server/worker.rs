//! A serving-instance worker thread: owns one PJRT [`Engine`] and runs
//! the continuous-batching loop (chunked prefill riding along batched
//! decode, §2.4) over the requests the leader assigns to it.

use crate::runtime::{ArtifactStore, Engine, KvState};
use crate::slo::Slo;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Instant;

/// A request as submitted to the live server.
#[derive(Debug, Clone)]
pub struct LiveRequest {
    /// Request id.
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Output-length cap.
    pub max_new_tokens: usize,
    /// The request's SLO.
    pub slo: Slo,
    /// TPOT tier bin assigned by the leader.
    pub tier: usize,
}

/// Command channel leader → worker.
pub enum WorkerCommand {
    /// Serve one request.
    Serve(LiveRequest),
    /// Stop the worker thread.
    Shutdown,
}

/// Token event stream worker → collector.
#[derive(Debug, Clone)]
pub struct TokenEvent {
    /// Request the token belongs to.
    pub request_id: u64,
    /// 0-based output-token index (0 = first token, from prefill).
    pub token_index: u64,
    /// Token id emitted.
    pub token: i32,
    /// Emission instant.
    pub at: Instant,
    /// Was this the request's last token?
    pub finished: bool,
}

/// Load published by a worker (read by the leader's router).
#[derive(Debug, Default)]
pub struct WorkerLoad {
    /// Live decode requests.
    pub batch: AtomicU64,
    /// Resident KV tokens.
    pub kv_tokens: AtomicU64,
    /// Queued prefill tokens not yet processed.
    pub queued_prefill: AtomicU64,
    /// Iterations executed (liveness/metrics).
    pub iterations: AtomicU64,
    /// Set to 1 once the engine is compiled and the worker is serving.
    pub ready: AtomicU64,
}

struct Active {
    req: LiveRequest,
    kv: KvState,
    emitted: u64,
}

/// Body of a worker thread. Loads the engine, then loops: drain
/// commands, form an iteration (all decode requests + one prefill
/// chunk), execute, emit tokens.
pub fn run_worker(
    worker_id: usize,
    artifacts: PathBuf,
    rx: Receiver<WorkerCommand>,
    tx_tokens: Sender<TokenEvent>,
    load: Arc<WorkerLoad>,
    chunk_tokens: usize,
) -> anyhow::Result<()> {
    let store = Rc::new(ArtifactStore::open(&artifacts)?);
    let max_batch = *store.decode_buckets.iter().max().unwrap();
    let engine = Engine::load(store)?;
    load.ready.store(1, Ordering::Relaxed);
    log::info!("worker {worker_id}: engine ready on {}", engine.platform());

    struct PrefillItem {
        req: LiveRequest,
        kv: KvState,
        done: usize,
        first_emitted: bool,
    }
    let mut prefill_queue: VecDeque<PrefillItem> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    let mut shutdown = false;

    loop {
        // 1. Drain commands (non-blocking unless idle).
        loop {
            let cmd = if active.is_empty() && prefill_queue.is_empty() && !shutdown {
                match rx.recv() {
                    Ok(c) => c,
                    Err(_) => return Ok(()),
                }
            } else {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            };
            match cmd {
                WorkerCommand::Serve(req) => {
                    let kv = engine.new_kv();
                    load.queued_prefill
                        .fetch_add(req.prompt.len() as u64, Ordering::Relaxed);
                    prefill_queue.push_back(PrefillItem {
                        req,
                        kv,
                        done: 0,
                        first_emitted: false,
                    });
                }
                WorkerCommand::Shutdown => shutdown = true,
            }
            if active.is_empty() && prefill_queue.is_empty() {
                continue; // blocking recv again
            }
        }
        if shutdown && active.is_empty() && prefill_queue.is_empty() {
            return Ok(());
        }

        // 2. One continuous-batching iteration.
        // 2a. Prefill chunk for the head-of-queue request (EDF order is
        //     maintained by the leader's assignment; FIFO here). Items
        //     whose prefill already completed but found no decode slot
        //     wait without re-executing anything.
        if let Some(mut item) = prefill_queue.pop_front() {
            if item.done == item.req.prompt.len() {
                // Waiting for a decode slot.
                if active.len() < max_batch {
                    load.batch.fetch_add(1, Ordering::Relaxed);
                    load.kv_tokens
                        .fetch_add(item.kv.kv_len as u64, Ordering::Relaxed);
                    active.push(Active {
                        req: item.req,
                        kv: item.kv,
                        emitted: 1,
                    });
                } else {
                    prefill_queue.push_back(item);
                }
            } else {
                let remaining = item.req.prompt.len() - item.done;
                let n = remaining.min(chunk_tokens.max(1));
                let tok =
                    engine.prefill_chunk(&mut item.kv, &item.req.prompt[item.done..item.done + n])?;
                item.done += n;
                load.queued_prefill.fetch_sub(n as u64, Ordering::Relaxed);
                if item.done == item.req.prompt.len() {
                    // Prefill complete: first token out (exactly once).
                    let finished = item.req.max_new_tokens <= 1;
                    debug_assert!(!item.first_emitted);
                    item.first_emitted = true;
                    let _ = tx_tokens.send(TokenEvent {
                        request_id: item.req.id,
                        token_index: 0,
                        token: tok,
                        at: Instant::now(),
                        finished,
                    });
                    if !finished {
                        if active.len() < max_batch {
                            load.batch.fetch_add(1, Ordering::Relaxed);
                            load.kv_tokens
                                .fetch_add(item.kv.kv_len as u64, Ordering::Relaxed);
                            active.push(Active {
                                req: item.req,
                                kv: item.kv,
                                emitted: 1,
                            });
                        } else {
                            prefill_queue.push_back(item);
                        }
                    }
                } else {
                    prefill_queue.push_front(item);
                }
            }
        }

        // 2b. Batched decode step for all active requests.
        if !active.is_empty() {
            let mut refs: Vec<&mut KvState> = active.iter_mut().map(|a| &mut a.kv).collect();
            let next = engine.decode_step(&mut refs)?;
            drop(refs);
            let now = Instant::now();
            let mut i = 0;
            while i < active.len() {
                let a = &mut active[i];
                a.emitted += 1;
                let finished = a.emitted >= a.req.max_new_tokens as u64
                    || a.kv.kv_len + 1 >= engine.store.model.max_seq_len;
                let _ = tx_tokens.send(TokenEvent {
                    request_id: a.req.id,
                    token_index: a.emitted - 1,
                    token: next[i],
                    at: now,
                    finished,
                });
                load.kv_tokens.fetch_add(1, Ordering::Relaxed);
                if finished {
                    load.batch.fetch_sub(1, Ordering::Relaxed);
                    load.kv_tokens
                        .fetch_sub(active[i].kv.kv_len as u64, Ordering::Relaxed);
                    active.swap_remove(i);
                } else {
                    i += 1;
                }
            }
        }
        load.iterations.fetch_add(1, Ordering::Relaxed);
    }
}
