//! The leader: spawns workers, routes requests PolyServe-style
//! (TPOT-tier binning + highest-load-feasible placement using worker
//! load telemetry), and collects token events into DSLO outcomes.

use super::worker::{self, LiveRequest, TokenEvent, WorkerCommand, WorkerLoad};
use crate::slo::{Slo, TierSet};
use crate::util::stats::Summary;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Live-server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Artifact directory the engines load from.
    pub artifacts: PathBuf,
    /// In-process serving instances.
    pub instances: usize,
    /// Prefill chunk tokens per iteration.
    pub chunk_tokens: usize,
    /// TPOT tier set for request binning.
    pub tiers: TierSet,
}

/// Per-request outcome measured by the collector.
#[derive(Debug, Clone)]
pub struct LiveOutcome {
    /// Request id.
    pub id: u64,
    /// The request's SLO.
    pub slo: Slo,
    /// Submission instant.
    pub submitted: Instant,
    /// First-token instant (`None` = never).
    pub first_token: Option<Instant>,
    /// Completion instant (`None` = unfinished).
    pub finished: Option<Instant>,
    /// Output tokens generated.
    pub tokens: u64,
    /// Did every token meet its DSLO deadline?
    pub attained: bool,
}

/// Aggregate report for a serving run.
#[derive(Debug)]
pub struct ServeReport {
    /// Per-request live outcomes.
    pub outcomes: Vec<LiveOutcome>,
    /// Wall-clock span of the serve run, seconds.
    pub wall_s: f64,
    /// Tokens generated across all requests.
    pub total_tokens: u64,
    /// Engine iterations executed.
    pub iterations: u64,
}

impl ServeReport {
    /// Fraction of served requests that met their SLO.
    pub fn attainment(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 1.0;
        }
        self.outcomes.iter().filter(|o| o.attained).count() as f64 / self.outcomes.len() as f64
    }

    /// Served requests per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        self.outcomes.len() as f64 / self.wall_s
    }

    /// Generated tokens per wall-clock second.
    pub fn token_throughput(&self) -> f64 {
        self.total_tokens as f64 / self.wall_s
    }

    /// TTFT distribution over served requests, ms (`None` when empty).
    pub fn ttft_ms(&self) -> Option<Summary> {
        let xs: Vec<f64> = self
            .outcomes
            .iter()
            .filter_map(|o| {
                o.first_token
                    .map(|t| t.duration_since(o.submitted).as_secs_f64() * 1000.0)
            })
            .collect();
        if xs.is_empty() {
            None
        } else {
            Some(Summary::of(&xs))
        }
    }

    /// Mean-TPOT distribution over served requests, ms (`None` when empty).
    pub fn mean_tpot_ms(&self) -> Option<Summary> {
        let xs: Vec<f64> = self
            .outcomes
            .iter()
            .filter_map(|o| match (o.first_token, o.finished) {
                (Some(f), Some(e)) if o.tokens > 1 => {
                    Some(e.duration_since(f).as_secs_f64() * 1000.0 / (o.tokens - 1) as f64)
                }
                _ => None,
            })
            .collect();
        if xs.is_empty() {
            None
        } else {
            Some(Summary::of(&xs))
        }
    }
}

struct WorkerHandle {
    tx: Sender<WorkerCommand>,
    load: Arc<WorkerLoad>,
    join: JoinHandle<anyhow::Result<()>>,
    /// Tier this worker currently serves (leader-side binning).
    tier: usize,
}

/// The live multi-instance server.
pub struct LiveServer {
    cfg: ServeConfig,
    workers: Vec<WorkerHandle>,
    tx_tokens: Sender<TokenEvent>,
    rx_tokens: std::sync::mpsc::Receiver<TokenEvent>,
    tracked: HashMap<u64, LiveOutcome>,
    next_id: u64,
    start: Instant,
}

impl LiveServer {
    /// Spawn `instances` workers (each compiles its own engine — takes
    /// seconds; done in parallel).
    pub fn start(cfg: ServeConfig) -> anyhow::Result<LiveServer> {
        let (tx_tokens, rx_tokens) = channel();
        let mut workers = Vec::with_capacity(cfg.instances);
        for w in 0..cfg.instances {
            let (tx_cmd, rx_cmd) = channel();
            let load = Arc::new(WorkerLoad::default());
            let load2 = Arc::clone(&load);
            let artifacts = cfg.artifacts.clone();
            let tok = tx_tokens.clone();
            let chunk = cfg.chunk_tokens;
            let join = std::thread::Builder::new()
                .name(format!("polyserve-worker-{w}"))
                .spawn(move || worker::run_worker(w, artifacts, rx_cmd, tok, load2, chunk))?;
            // Spread workers across tiers round-robin at startup.
            workers.push(WorkerHandle {
                tx: tx_cmd,
                load,
                join,
                tier: w % cfg.tiers.len(),
            });
        }
        // Barrier: wait until every worker's engine is compiled, so
        // latency measurements exclude startup (ServerlessLLM-style
        // startup optimization is out of scope; see DESIGN.md).
        loop {
            let ready = workers
                .iter()
                .filter(|w| w.load.ready.load(Ordering::Relaxed) == 1)
                .count();
            if ready == workers.len() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        Ok(LiveServer {
            cfg,
            workers,
            tx_tokens,
            rx_tokens,
            tracked: HashMap::new(),
            next_id: 0,
            start: Instant::now(),
        })
    }

    /// Submit a request: bin by TPOT, then place on the highest-load
    /// same-tier worker under a load cap, spilling to tighter tiers
    /// (lazy promotion) and finally to the globally least-loaded worker.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new_tokens: usize, slo: Slo) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let tier = self.cfg.tiers.bin_for_tpot(slo.tpot_ms);
        let req = LiveRequest {
            id,
            prompt: prompt.clone(),
            max_new_tokens,
            slo,
            tier,
        };
        let target = self.pick_worker(tier, prompt.len());
        self.tracked.insert(
            id,
            LiveOutcome {
                id,
                slo,
                submitted: Instant::now(),
                first_token: None,
                finished: None,
                tokens: 0,
                attained: true,
            },
        );
        let _ = self.workers[target].tx.send(WorkerCommand::Serve(req));
        id
    }

    fn pick_worker(&self, tier: usize, prompt_len: usize) -> usize {
        // Load cap: decode batch must stay under the engine's max batch
        // bucket with headroom for queued prefills.
        let score = |w: &WorkerHandle| {
            let batch = w.load.batch.load(Ordering::Relaxed);
            let queued = w.load.queued_prefill.load(Ordering::Relaxed);
            (batch, queued)
        };
        let feasible = |w: &WorkerHandle| {
            let (batch, queued) = score(w);
            batch + 1 < 8 && queued < 4 * prompt_len.max(256) as u64
        };
        // own tier, highest load first (load gradient);
        let mut same_tier: Vec<usize> = (0..self.workers.len())
            .filter(|&i| self.workers[i].tier == tier)
            .collect();
        same_tier.sort_by_key(|&i| std::cmp::Reverse(score(&self.workers[i])));
        if let Some(&i) = same_tier.iter().find(|&&i| feasible(&self.workers[i])) {
            return i;
        }
        // lazy promotion: tighter tiers;
        let mut tighter: Vec<usize> = (0..self.workers.len())
            .filter(|&i| self.workers[i].tier < tier)
            .collect();
        tighter.sort_by_key(|&i| std::cmp::Reverse(score(&self.workers[i])));
        if let Some(&i) = tighter.iter().find(|&&i| feasible(&self.workers[i])) {
            return i;
        }
        // fallback: least-loaded anywhere.
        (0..self.workers.len())
            .min_by_key(|&i| score(&self.workers[i]))
            .unwrap_or(0)
    }

    /// Wait for all submitted requests to finish; returns the report.
    pub fn finish(mut self) -> anyhow::Result<ServeReport> {
        let mut remaining: usize = self
            .tracked
            .values()
            .filter(|o| o.finished.is_none())
            .count();
        let mut total_tokens = 0u64;
        while remaining > 0 {
            let ev = self.rx_tokens.recv()?;
            total_tokens += 1;
            if let Some(out) = self.tracked.get_mut(&ev.request_id) {
                let deadline_ms = out.slo.deadline(0, ev.token_index);
                let elapsed_ms =
                    ev.at.duration_since(out.submitted).as_secs_f64() * 1000.0;
                if elapsed_ms > deadline_ms as f64 {
                    out.attained = false;
                }
                out.tokens = out.tokens.max(ev.token_index + 1);
                if ev.token_index == 0 {
                    out.first_token = Some(ev.at);
                }
                if ev.finished {
                    out.finished = Some(ev.at);
                    remaining -= 1;
                }
            }
        }
        for w in &self.workers {
            let _ = w.tx.send(WorkerCommand::Shutdown);
        }
        let mut iterations = 0;
        for w in self.workers.drain(..) {
            iterations += w.load.iterations.load(Ordering::Relaxed);
            match w.join.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => return Err(e),
                Err(_) => anyhow::bail!("worker panicked"),
            }
        }
        drop(self.tx_tokens);
        let mut outcomes: Vec<LiveOutcome> = self.tracked.into_values().collect();
        outcomes.sort_by_key(|o| o.id);
        Ok(ServeReport {
            outcomes,
            wall_s: self.start.elapsed().as_secs_f64(),
            total_tokens,
            iterations,
        })
    }
}
