//! The `polyserve serve` demo: a real serving run over the AOT model.
//!
//! Calibrates TPOT tiers to the measured decode floor of this machine
//! (the paper's tiers are H200-relative; CPU PJRT needs its own scale),
//! then serves a Poisson-arrival synthetic workload across N in-process
//! instances with the PolyServe-style leader and reports throughput,
//! latency percentiles and DSLO attainment.

use super::leader::{LiveServer, ServeConfig};
use crate::runtime::{ArtifactStore, Engine};
use crate::slo::{Slo, TierSet};
use crate::util::rng::Rng;
use std::fmt::Write as _;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

/// Measured per-iteration floors on this machine (ms).
#[derive(Debug, Clone, Copy)]
pub struct Floors {
    /// Decode step, batch = 1.
    pub decode_ms: f64,
    /// Decode step, batch = 4 (amortization probe).
    pub decode_b4_ms: f64,
    /// Prefill chunk of 128 tokens.
    pub prefill128_ms: f64,
}

/// Measure decode/prefill iteration floors (one engine load).
pub fn measure_floors(artifacts: &Path) -> anyhow::Result<Floors> {
    let store = Rc::new(ArtifactStore::open(artifacts)?);
    let engine = Engine::load(store)?;
    let prompt: Vec<i32> = (1..40).collect();

    let time_decode = |batch: usize| -> anyhow::Result<f64> {
        let mut kvs: Vec<_> = (0..batch)
            .map(|_| {
                let mut kv = engine.new_kv();
                engine.prefill(&mut kv, &prompt).map(|_| kv)
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        for _ in 0..3 {
            let mut refs: Vec<&mut _> = kvs.iter_mut().collect();
            engine.decode_step(&mut refs)?;
        }
        let iters = 10;
        let t0 = Instant::now();
        for _ in 0..iters {
            let mut refs: Vec<&mut _> = kvs.iter_mut().collect();
            engine.decode_step(&mut refs)?;
        }
        Ok(t0.elapsed().as_secs_f64() * 1000.0 / iters as f64)
    };
    let decode_ms = time_decode(1)?;
    let decode_b4_ms = time_decode(4)?;

    let chunk: Vec<i32> = (0..128).map(|i| (i % 500) as i32).collect();
    // warmup + timed prefill chunks on fresh caches
    let mut kv = engine.new_kv();
    engine.prefill_chunk(&mut kv, &chunk)?;
    let iters = 5;
    let t0 = Instant::now();
    for _ in 0..iters {
        let mut kv = engine.new_kv();
        engine.prefill_chunk(&mut kv, &chunk)?;
    }
    let prefill128_ms = t0.elapsed().as_secs_f64() * 1000.0 / iters as f64;
    Ok(Floors {
        decode_ms,
        decode_b4_ms,
        prefill128_ms,
    })
}

/// Run the full serving demo; returns a human-readable report.
pub fn run_demo(
    artifacts: &Path,
    instances: usize,
    requests: usize,
    rate_rps: f64,
) -> anyhow::Result<String> {
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let mut out = String::new();
    let floors = measure_floors(artifacts)?;
    let floor = floors.decode_ms;
    let _ = writeln!(
        out,
        "floors: decode {floor:.2} ms (b=1), {:.2} ms (b=4), prefill128 {:.2} ms",
        floors.decode_b4_ms, floors.prefill128_ms
    );

    // Two TPOT tiers at 6× and 14× the floor (room for batch growth),
    // TTFTs sized for chunked prefill of ~500-token prompts.
    let tight = (floor * 6.0).ceil() as u64;
    let loose = (floor * 14.0).ceil() as u64;
    let tiers = TierSet::new(vec![tight, loose]);
    let ttft = (floor * 120.0).ceil() as u64;
    let _ = writeln!(
        out,
        "SLO tiers: TPOT {{{tight}, {loose}}} ms, TTFT {ttft} ms; {instances} instances"
    );

    let mut server = LiveServer::start(ServeConfig {
        artifacts: artifacts.to_path_buf(),
        instances,
        chunk_tokens: 128,
        tiers: tiers.clone(),
    })?;

    // Auto-calibrate the arrival rate when requested (rate_rps <= 0):
    // per-request service time ≈ prefill chunks + decode tokens at the
    // batch-4 amortized iteration cost, targeting ~60% utilization.
    let avg_p = 104.0f64; // mean of range_u64(8, 200)
    let avg_d = 26.0f64; // mean of range_u64(4, 48)
    // CPU PJRT shows little decode-batch amortization (the KV staging
    // copies scale with the bucket — see EXPERIMENTS.md §Perf), so use
    // the measured batch-4 per-token cost directly and target modest
    // utilization to keep queues short.
    let per_req_ms =
        (avg_p / 128.0).ceil() * floors.prefill128_ms + avg_d * floors.decode_b4_ms / 4.0;
    let capacity_rps = instances as f64 * 1000.0 / per_req_ms;
    let rate_rps = if rate_rps > 0.0 {
        rate_rps
    } else {
        0.35 * capacity_rps
    };
    let _ = writeln!(
        out,
        "estimated capacity {capacity_rps:.2} req/s; offering {rate_rps:.2} req/s"
    );

    let mut rng = Rng::new(0xFEED);
    let vocab = 512u64;
    let mut submitted = 0usize;
    let t0 = Instant::now();
    let mut next_arrival = 0.0f64;
    while submitted < requests {
        // Poisson arrivals in real time.
        next_arrival += rng.exp(rate_rps);
        let now = t0.elapsed().as_secs_f64();
        if next_arrival > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(next_arrival - now));
        }
        let p_len = rng.range_u64(8, 200) as usize;
        let d_len = rng.range_u64(4, 48) as usize;
        let prompt: Vec<i32> = (0..p_len).map(|_| rng.below(vocab) as i32).collect();
        let tpot = if rng.chance(0.3) { tight } else { loose };
        server.submit(prompt, d_len, Slo::new(ttft, tpot));
        submitted += 1;
    }
    let report = server.finish()?;

    let _ = writeln!(
        out,
        "served {} requests / {} tokens in {:.2} s  ({:.2} req/s, {:.1} tok/s, {} iterations)",
        report.outcomes.len(),
        report.total_tokens,
        report.wall_s,
        report.throughput_rps(),
        report.token_throughput(),
        report.iterations,
    );
    let _ = writeln!(out, "DSLO attainment: {:.3}", report.attainment());
    if let Some(s) = report.ttft_ms() {
        let _ = writeln!(
            out,
            "TTFT ms: p50 {:.0}  p90 {:.0}  p99 {:.0}",
            s.p50(),
            s.percentiles[3],
            s.p99()
        );
    }
    if let Some(s) = report.mean_tpot_ms() {
        let _ = writeln!(
            out,
            "mean TPOT ms: p50 {:.1}  p90 {:.1}  p99 {:.1}",
            s.p50(),
            s.percentiles[3],
            s.p99()
        );
    }
    Ok(out)
}
