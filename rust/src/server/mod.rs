//! Live multi-instance serving loop — the end-to-end proof that all
//! three layers compose: Rust coordinator (this module) → AOT-compiled
//! JAX model (Layer 2) → Pallas kernels (Layer 1), executed through
//! PJRT with Python nowhere on the request path.
//!
//! Architecture (thread-per-instance, std channels — no async runtime
//! is available offline, and a worker is CPU-bound in PJRT anyway):
//!
//! ```text
//!  submit() ─→ leader (router thread)
//!                 │ bin by TPOT tier, profile-based admission,
//!                 │ highest-load-feasible placement (§4)
//!                 ▼
//!           worker 0..N  (each owns an Engine: PJRT client + buckets)
//!                 │ continuous batching: chunked prefill + batched
//!                 │ decode per iteration
//!                 ▼
//!           token events ─→ collector (DSLO accounting)
//! ```
//!
//! The PJRT `Engine` is not `Send` (raw C pointers), so each worker
//! constructs its own engine inside its thread; workers publish their
//! load (batch, KV tokens) through atomics the router reads.

pub mod demo;
pub mod worker;
pub mod leader;

pub use leader::{LiveServer, ServeConfig, ServeReport};
pub use worker::{TokenEvent, WorkerCommand, WorkerLoad};
