//! # PolyServe — Efficient Multi-SLO Serving at Scale
//!
//! A reproduction of the PolyServe paper (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: a multi-SLO
//!   request router with request binning per TPOT tier, load-gradient
//!   routing, lazy promotion, fine-grained auto-scaling, profile-based
//!   batch formation, wait-time-aware scheduling, dynamic chunking
//!   (PD-disaggregation) and continuous chunked-prefill prediction
//!   (co-location). Plus the discrete-event cluster simulator the paper
//!   evaluates on, and a real serving runtime executing AOT-compiled
//!   model artifacts through PJRT.
//! * **Layer 2 (python/compile/model.py)** — a LLaMA-style transformer
//!   (GQA + SwiGLU) decode/prefill step in JAX, lowered once to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for decode
//!   attention, prefill attention and the fused FFN, verified against
//!   pure-jnp oracles.
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! model once; the Rust binary is self-contained afterwards.
//!
//! See `ARCHITECTURE.md` at the repo root for the module map, the
//! simulator's event-loop lifecycle, and a comparison of the fleet
//! autoscalers (gradient / threshold / predictive).

#![warn(missing_docs)]

pub mod util;
pub mod slo;
pub mod model;
pub mod profile;
pub mod workload;
pub mod analysis;
pub mod sim;
pub mod coordinator;
pub mod runtime;
pub mod server;
pub mod config;
pub mod metrics;
pub mod figures;
