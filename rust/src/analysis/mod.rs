//! Closed-form analysis from §3 of the paper: batch-size limits
//! (Fig 2, Fig 3), serving-cost curves (Fig 4), the SLO achievability
//! test used when assigning SLOs to trace requests (§5.1), and the
//! optimal-goodput bound the evaluation normalizes against ("92.5% of
//! optimal").

use crate::model::CostModel;
use crate::slo::Slo;
use crate::workload::Workload;

/// One point of a Fig-2 style series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchPoint {
    /// TPOT budget of the point, ms.
    pub tpot_ms: f64,
    /// Max batch size meeting that budget.
    pub batch: u64,
}

/// Fig 2: max decode batch size vs TPOT for a (p, d) configuration
/// under PD-disaggregation.
pub fn fig2_decode_batch_series(
    cm: &CostModel,
    p: u64,
    d: u64,
    tpots_ms: &[f64],
) -> Vec<BatchPoint> {
    let kv_per_req = p + d / 2;
    tpots_ms
        .iter()
        .map(|&tpot| BatchPoint {
            tpot_ms: tpot,
            batch: cm.max_decode_batch(tpot, kv_per_req),
        })
        .collect()
}

/// Fig 3: max co-located token batch B vs TPOT for (p, d) and TTFT.
pub fn fig3_coloc_batch_series(
    cm: &CostModel,
    p: u64,
    d: u64,
    ttft_ms: f64,
    tpots_ms: &[f64],
) -> Vec<BatchPoint> {
    tpots_ms
        .iter()
        .map(|&tpot| BatchPoint {
            tpot_ms: tpot,
            batch: cm.max_coloc_batch(p, d, tpot, ttft_ms),
        })
        .collect()
}

/// One point of a Fig-4 style series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostPoint {
    /// TPOT budget of the point, ms.
    pub tpot_ms: f64,
    /// instance·seconds per request.
    pub cost_coloc_s: f64,
    /// PD-disaggregation instance·seconds per request.
    pub cost_pd_s: f64,
}

/// Fig 4: per-request cost vs TPOT for co-location (solid) and
/// PD-disaggregation (dashed) at a TTFT budget.
pub fn fig4_cost_series(
    cm: &CostModel,
    p: u64,
    d: u64,
    ttft_ms: f64,
    tpots_ms: &[f64],
) -> Vec<CostPoint> {
    tpots_ms
        .iter()
        .map(|&tpot| {
            let b_co = cm.max_coloc_batch(p, d, tpot, ttft_ms);
            let b_dc = cm.max_decode_batch(tpot, p + d / 2);
            let b_pf = cm.max_token_batch; // §3.4: prefill saturates
            CostPoint {
                tpot_ms: tpot,
                cost_coloc_s: cm.cost_coloc_ms(p, d, b_co) / 1000.0,
                cost_pd_s: cm.cost_pd_ms(p, d, b_pf, b_dc) / 1000.0,
            }
        })
        .collect()
}

/// §5.1 achievability: an SLO is assignable to a (p, d) request iff an
/// idle server could meet it — prefill under TTFT and a feasible decode
/// batch of at least 1 at the TPOT.
pub fn slo_achievable(cm: &CostModel, mode: ServingMode, p: u32, d: u32, slo: Slo) -> bool {
    if slo.is_best_effort() {
        return true;
    }
    let (p, d) = (p as u64, d as u64);
    match mode {
        ServingMode::PdDisaggregated => {
            // prefill on an idle prefill server, chunked at max batch:
            let chunks = p.div_ceil(cm.max_token_batch);
            let mut prefill_ms = 0.0;
            for c in 0..chunks {
                let chunk = (p - c * cm.max_token_batch).min(cm.max_token_batch);
                prefill_ms += cm.iter_ms_mixed(0, chunk, c * cm.max_token_batch + chunk);
            }
            if prefill_ms >= slo.ttft_ms as f64 {
                return false;
            }
            // decode: B=1 iteration time under TPOT at worst-case KV
            cm.iter_ms(1, p + d) < slo.tpot_ms as f64
        }
        ServingMode::Colocated => {
            cm.max_coloc_batch(p, d, slo.tpot_ms as f64, slo.ttft_ms as f64) >= 1
        }
    }
}

/// Which serving architecture (§2.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ServingMode {
    /// Separate prefill and decode clusters (§2.4).
    PdDisaggregated,
    /// Chunked-prefill co-location on every server.
    Colocated,
}

impl ServingMode {
    /// Config/CLI name of this serving mode (`pd` / `coloc`).
    pub fn name(&self) -> &'static str {
        match self {
            ServingMode::PdDisaggregated => "pd",
            ServingMode::Colocated => "coloc",
        }
    }
}

/// Optimal-goodput bound for a workload on `n_instances` (§3.5):
/// every request is served at its own maximal batch size, so the fleet
/// capacity is `n_instances / E[min-cost]`. Returns requests/s.
pub fn optimal_goodput_rps(
    cm: &CostModel,
    mode: ServingMode,
    workload: &Workload,
    n_instances: usize,
) -> f64 {
    if workload.is_empty() {
        return 0.0;
    }
    let mut total_cost_s = 0.0f64;
    for r in &workload.requests {
        total_cost_s += min_request_cost_s(cm, mode, r.prefill_len, r.decode_len, r.slo);
    }
    let mean_cost_s = total_cost_s / workload.len() as f64;
    n_instances as f64 / mean_cost_s
}

/// Minimal per-request cost (instance·s) at the request's own maximal
/// batch size (§3.5).
pub fn min_request_cost_s(cm: &CostModel, mode: ServingMode, p: u32, d: u32, slo: Slo) -> f64 {
    let (p, d) = (p as u64, d as u64);
    let tpot = (slo.tpot_ms as f64).min(10_000.0); // cap best-effort
    let ttft = (slo.ttft_ms as f64).min(120_000.0);
    match mode {
        ServingMode::PdDisaggregated => {
            let b_dc = cm.max_decode_batch(tpot, p + d / 2).max(1);
            cm.cost_pd_ms(p, d, cm.max_token_batch, b_dc) / 1000.0
        }
        ServingMode::Colocated => {
            let b = cm.max_coloc_batch(p, d, tpot, ttft).max(1);
            cm.cost_coloc_ms(p, d, b) / 1000.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slo::TierDistribution;
    use crate::util::rng::Rng;
    use crate::workload::{TraceGenerator, TraceKind};

    fn cm() -> CostModel {
        CostModel::h200_llama8b()
    }

    #[test]
    fn fig2_series_monotone_nondecreasing() {
        let s = fig2_decode_batch_series(&cm(), 1000, 4000, &[16.0, 20.0, 30.0, 40.0, 60.0, 100.0]);
        for w in s.windows(2) {
            assert!(w[1].batch >= w[0].batch, "{s:?}");
        }
        // anchor points
        let b20 = s.iter().find(|pt| pt.tpot_ms == 20.0).unwrap().batch;
        let b40 = s.iter().find(|pt| pt.tpot_ms == 40.0).unwrap().batch;
        assert!((45..=55).contains(&b20));
        assert!((140..=160).contains(&b40));
    }

    #[test]
    fn fig3_tighter_ttft_smaller_batch() {
        let tpots = [30.0, 50.0, 100.0];
        let tight = fig3_coloc_batch_series(&cm(), 4000, 1000, 300.0, &tpots);
        let loose = fig3_coloc_batch_series(&cm(), 4000, 1000, 2000.0, &tpots);
        for (a, b) in tight.iter().zip(&loose) {
            assert!(a.batch <= b.batch, "tight={a:?} loose={b:?}");
        }
    }

    #[test]
    fn fig4_costs_fall_with_tpot() {
        let s = fig4_cost_series(&cm(), 1000, 1000, 700.0, &[20.0, 30.0, 50.0, 100.0]);
        for w in s.windows(2) {
            assert!(w[1].cost_coloc_s <= w[0].cost_coloc_s + 1e-9);
            assert!(w[1].cost_pd_s <= w[0].cost_pd_s + 1e-9);
        }
    }

    #[test]
    fn fig4_long_sequences_favor_coloc() {
        // §3.5: "for long sequences, Co-location features lower cost."
        // Validated in the paper's implicit regime (non-binding KV
        // capacity, TTFT loose enough to be feasible) — see the cost
        // model tests and EXPERIMENTS.md.
        let s = fig4_cost_series(&cm().with_unbounded_kv(), 4000, 4000, 2000.0, &[100.0, 150.0]);
        for pt in &s {
            assert!(
                pt.cost_coloc_s < pt.cost_pd_s,
                "coloc {:.2} pd {:.2} @ {}",
                pt.cost_coloc_s,
                pt.cost_pd_s,
                pt.tpot_ms
            );
        }
    }

    #[test]
    fn achievability_rejects_impossible() {
        // 10 ms TPOT is below the 15 ms floor: unachievable.
        assert!(!slo_achievable(
            &cm(),
            ServingMode::PdDisaggregated,
            100,
            100,
            Slo::new(1000, 10)
        ));
        // 100 ms TPOT with small p: achievable.
        assert!(slo_achievable(
            &cm(),
            ServingMode::PdDisaggregated,
            100,
            100,
            Slo::new(1000, 100)
        ));
        // best effort always achievable.
        assert!(slo_achievable(
            &cm(),
            ServingMode::Colocated,
            1_000_000,
            1_000_000,
            Slo::BEST_EFFORT
        ));
    }

    #[test]
    fn achievability_huge_prompt_tight_ttft_fails() {
        // 80k-token prompt can't prefill in 300 ms.
        assert!(!slo_achievable(
            &cm(),
            ServingMode::PdDisaggregated,
            80_000,
            100,
            Slo::new(300, 100)
        ));
    }

    #[test]
    fn optimal_goodput_scales_with_instances() {
        let g = TraceGenerator::new(TraceKind::ShareGpt);
        let mut rng = Rng::new(2);
        let tiers = TierDistribution::paper_default();
        let w = g.generate(2000, 50.0, &tiers, |_, _, _| true, &mut rng);
        let g10 = optimal_goodput_rps(&cm(), ServingMode::PdDisaggregated, &w, 10);
        let g20 = optimal_goodput_rps(&cm(), ServingMode::PdDisaggregated, &w, 20);
        assert!((g20 / g10 - 2.0).abs() < 1e-9);
        assert!(g10 > 0.0);
    }

    #[test]
    fn min_cost_lower_for_looser_slo() {
        let c_tight = min_request_cost_s(&cm(), ServingMode::PdDisaggregated, 1000, 1000, Slo::new(500, 20));
        let c_loose = min_request_cost_s(&cm(), ServingMode::PdDisaggregated, 1000, 1000, Slo::new(500, 100));
        assert!(c_loose < c_tight, "loose={c_loose} tight={c_tight}");
    }
}
