//! Typed configuration for simulations, figure harnesses and the live
//! server. Parses the TOML subset (`util::tomlish`), applies the
//! paper's §5.1 defaults, and validates.

use crate::analysis::ServingMode;
use crate::slo::{TierDistribution, TierSet};
use crate::util::tomlish::{self, Doc};
use crate::workload::TraceKind;
use std::path::Path;

/// Scheduling policies under evaluation (§5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// The paper's router (§4).
    PolyServe,
    /// Uniform random placement.
    Random,
    /// "Assigning requests to the lowest cycle-time server".
    Minimal,
    /// Static chunked scheduler with a fixed token budget (co-location
    /// only); budget swept externally per the paper.
    Chunk,
}

impl Policy {
    /// Every policy, in §5.1 order.
    pub const ALL: [Policy; 4] = [Policy::PolyServe, Policy::Random, Policy::Minimal, Policy::Chunk];

    /// Config/CLI name of this policy.
    pub fn name(&self) -> &'static str {
        match self {
            Policy::PolyServe => "polyserve",
            Policy::Random => "random",
            Policy::Minimal => "minimal",
            Policy::Chunk => "chunk",
        }
    }

    /// Parse a config/CLI policy name.
    pub fn from_name(s: &str) -> Option<Policy> {
        Policy::ALL.iter().copied().find(|p| p.name() == s)
    }

    /// Display name combined with the serving mode, as the paper labels
    /// its curves (PD-PolyServe, CO-Chunk, ...).
    pub fn label(&self, mode: ServingMode) -> String {
        let prefix = match mode {
            ServingMode::PdDisaggregated => "PD",
            ServingMode::Colocated => "CO",
        };
        let name = match self {
            Policy::PolyServe => "PolyServe",
            Policy::Random => "Random",
            Policy::Minimal => "Minimal",
            Policy::Chunk => "Chunk",
        };
        format!("{prefix}-{name}")
    }
}

/// Which fleet autoscaler drives an elastic run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScalerKind {
    /// Fixed fleet (seed behaviour).
    Off,
    /// PolyServe §4.4 load-gradient fleet scaler.
    Gradient,
    /// Reactive utilization-threshold baseline.
    Threshold,
    /// Profile-driven predictive scaler: sizes the fleet for the
    /// arrival rate projected `provision_lead_ms` ahead.
    Predictive,
}

impl ScalerKind {
    /// Every scaler kind, in config-name order.
    pub const ALL: [ScalerKind; 4] = [
        ScalerKind::Off,
        ScalerKind::Gradient,
        ScalerKind::Threshold,
        ScalerKind::Predictive,
    ];

    /// Config/CLI name of this scaler.
    pub fn name(&self) -> &'static str {
        match self {
            ScalerKind::Off => "off",
            ScalerKind::Gradient => "gradient",
            ScalerKind::Threshold => "threshold",
            ScalerKind::Predictive => "predictive",
        }
    }

    /// Parse a config/CLI name.
    pub fn from_name(s: &str) -> Option<ScalerKind> {
        ScalerKind::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// Elastic-fleet knobs. `min`/`max` bound the *scalable* role — decode
/// servers under PD-disaggregation, coloc servers under co-location;
/// the PD prefill cluster stays static unless `prefill_elastic` gives
/// it bounds of its own.
#[derive(Debug, Clone, PartialEq)]
pub struct ElasticConfig {
    /// Which policy drives the fleet (`[elastic] scaler`, alias
    /// `policy`): off | gradient | threshold | predictive.
    pub scaler: ScalerKind,
    /// Never drain the scalable fleet below this.
    pub min_instances: usize,
    /// Never provision above this (active + cold-starting).
    pub max_instances: usize,
    /// Cold-start delay, provision → serving.
    pub provision_delay_ms: u64,
    /// Autoscaler evaluation period.
    pub scale_eval_ms: u64,
    /// Scale-in KV migration (`migration = "off"|"on"`): evict a
    /// drainer's decode residents to surviving servers instead of
    /// waiting for them to finish. `"off"` reproduces the wait-drain
    /// path bit-for-bit.
    pub migration: bool,
    /// Batch per-destination migration transfers
    /// (`migration_batching = "off"|"on"`): coalesce a drainer's
    /// same-destination KV streams into one bulk transfer whose arrival
    /// time is sized by total migrated KV, instead of one fixed-delay
    /// event per request. `"off"` reproduces per-request transfers
    /// bit-for-bit.
    pub migration_batching: bool,
    /// Predictive-scaler anticipation horizon: size the fleet for the
    /// rate projected this far ahead. `None` defaults to
    /// `provision_delay_ms` (capacity lands exactly when the projected
    /// rate does).
    pub provision_lead_ms: Option<u64>,
    /// Elastic PD prefill tier (`prefill_elastic = "off"|"on"`): let
    /// TTFT pressure provision/drain prefill servers too. `"off"`
    /// reproduces the static-prefill path bit-for-bit.
    pub prefill_elastic: bool,
    /// Never drain the prefill cluster below this (elastic prefill).
    pub prefill_min: usize,
    /// Never provision prefill above this (elastic prefill; must be
    /// set ≥ `prefill_min` when `prefill_elastic` is on).
    pub prefill_max: usize,
}

impl Default for ElasticConfig {
    fn default() -> ElasticConfig {
        ElasticConfig {
            scaler: ScalerKind::Off,
            min_instances: 1,
            max_instances: 0,
            provision_delay_ms: 15_000,
            scale_eval_ms: 1_000,
            migration: false,
            migration_batching: false,
            provision_lead_ms: None,
            prefill_elastic: false,
            prefill_min: 1,
            prefill_max: 0,
        }
    }
}

impl ElasticConfig {
    /// Elastic machinery engages only with a scaler selected *and* real
    /// headroom between some pair of bounds; `max == min` (with the
    /// prefill tier off or equally pinned) is exactly the static fleet
    /// (bit-for-bit the seed code path).
    pub fn enabled(&self) -> bool {
        self.scaler != ScalerKind::Off
            && (self.max_instances > self.min_instances
                || (self.prefill_elastic && self.prefill_max > self.prefill_min))
    }
}

/// Model-fleet knobs (`[models]`): which built-in models the fleet
/// serves and how requests split across them. `mix = [1.0]` (the
/// default) is the single-model configuration — model 0 only, with
/// every multi-model code path inert and decisions bit-for-bit
/// identical to the pre-registry simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelsConfig {
    /// Request-mix weights, one per model id in registry order
    /// (normalized internally). Length 1 = single-model default
    /// (LLaMA-3.1-8B); length 2 deploys the built-in pair
    /// (LLaMA-3.1-8B + Qwen2.5-32B).
    pub mix: Vec<f64>,
    /// Weight-reload delay a server pays to swap its loaded model
    /// (drain first, then this, then cold-start provisioning).
    pub swap_delay_ms: u64,
}

impl Default for ModelsConfig {
    fn default() -> ModelsConfig {
        ModelsConfig {
            mix: vec![1.0],
            swap_delay_ms: 20_000,
        }
    }
}

impl ModelsConfig {
    /// True when the config deploys more than one model.
    pub fn is_multi(&self) -> bool {
        self.mix.len() > 1
    }
}

/// Fault-injection / spot-market knobs (`[chaos]`): seeded MTBF
/// processes for hard kills and spot preemptions, plus the spot class
/// assignment and its discounted price. All-off by default — then the
/// simulator constructs no chaos machinery at all and the run is
/// bit-for-bit the chaos-free path. Explicit `(t_ms, instance)`
/// kill/preempt lists are a test/bench-level feature of the
/// simulator's `ChaosParams`, not expressible from a config file.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    /// Mean time between random instance hard-kills, seconds
    /// (exponential inter-arrival over the live fleet). 0 = off.
    pub fail_mtbf_s: f64,
    /// Mean time between random spot-preemption notices, seconds
    /// (over active spot instances). 0 = off; requires
    /// `spot_fraction > 0` to have any target.
    pub preempt_mtbf_s: f64,
    /// Grace window between a preemption notice and its hard deadline
    /// kill, ms.
    pub preempt_grace_ms: u64,
    /// Fraction of elastically provisioned instances assigned to the
    /// spot class (the initial fleet is always on-demand). 0 = none.
    pub spot_fraction: f64,
    /// Spot price as a fraction of the on-demand rate (discounted-bill
    /// reporting only; the attainment math never sees it).
    pub spot_price_frac: f64,
    /// Failure-domain zones the fleet is striped across. 0 = no domain
    /// model (every instance in zone 0, rack 0; correlated kills
    /// unavailable).
    pub zones: u32,
    /// Racks per zone (the inner stripe). Only meaningful with
    /// `zones > 0`; must be >= 1 then.
    pub racks_per_zone: u32,
    /// Mean time between correlated domain kills, seconds: each draw
    /// picks a zone (and usually a rack inside it) and hard-kills every
    /// live instance in that blast radius at once. 0 = off; requires
    /// `zones > 0`.
    pub domain_fail_mtbf_s: f64,
    /// KV checkpoint period, ms: snapshot every resident request's
    /// committed prefill watermark so an `InstanceFail` rewinds to the
    /// last checkpoint instead of zero (suffix-only re-prefill).
    /// Snapshots bill a transfer cost per delta token. 0 = off.
    pub checkpoint_period_ms: u64,
    /// Stepwise spot price curve: flattened `(t_s, price_frac)` pairs,
    /// times strictly increasing. Before the first step the flat
    /// `spot_price_frac` applies; empty = flat pricing only
    /// (bit-for-bit the single-step default).
    pub spot_price_schedule: Vec<f64>,
    /// Stepwise spot availability curve: flattened `(t_s, multiplier)`
    /// pairs scaling the preempt-MTBF gap (multiplier < 1 = scarcer
    /// spot capacity, preemptions come faster). Empty = off.
    pub spot_avail_schedule: Vec<f64>,
    /// Chaos-adaptive provisioning: the predictive scaler reads
    /// `ChaosStats` online, pads the plan by the observed kill rate and
    /// forces the spot split on-demand when realized churn makes the
    /// discounted bill worse than on-demand.
    pub adaptive: bool,
    /// Seed of the chaos RNG stream (independent of the workload seed).
    pub seed: u64,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            fail_mtbf_s: 0.0,
            preempt_mtbf_s: 0.0,
            preempt_grace_ms: 30_000,
            spot_fraction: 0.0,
            spot_price_frac: 0.3,
            zones: 0,
            racks_per_zone: 1,
            domain_fail_mtbf_s: 0.0,
            checkpoint_period_ms: 0,
            spot_price_schedule: Vec::new(),
            spot_avail_schedule: Vec::new(),
            adaptive: false,
            seed: 0xC1A05,
        }
    }
}

impl ChaosConfig {
    /// Does this config inject anything? `false` keeps the simulator's
    /// chaos machinery entirely unconstructed (the seed path). Domain
    /// striping (`zones`) alone does not enable chaos — it only labels
    /// instances; something must inject or checkpoint.
    pub fn enabled(&self) -> bool {
        self.fail_mtbf_s > 0.0
            || self.preempt_mtbf_s > 0.0
            || self.spot_fraction > 0.0
            || self.domain_fail_mtbf_s > 0.0
            || self.checkpoint_period_ms > 0
    }
}

/// Overload-handling knobs (`[overload]`): graceful degradation past
/// saturation. With `enabled = "on"` the PolyServe router orders its
/// per-(model, tier) pending queues by absolute deadline (EDF) instead
/// of FIFO; `reject` adds SLO-feasibility admission control at the
/// arrival edge (provably unattainable requests get a typed `Rejected`
/// outcome instead of blowing out every tier's tail), and `retry` lets
/// rejected clients re-arrive after a capped exponential backoff with
/// seeded jitter. All-off by default — then the simulator constructs no
/// overload machinery and the run is bit-for-bit the seed path.
#[derive(Debug, Clone, PartialEq)]
pub struct OverloadConfig {
    /// Master switch (`enabled = "off"|"on"`): EDF pending queues plus
    /// whatever sub-features are selected below.
    pub enabled: bool,
    /// Early rejection at the arrival edge (`reject = "off"|"on"`):
    /// requests whose SLO is infeasible against the profile table are
    /// rejected instead of queued.
    pub reject: bool,
    /// Retry-with-backoff clients (`retry = "off"|"on"`): rejected
    /// requests re-arrive through the event queue after
    /// `retry_base_ms * 2^(attempt-1)` plus seeded jitter.
    pub retry: bool,
    /// Backoff base for the first retry, ms.
    pub retry_base_ms: u64,
    /// Give up (final `Rejected` outcome) after this many retries.
    pub retry_max_attempts: u32,
    /// Client-side deadline propagation (`propagate_deadline =
    /// "off"|"on"`): a retry re-arrives with the *remaining*
    /// end-to-end budget — its SLO clock stays anchored at the original
    /// arrival instead of resetting at the retry. Default off
    /// (digest-pinned to the PR 9 reset-clock behavior).
    pub propagate_deadline: bool,
    /// Seed of the retry-jitter RNG stream (independent of the
    /// workload and chaos seeds).
    pub seed: u64,
    /// Runtime reference mode (not a TOML knob): keep the pending
    /// queues FIFO even with overload on — the pre-EDF engine, used by
    /// the digest-identity harness and the bench's fifo policy axis.
    pub fifo_reference: bool,
}

impl Default for OverloadConfig {
    fn default() -> OverloadConfig {
        OverloadConfig {
            enabled: false,
            reject: false,
            retry: false,
            retry_base_ms: 500,
            retry_max_attempts: 3,
            propagate_deadline: false,
            seed: 0x0E71,
            fifo_reference: false,
        }
    }
}

impl OverloadConfig {
    /// Does this config engage any overload handling? `false` keeps the
    /// simulator's overload machinery entirely unconstructed (the seed
    /// path) and the router's queues FIFO.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// EDF pending-queue ordering is active (overload on and not
    /// pinned to the FIFO reference engine).
    pub fn edf(&self) -> bool {
        self.enabled && !self.fifo_reference
    }
}

/// Diurnal demand-curve spec: when set, arrivals follow a sinusoid-
/// approximating piecewise `RateSchedule` with this peak:trough ratio
/// and period, instead of constant-rate Poisson.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiurnalSpec {
    /// Peak rate over trough rate (≥ 1).
    pub peak_to_trough: f64,
    /// Diurnal period, seconds.
    pub period_s: f64,
}

/// Full simulation/experiment configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Workload trace the generator samples lengths from.
    pub trace: TraceKind,
    /// Routing policy under test.
    pub policy: Policy,
    /// Serving architecture (PD-disaggregated / co-located).
    pub mode: ServingMode,
    /// Initial fleet size.
    pub instances: usize,
    /// Number of requests to simulate.
    pub requests: usize,
    /// Request rate as a fraction of the optimal-goodput bound (§5.2
    /// varies 20%–120% of optimal); `rate_rps` overrides if set.
    pub rate_frac_of_optimal: f64,
    /// Absolute request rate, req/s (overrides `rate_frac_of_optimal`).
    pub rate_rps: Option<f64>,
    /// RNG seed for workload generation and stochastic policies.
    pub seed: u64,
    /// TPOT tier set requests are binned into.
    pub tiers: TierSet,
    /// Distribution SLOs are sampled from (§5.1).
    pub tier_dist: TierDistribution,
    /// CO-Chunk static token budget (paper sweeps this; default 512).
    pub chunk_budget: u64,
    /// For PD mode: fraction of instances dedicated to prefill.
    /// `0.0` = auto-size from the workload's prefill/decode work ratio
    /// (computed by `figures::Experiment::prepare`).
    pub prefill_frac: f64,
    /// Router feature toggles (ablations).
    pub features: Features,
    /// Elastic-fleet knobs (default: fixed fleet).
    pub elastic: ElasticConfig,
    /// Model-fleet knobs (default: single model).
    pub models: ModelsConfig,
    /// Diurnal demand curve (default: constant-rate Poisson).
    pub diurnal: Option<DiurnalSpec>,
    /// Fault-injection / spot knobs (default: fully off).
    pub chaos: ChaosConfig,
    /// Overload-handling knobs (default: fully off).
    pub overload: OverloadConfig,
}

/// PolyServe mechanism toggles — each maps to a §4 subsection, and the
/// ablation bench flips them individually.
#[derive(Debug, Clone, PartialEq)]
pub struct Features {
    /// §4.3: route to highest-load SLO-attainable server (off = least-loaded).
    pub load_gradient: bool,
    /// §4.4: lazy promotion into tighter tiers (off = no promotion).
    pub lazy_promotion: bool,
    /// off + lazy_promotion=true is invalid; eager promotion variant:
    pub eager_promotion: bool,
    /// §4.6: include wait-for-current-iteration in admission estimates.
    pub wait_time_aware: bool,
    /// §4.7 PD: merge a short final chunk into the prior iteration.
    pub dynamic_chunking: bool,
    /// §4.7 CO: admit only if the chunk size can be maintained
    /// throughout the prefill as KV grows.
    pub continuous_chunk_prediction: bool,
}

impl Default for Features {
    fn default() -> Features {
        Features {
            load_gradient: true,
            lazy_promotion: true,
            eager_promotion: false,
            wait_time_aware: true,
            dynamic_chunking: true,
            continuous_chunk_prediction: true,
        }
    }
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            trace: TraceKind::ShareGpt,
            policy: Policy::PolyServe,
            mode: ServingMode::PdDisaggregated,
            instances: 20,
            requests: 30_000,
            rate_frac_of_optimal: 0.8,
            rate_rps: None,
            seed: 0xD15C0,
            tiers: TierSet::paper_default(),
            tier_dist: TierDistribution::paper_default(),
            chunk_budget: 512,
            prefill_frac: 0.0, // auto
            features: Features::default(),
            elastic: ElasticConfig::default(),
            models: ModelsConfig::default(),
            diurnal: None,
            chaos: ChaosConfig::default(),
            overload: OverloadConfig::default(),
        }
    }
}

impl SimConfig {
    /// Parse from a TOML-subset file; unspecified keys keep defaults.
    pub fn from_file(path: &Path) -> anyhow::Result<SimConfig> {
        let text = std::fs::read_to_string(path)?;
        let doc = tomlish::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        SimConfig::from_doc(&doc)
    }

    /// Parse from an already-parsed TOML-subset document.
    pub fn from_doc(doc: &Doc) -> anyhow::Result<SimConfig> {
        let mut cfg = SimConfig::default();
        if let Some(v) = doc.get("trace") {
            let name = v.as_str().ok_or_else(|| anyhow::anyhow!("trace must be a string"))?;
            cfg.trace = TraceKind::from_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown trace '{name}'"))?;
        }
        if let Some(v) = doc.get("policy") {
            let name = v.as_str().ok_or_else(|| anyhow::anyhow!("policy must be a string"))?;
            cfg.policy = Policy::from_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown policy '{name}'"))?;
        }
        match doc.str_or("mode", "pd") {
            "pd" => cfg.mode = ServingMode::PdDisaggregated,
            "coloc" => cfg.mode = ServingMode::Colocated,
            other => anyhow::bail!("unknown mode '{other}' (pd|coloc)"),
        }
        cfg.instances = doc.usize_or("cluster.instances", cfg.instances);
        cfg.requests = doc.usize_or("requests", cfg.requests);
        cfg.rate_frac_of_optimal = doc.f64_or("rate_frac_of_optimal", cfg.rate_frac_of_optimal);
        if let Some(v) = doc.get("rate_rps") {
            cfg.rate_rps = v.as_f64();
        }
        cfg.seed = doc.f64_or("seed", cfg.seed as f64) as u64;
        cfg.chunk_budget = doc.usize_or("chunk_budget", cfg.chunk_budget as usize) as u64;
        cfg.prefill_frac = doc.f64_or("cluster.prefill_frac", cfg.prefill_frac);
        if let Some(v) = doc.get("slo.tpot_ms") {
            let tpots: Vec<u64> = v
                .to_f64s()
                .ok_or_else(|| anyhow::anyhow!("slo.tpot_ms must be an array"))?
                .into_iter()
                .map(|x| x as u64)
                .collect();
            cfg.tiers = TierSet::new(tpots.clone());
            cfg.tier_dist.tpot_choices_ms = tpots;
        }
        if let Some(v) = doc.get("slo.tpot_weights") {
            cfg.tier_dist.tpot_weights = v
                .to_f64s()
                .ok_or_else(|| anyhow::anyhow!("slo.tpot_weights must be an array"))?;
        }
        if let Some(v) = doc.get("slo.ttft_ms") {
            cfg.tier_dist.ttft_choices_ms = v
                .to_f64s()
                .ok_or_else(|| anyhow::anyhow!("slo.ttft_ms must be an array"))?
                .into_iter()
                .map(|x| x as u64)
                .collect();
        }
        // `elastic.scaler`, with `elastic.policy` as an accepted alias.
        for key in ["elastic.scaler", "elastic.policy"] {
            if let Some(v) = doc.get(key) {
                let name = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("{key} must be a string"))?;
                cfg.elastic.scaler = ScalerKind::from_name(name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown scaler '{name}' (off|gradient|threshold|predictive)"
                    )
                })?;
            }
        }
        cfg.elastic.min_instances =
            doc.usize_or("elastic.min_instances", cfg.elastic.min_instances);
        cfg.elastic.max_instances =
            doc.usize_or("elastic.max_instances", cfg.elastic.max_instances);
        cfg.elastic.provision_delay_ms =
            doc.usize_or("elastic.provision_delay_ms", cfg.elastic.provision_delay_ms as usize)
                as u64;
        cfg.elastic.scale_eval_ms =
            doc.usize_or("elastic.scale_eval_ms", cfg.elastic.scale_eval_ms as usize) as u64;
        if let Some(v) = doc.get("elastic.provision_lead_ms") {
            cfg.elastic.provision_lead_ms = Some(
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("elastic.provision_lead_ms must be a number"))?
                    as u64,
            );
        }
        if let Some(v) = doc.get("elastic.migration") {
            cfg.elastic.migration = match (v.as_str(), v.as_bool()) {
                (Some("on"), _) => true,
                (Some("off"), _) => false,
                (None, Some(b)) => b,
                (Some(other), _) => {
                    anyhow::bail!("unknown elastic.migration '{other}' (off|on)")
                }
                _ => anyhow::bail!("elastic.migration must be \"off\"|\"on\""),
            };
        }
        if let Some(v) = doc.get("elastic.prefill_elastic") {
            cfg.elastic.prefill_elastic = match (v.as_str(), v.as_bool()) {
                (Some("on"), _) => true,
                (Some("off"), _) => false,
                (None, Some(b)) => b,
                (Some(other), _) => {
                    anyhow::bail!("unknown elastic.prefill_elastic '{other}' (off|on)")
                }
                _ => anyhow::bail!("elastic.prefill_elastic must be \"off\"|\"on\""),
            };
        }
        if let Some(v) = doc.get("elastic.migration_batching") {
            cfg.elastic.migration_batching = match (v.as_str(), v.as_bool()) {
                (Some("on"), _) => true,
                (Some("off"), _) => false,
                (None, Some(b)) => b,
                (Some(other), _) => {
                    anyhow::bail!("unknown elastic.migration_batching '{other}' (off|on)")
                }
                _ => anyhow::bail!("elastic.migration_batching must be \"off\"|\"on\""),
            };
        }
        cfg.elastic.prefill_min = doc.usize_or("elastic.prefill_min", cfg.elastic.prefill_min);
        cfg.elastic.prefill_max = doc.usize_or("elastic.prefill_max", cfg.elastic.prefill_max);
        if let Some(v) = doc.get("models.mix") {
            cfg.models.mix = v
                .to_f64s()
                .ok_or_else(|| anyhow::anyhow!("models.mix must be an array of weights"))?;
        }
        cfg.models.swap_delay_ms =
            doc.usize_or("models.swap_delay_ms", cfg.models.swap_delay_ms as usize) as u64;
        if let Some(v) = doc.get("diurnal.peak_to_trough") {
            let ratio = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("diurnal.peak_to_trough must be a number"))?;
            cfg.diurnal = Some(DiurnalSpec {
                peak_to_trough: ratio,
                period_s: doc.f64_or("diurnal.period_s", 600.0),
            });
        }
        let ch = &mut cfg.chaos;
        ch.fail_mtbf_s = doc.f64_or("chaos.fail_mtbf_s", ch.fail_mtbf_s);
        ch.preempt_mtbf_s = doc.f64_or("chaos.preempt_mtbf_s", ch.preempt_mtbf_s);
        ch.preempt_grace_ms =
            doc.usize_or("chaos.preempt_grace_ms", ch.preempt_grace_ms as usize) as u64;
        ch.spot_fraction = doc.f64_or("chaos.spot_fraction", ch.spot_fraction);
        ch.spot_price_frac = doc.f64_or("chaos.spot_price_frac", ch.spot_price_frac);
        ch.zones = doc.usize_or("chaos.zones", ch.zones as usize) as u32;
        ch.racks_per_zone = doc.usize_or("chaos.racks_per_zone", ch.racks_per_zone as usize) as u32;
        ch.domain_fail_mtbf_s = doc.f64_or("chaos.domain_fail_mtbf_s", ch.domain_fail_mtbf_s);
        ch.checkpoint_period_ms =
            doc.usize_or("chaos.checkpoint_period_ms", ch.checkpoint_period_ms as usize) as u64;
        if let Some(v) = doc.get("chaos.spot_price_schedule") {
            ch.spot_price_schedule = v.to_f64s().ok_or_else(|| {
                anyhow::anyhow!("chaos.spot_price_schedule must be an array of (t_s, frac) pairs")
            })?;
        }
        if let Some(v) = doc.get("chaos.spot_avail_schedule") {
            ch.spot_avail_schedule = v.to_f64s().ok_or_else(|| {
                anyhow::anyhow!("chaos.spot_avail_schedule must be an array of (t_s, mult) pairs")
            })?;
        }
        if let Some(v) = doc.get("chaos.adaptive") {
            ch.adaptive = match (v.as_str(), v.as_bool()) {
                (Some("on"), _) => true,
                (Some("off"), _) => false,
                (None, Some(b)) => b,
                (Some(other), _) => anyhow::bail!("unknown chaos.adaptive '{other}' (off|on)"),
                _ => anyhow::bail!("chaos.adaptive must be \"off\"|\"on\""),
            };
        }
        ch.seed = doc.f64_or("chaos.seed", ch.seed as f64) as u64;
        let ol = &mut cfg.overload;
        for (key, field) in [
            ("overload.enabled", 0usize),
            ("overload.reject", 1),
            ("overload.retry", 2),
            ("overload.propagate_deadline", 3),
        ] {
            if let Some(v) = doc.get(key) {
                let on = match (v.as_str(), v.as_bool()) {
                    (Some("on"), _) => true,
                    (Some("off"), _) => false,
                    (None, Some(b)) => b,
                    (Some(other), _) => anyhow::bail!("unknown {key} '{other}' (off|on)"),
                    _ => anyhow::bail!("{key} must be \"off\"|\"on\""),
                };
                match field {
                    0 => ol.enabled = on,
                    1 => ol.reject = on,
                    2 => ol.retry = on,
                    _ => ol.propagate_deadline = on,
                }
            }
        }
        ol.retry_base_ms =
            doc.usize_or("overload.retry_base_ms", ol.retry_base_ms as usize) as u64;
        ol.retry_max_attempts =
            doc.usize_or("overload.retry_max_attempts", ol.retry_max_attempts as usize) as u32;
        ol.seed = doc.f64_or("overload.seed", ol.seed as f64) as u64;
        let f = &mut cfg.features;
        f.load_gradient = doc.bool_or("features.load_gradient", f.load_gradient);
        f.lazy_promotion = doc.bool_or("features.lazy_promotion", f.lazy_promotion);
        f.eager_promotion = doc.bool_or("features.eager_promotion", f.eager_promotion);
        f.wait_time_aware = doc.bool_or("features.wait_time_aware", f.wait_time_aware);
        f.dynamic_chunking = doc.bool_or("features.dynamic_chunking", f.dynamic_chunking);
        f.continuous_chunk_prediction = doc.bool_or(
            "features.continuous_chunk_prediction",
            f.continuous_chunk_prediction,
        );
        cfg.validate()?;
        Ok(cfg)
    }

    /// Check cross-field invariants; every construction path calls this.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.instances >= 1, "need at least one instance");
        anyhow::ensure!(self.requests >= 1, "need at least one request");
        anyhow::ensure!(
            self.tier_dist.tpot_weights.len() == self.tier_dist.tpot_choices_ms.len(),
            "tpot_weights and tpot_ms length mismatch"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.prefill_frac),
            "prefill_frac must be in [0,1]"
        );
        anyhow::ensure!(
            !(self.features.lazy_promotion && self.features.eager_promotion),
            "lazy_promotion and eager_promotion are mutually exclusive"
        );
        if self.elastic.scaler != ScalerKind::Off {
            // `max == min` (> 0) is the documented static pin; an unset
            // max with a scaler selected would silently run a fixed
            // fleet, so reject it loudly.
            anyhow::ensure!(
                self.elastic.max_instances >= 1,
                "elastic.max_instances must be set (>= 1) when a scaler is selected \
                 (use max == min to pin a static fleet)"
            );
            anyhow::ensure!(
                self.elastic.min_instances >= 1,
                "elastic.min_instances must be >= 1"
            );
            anyhow::ensure!(
                self.elastic.max_instances >= self.elastic.min_instances,
                "elastic.max_instances must be >= elastic.min_instances"
            );
            anyhow::ensure!(self.elastic.scale_eval_ms >= 1, "elastic.scale_eval_ms must be >= 1");
            if self.elastic.prefill_elastic {
                // The PD router needs at least one active prefill
                // server, and an unset prefill_max with the feature on
                // would silently pin the tier — reject loudly, like the
                // primary bounds.
                anyhow::ensure!(
                    self.elastic.prefill_min >= 1,
                    "elastic.prefill_min must be >= 1 when prefill_elastic is on"
                );
                anyhow::ensure!(
                    self.elastic.prefill_max >= self.elastic.prefill_min,
                    "elastic.prefill_max must be set >= elastic.prefill_min when \
                     prefill_elastic is on (use max == min to pin the prefill tier)"
                );
            }
        }
        anyhow::ensure!(
            !self.models.mix.is_empty(),
            "models.mix must list at least one weight"
        );
        anyhow::ensure!(
            self.models.mix.iter().all(|w| w.is_finite() && *w > 0.0),
            "models.mix weights must be positive"
        );
        if self.models.is_multi() {
            anyhow::ensure!(
                self.instances >= self.models.mix.len(),
                "multi-model fleets need at least one instance per model"
            );
        }
        if let Some(d) = &self.diurnal {
            anyhow::ensure!(d.peak_to_trough >= 1.0, "diurnal.peak_to_trough must be >= 1");
            anyhow::ensure!(d.period_s > 0.0, "diurnal.period_s must be positive");
        }
        let ch = &self.chaos;
        anyhow::ensure!(
            ch.fail_mtbf_s.is_finite() && ch.fail_mtbf_s >= 0.0,
            "chaos.fail_mtbf_s must be >= 0"
        );
        anyhow::ensure!(
            ch.preempt_mtbf_s.is_finite() && ch.preempt_mtbf_s >= 0.0,
            "chaos.preempt_mtbf_s must be >= 0"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&ch.spot_fraction),
            "chaos.spot_fraction must be in [0,1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&ch.spot_price_frac),
            "chaos.spot_price_frac must be in [0,1]"
        );
        if ch.preempt_mtbf_s > 0.0 {
            // Notices only ever target spot instances, and spot
            // instances only exist among *elastic* provisions — either
            // omission would make the process a silent no-op.
            anyhow::ensure!(
                ch.spot_fraction > 0.0,
                "chaos.preempt_mtbf_s needs chaos.spot_fraction > 0 (notices target spot \
                 instances)"
            );
            anyhow::ensure!(
                ch.preempt_grace_ms >= 1,
                "chaos.preempt_grace_ms must be >= 1 when preemptions are on"
            );
        }
        anyhow::ensure!(
            ch.domain_fail_mtbf_s.is_finite() && ch.domain_fail_mtbf_s >= 0.0,
            "chaos.domain_fail_mtbf_s must be >= 0"
        );
        if ch.domain_fail_mtbf_s > 0.0 {
            anyhow::ensure!(
                ch.zones > 0,
                "chaos.domain_fail_mtbf_s needs chaos.zones > 0 (domain kills need a domain \
                 model)"
            );
        }
        if ch.zones > 0 {
            anyhow::ensure!(
                ch.racks_per_zone >= 1,
                "chaos.racks_per_zone must be >= 1 when chaos.zones > 0"
            );
        }
        if ch.adaptive {
            anyhow::ensure!(
                ch.enabled(),
                "chaos.adaptive needs some chaos injection enabled (nothing to adapt to)"
            );
        }
        for (name, sched, lo_ok) in [
            ("spot_price_schedule", &ch.spot_price_schedule, false),
            ("spot_avail_schedule", &ch.spot_avail_schedule, true),
        ] {
            if sched.is_empty() {
                continue;
            }
            anyhow::ensure!(
                ch.spot_fraction > 0.0,
                "chaos.{name} needs chaos.spot_fraction > 0 (no spot instances to price)"
            );
            anyhow::ensure!(
                sched.len() % 2 == 0,
                "chaos.{name} must be flattened (t_s, value) pairs (even length)"
            );
            let mut prev_t = f64::NEG_INFINITY;
            for pair in sched.chunks(2) {
                let (t, v) = (pair[0], pair[1]);
                anyhow::ensure!(
                    t.is_finite() && t >= 0.0 && t > prev_t,
                    "chaos.{name} times must be >= 0 and strictly increasing"
                );
                prev_t = t;
                if lo_ok {
                    anyhow::ensure!(
                        v.is_finite() && v > 0.0,
                        "chaos.{name} multipliers must be > 0"
                    );
                } else {
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&v),
                        "chaos.{name} prices must be in [0,1]"
                    );
                }
            }
        }
        let ol = &self.overload;
        if ol.retry {
            anyhow::ensure!(
                ol.enabled && ol.reject,
                "overload.retry needs overload.enabled and overload.reject (only rejected \
                 requests retry)"
            );
            anyhow::ensure!(
                ol.retry_base_ms >= 1,
                "overload.retry_base_ms must be >= 1 when retries are on"
            );
            anyhow::ensure!(
                ol.retry_max_attempts >= 1,
                "overload.retry_max_attempts must be >= 1 when retries are on"
            );
        }
        if ol.reject {
            anyhow::ensure!(
                ol.enabled,
                "overload.reject needs overload.enabled = \"on\""
            );
        }
        if ol.propagate_deadline {
            anyhow::ensure!(
                ol.retry,
                "overload.propagate_deadline needs overload.retry (only retries re-arrive)"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_defaults() {
        let c = SimConfig::default();
        assert_eq!(c.instances, 20);
        assert_eq!(c.tiers.tpots(), &[20, 30, 50, 100]);
        assert_eq!(c.tier_dist.tpot_weights, vec![0.1, 0.2, 0.3, 0.4]);
        c.validate().unwrap();
    }

    #[test]
    fn parses_full_document() {
        let doc = tomlish::parse(
            r#"
trace = "lmsys"
policy = "chunk"
mode = "coloc"
requests = 1000
chunk_budget = 1024

[cluster]
instances = 8
prefill_frac = 0.5

[slo]
tpot_ms = [25, 75]
tpot_weights = [0.5, 0.5]
ttft_ms = [400]

[features]
lazy_promotion = false
"#,
        )
        .unwrap();
        let c = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(c.trace, TraceKind::Lmsys);
        assert_eq!(c.policy, Policy::Chunk);
        assert_eq!(c.mode, ServingMode::Colocated);
        assert_eq!(c.instances, 8);
        assert_eq!(c.chunk_budget, 1024);
        assert_eq!(c.tiers.tpots(), &[25, 75]);
        assert_eq!(c.tier_dist.ttft_choices_ms, vec![400]);
        assert!(!c.features.lazy_promotion);
    }

    #[test]
    fn parses_elastic_and_diurnal() {
        let doc = tomlish::parse(
            r#"
[elastic]
scaler = "gradient"
min_instances = 4
max_instances = 32
provision_delay_ms = 30000
scale_eval_ms = 2000
migration = "on"

[diurnal]
peak_to_trough = 3.0
period_s = 900.0
"#,
        )
        .unwrap();
        let c = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(c.elastic.scaler, ScalerKind::Gradient);
        assert_eq!(c.elastic.min_instances, 4);
        assert_eq!(c.elastic.max_instances, 32);
        assert_eq!(c.elastic.provision_delay_ms, 30_000);
        assert_eq!(c.elastic.scale_eval_ms, 2_000);
        assert!(c.elastic.migration);
        assert!(c.elastic.enabled());
        // New knobs keep their defaults when unspecified.
        assert_eq!(c.elastic.provision_lead_ms, None);
        assert!(!c.elastic.prefill_elastic);
        let d = c.diurnal.unwrap();
        assert_eq!(d.peak_to_trough, 3.0);
        assert_eq!(d.period_s, 900.0);
    }

    #[test]
    fn parses_predictive_policy_and_elastic_prefill() {
        // `policy` is an accepted alias for `scaler` (the predictive
        // feature's documented spelling).
        let doc = tomlish::parse(
            r#"
[elastic]
policy = "predictive"
min_instances = 4
max_instances = 32
provision_lead_ms = 20000
prefill_elastic = "on"
prefill_min = 2
prefill_max = 8
"#,
        )
        .unwrap();
        let c = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(c.elastic.scaler, ScalerKind::Predictive);
        assert_eq!(c.elastic.provision_lead_ms, Some(20_000));
        assert!(c.elastic.prefill_elastic);
        assert_eq!(c.elastic.prefill_min, 2);
        assert_eq!(c.elastic.prefill_max, 8);
        assert!(c.elastic.enabled());
    }

    #[test]
    fn parses_models_and_migration_batching() {
        let doc = tomlish::parse(
            r#"
[elastic]
scaler = "gradient"
min_instances = 2
max_instances = 16
migration_batching = "on"

[models]
mix = [0.7, 0.3]
swap_delay_ms = 5000
"#,
        )
        .unwrap();
        let c = SimConfig::from_doc(&doc).unwrap();
        assert!(c.elastic.migration_batching);
        assert_eq!(c.models.mix, vec![0.7, 0.3]);
        assert_eq!(c.models.swap_delay_ms, 5_000);
        assert!(c.models.is_multi());
        // Defaults: one model, per-request transfers — the bit-identical path.
        let d = SimConfig::default();
        assert!(!d.models.is_multi());
        assert!(!d.elastic.migration_batching);
        d.validate().unwrap();
    }

    #[test]
    fn prefill_bounds_alone_enable_elastic() {
        // A pinned decode fleet with an elastic prefill tier still
        // engages the elastic machinery.
        let mut c = SimConfig::default();
        c.elastic.scaler = ScalerKind::Predictive;
        c.elastic.min_instances = 8;
        c.elastic.max_instances = 8;
        assert!(!c.elastic.enabled());
        c.elastic.prefill_elastic = true;
        c.elastic.prefill_min = 2;
        c.elastic.prefill_max = 6;
        assert!(c.elastic.enabled());
        c.validate().unwrap();
    }

    #[test]
    fn static_bounds_disable_elastic() {
        // max == min is *the* static-fleet config: the elastic machinery
        // must stay off so results are bit-for-bit the fixed-fleet path.
        let mut c = SimConfig::default();
        c.elastic.scaler = ScalerKind::Gradient;
        c.elastic.min_instances = 8;
        c.elastic.max_instances = 8;
        assert!(!c.elastic.enabled());
        c.elastic.max_instances = 9;
        assert!(c.elastic.enabled());
        c.elastic.scaler = ScalerKind::Off;
        assert!(!c.elastic.enabled());
    }

    #[test]
    fn rejects_bad_values() {
        for bad in [
            "trace = \"nope\"",
            "policy = \"nope\"",
            "mode = \"nope\"",
            "[slo]\ntpot_ms = [20]\ntpot_weights = [0.5, 0.5]",
            "[features]\nlazy_promotion = true\neager_promotion = true",
            "[elastic]\nscaler = \"nope\"",
            "[elastic]\nscaler = \"gradient\"\nmin_instances = 0\nmax_instances = 4",
            "[elastic]\nscaler = \"gradient\"", // max unset → silent no-op, reject
            "[elastic]\nscaler = \"gradient\"\nmin_instances = 12\nmax_instances = 8",
            "[elastic]\nmigration = \"nope\"",
            "[elastic]\npolicy = \"nope\"",
            "[elastic]\nprefill_elastic = \"nope\"",
            // prefill_elastic on without prefill_max → silent pin, reject.
            "[elastic]\nscaler = \"predictive\"\nmin_instances = 2\nmax_instances = 8\nprefill_elastic = \"on\"",
            "[elastic]\nscaler = \"predictive\"\nmin_instances = 2\nmax_instances = 8\nprefill_elastic = \"on\"\nprefill_min = 0\nprefill_max = 4",
            "[diurnal]\npeak_to_trough = 0.5",
            "[elastic]\nmigration_batching = \"nope\"",
            // Empty or non-positive mixes stay rejected; any length of
            // positive weights is accepted (N-model registries).
            "[models]\nmix = []",
            "[models]\nmix = [1.0, 0.0]",
            "[models]\nmix = [0.5, -0.5, 1.0]",
            "[chaos]\nfail_mtbf_s = -1.0",
            "[chaos]\nspot_fraction = 1.5",
            "[chaos]\nspot_price_frac = -0.1",
            // Preemptions without spot capacity would be a silent no-op.
            "[chaos]\npreempt_mtbf_s = 60.0",
            "[chaos]\npreempt_mtbf_s = 60.0\nspot_fraction = 0.5\npreempt_grace_ms = 0",
            // Overload sub-features without the master switch (or retry
            // without reject) would be silent no-ops — reject loudly.
            "[overload]\nreject = \"on\"",
            "[overload]\nenabled = \"on\"\nretry = \"on\"",
            "[overload]\nenabled = \"on\"\nreject = \"on\"\nretry = \"on\"\nretry_base_ms = 0",
            "[overload]\nenabled = \"on\"\nreject = \"on\"\nretry = \"on\"\nretry_max_attempts = 0",
            "[overload]\nenabled = \"nope\"",
            // Domain kills without a domain model (or a zoned fleet
            // with no racks) would be silent no-ops — reject loudly.
            "[chaos]\ndomain_fail_mtbf_s = 60.0",
            "[chaos]\nzones = 3\nracks_per_zone = 0",
            "[chaos]\ndomain_fail_mtbf_s = -1.0",
            // Adaptive provisioning with nothing injected has nothing
            // to adapt to; schedules need spot capacity and sane shape.
            "[chaos]\nadaptive = \"on\"",
            "[chaos]\nspot_price_schedule = [0.0, 0.5]",
            "[chaos]\nspot_fraction = 0.5\nspot_price_schedule = [0.0, 0.5, 10.0]",
            "[chaos]\nspot_fraction = 0.5\nspot_price_schedule = [10.0, 0.5, 10.0, 0.6]",
            "[chaos]\nspot_fraction = 0.5\nspot_price_schedule = [0.0, 1.5]",
            "[chaos]\nspot_fraction = 0.5\nspot_avail_schedule = [0.0, 0.0]",
            // Deadline propagation without retries never fires.
            "[overload]\nenabled = \"on\"\npropagate_deadline = \"on\"",
        ] {
            let doc = tomlish::parse(bad).unwrap();
            assert!(SimConfig::from_doc(&doc).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn parses_chaos() {
        let doc = tomlish::parse(
            r#"
[chaos]
fail_mtbf_s = 120.0
preempt_mtbf_s = 90.0
preempt_grace_ms = 5000
spot_fraction = 0.5
spot_price_frac = 0.25
zones = 3
racks_per_zone = 4
domain_fail_mtbf_s = 45.0
checkpoint_period_ms = 2000
spot_price_schedule = [0.0, 0.25, 60.0, 0.8]
spot_avail_schedule = [0.0, 1.0, 30.0, 0.5]
adaptive = "on"
seed = 7
"#,
        )
        .unwrap();
        let c = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(c.chaos.fail_mtbf_s, 120.0);
        assert_eq!(c.chaos.preempt_mtbf_s, 90.0);
        assert_eq!(c.chaos.preempt_grace_ms, 5_000);
        assert_eq!(c.chaos.spot_fraction, 0.5);
        assert_eq!(c.chaos.spot_price_frac, 0.25);
        assert_eq!(c.chaos.zones, 3);
        assert_eq!(c.chaos.racks_per_zone, 4);
        assert_eq!(c.chaos.domain_fail_mtbf_s, 45.0);
        assert_eq!(c.chaos.checkpoint_period_ms, 2_000);
        assert_eq!(c.chaos.spot_price_schedule, vec![0.0, 0.25, 60.0, 0.8]);
        assert_eq!(c.chaos.spot_avail_schedule, vec![0.0, 1.0, 30.0, 0.5]);
        assert!(c.chaos.adaptive);
        assert_eq!(c.chaos.seed, 7);
        assert!(c.chaos.enabled());
        // Default: fully off — the chaos-free seed path.
        let d = SimConfig::default();
        assert!(!d.chaos.enabled());
        d.validate().unwrap();
        // Zone striping alone only labels instances — nothing injects,
        // so the chaos machinery must stay unconstructed.
        let mut z = SimConfig::default();
        z.chaos.zones = 4;
        assert!(!z.chaos.enabled());
        z.validate().unwrap();
        // Checkpointing alone does enable (snapshots cost something
        // even if nothing ever fails).
        let mut k = SimConfig::default();
        k.chaos.checkpoint_period_ms = 1_000;
        assert!(k.chaos.enabled());
        k.validate().unwrap();
    }

    #[test]
    fn parses_overload() {
        let doc = tomlish::parse(
            r#"
[overload]
enabled = "on"
reject = "on"
retry = "on"
retry_base_ms = 250
retry_max_attempts = 5
propagate_deadline = "on"
seed = 11
"#,
        )
        .unwrap();
        let c = SimConfig::from_doc(&doc).unwrap();
        assert!(c.overload.enabled());
        assert!(c.overload.edf());
        assert!(c.overload.reject);
        assert!(c.overload.retry);
        assert_eq!(c.overload.retry_base_ms, 250);
        assert_eq!(c.overload.retry_max_attempts, 5);
        assert!(c.overload.propagate_deadline);
        assert_eq!(c.overload.seed, 11);
        // Default: fully off — the overload-free seed path.
        let d = SimConfig::default();
        assert!(!d.overload.enabled());
        assert!(!d.overload.edf());
        d.validate().unwrap();
        // The FIFO reference pin disables EDF but keeps overload on.
        let mut f = SimConfig::default();
        f.overload.enabled = true;
        f.overload.fifo_reference = true;
        assert!(f.overload.enabled());
        assert!(!f.overload.edf());
        f.validate().unwrap();
    }

    #[test]
    fn accepts_n_model_mixes() {
        // The PR-9 satellite: any positive-weight list is valid — the
        // registry derives variants past the built-in pair.
        let doc = tomlish::parse("[models]\nmix = [0.5, 0.3, 0.2]").unwrap();
        let c = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(c.models.mix.len(), 3);
        assert!(c.models.is_multi());
    }

    #[test]
    fn policy_labels() {
        assert_eq!(Policy::PolyServe.label(ServingMode::PdDisaggregated), "PD-PolyServe");
        assert_eq!(Policy::Chunk.label(ServingMode::Colocated), "CO-Chunk");
    }
}
