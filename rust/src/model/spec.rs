//! Transformer architecture specs.

/// Architecture description of a decoder-only transformer with GQA and
/// SwiGLU FFN (the LLaMA/Qwen family shape the paper targets).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Human-readable model name.
    pub name: String,
    /// Transformer layer count.
    pub num_layers: usize,
    /// Residual-stream width.
    pub hidden: usize,
    /// Query heads.
    pub num_q_heads: usize,
    /// KV heads (GQA).
    pub num_kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// FFN inner width (SwiGLU).
    pub ffn_hidden: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Bytes per parameter / KV element (2 = bf16).
    pub bytes_per_elem: usize,
}

impl ModelSpec {
    /// LLaMA-3.1-8B — the model the paper profiles on an H200 (§5.1).
    pub fn llama31_8b() -> ModelSpec {
        ModelSpec {
            name: "llama3.1-8b".into(),
            num_layers: 32,
            hidden: 4096,
            num_q_heads: 32,
            num_kv_heads: 8,
            head_dim: 128,
            ffn_hidden: 14336,
            vocab: 128_256,
            bytes_per_elem: 2,
        }
    }

    /// Qwen2.5-32B — the larger GQA config of the built-in multi-model
    /// registry (64 layers × 5120 hidden, 40 query / 8 KV heads,
    /// SwiGLU FFN 27648, 152k vocab). Same architecture family as the
    /// 8B anchor but ~4× the weights and 2× the per-token KV bytes, so
    /// its cost profile ([`crate::model::CostModel::h200_qwen32b`]) is
    /// meaningfully distinct — the point of a model-mix fleet.
    pub fn qwen25_32b() -> ModelSpec {
        ModelSpec {
            name: "qwen2.5-32b".into(),
            num_layers: 64,
            hidden: 5120,
            num_q_heads: 40,
            num_kv_heads: 8,
            head_dim: 128,
            ffn_hidden: 27648,
            vocab: 152_064,
            bytes_per_elem: 2,
        }
    }

    /// The small serving model compiled to HLO for the real PJRT path
    /// (examples/, rust/src/server). Dimensionally faithful — GQA 4:2,
    /// SwiGLU, RoPE — but sized to run a decode step in ~ms on CPU.
    /// Must match `python/compile/model.py::SMALL_CONFIG`.
    pub fn small_serving() -> ModelSpec {
        ModelSpec {
            name: "polyserve-small".into(),
            num_layers: 4,
            hidden: 256,
            num_q_heads: 4,
            num_kv_heads: 2,
            head_dim: 64,
            ffn_hidden: 688,
            vocab: 512,
            bytes_per_elem: 4, // f32 on CPU PJRT
        }
    }

    /// Parameter count (embeddings + layers + head; untied head).
    pub fn param_count(&self) -> u64 {
        let h = self.hidden as u64;
        let qd = (self.num_q_heads * self.head_dim) as u64;
        let kvd = (self.num_kv_heads * self.head_dim) as u64;
        let f = self.ffn_hidden as u64;
        let per_layer = h * qd          // Wq
            + h * kvd                    // Wk
            + h * kvd                    // Wv
            + qd * h                     // Wo
            + h * f * 2                  // gate + up
            + f * h                      // down
            + 2 * h; // two RMSNorm gains
        let v = self.vocab as u64;
        v * h                            // embedding
            + self.num_layers as u64 * per_layer
            + h                          // final norm
            + h * v // lm head
    }

    /// Weight bytes.
    pub fn weight_bytes(&self) -> u64 {
        self.param_count() * self.bytes_per_elem as u64
    }

    /// KV-cache bytes per token (all layers, K+V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.num_layers * self.num_kv_heads * self.head_dim * self.bytes_per_elem) as u64
    }

    /// FLOPs for one forward pass over `n_tokens` new tokens, ignoring
    /// attention score FLOPs (counted separately since they scale with
    /// context length).
    pub fn gemm_flops_per_token(&self) -> u64 {
        // 2 FLOPs per MAC; weight GEMMs only.
        2 * (self.param_count() - (self.vocab * self.hidden) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama8b_params_about_8b() {
        let m = ModelSpec::llama31_8b();
        let p = m.param_count() as f64;
        assert!(
            (7.5e9..8.6e9).contains(&p),
            "param count {p:.3e} should be ~8B"
        );
    }

    #[test]
    fn llama8b_kv_bytes_match_design_doc() {
        // DESIGN.md §3: ≈131 kB/token.
        let m = ModelSpec::llama31_8b();
        assert_eq!(m.kv_bytes_per_token(), 131_072);
    }

    #[test]
    fn llama8b_weight_bytes_about_16gb() {
        let m = ModelSpec::llama31_8b();
        let gb = m.weight_bytes() as f64 / 1e9;
        assert!((15.0..17.5).contains(&gb), "weights {gb:.1} GB");
    }

    #[test]
    fn qwen32b_params_about_32b() {
        let m = ModelSpec::qwen25_32b();
        let p = m.param_count() as f64;
        assert!(
            (31.0e9..34.0e9).contains(&p),
            "param count {p:.3e} should be ~32B"
        );
    }

    #[test]
    fn qwen32b_kv_bytes_double_llama8b() {
        // 64 layers vs 32, same 8 KV heads × 128 head-dim → 2× per token.
        assert_eq!(
            ModelSpec::qwen25_32b().kv_bytes_per_token(),
            2 * ModelSpec::llama31_8b().kv_bytes_per_token()
        );
    }

    #[test]
    fn small_model_is_small() {
        let m = ModelSpec::small_serving();
        let p = m.param_count();
        assert!(p < 10_000_000, "small model has {p} params");
        assert!(m.kv_bytes_per_token() > 0);
    }
}
