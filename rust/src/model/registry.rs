//! Model registry: the `ModelId`-keyed catalog of everything the fleet
//! can serve.
//!
//! Production multi-SLO fleets serve several models with distinct cost
//! profiles on one pool (cf. PolarisLLM). The registry bundles, per
//! model, the architecture spec, the H200-calibrated [`CostModel`] the
//! simulator executes, and the sampled [`ProfileTable`] the router and
//! autoscalers consult — so "which model" becomes a first-class
//! placement axis next to the SLO tier.
//!
//! `ModelId` is a dense index into the registry (model 0 is always the
//! single-model default), which lets the cluster keep flat
//! `model × tier` index arrays instead of hash maps on the hot path.

use crate::model::{CostModel, ModelSpec};
use crate::profile::ProfileTable;

/// Dense identifier of a model in the [`ModelRegistry`] (0-based).
/// Model 0 is the default: single-model configurations never mention
/// any other id, which is what keeps them bit-for-bit identical to the
/// pre-registry code paths.
pub type ModelId = usize;

/// One registered model: spec + execution cost model + profiling table.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// Architecture description (layer count, GQA shape, …).
    pub spec: ModelSpec,
    /// Ground-truth hardware cost model the simulator executes.
    pub cost_model: CostModel,
    /// Sampled profiling table the scheduler consults (§3: the router
    /// only ever sees the table, never the analytic model).
    pub profile: ProfileTable,
}

impl ModelEntry {
    /// Build an entry from a spec + cost model, sampling the profile
    /// table from the cost model.
    pub fn new(spec: ModelSpec, cost_model: CostModel) -> ModelEntry {
        let profile = ProfileTable::from_cost_model(&cost_model);
        ModelEntry {
            spec,
            cost_model,
            profile,
        }
    }
}

/// The fleet's model catalog, indexed by [`ModelId`].
#[derive(Debug, Clone)]
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
}

impl ModelRegistry {
    /// Registry with exactly one model — the single-model default that
    /// every pre-registry configuration maps onto.
    pub fn single(spec: ModelSpec, cost_model: CostModel) -> ModelRegistry {
        ModelRegistry {
            entries: vec![ModelEntry::new(spec, cost_model)],
        }
    }

    /// The single-model default registry: LLaMA-3.1-8B on H200, the
    /// calibration the paper profiles.
    pub fn default_single() -> ModelRegistry {
        ModelRegistry::single(ModelSpec::llama31_8b(), CostModel::h200_llama8b())
    }

    /// The built-in two-model fleet: model 0 = LLaMA-3.1-8B (the
    /// paper's anchor), model 1 = Qwen2.5-32B (larger GQA config with
    /// a distinct — ~4× slower, KV-tighter — profile).
    pub fn builtin_pair() -> ModelRegistry {
        ModelRegistry {
            entries: vec![
                ModelEntry::new(ModelSpec::llama31_8b(), CostModel::h200_llama8b()),
                ModelEntry::new(ModelSpec::qwen25_32b(), CostModel::h200_qwen32b()),
            ],
        }
    }

    /// A registry of `n ≥ 1` models cycling the two built-in
    /// calibrations (even ids = LLaMA-3.1-8B, odd ids = Qwen2.5-32B)
    /// with distinct display names — the N-model mix fleet.
    /// `builtin(1)`/`builtin(2)` are exactly [`Self::default_single`] /
    /// [`Self::builtin_pair`], so existing mixes resolve unchanged.
    pub fn builtin(n: usize) -> ModelRegistry {
        assert!(n >= 1, "a fleet serves at least one model");
        match n {
            1 => ModelRegistry::default_single(),
            2 => ModelRegistry::builtin_pair(),
            _ => ModelRegistry {
                entries: (0..n)
                    .map(|i| {
                        let mut e = if i % 2 == 0 {
                            ModelEntry::new(ModelSpec::llama31_8b(), CostModel::h200_llama8b())
                        } else {
                            ModelEntry::new(ModelSpec::qwen25_32b(), CostModel::h200_qwen32b())
                        };
                        if i >= 2 {
                            // Cycled replicas are distinct deployments
                            // of the same architecture: distinct names,
                            // identical calibration.
                            e.spec.name = format!("{}-v{}", e.spec.name, i / 2 + 1);
                        }
                        e
                    })
                    .collect(),
            },
        }
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the registry holds no models (never the case for the
    /// built-in constructors; exists for `len`/`is_empty` symmetry).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// True when more than one model is registered — the switch that
    /// activates the multi-model code paths.
    pub fn is_multi(&self) -> bool {
        self.entries.len() > 1
    }

    /// The entry for `model`. Panics on an unregistered id — model ids
    /// are dense and validated at config time.
    pub fn entry(&self, model: ModelId) -> &ModelEntry {
        &self.entries[model]
    }

    /// All entries in id order.
    pub fn entries(&self) -> &[ModelEntry] {
        &self.entries
    }

    /// Per-model cost models in id order (cloned — the simulator owns
    /// its copy).
    pub fn cost_models(&self) -> Vec<CostModel> {
        self.entries.iter().map(|e| e.cost_model.clone()).collect()
    }

    /// Per-model profile tables in id order (cloned — routers and
    /// autoscalers own their copies).
    pub fn profiles(&self) -> Vec<ProfileTable> {
        self.entries.iter().map(|e| e.profile.clone()).collect()
    }

    /// Per-model `(kv_capacity_tokens, max_token_batch)` instance caps
    /// in id order — what [`crate::sim::Cluster::build_models`] needs
    /// to size each instance for the model it loads.
    pub fn instance_caps(&self) -> Vec<(u64, u64)> {
        self.entries
            .iter()
            .map(|e| (e.cost_model.kv_capacity_tokens, e.cost_model.max_token_batch))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_pair_has_distinct_profiles() {
        let reg = ModelRegistry::builtin_pair();
        assert_eq!(reg.len(), 2);
        assert!(reg.is_multi());
        assert_ne!(reg.entry(0).cost_model, reg.entry(1).cost_model);
        assert_ne!(reg.entry(0).spec.name, reg.entry(1).spec.name);
        let caps = reg.instance_caps();
        assert!(caps[1].0 < caps[0].0, "32B model has tighter KV: {caps:?}");
    }

    #[test]
    fn builtin_n_cycles_the_pair_with_distinct_names() {
        assert_eq!(ModelRegistry::builtin(1).len(), 1);
        assert_eq!(
            ModelRegistry::builtin(2).entry(1).spec.name,
            ModelRegistry::builtin_pair().entry(1).spec.name
        );
        let reg = ModelRegistry::builtin(5);
        assert_eq!(reg.len(), 5);
        // Calibration cycles, names don't collide.
        assert_eq!(reg.entry(0).cost_model, reg.entry(2).cost_model);
        assert_eq!(reg.entry(1).cost_model, reg.entry(3).cost_model);
        let mut names: Vec<&str> =
            reg.entries().iter().map(|e| e.spec.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 5, "model names must be distinct");
        assert_eq!(reg.instance_caps().len(), 5);
    }

    #[test]
    fn single_default_is_the_paper_anchor() {
        let reg = ModelRegistry::default_single();
        assert_eq!(reg.len(), 1);
        assert!(!reg.is_multi());
        assert_eq!(reg.entry(0).cost_model, CostModel::h200_llama8b());
        assert_eq!(reg.cost_models().len(), 1);
        assert_eq!(reg.profiles().len(), 1);
    }
}
