//! H200-calibrated analytic iteration-time model.
//!
//! The paper's simulator replays vLLM kernel profiling from an H200
//! serving LLaMA-3.1-8B; we cannot profile that hardware, so this module
//! provides an analytic surrogate calibrated to every number the paper
//! publishes (see DESIGN.md §3 for the derivation):
//!
//! ```text
//! iter_ms(B_dc, B_pf, KV) = t_fixed
//!                         + max(t_weight, c_dc·B_dc + c_pf·B_pf)
//!                         + c_attn · KV
//! ```
//!
//! * `B_dc` — decode tokens in the batch (= decode requests; each incurs
//!   per-request work: sampling, KV paging, launch bookkeeping).
//! * `B_pf` — prefill-chunk tokens (a single request's contiguous chunk
//!   amortizes per-request work, so its per-token GEMM coefficient is the
//!   compute-bound rate — 4× cheaper than a decode token's effective rate).
//! * `KV`   — KV-cache tokens read by attention this iteration.
//! * `t_fixed`  — launch/collective overhead per iteration.
//! * `t_weight` — weight-load floor (GEMMs are memory-bound until the
//!   token term exceeds it — the "batching effect" of §2.2).
//! * `c_attn`   — per-KV-token attention cost; prefill attention is
//!   modeled as decode attention at equal KV footprint (§3.4).
//!
//! Calibration anchors (paper §3.6/§5.1): 15 ms min per-token latency at
//! B=1; Fig 2's (p,d)=(1000,4000) points B≈50 @ 20 ms and B≈150 @ 40 ms;
//! H200 KV capacity ≈ 900k tokens for 8B bf16; prefill rate ≈ 30k tok/s
//! (2048-token chunk in ≈ 73 ms, the vLLM chunked-prefill ballpark).
//!
//! Everything downstream (simulator, scheduler, analysis) consumes this
//! through either the closed-form methods here or a sampled
//! [`crate::profile::ProfileTable`] — the scheduler only ever sees the
//! table, mirroring the paper's profiling-driven design.

/// Analytic cost model parameters. Times in ms, sizes in tokens.
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// Per-iteration fixed overhead (launch, collectives, sampling).
    pub t_fixed_ms: f64,
    /// Weight-load floor for the GEMM bundle.
    pub t_weight_ms: f64,
    /// Incremental GEMM cost per *decode* batch token once compute-bound.
    pub c_gemm_ms_per_token: f64,
    /// Incremental GEMM cost per *prefill-chunk* token (compute-bound).
    pub c_gemm_prefill_ms_per_token: f64,
    /// Attention cost per KV token resident in the batch.
    pub c_attn_ms_per_kv_token: f64,
    /// KV-cache capacity in tokens (the paper's `C`).
    pub kv_capacity_tokens: u64,
    /// Max GEMM token batch per iteration (prefill saturation, §3.4:
    /// "prefill batch size can easily reach 2048").
    pub max_token_batch: u64,
}

impl CostModel {
    /// The H200 / LLaMA-3.1-8B calibration from DESIGN.md §3.
    pub fn h200_llama8b() -> CostModel {
        CostModel {
            t_fixed_ms: 5.0,
            t_weight_ms: 10.0,
            c_gemm_ms_per_token: 0.1333,
            c_gemm_prefill_ms_per_token: 0.0333,
            c_attn_ms_per_kv_token: 3.333e-5,
            kv_capacity_tokens: 900_000,
            max_token_batch: 2048,
        }
    }

    /// An H200 calibration for the larger Qwen2.5-32B GQA config
    /// ([`crate::model::ModelSpec::qwen25_32b`]) — the second built-in
    /// model of the multi-model registry. Scaled from the 8B anchor by
    /// first principles rather than re-profiled: ~4× the weight bytes
    /// (64 layers × 5120 hidden vs 32 × 4096) pushes the weight-load
    /// floor and per-token GEMM cost up ~4×, the per-layer KV read cost
    /// doubles with layer count (same 8 KV heads × 128 head-dim per
    /// layer), and the KV pool shrinks to roughly what an H200 has left
    /// after 32B bf16 weights (~64 GB), ~256k tokens at 256 KiB/token.
    pub fn h200_qwen32b() -> CostModel {
        CostModel {
            t_fixed_ms: 8.0,
            t_weight_ms: 40.0,
            c_gemm_ms_per_token: 0.5333,
            c_gemm_prefill_ms_per_token: 0.1333,
            c_attn_ms_per_kv_token: 6.667e-5,
            kv_capacity_tokens: 256_000,
            max_token_batch: 2048,
        }
    }

    /// Variant with the KV-capacity constraint lifted — the regime the
    /// paper's Fig 3/4 plots implicitly assume (its co-location batch
    /// sizes exceed any single-GPU KV capacity; see EXPERIMENTS.md).
    pub fn with_unbounded_kv(&self) -> CostModel {
        CostModel {
            kv_capacity_tokens: u64::MAX / 4,
            ..self.clone()
        }
    }

    /// Effective decode-equivalent token count of a mixed batch — the
    /// single "batch size" axis of the profiling table.
    #[inline]
    pub fn effective_tokens(&self, b_dc: u64, b_pf: u64) -> f64 {
        b_dc as f64
            + b_pf as f64 * (self.c_gemm_prefill_ms_per_token / self.c_gemm_ms_per_token)
    }

    /// GEMM bundle time for a decode-token batch of `b` (paper's GEMM(B)).
    #[inline]
    pub fn gemm_ms(&self, b: u64) -> f64 {
        self.t_weight_ms.max(self.c_gemm_ms_per_token * b as f64)
    }

    /// GEMM bundle time for a mixed decode/prefill batch.
    #[inline]
    pub fn gemm_ms_mixed(&self, b_dc: u64, b_pf: u64) -> f64 {
        self.t_weight_ms.max(
            self.c_gemm_ms_per_token * b_dc as f64
                + self.c_gemm_prefill_ms_per_token * b_pf as f64,
        )
    }

    /// GEMM time for a pure prefill chunk of `b` tokens.
    #[inline]
    pub fn gemm_prefill_ms(&self, b: u64) -> f64 {
        self.t_weight_ms
            .max(self.c_gemm_prefill_ms_per_token * b as f64)
    }

    /// Decode-attention time for `kv_tokens` total resident KV
    /// (the paper's DcAttn(·)).
    #[inline]
    pub fn dc_attn_ms(&self, kv_tokens: u64) -> f64 {
        self.c_attn_ms_per_kv_token * kv_tokens as f64
    }

    /// Prefill-attention time. §3.4: "its execution time is comparable
    /// to decode attention with the same existing KV-cache length", so
    /// we reuse the same coefficient.
    #[inline]
    pub fn pf_attn_ms(&self, kv_tokens: u64) -> f64 {
        self.dc_attn_ms(kv_tokens)
    }

    /// Iteration time for a decode-only batch `b` with `kv_tokens`
    /// resident.
    #[inline]
    pub fn iter_ms(&self, b: u64, kv_tokens: u64) -> f64 {
        if b == 0 {
            return 0.0;
        }
        self.t_fixed_ms + self.gemm_ms(b) + self.dc_attn_ms(kv_tokens)
    }

    /// Iteration time for a mixed batch.
    #[inline]
    pub fn iter_ms_mixed(&self, b_dc: u64, b_pf: u64, kv_tokens: u64) -> f64 {
        if b_dc == 0 && b_pf == 0 {
            return 0.0;
        }
        self.t_fixed_ms + self.gemm_ms_mixed(b_dc, b_pf) + self.dc_attn_ms(kv_tokens)
    }

    /// Iteration time rounded up to the simulator's 1 ms resolution.
    #[inline]
    pub fn iter_ms_quantized(&self, b: u64, kv_tokens: u64) -> u64 {
        self.iter_ms(b, kv_tokens).ceil() as u64
    }

    /// The decode batch size at which GEMM transitions from weight-bound
    /// to compute-bound (the knee of the batching-effect curve).
    pub fn gemm_knee(&self) -> u64 {
        (self.t_weight_ms / self.c_gemm_ms_per_token).ceil() as u64
    }

    /// Largest decode batch size meeting `tpot_ms` for PD-disaggregation
    /// with per-request KV footprint `kv_per_req` tokens (§3.4:
    /// GEMM(B) + DcAttn(B·(p + d/2)) < TPOT and B·(p + d/2) < C).
    /// Returns 0 if even B=1 misses.
    pub fn max_decode_batch(&self, tpot_ms: f64, kv_per_req: u64) -> u64 {
        let mut lo = 0u64;
        let mut hi = self.max_token_batch.max(4096);
        // KV capacity bound
        if kv_per_req > 0 {
            hi = hi.min(self.kv_capacity_tokens / kv_per_req);
        }
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            let t = self.iter_ms(mid, mid.saturating_mul(kv_per_req));
            if t < tpot_ms {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }

    /// Largest co-located token batch `B` meeting both TPOT and TTFT for
    /// a (p, d) workload (§3.4 co-location derivation):
    ///
    /// * decode: `iter(B_dc, B_pf, d/(p+d)·B·(p+d/2) + p) < TPOT`
    ///   with `B_dc = d/(p+d)·B`, `B_pf = p/(p+d)·B`
    /// * prefill: `N_iter · iter = (p+d)/B · iter < TTFT`
    /// * memory: `d/(p+d)·B·(p+d/2) + p < C`
    pub fn max_coloc_batch(&self, p: u64, d: u64, tpot_ms: f64, ttft_ms: f64) -> u64 {
        let pd = (p + d) as f64;
        let split = |b: u64| -> (u64, u64) {
            let b_dc = (d as f64 / pd * b as f64).round() as u64;
            (b_dc, b - b_dc.min(b))
        };
        let kv_of = |b: u64| -> u64 {
            let (b_dc, _) = split(b);
            (b_dc as f64 * (p as f64 + d as f64 / 2.0)) as u64 + p
        };
        // TPOT + memory predicate is monotone in B; binary search it,
        // then verify TTFT by scanning down (TTFT improves with larger
        // B, so violations at the top mean total infeasibility — but we
        // scan defensively for robustness near the boundary).
        let tpot_ok = |b: u64| -> bool {
            let kv = kv_of(b);
            if kv >= self.kv_capacity_tokens {
                return false;
            }
            let (b_dc, b_pf) = split(b);
            self.iter_ms_mixed(b_dc, b_pf, kv) < tpot_ms
        };
        let ttft_ok = |b: u64| -> bool {
            let (b_dc, b_pf) = split(b);
            let t = self.iter_ms_mixed(b_dc, b_pf, kv_of(b));
            (pd / b as f64) * t < ttft_ms
        };
        let mut lo = 0u64;
        let mut hi = self.max_token_batch;
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if tpot_ok(mid) {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        let mut b = lo;
        while b > 0 && !ttft_ok(b) {
            b -= 1;
        }
        b
    }

    /// Per-request serving cost (instance·ms) for PD-disaggregation at
    /// decode batch `b_dc` and prefill batch `b_pf` (§3.5), split into
    /// (prefill, decode) components:
    ///
    /// `p·GEMM_pf(B_pf)/B_pf + PF(p)` and `d·GEMM(B_dc)/B_dc + DcAttn(d(p+d/2))`
    pub fn cost_pd_split_ms(&self, p: u64, d: u64, b_pf: u64, b_dc: u64) -> (f64, f64) {
        if b_pf == 0 || b_dc == 0 {
            return (f64::INFINITY, f64::INFINITY);
        }
        let prefill = p as f64
            * ((self.t_fixed_ms + self.gemm_prefill_ms(b_pf)) / b_pf as f64)
            + self.pf_attn_ms(p);
        let decode = d as f64 * ((self.t_fixed_ms + self.gemm_ms(b_dc)) / b_dc as f64)
            + self.dc_attn_ms(d * (p + d / 2));
        (prefill, decode)
    }

    /// Total PD per-request cost (instance·ms).
    pub fn cost_pd_ms(&self, p: u64, d: u64, b_pf: u64, b_dc: u64) -> f64 {
        let (a, b) = self.cost_pd_split_ms(p, d, b_pf, b_dc);
        a + b
    }

    /// Per-request serving cost (instance·ms) for co-location at token
    /// batch `b` (§3.5): `(p+d)·GEMM(B)/B + PF(p) + DcAttn(d(p+d/2))`.
    pub fn cost_coloc_ms(&self, p: u64, d: u64, b: u64) -> f64 {
        if b == 0 {
            return f64::INFINITY;
        }
        let pd = (p + d) as f64;
        let b_dc = (d as f64 / pd * b as f64).round() as u64;
        let b_pf = b - b_dc.min(b);
        let gemm = self.t_fixed_ms + self.gemm_ms_mixed(b_dc, b_pf);
        pd * (gemm / b as f64) + self.pf_attn_ms(p) + self.dc_attn_ms(d * (p + d / 2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> CostModel {
        CostModel::h200_llama8b()
    }

    #[test]
    fn calibration_anchor_b1() {
        // §5.1: min per-token latency ≈ 15 ms at B=1, ctx=1.
        let t = m().iter_ms(1, 1);
        assert!((t - 15.0).abs() < 0.1, "iter(1,1) = {t}");
    }

    #[test]
    fn calibration_anchor_fig2_20ms() {
        // Fig 2 @ (p,d)=(1000,4000): B≈50 at 20 ms TPOT.
        let b = m().max_decode_batch(20.0, 1000 + 4000 / 2);
        assert!((45..=55).contains(&b), "B@20ms = {b}");
    }

    #[test]
    fn calibration_anchor_fig2_40ms() {
        // Fig 2 @ (p,d)=(1000,4000): B≈150 at 40 ms TPOT.
        let b = m().max_decode_batch(40.0, 3000);
        assert!((140..=160).contains(&b), "B@40ms = {b}");
    }

    #[test]
    fn paper_cost_ratio_anchor() {
        // §3.6: dropping from B=150 (40 ms) to B=50 (20 ms) is a "near
        // 1.5× cost increase" — per-token time 0.4 vs 0.267 ms.
        let mm = m();
        let per_tok_50 = mm.iter_ms(50, 50 * 3000) / 50.0;
        let per_tok_150 = mm.iter_ms(150, 150 * 3000) / 150.0;
        let ratio = per_tok_50 / per_tok_150;
        assert!((1.35..=1.65).contains(&ratio), "ratio = {ratio}");
    }

    #[test]
    fn qwen32b_profile_is_distinct_and_slower() {
        // The registry's second model must be meaningfully more
        // expensive than the 8B anchor on every axis the router and
        // sizers consume, or a model mix degenerates to one profile.
        let small = CostModel::h200_llama8b();
        let big = CostModel::h200_qwen32b();
        assert!(big.t_weight_ms > 2.0 * small.t_weight_ms);
        assert!(big.iter_ms(1, 1) > 2.0 * small.iter_ms(1, 1));
        assert!(big.kv_capacity_tokens < small.kv_capacity_tokens / 2);
        // Same TPOT target → strictly smaller feasible decode batch.
        assert!(big.max_decode_batch(60.0, 3000) < small.max_decode_batch(60.0, 3000));
    }

    #[test]
    fn batch_size_monotone_in_tpot() {
        let mm = m();
        let mut last = 0;
        for tpot in [16.0, 20.0, 30.0, 50.0, 100.0] {
            let b = mm.max_decode_batch(tpot, 3000);
            assert!(b >= last, "tpot={tpot} b={b} last={last}");
            last = b;
        }
    }

    #[test]
    fn below_floor_tpot_gives_zero_batch() {
        // 14 ms < 15 ms floor → nothing schedulable.
        assert_eq!(m().max_decode_batch(14.0, 3000), 0);
    }

    #[test]
    fn kv_capacity_caps_batch() {
        let mm = m();
        // Enormous per-request KV: capacity, not latency, binds.
        let b = mm.max_decode_batch(100.0, 200_000);
        assert_eq!(b, mm.kv_capacity_tokens / 200_000);
    }

    #[test]
    fn prefill_tokens_cheaper_than_decode_tokens() {
        let mm = m();
        assert!(mm.gemm_prefill_ms(2048) < mm.gemm_ms(2048));
        // 2048-token chunk ≈ 68 ms GEMM → ~30k tok/s prefill.
        let t = mm.gemm_prefill_ms(2048);
        assert!((60.0..80.0).contains(&t), "chunk gemm = {t}");
    }

    #[test]
    fn coloc_batch_increases_with_tpot() {
        let mm = m();
        let b20 = mm.max_coloc_batch(1000, 1000, 20.0, 2000.0);
        let b50 = mm.max_coloc_batch(1000, 1000, 50.0, 2000.0);
        assert!(b50 > b20, "b20={b20} b50={b50}");
    }

    #[test]
    fn coloc_ttft_binds_for_long_prompts() {
        let mm = m();
        // Long prompt + tight TTFT forces infeasibility (or tiny batch).
        let loose = mm.max_coloc_batch(8000, 1000, 50.0, 10_000.0);
        let tight = mm.max_coloc_batch(8000, 1000, 50.0, 700.0);
        assert!(tight <= loose, "tight={tight} loose={loose}");
    }

    #[test]
    fn cost_decreases_with_batch() {
        let mm = m();
        let c1 = mm.cost_pd_ms(1000, 1000, 2048, 10);
        let c2 = mm.cost_pd_ms(1000, 1000, 2048, 100);
        assert!(c2 < c1);
    }

    #[test]
    fn cost_zero_batch_is_infinite() {
        assert!(m().cost_pd_ms(100, 100, 0, 10).is_infinite());
        assert!(m().cost_coloc_ms(100, 100, 0).is_infinite());
    }

    #[test]
    fn fig4_short_sequences_near_parity() {
        // §3.5: "For short sequences, Co-location and PD-Disaggregate do
        // not incur a large difference."
        let mm = m();
        let (p, d) = (512u64, 512u64);
        let b_co = mm.max_coloc_batch(p, d, 50.0, 700.0);
        let b_dc = mm.max_decode_batch(50.0, p + d / 2);
        let cost_co = mm.cost_coloc_ms(p, d, b_co);
        let cost_pd = mm.cost_pd_ms(p, d, mm.max_token_batch, b_dc);
        let ratio = cost_co / cost_pd;
        assert!(
            (0.6..=1.4).contains(&ratio),
            "co={cost_co:.0} pd={cost_pd:.0} ratio={ratio:.2}"
        );
    }

    #[test]
    fn fig4_long_sequences_favor_coloc_when_memory_unbound() {
        // §3.5: "for long sequences, Co-location features lower cost."
        // The mechanism: PD pays decode GEMM at a small (memory/TPOT
        // capped) B_dc, while co-location amortizes all p+d tokens at the
        // large mixed batch. The paper's Fig 3/4 batch sizes imply a
        // non-binding KV capacity, so we validate the claim in that
        // regime (see EXPERIMENTS.md for the discussion).
        let mm = m().with_unbounded_kv();
        let (p, d) = (4000u64, 4000u64);
        let tpot = 100.0;
        let ttft = 2000.0;
        let b_co = mm.max_coloc_batch(p, d, tpot, ttft);
        let b_dc = mm.max_decode_batch(tpot, p + d / 2);
        let cost_co = mm.cost_coloc_ms(p, d, b_co);
        let cost_pd = mm.cost_pd_ms(p, d, mm.max_token_batch, b_dc);
        assert!(
            cost_co < cost_pd,
            "cost_co={cost_co:.0} cost_pd={cost_pd:.0} (b_co={b_co}, b_dc={b_dc})"
        );
    }

    #[test]
    fn gemm_knee_location() {
        let mm = m();
        assert_eq!(mm.gemm_knee(), 76); // 10 / 0.1333 ≈ 75.02 → 76
        assert_eq!(mm.gemm_ms(10), mm.t_weight_ms);
        assert!(mm.gemm_ms(200) > mm.t_weight_ms);
    }

    #[test]
    fn effective_tokens_weights_prefill_down() {
        let mm = m();
        let eff = mm.effective_tokens(100, 400);
        // 100 + 400·(0.0333/0.1333) ≈ 100 + 99.9
        assert!((eff - 200.0).abs() < 1.0, "eff={eff}");
    }

    #[test]
    fn mixed_iter_cheaper_than_all_decode() {
        let mm = m();
        let mixed = mm.iter_ms_mixed(100, 400, 10_000);
        let all_dc = mm.iter_ms(500, 10_000);
        assert!(mixed < all_dc);
    }

    #[test]
    fn quantized_rounds_up() {
        let mm = m();
        let t = mm.iter_ms(1, 1); // 15.00003...
        assert_eq!(mm.iter_ms_quantized(1, 1), t.ceil() as u64);
        assert_eq!(mm.iter_ms_quantized(0, 0), 0);
    }
}
