//! Model descriptions and the execution cost model.
//!
//! * [`spec`] — transformer architecture descriptions: the LLaMA-3.1-8B
//!   dims the paper profiles (used by the simulator's cost model and the
//!   analysis closed forms), and the small serving model compiled by
//!   `python/compile/aot.py` for the real PJRT path.
//! * [`costmodel`] — the H200-calibrated analytic iteration-time model
//!   (DESIGN.md §3) with the paper's GEMM / decode-attention / prefill
//!   components.

//! * [`registry`] — the `ModelId`-keyed catalog bundling spec + cost
//!   model + profile per servable model (multi-model fleet serving).

pub mod spec;
pub mod costmodel;
pub mod registry;

pub use costmodel::CostModel;
pub use registry::{ModelEntry, ModelId, ModelRegistry};
pub use spec::ModelSpec;
