//! Shared experiment harness: wires workload → cluster → router →
//! simulation, used by the CLI, the examples, and every bench.

use crate::analysis;
use crate::config::{Policy, SimConfig};
use crate::coordinator::{make_autoscaler_with_models, make_router_with_models};
use crate::metrics::AttainmentCurve;
use crate::model::{CostModel, ModelRegistry};
use crate::profile::ProfileTable;
use crate::sim::{
    ChaosParams, Cluster, ElasticParams, OverloadParams, PrefillElastic, SimParams, SimResult,
    Simulation,
};
use crate::util::rng::Rng;
use crate::util::threadpool::par_map;
use crate::workload::{RateSchedule, TraceGenerator, Workload};

// The fleet-sizing math grew into a shared module consumed by the
// predictive autoscaler too; benches keep importing it from here.
pub use crate::coordinator::sizing::size_elastic_pd_cell;

/// Flattened `[chaos]` schedule pairs `(t_s, value, t_s, value, …)` →
/// the simulator's `(TimeMs, value)` steps (config validation already
/// guaranteed even length and ascending times).
fn schedule_pairs(flat: &[f64]) -> Vec<(u64, f64)> {
    flat.chunks_exact(2).map(|c| ((c[0] * 1000.0) as u64, c[1])).collect()
}

/// Everything needed to run one simulation cell, pre-computed.
pub struct Experiment {
    /// The (auto-resolved) configuration of the cell.
    pub cfg: SimConfig,
    /// Ground-truth hardware model of model 0 (the run's anchor; the
    /// registry carries the rest for multi-model fleets).
    pub cost_model: CostModel,
    /// Profiling table the router sees for model 0.
    pub profile: ProfileTable,
    /// Model catalog of the run. Single-entry (`default_single`) for
    /// the classic configuration — which keeps every decision
    /// bit-for-bit identical to the pre-registry harness — or the
    /// built-in N-model cycle when `cfg.models.mix` lists N ≥ 2
    /// weights (N = 2 is exactly the built-in pair).
    pub models: ModelRegistry,
    /// Generated request stream.
    pub workload: Workload,
    /// Optimal-goodput bound for this trace + SLO mix, req/s.
    pub optimal_rps: f64,
    /// Actual request rate of the workload, req/s.
    pub rate_rps: f64,
    /// Run through the scan-based pre-PR-4 reference path (full-fleet
    /// membership scans + per-placement resident rescans) instead of
    /// the ordered/indexed/cached hot path. Decisions are bit-for-bit
    /// identical by construction — used for A/B identity tests and as
    /// the `sim_perf` speedup baseline. Takes precedence over
    /// `indexed_reference`.
    pub scan_reference: bool,
    /// Run through the PR-4 *indexed* reference path: id-indexed
    /// membership and O(1) cached load reads, but the router
    /// materializes and sorts each tier per placement instead of
    /// walking the load-ordered sets, and unplaced demand is
    /// reconstructed by scan. Isolates what the ordered indices alone
    /// buy; decisions stay bit-for-bit identical.
    pub indexed_reference: bool,
    /// Run the event loop off the pre-PR-6 global binary heap instead
    /// of the calendar queue (`SimParams::heap_reference`). The two
    /// engines pop the identical `(t, seq)` sequence by construction,
    /// so decisions are bit-for-bit unchanged — the queue axis of the
    /// digest-identity matrix and the `speedup_calendar_over_heap`
    /// baseline. Composes freely with the index-axis flags above.
    pub heap_reference: bool,
    /// Run the per-event cache/index coherence audit in debug-assertion
    /// builds (`SimParams::debug_audit`). On by default; `sim_perf`
    /// timing cells disable it so the bench doesn't measure the audit's
    /// own scans.
    pub debug_audit: bool,
    /// Keep the router's pending queues FIFO-ordered even with
    /// `[overload]` on (`OverloadConfig::fifo_reference`) — the pre-EDF
    /// reference engine for digest-identity runs and the bench's `fifo`
    /// policy axis. A no-op with overload off (the queues are FIFO
    /// either way, bit for bit).
    pub fifo_reference: bool,
}

impl Experiment {
    /// Build workload + profile for a config. The request rate is
    /// `rate_frac_of_optimal × optimal` unless `rate_rps` overrides.
    pub fn prepare(cfg: &SimConfig) -> Experiment {
        let models = if cfg.models.is_multi() {
            ModelRegistry::builtin(cfg.models.mix.len())
        } else {
            ModelRegistry::default_single()
        };
        // Model 0 anchors the probe passes (optimal-goodput bound and
        // prefill auto-sizing) in both branches, so the single-model
        // RNG stream — and therefore the workload — never shifts.
        let cm = models.entry(0).cost_model.clone();
        let profile = models.entry(0).profile.clone();
        let gen = TraceGenerator::new(cfg.trace);
        let mut rng = Rng::new(cfg.seed);

        // Pass 1: provisional workload at a nominal rate to measure the
        // optimal-goodput bound for this trace + SLO mix.
        let mode = cfg.mode;
        let cm_for_filter = cm.clone();
        let achievable =
            move |p: u32, d: u32, slo| analysis::slo_achievable(&cm_for_filter, mode, p, d, slo);
        let probe = gen.generate(
            (cfg.requests / 4).clamp(500, 20_000),
            10.0,
            &cfg.tier_dist,
            &achievable,
            &mut rng,
        );
        let optimal_rps = analysis::optimal_goodput_rps(&cm, cfg.mode, &probe, cfg.instances);

        // Auto-size the PD prefill cluster from the probe's work split
        // (§2.4: "each cluster can scale independently").
        let mut cfg = cfg.clone();
        if cfg.prefill_frac == 0.0 {
            cfg.prefill_frac = prefill_share(&cm, &probe);
        }

        let rate_rps = cfg
            .rate_rps
            .unwrap_or(optimal_rps * cfg.rate_frac_of_optimal)
            .max(0.001);
        let mut rng2 = Rng::new(cfg.seed ^ 0x5EED);
        let mut workload = match cfg.diurnal {
            Some(d) => {
                // Diurnal arrivals at the same *mean* rate: the elastic
                // fleet gets a demand curve to chase while rate-based
                // comparisons stay apples-to-apples.
                let period_ms = ((d.period_s * 1000.0) as u64).max(2);
                let expected_span_ms =
                    (cfg.requests as f64 / rate_rps * 1000.0).max(period_ms as f64);
                let periods = (expected_span_ms / period_ms as f64).ceil() as usize + 1;
                let schedule =
                    RateSchedule::diurnal(rate_rps, d.peak_to_trough, period_ms, 24, periods);
                let arrivals = schedule.arrivals(cfg.requests, &mut rng2);
                gen.generate_with_arrivals(&arrivals, &cfg.tier_dist, &achievable, &mut rng2)
            }
            None => {
                gen.generate(cfg.requests, rate_rps, &cfg.tier_dist, &achievable, &mut rng2)
            }
        };
        if models.is_multi() {
            // Dedicated RNG stream: the mix assignment must not perturb
            // the workload generator's draws (and is skipped entirely —
            // stream and all — for single-model runs).
            let mut rng3 = Rng::new(cfg.seed ^ 0x30DE15);
            workload.assign_model_mix(&cfg.models.mix, &mut rng3);
        }
        Experiment {
            cfg,
            cost_model: cm,
            profile,
            models,
            workload,
            optimal_rps,
            rate_rps,
            scan_reference: false,
            indexed_reference: false,
            heap_reference: false,
            debug_audit: true,
            fifo_reference: false,
        }
    }

    /// Regenerate the workload's arrivals from an explicit
    /// [`RateSchedule`] (flash-crowd / regime-switch stress cells),
    /// re-drawing on the same dedicated RNG stream the diurnal branch
    /// uses (`seed ^ 0x5EED`), so swapping the demand curve never
    /// perturbs any other stream.
    pub fn override_arrivals(&mut self, schedule: &RateSchedule) {
        let gen = TraceGenerator::new(self.cfg.trace);
        let cm = self.cost_model.clone();
        let mode = self.cfg.mode;
        let achievable =
            move |p: u32, d: u32, slo| analysis::slo_achievable(&cm, mode, p, d, slo);
        let mut rng2 = Rng::new(self.cfg.seed ^ 0x5EED);
        let arrivals = schedule.arrivals(self.cfg.requests, &mut rng2);
        self.workload =
            gen.generate_with_arrivals(&arrivals, &self.cfg.tier_dist, &achievable, &mut rng2);
        if self.models.is_multi() {
            let mut rng3 = Rng::new(self.cfg.seed ^ 0x30DE15);
            self.workload.assign_model_mix(&self.cfg.models.mix, &mut rng3);
        }
    }

    /// Run the simulation for this experiment. With `cfg.elastic`
    /// enabled the fleet starts at `cfg.instances` and the configured
    /// autoscaler drives it within the elastic bounds; otherwise this
    /// is exactly the seed fixed-fleet path.
    pub fn run(&self) -> SimResult {
        let polyserve_managed = self.cfg.policy == Policy::PolyServe;
        let elastic = self.cfg.elastic.enabled();
        // `cfg.instances` is the *initial* fleet; the elastic bounds
        // only constrain scaling transitions (they apply to the
        // scalable role, which under PD is a subset of the fleet).
        let mut cluster = if self.models.is_multi() {
            let counts = split_mix(self.cfg.instances, &self.cfg.models.mix);
            Cluster::build_models(
                self.cfg.mode,
                &counts,
                self.cfg.prefill_frac,
                self.cfg.tiers.len(),
                &self.models.instance_caps(),
                polyserve_managed,
            )
        } else {
            Cluster::build(
                self.cfg.mode,
                self.cfg.instances,
                self.cfg.prefill_frac,
                self.cfg.tiers.len(),
                &self.cost_model,
                polyserve_managed,
            )
        };
        if self.scan_reference {
            cluster.set_scan_reference(true);
        } else if self.indexed_reference {
            cluster.set_indexed_reference(true);
        }
        let params = SimParams {
            mode: self.cfg.mode,
            debug_audit: self.debug_audit,
            heap_reference: self.heap_reference,
            elastic: elastic.then(|| ElasticParams {
                min_instances: self.cfg.elastic.min_instances.max(1),
                max_instances: self.cfg.elastic.max_instances,
                provision_delay_ms: self.cfg.elastic.provision_delay_ms,
                scale_eval_ms: self.cfg.elastic.scale_eval_ms.max(1),
                migration: self.cfg.elastic.migration,
                migration_batching: self.cfg.elastic.migration_batching,
                model_swap_delay_ms: self.cfg.models.swap_delay_ms,
                prefill: (self.cfg.elastic.prefill_elastic
                    && self.cfg.mode == crate::analysis::ServingMode::PdDisaggregated)
                    .then(|| PrefillElastic {
                        min_instances: self.cfg.elastic.prefill_min.max(1),
                        max_instances: self.cfg.elastic.prefill_max,
                    }),
            }),
            // `None` when `[chaos]` is off: the simulator then builds
            // no chaos machinery at all (the bit-identical seed path).
            chaos: self.cfg.chaos.enabled().then(|| ChaosParams {
                fail_at: Vec::new(),
                fail_mtbf_ms: (self.cfg.chaos.fail_mtbf_s * 1000.0) as u64,
                preempt_at: Vec::new(),
                preempt_mtbf_ms: (self.cfg.chaos.preempt_mtbf_s * 1000.0) as u64,
                preempt_grace_ms: self.cfg.chaos.preempt_grace_ms,
                spot_fraction: self.cfg.chaos.spot_fraction,
                spot_price_frac: self.cfg.chaos.spot_price_frac,
                zones: self.cfg.chaos.zones,
                racks_per_zone: self.cfg.chaos.racks_per_zone,
                domain_fail_at: Vec::new(),
                domain_fail_mtbf_ms: (self.cfg.chaos.domain_fail_mtbf_s * 1000.0) as u64,
                checkpoint_period_ms: self.cfg.chaos.checkpoint_period_ms,
                spot_price_schedule: schedule_pairs(&self.cfg.chaos.spot_price_schedule),
                spot_avail_schedule: schedule_pairs(&self.cfg.chaos.spot_avail_schedule),
                seed: self.cfg.chaos.seed,
            }),
            // Simulator-side overload machinery exists only when the
            // arrival gate is on; EDF-only configs are purely a router
            // ordering change with nothing to construct here.
            overload: (self.cfg.overload.enabled() && self.cfg.overload.reject).then(|| {
                OverloadParams {
                    reject: true,
                    retry: self.cfg.overload.retry,
                    retry_base_ms: self.cfg.overload.retry_base_ms,
                    retry_max_attempts: self.cfg.overload.retry_max_attempts,
                    propagate_deadline: self.cfg.overload.propagate_deadline,
                    seed: self.cfg.overload.seed,
                }
            }),
            ..Default::default()
        };
        let mut sim = Simulation::new(
            params,
            self.cost_model.clone(),
            &self.profile,
            &self.workload,
            cluster,
            &self.cfg.tiers,
        );
        let profiles = if self.models.is_multi() {
            sim = sim.with_cost_models(self.models.cost_models());
            self.models.profiles()
        } else {
            Vec::new()
        };
        // The FIFO reference flag is runtime-only (not a TOML knob):
        // thread it to the router through a config copy.
        let mut router_cfg = self.cfg.clone();
        router_cfg.overload.fifo_reference = self.fifo_reference;
        let mut router =
            make_router_with_models(&router_cfg, self.workload.avg_decode_len(), &profiles);
        let mut scaler = if elastic {
            make_autoscaler_with_models(&self.cfg, &profiles)
        } else {
            None
        };
        let res = match scaler.as_deref_mut() {
            Some(sc) => sim.run_elastic(router.as_mut(), Some(sc)),
            None => sim.run(router.as_mut()),
        };
        let diag = router.diagnostics();
        if !diag.is_empty() {
            log::debug!("router diagnostics: {diag}");
        }
        res
    }
}

/// Convenience: run one config end to end.
pub fn run_sim(cfg: &SimConfig) -> SimResult {
    Experiment::prepare(cfg).run()
}

/// Split `total` instances across models by largest-remainder
/// apportionment of `weights`, guaranteeing every model at least one
/// instance (a model with zero servers could never serve its
/// requests). Deterministic: remainder ties break toward the lower
/// model id.
pub fn split_mix(total: usize, weights: &[f64]) -> Vec<usize> {
    assert!(!weights.is_empty(), "need at least one mix weight");
    let m = weights.len();
    assert!(total >= m, "need at least one instance per model");
    let sum: f64 = weights.iter().sum();
    let rem = total - m;
    let quotas: Vec<f64> = weights.iter().map(|w| w / sum * rem as f64).collect();
    let floors: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
    let mut counts: Vec<usize> = floors.iter().map(|f| f + 1).collect();
    let mut assigned: usize = floors.iter().sum();
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| {
        let (ra, rb) = (quotas[a] - floors[a] as f64, quotas[b] - floors[b] as f64);
        rb.partial_cmp(&ra).unwrap().then(a.cmp(&b))
    });
    let mut i = 0;
    while assigned < rem {
        counts[order[i % m]] += 1;
        assigned += 1;
        i += 1;
    }
    counts
}

/// Share of the per-request optimal cost spent in prefill, with 1.25×
/// burstiness headroom (clamped) — the §2.4 auto-sizing rule for the
/// PD prefill cluster.
fn prefill_share(cm: &CostModel, probe: &Workload) -> f64 {
    let (mut pf, mut total) = (0.0f64, 0.0f64);
    for r in &probe.requests {
        let tpot = (r.slo.tpot_ms as f64).min(10_000.0);
        let b_dc = cm.max_decode_batch(tpot, r.avg_kv_tokens()).max(1);
        let (a, b) = cm.cost_pd_split_ms(
            r.prefill_len as u64,
            r.decode_len as u64,
            cm.max_token_batch,
            b_dc,
        );
        pf += a;
        total += a + b;
    }
    let share = if total > 0.0 { pf / total } else { 0.3 };
    (share * 1.25).clamp(0.08, 0.6)
}

/// The auto-resolved PD prefill share for `cfg` — the same probe and
/// rule `Experiment::prepare` applies (identical RNG seeding, so the
/// two always agree) — without generating the full workload or running
/// the optimal-goodput analysis. For benches that only need the peak
/// fleet's prefill split.
pub fn auto_prefill_frac(cfg: &SimConfig) -> f64 {
    if cfg.prefill_frac > 0.0 {
        return cfg.prefill_frac;
    }
    let cm = CostModel::h200_llama8b();
    let gen = TraceGenerator::new(cfg.trace);
    let mut rng = Rng::new(cfg.seed);
    let mode = cfg.mode;
    let cm_for_filter = cm.clone();
    let achievable =
        move |p: u32, d: u32, slo| analysis::slo_achievable(&cm_for_filter, mode, p, d, slo);
    let probe = gen.generate(
        (cfg.requests / 4).clamp(500, 20_000),
        10.0,
        &cfg.tier_dist,
        &achievable,
        &mut rng,
    );
    prefill_share(&cm, &probe)
}

/// Sweep request rate fractions and build the attainment-vs-rate curve
/// (the Fig 6 per-cell harness). Returns (curve, optimal_rps).
pub fn attainment_curve(
    base: &SimConfig,
    fracs: &[f64],
    threads: usize,
) -> (AttainmentCurve, f64) {
    let cells: Vec<SimConfig> = fracs
        .iter()
        .map(|&f| {
            let mut c = base.clone();
            c.rate_frac_of_optimal = f;
            c
        })
        .collect();
    let results = par_map(cells, threads, |_, cfg| {
        let exp = Experiment::prepare(&cfg);
        let res = exp.run();
        (exp.rate_rps, res.attainment.overall(), exp.optimal_rps)
    });
    let mut curve = AttainmentCurve::default();
    let mut optimal = 0.0;
    for (rate, att, opt) in results {
        curve.push(rate, att);
        optimal = opt;
    }
    (curve, optimal)
}

/// CO-Chunk with the paper's budget sweep: runs each budget and keeps
/// the best attainment (§5.1).
pub fn best_chunk_attainment(base: &SimConfig, budgets: &[u64], threads: usize) -> (u64, f64) {
    let cells: Vec<SimConfig> = budgets
        .iter()
        .map(|&b| {
            let mut c = base.clone();
            c.policy = Policy::Chunk;
            c.chunk_budget = b;
            c
        })
        .collect();
    let budgets_owned: Vec<u64> = budgets.to_vec();
    let results = par_map(cells, threads, move |i, cfg| {
        let res = run_sim(&cfg);
        (budgets_owned[i], res.attainment.overall())
    });
    results
        .into_iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap_or((512, 0.0))
}
