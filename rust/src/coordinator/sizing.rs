//! Fleet-sizing math shared by the autoscalers, the benches and the CLI.
//!
//! One question, asked three ways: *how many servers does a given
//! arrival rate need?* The answers all come from the same two
//! ingredients the paper's scheduler already has — the profiled
//! [`ProfileTable`] (batch, KV) → iteration-time map (§4.5) and the
//! per-tier TPOT budgets — so the predictive autoscaler, the static
//! bench baselines, and equal-peak-capacity experiment sizing can never
//! disagree about what "enough capacity" means.
//!
//! * [`required_decode_fleet`] / [`required_coloc_fleet`] — Little's-law
//!   sizing: tier-`k` arrivals at `λ_k` req/s each hold a decode slot
//!   for `decode_len × TPOT_k` ms (an instance packed to its profile
//!   limit runs exactly at the TPOT edge), so the needed concurrency is
//!   `λ_k · decode_len · TPOT_k`, divided by the per-instance batch
//!   capacity [`ProfileTable::max_batch_under`] gives servers.
//! * [`required_prefill_fleet`] — throughput sizing for the PD prefill
//!   cluster: arrivals bring `λ · prefill_len` prompt tokens per second
//!   against a per-server chunked-prefill token rate
//!   ([`prefill_tokens_per_ms`]).
//! * [`size_elastic_pd_cell`] — the equal-peak-capacity experiment
//!   helper (previously in `figures`): splits a peak fleet into a
//!   static prefill share and an elastic decode range.
//!
//! All sizing targets [`SIZING_UTIL_TARGET`] utilization, not 100%:
//! Poisson arrivals need headroom, and the admission layer refuses the
//! last few percent anyway ([`super::admission::SAFETY`]).

use super::admission::SAFETY;
use crate::analysis::ServingMode;
use crate::config::SimConfig;
use crate::profile::ProfileTable;
use crate::slo::TierSet;

/// Ratio of prefill-token to decode-token GEMM cost — how the profile
/// table's decode-equivalent batch axis weighs prefill chunk tokens
/// (see `CostModel::effective_tokens`). Shared with the PolyServe
/// router's chunk admission math.
pub const PF_TOKEN_RATIO: f64 = 0.25;

/// Target utilization all sizing aims at. Sizing to 100% leaves zero
/// headroom for Poisson burstiness and admission-margin refusals; ~85%
/// is the classic provisioning knee.
pub const SIZING_UTIL_TARGET: f64 = 0.85;

/// The PD prefill static chunk budget the PolyServe router runs with.
/// Shared here so the TTFT-pressure and prefill-fleet-sizing estimates
/// can never desynchronize from the router's actual chunk rate.
pub const DEFAULT_PREFILL_BUDGET: u64 = 2_048;

/// Chunked-prefill throughput of one dedicated prefill server at token
/// budget `budget`, in tokens/ms — the chunk time predicted by the
/// profile table at the packed budget (`PF_TOKEN_RATIO`-weighted batch
/// axis, budget-sized KV), exactly as the router's own
/// `prefill_queue_feasible` estimates it.
pub fn prefill_tokens_per_ms(profile: &ProfileTable, budget: u64) -> f64 {
    let budget = budget.max(1);
    let eff = ((budget as f64 * PF_TOKEN_RATIO).ceil() as u64).max(1);
    let chunk_ms = profile.iter_ms(eff, budget).max(1e-9);
    budget as f64 / chunk_ms
}

/// Largest decode batch one instance sustains at tier TPOT `tpot_ms`
/// with `kv_per_req` resident KV tokens per request, under the same
/// `SAFETY` margin the admission layer applies.
pub fn decode_batch_capacity(profile: &ProfileTable, tpot_ms: u64, kv_per_req: u64) -> u64 {
    profile
        .max_batch_under(SAFETY * tpot_ms as f64, kv_per_req.max(1))
        .max(1)
}

/// Fractional decode-server requirement (PD decode cluster) for
/// per-tier arrival rates `tier_rates_rps` (parallel to `tiers`,
/// tightest first): Little's law per tier, summed.
pub fn required_decode_fleet_f(
    profile: &ProfileTable,
    tiers: &TierSet,
    tier_rates_rps: &[f64],
    avg_decode_len: f64,
    avg_kv_per_req: u64,
) -> f64 {
    let mut total = 0.0f64;
    for (k, &rate) in tier_rates_rps.iter().enumerate().take(tiers.len()) {
        if rate <= 0.0 {
            continue;
        }
        let tpot = tiers.tier(k).tpot_ms;
        let cap = decode_batch_capacity(profile, tpot, avg_kv_per_req) as f64;
        // A decode stream holds its slot for decode_len iterations; at
        // the packed-batch operating point each iteration takes TPOT ms.
        let service_s = avg_decode_len.max(1.0) * tpot as f64 / 1000.0;
        total += rate * service_s / (cap * SIZING_UTIL_TARGET);
    }
    total
}

/// Decode-server requirement, rounded up (at least 1).
pub fn required_decode_fleet(
    profile: &ProfileTable,
    tiers: &TierSet,
    tier_rates_rps: &[f64],
    avg_decode_len: f64,
    avg_kv_per_req: u64,
) -> usize {
    (required_decode_fleet_f(profile, tiers, tier_rates_rps, avg_decode_len, avg_kv_per_req)
        .ceil() as usize)
        .max(1)
}

/// Co-located fleet requirement: the decode slots of
/// [`required_decode_fleet_f`], inflated by the share of each
/// iteration's token budget that chunked prefill consumes
/// (`PF_TOKEN_RATIO · prefill_len / decode_len` effective decode tokens
/// per decode token).
pub fn required_coloc_fleet(
    profile: &ProfileTable,
    tiers: &TierSet,
    tier_rates_rps: &[f64],
    avg_prefill_len: f64,
    avg_decode_len: f64,
    avg_kv_per_req: u64,
) -> usize {
    let decode =
        required_decode_fleet_f(profile, tiers, tier_rates_rps, avg_decode_len, avg_kv_per_req);
    let pf_factor = 1.0 + PF_TOKEN_RATIO * avg_prefill_len.max(0.0) / avg_decode_len.max(1.0);
    ((decode * pf_factor).ceil() as usize).max(1)
}

/// Serving-mode dispatch over [`required_decode_fleet`] /
/// [`required_coloc_fleet`] — the per-model entry point: the
/// multi-model planner sizes each registered model's sub-fleet by
/// calling this once per model with *that model's* profile table and
/// arrival shares, so per-model sizing and the single-model scalers
/// can never disagree about what "enough capacity" means.
pub fn required_fleet(
    profile: &ProfileTable,
    mode: ServingMode,
    tiers: &TierSet,
    tier_rates_rps: &[f64],
    avg_prefill_len: f64,
    avg_decode_len: f64,
    avg_kv_per_req: u64,
) -> usize {
    match mode {
        ServingMode::PdDisaggregated => required_decode_fleet(
            profile,
            tiers,
            tier_rates_rps,
            avg_decode_len,
            avg_kv_per_req,
        ),
        ServingMode::Colocated => required_coloc_fleet(
            profile,
            tiers,
            tier_rates_rps,
            avg_prefill_len,
            avg_decode_len,
            avg_kv_per_req,
        ),
    }
}

/// PD prefill-cluster requirement at total arrival rate
/// `total_rate_rps`: prompt-token demand over per-server chunked
/// throughput at `budget`.
pub fn required_prefill_fleet(
    profile: &ProfileTable,
    total_rate_rps: f64,
    avg_prefill_len: f64,
    budget: u64,
) -> usize {
    if total_rate_rps <= 0.0 || avg_prefill_len <= 0.0 {
        return 1;
    }
    let per_server_tps = prefill_tokens_per_ms(profile, budget) * 1000.0;
    ((total_rate_rps * avg_prefill_len / (per_server_tps * SIZING_UTIL_TARGET)).ceil() as usize)
        .max(1)
}

/// Chaos-churn provisioning pad: instances the observed kill rate is
/// expected to claim inside the anticipation lead, rounded up —
/// capacity that must already be cold-starting *now* to land when the
/// kills do. Capped at 8 (the predictive scaler's per-epoch provision
/// step) so a transient kill-rate spike can't demand an unbounded
/// fleet; a zero rate pads nothing (bit-identical sizing).
pub fn churn_pad(kill_rate_per_ms: f64, lead_ms: u64) -> usize {
    if kill_rate_per_ms <= 0.0 {
        return 0;
    }
    ((kill_rate_per_ms * lead_ms as f64).ceil() as usize).min(8)
}

/// Split a peak PD fleet of `n_peak` into its static prefill share
/// (`peak_prefill_frac`, clamped so both sides keep at least one
/// server) and the scalable decode remainder.
pub fn split_pd_fleet(n_peak: usize, peak_prefill_frac: f64) -> (usize, usize) {
    let n_pf = ((n_peak as f64 * peak_prefill_frac).round() as usize)
        .clamp(1, n_peak.saturating_sub(1).max(1));
    (n_pf, n_peak.saturating_sub(n_pf))
}

/// Equal-peak-capacity sizing for an elastic PD cell: the static
/// prefill cluster keeps its peak share (it does not scale), only the
/// decode fleet is elastic within `[min, scalable_peak]`, and the run
/// starts at the floor. `peak_prefill_frac` is the prefill share *of
/// the peak fleet* (e.g. from `figures::auto_prefill_frac`);
/// `min_of_scalable` maps the scalable peak to the elastic floor.
///
/// (With `cfg.elastic.prefill_elastic` the prefill side stops being
/// static too — callers then set `prefill_min`/`prefill_max` on top of
/// this split.)
pub fn size_elastic_pd_cell(
    cfg: &mut SimConfig,
    n_peak: usize,
    peak_prefill_frac: f64,
    min_of_scalable: impl Fn(usize) -> usize,
) {
    let (n_pf, scalable_peak) = split_pd_fleet(n_peak, peak_prefill_frac);
    cfg.elastic.min_instances = min_of_scalable(scalable_peak).clamp(1, scalable_peak.max(1));
    cfg.elastic.max_instances = scalable_peak;
    cfg.instances = n_pf + cfg.elastic.min_instances;
    cfg.prefill_frac = n_pf as f64 / cfg.instances as f64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;

    fn table() -> ProfileTable {
        ProfileTable::from_cost_model(&CostModel::h200_llama8b())
    }

    #[test]
    fn decode_fleet_scales_linearly_with_rate() {
        let t = table();
        let tiers = TierSet::paper_default();
        let rates = [1.0, 2.0, 3.0, 4.0];
        let one = required_decode_fleet_f(&t, &tiers, &rates, 300.0, 3_000);
        let double: Vec<f64> = rates.iter().map(|r| r * 2.0).collect();
        let two = required_decode_fleet_f(&t, &tiers, &double, 300.0, 3_000);
        assert!(one > 0.0);
        assert!((two / one - 2.0).abs() < 1e-9, "Little's law is linear in rate");
    }

    #[test]
    fn tighter_tiers_need_more_servers_per_request() {
        let t = table();
        let tiers = TierSet::paper_default();
        // Same rate, all load in the tightest vs the loosest tier.
        let tight = required_decode_fleet_f(&t, &tiers, &[10.0, 0.0, 0.0, 0.0], 300.0, 3_000);
        let loose = required_decode_fleet_f(&t, &tiers, &[0.0, 0.0, 0.0, 10.0], 300.0, 3_000);
        // A 20 ms TPOT caps the batch far below the 100 ms tier, and the
        // shorter service time does not fully compensate at H200-like
        // batch knees.
        assert!(tight > 0.0 && loose > 0.0);
    }

    #[test]
    fn coloc_fleet_exceeds_pure_decode() {
        let t = table();
        let tiers = TierSet::paper_default();
        let rates = [2.0, 4.0, 6.0, 8.0];
        let dc = required_decode_fleet(&t, &tiers, &rates, 300.0, 3_000);
        let co = required_coloc_fleet(&t, &tiers, &rates, 1_000.0, 300.0, 3_000);
        assert!(co >= dc, "prefill share must not shrink the fleet: co={co} dc={dc}");
    }

    #[test]
    fn prefill_fleet_tracks_token_demand() {
        let t = table();
        let one = required_prefill_fleet(&t, 10.0, 1_000.0, 2_048);
        let four = required_prefill_fleet(&t, 40.0, 1_000.0, 2_048);
        assert!(four >= 4 * one - 3, "one={one} four={four}");
        assert_eq!(required_prefill_fleet(&t, 0.0, 1_000.0, 2_048), 1);
    }

    #[test]
    fn churn_pad_rounds_up_and_caps() {
        assert_eq!(churn_pad(0.0, 30_000), 0);
        assert_eq!(churn_pad(-1.0, 30_000), 0);
        // 1 kill / 20 s over a 30 s lead → expect 1.5 → pad 2.
        assert_eq!(churn_pad(1.0 / 20_000.0, 30_000), 2);
        // A spike can never demand more than one provision step.
        assert_eq!(churn_pad(1.0, 30_000), 8);
    }

    #[test]
    fn pd_split_keeps_both_sides_nonempty() {
        assert_eq!(split_pd_fleet(20, 0.35), (7, 13));
        assert_eq!(split_pd_fleet(2, 0.01), (1, 1));
        assert_eq!(split_pd_fleet(2, 0.99), (1, 1));
    }

    #[test]
    fn size_elastic_pd_cell_equal_peak() {
        let mut cfg = SimConfig::default();
        size_elastic_pd_cell(&mut cfg, 48, 0.25, |sp| sp / 4);
        assert_eq!(cfg.elastic.max_instances, 36);
        assert_eq!(cfg.elastic.min_instances, 9);
        assert_eq!(cfg.instances, 12 + 9);
        let n_pf = (cfg.prefill_frac * cfg.instances as f64).round() as usize;
        assert_eq!(n_pf, 12);
    }
}
