//! Baseline routing policies from §5.1.
//!
//! * [`RandomRouter`] — PD-Random / CO-Random: uniform random server.
//! * [`MinimalRouter`] — PD-Minimal / CO-Minimal: lowest predicted
//!   cycle-time server.
//! * [`ChunkRouter`] — CO-Chunk: chunked scheduler with a static
//!   maximum token budget (the budget is swept externally per the
//!   paper: "we iterate over different token budgets and select the
//!   one yielding either the highest SLO attainment or lowest number
//!   of servers").
//!
//! None of them bin by tier, manage auto-scaling, or do admission
//! control — every instance is `Static` and requests are placed
//! immediately.
//!
//! Per-placement cost: the candidate sets come from the cluster's
//! role indices and `load_estimate`/`queued_prefill_tokens` read the
//! instances' cached O(1) load counters, so even these full-fleet
//! min-scans are O(fleet) with O(1) work per candidate — no rescans of
//! resident requests. (The *load-ordered* tier indices are a
//! PolyServe-router concern: baselines place by full-role min scans,
//! which need every candidate anyway, so an ordered walk buys them
//! nothing.)

use super::admission::load_estimate;
use super::autoscaler::scaling_role;
use super::{RouteCtx, Router};
use crate::analysis::ServingMode;
use crate::sim::Role;
use crate::slo::TimeMs;
use crate::util::rng::Rng;

/// Default chunked-prefill token budget for the non-Chunk baselines
/// (the common serving-engine default).
const DEFAULT_BUDGET: u64 = 512;

fn entry_role(mode: ServingMode) -> Role {
    match mode {
        ServingMode::PdDisaggregated => Role::Prefill,
        ServingMode::Colocated => Role::Coloc,
    }
}

// Decode phases live on the scaling role (decode servers under PD, the
// coloc servers themselves under co-location); `route_decode` reaches
// the coloc case only for scale-in migration re-placement.
//
// Loaded model is a hard placement constraint even for baselines: every
// candidate walk goes through `with_role_of(model, role)`, which is the
// plain role index filtered by the request's model — identical
// iteration order (and decisions) to `with_role` when one model is
// deployed.

// ---------------------------------------------------------------- Random

/// PD-Random / CO-Random: uniform random placement.
pub struct RandomRouter {
    rng: Rng,
}

impl RandomRouter {
    /// Build with a deterministic RNG seed.
    pub fn new(seed: u64) -> RandomRouter {
        RandomRouter { rng: Rng::new(seed) }
    }

    fn pick_random(&mut self, ids: &[usize]) -> Option<usize> {
        if ids.is_empty() {
            None
        } else {
            Some(ids[self.rng.below(ids.len() as u64) as usize])
        }
    }
}

impl Router for RandomRouter {
    fn route_new(&mut self, _now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> Option<usize> {
        let model = ctx.requests[req_idx].req.model;
        let ids: Vec<usize> = ctx.cluster.with_role_of(model, entry_role(ctx.mode)).collect();
        self.pick_random(&ids)
    }

    fn route_decode(&mut self, _now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> Option<usize> {
        let model = ctx.requests[req_idx].req.model;
        let ids: Vec<usize> = ctx.cluster.with_role_of(model, scaling_role(ctx.mode)).collect();
        self.pick_random(&ids)
    }

    fn chunk_budget(&mut self, _now: TimeMs, inst: usize, ctx: &mut RouteCtx) -> u64 {
        match ctx.cluster.instances[inst].role {
            Role::Prefill => 2048,
            Role::Decode => 0,
            Role::Coloc => DEFAULT_BUDGET,
        }
    }

    fn on_iter_end(&mut self, _now: TimeMs, _inst: usize, _ctx: &mut RouteCtx) {}
    fn on_tick(&mut self, _now: TimeMs, _ctx: &mut RouteCtx) {}

    fn name(&self) -> String {
        "Random".into()
    }
}

// --------------------------------------------------------------- Minimal

/// "Assigning requests to the lowest cycle-time server": cycle time is
/// the profile-predicted iteration time at the server's current state.
pub struct MinimalRouter;

impl MinimalRouter {
    #[allow(clippy::new_without_default)]
    pub fn new() -> MinimalRouter {
        MinimalRouter
    }

    fn pick_min_cycle(&self, ctx: &RouteCtx, model: crate::model::ModelId, role: Role) -> Option<usize> {
        ctx.cluster
            .with_role_of(model, role)
            .map(|id| {
                let est = load_estimate(&ctx.cluster.instances[id], ctx.requests, ctx.profile);
                // Prefill servers: cycle dominated by queued prefill work.
                let queued = ctx.cluster.instances[id].queued_prefill_tokens(ctx.requests);
                ((est.iter_now_ms * 1000.0) as u64 + queued, id)
            })
            .min()
            .map(|(_, id)| id)
    }
}

impl Router for MinimalRouter {
    fn route_new(&mut self, _now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> Option<usize> {
        self.pick_min_cycle(ctx, ctx.requests[req_idx].req.model, entry_role(ctx.mode))
    }

    fn route_decode(&mut self, _now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> Option<usize> {
        self.pick_min_cycle(ctx, ctx.requests[req_idx].req.model, scaling_role(ctx.mode))
    }

    fn chunk_budget(&mut self, _now: TimeMs, inst: usize, ctx: &mut RouteCtx) -> u64 {
        match ctx.cluster.instances[inst].role {
            Role::Prefill => 2048,
            Role::Decode => 0,
            Role::Coloc => DEFAULT_BUDGET,
        }
    }

    fn on_iter_end(&mut self, _now: TimeMs, _inst: usize, _ctx: &mut RouteCtx) {}
    fn on_tick(&mut self, _now: TimeMs, _ctx: &mut RouteCtx) {}

    fn name(&self) -> String {
        "Minimal".into()
    }
}

// ----------------------------------------------------------------- Chunk

/// CO-Chunk: least-loaded placement with a *static* chunked-prefill
/// token budget.
pub struct ChunkRouter {
    /// Static prefill token budget per iteration.
    pub budget: u64,
}

impl ChunkRouter {
    /// Build with a static chunked-prefill token budget (clamped ≥ 1).
    pub fn new(budget: u64) -> ChunkRouter {
        ChunkRouter { budget: budget.max(1) }
    }
}

impl Router for ChunkRouter {
    fn route_new(&mut self, _now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> Option<usize> {
        // Least loaded by predicted cycle time (the sensible static
        // chunk deployment; the paper leaves the baseline's placement
        // unspecified beyond the budget).
        let model = ctx.requests[req_idx].req.model;
        ctx.cluster
            .with_role_of(model, entry_role(ctx.mode))
            .map(|id| {
                let est = load_estimate(&ctx.cluster.instances[id], ctx.requests, ctx.profile);
                let queued = ctx.cluster.instances[id].queued_prefill_tokens(ctx.requests);
                ((est.iter_now_ms * 1000.0) as u64 + queued, id)
            })
            .min()
            .map(|(_, id)| id)
    }

    fn route_decode(&mut self, _now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> Option<usize> {
        let model = ctx.requests[req_idx].req.model;
        ctx.cluster
            .with_role_of(model, scaling_role(ctx.mode))
            .map(|id| {
                let est = load_estimate(&ctx.cluster.instances[id], ctx.requests, ctx.profile);
                ((est.iter_now_ms * 1000.0) as u64, id)
            })
            .min()
            .map(|(_, id)| id)
    }

    fn chunk_budget(&mut self, _now: TimeMs, inst: usize, ctx: &mut RouteCtx) -> u64 {
        match ctx.cluster.instances[inst].role {
            Role::Decode => 0,
            _ => self.budget,
        }
    }

    fn on_iter_end(&mut self, _now: TimeMs, _inst: usize, _ctx: &mut RouteCtx) {}
    fn on_tick(&mut self, _now: TimeMs, _ctx: &mut RouteCtx) {}

    fn name(&self) -> String {
        format!("Chunk({})", self.budget)
    }
}
