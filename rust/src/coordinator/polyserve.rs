//! The PolyServe router (§4): request binning, load-gradient routing,
//! lazy promotion, fine-grained auto-scaling, profile-based batch
//! formation, wait-time-aware scheduling, dynamic chunking (PD) and
//! continuous chunked-prefill prediction (CO).
//!
//! One struct serves both serving modes (the paper's PD-PolyServe and
//! CO-PolyServe): mode-specific behaviour lives in `route_new` /
//! `route_decode` / `chunk_budget`; binning, promotion and auto-scaling
//! are shared.

use super::admission::{self, load_estimate};
use super::sizing::{DEFAULT_PREFILL_BUDGET, PF_TOKEN_RATIO};
use super::{RouteCtx, Router};
use crate::analysis::ServingMode;
use crate::config::{Features, SimConfig};
use crate::model::ModelId;
use crate::profile::ProfileTable;
use crate::sim::{Role, TierAssign};
use crate::slo::{TierSet, TimeMs};
use std::collections::BTreeSet;

/// How long a late pending request may keep failing relaxed admission
/// before the liveness backstop places it unconditionally.
const FORCED_GRACE_MS: u64 = 2_000;

/// A request waiting for capacity in some tier. The ordering derives
/// exist only so entries can live in the deadline-keyed ordered set —
/// the `(deadline, seq)` key prefix is unique per entry, so the derived
/// order is never load-bearing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Pending {
    req_idx: usize,
    /// true = needs decode placement (PD); false = needs full placement.
    decode_phase: bool,
    /// When the request was parked (queue-aging diagnostics).
    pended_at: TimeMs,
}

/// The PolyServe router (§4). One struct serves both modes
/// (PD-PolyServe and CO-PolyServe); see the module docs.
pub struct PolyServeRouter {
    tiers: TierSet,
    features: Features,
    avg_decode_len: f64,
    /// Per-model profile tables for model-mix runs (empty in
    /// single-model configurations, where `ctx.profile` is the only
    /// timing oracle). Attached via [`Self::with_models`].
    profiles: Vec<ProfileTable>,
    /// Per-(model, tier) pending queues, flat `model × n_tiers + k`
    /// (§4.3: "requests start pending for one SLO tier"; the model
    /// axis keeps one model's head-of-line block from stalling
    /// another's dispatch). Single-model: exactly the per-tier layout.
    /// Grown lazily to the fleet's model count on first routing call.
    ///
    /// Entries are keyed `(deadline, seq, pending)`. With `[overload]`
    /// EDF on, `deadline` is the request's least-headroom key (TTFT
    /// deadline for fresh requests, next-token deadline for decode
    /// handoffs) frozen at park time — keys are immutable while queued,
    /// so the set order never goes stale. With EDF off every key is
    /// `(0, seq)` and iteration order is exactly the old FIFO
    /// insertion order, bit for bit.
    pending: Vec<BTreeSet<(TimeMs, u64, Pending)>>,
    /// Monotone tie-breaker for pending keys (also the FIFO order).
    seq: u64,
    /// Deadline-ordered (EDF) pending dispatch — `[overload]` on and
    /// not running the FIFO reference.
    edf: bool,
    /// Requests currently parked across all pending queues — lets
    /// `drain_pending` (called on every iteration end and tick) return
    /// in O(1) on the common all-placed fast path.
    pending_total: usize,
    /// Full candidate tier order per tier (own tier + promotion, or
    /// promotion-first under the eager ablation) — cached at
    /// construction so neither the placement ladder nor the
    /// relaxed/forced paths reallocate it per routed request. The bare
    /// promotion order is the slice of this with the own tier stripped
    /// ([`Self::promo_order`]), so there is a single source of truth.
    order: Vec<Vec<usize>>,
    mode: ServingMode,
    /// PD prefill static budget (dynamic chunking modulates it).
    prefill_budget: u64,
    /// Failure-domain steering hint ([`Router::set_avoid_zone`]): while
    /// set, placements prefer instances outside this zone (two-pass
    /// with the full fleet as fallback — never a hard filter). `None`
    /// on every run without a `[chaos]` domain model, leaving the
    /// placement walks bit-for-bit untouched.
    avoid_zone: Option<u32>,
    /// Diagnostics (logged at drop in debug level).
    pub stats: RouterStats,
}

/// Scheduling-event counters for diagnostics and tests.
#[derive(Debug, Default, Clone)]
pub struct RouterStats {
    /// Requests placed in their own tier on first try.
    pub placed_direct: u64,
    /// Requests placed in a tighter tier (lazy promotion).
    pub placed_promoted: u64,
    /// Requests parked in a pending queue.
    pub pends: u64,
    /// Late requests placed under relaxed admission.
    pub placed_relaxed: u64,
    /// Liveness-backstop forced placements.
    pub forced: u64,
    /// Instances claimed from the best-effort pool.
    pub claims: u64,
    /// Pending instances adopted into a tier.
    pub adoptions: u64,
    /// Instances released back to the pool.
    pub releases: u64,
    /// Instances moved to the §4.4 pending state.
    pub marked_pending: u64,
    /// Dispatches whose pending wait exceeded the relaxed-admission
    /// patience window ([`FORCED_GRACE_MS`]) — queue-aging diagnostic.
    pub aged_past_patience: u64,
    /// Longest observed pend→dispatch wait, ms.
    pub max_pend_ms: u64,
}

impl Drop for RouterStats {
    /// Log the scheduling-event counters when the router (and with it
    /// its stats) is dropped at the end of a run — the debug-level
    /// post-mortem the field doc promises.
    fn drop(&mut self) {
        log::debug!("router stats at drop: {self:?}");
    }
}

impl PolyServeRouter {
    /// Build from a config; `avg_decode_len` is the workload's mean output
    /// length, the only output-length knowledge the §4.5 predictors get.
    pub fn new(cfg: &SimConfig, avg_decode_len: f64) -> PolyServeRouter {
        let n_tiers = cfg.tiers.len();
        let order: Vec<Vec<usize>> = (0..n_tiers)
            .map(|k| {
                let mut o = Vec::with_capacity(k + 1);
                if cfg.features.eager_promotion {
                    o.extend(cfg.tiers.promotion_order(k)); // tighter first
                    o.push(k);
                } else {
                    o.push(k);
                    if cfg.features.lazy_promotion {
                        o.extend(cfg.tiers.promotion_order(k));
                    }
                }
                o
            })
            .collect();
        PolyServeRouter {
            tiers: cfg.tiers.clone(),
            features: cfg.features.clone(),
            avg_decode_len,
            profiles: Vec::new(),
            pending: (0..n_tiers).map(|_| BTreeSet::new()).collect(),
            pending_total: 0,
            seq: 0,
            edf: cfg.overload.edf(),
            order,
            mode: cfg.mode,
            prefill_budget: DEFAULT_PREFILL_BUDGET,
            avoid_zone: None,
            stats: RouterStats::default(),
        }
    }

    /// Attach per-model profile tables (model-id order) for a
    /// model-mix run: admission, chunk sizing and queue-feasibility
    /// estimates then consult the table of the instance's / request's
    /// model. With fewer than two tables this is a no-op, so
    /// single-model decision streams stay bit-for-bit unchanged.
    pub fn with_models(mut self, profiles: Vec<ProfileTable>) -> Self {
        if profiles.len() > 1 {
            self.profiles = profiles;
        }
        self
    }

    /// Timing oracle for `model`: the attached per-model table, or the
    /// run-wide `fallback` (always the case in single-model runs).
    fn profile_for<'p>(&'p self, fallback: &'p ProfileTable, model: ModelId) -> &'p ProfileTable {
        self.profiles.get(model).unwrap_or(fallback)
    }

    /// Flat index of `(model, tier)` in the pending-queue layout.
    fn pending_idx(&self, model: ModelId, k: usize) -> usize {
        model * self.tiers.len() + k
    }

    /// Grow the pending-queue layout to the fleet's model count (a
    /// no-op from the second call on, and entirely for single-model
    /// fleets, whose layout is already complete at construction).
    fn ensure_models(&mut self, ctx: &RouteCtx) {
        let need = ctx.cluster.num_models * self.tiers.len();
        if self.pending.len() < need {
            self.pending.resize_with(need, BTreeSet::new);
        }
    }

    /// Ordering key for a request about to be parked: `(deadline, seq)`.
    /// EDF keys on the least-headroom deadline *frozen at park time* —
    /// TTFT deadline for fresh requests, next-token deadline for decode
    /// handoffs; both are immutable while the request waits (nothing
    /// advances its tracker), so the set order cannot go stale. FIFO
    /// mode keys everything at deadline 0, leaving `seq` (monotone
    /// insertion order) as the sole order — exactly the old VecDeque.
    fn pend_key(&mut self, req_idx: usize, decode_phase: bool, ctx: &RouteCtx) -> (TimeMs, u64) {
        let deadline = if self.edf {
            let r = &ctx.requests[req_idx];
            if decode_phase {
                r.tracker.next_deadline()
            } else {
                r.ttft_deadline()
            }
        } else {
            0
        };
        let s = self.seq;
        self.seq += 1;
        (deadline, s)
    }

    /// Park a request in its (model, tier) pending queue.
    fn park(&mut self, now: TimeMs, req_idx: usize, decode_phase: bool, ctx: &RouteCtx) {
        let r = &ctx.requests[req_idx];
        let q = self.pending_idx(r.req.model, r.tier);
        let (deadline, s) = self.pend_key(req_idx, decode_phase, ctx);
        self.stats.pends += 1;
        self.pending_total += 1;
        self.pending[q].insert((
            deadline,
            s,
            Pending {
                req_idx,
                decode_phase,
                pended_at: now,
            },
        ));
    }

    /// Queue-aging bookkeeping on every pending dispatch.
    fn note_dispatch(&mut self, now: TimeMs, pended_at: TimeMs) {
        let waited = now.saturating_sub(pended_at);
        self.stats.max_pend_ms = self.stats.max_pend_ms.max(waited);
        if waited > FORCED_GRACE_MS {
            self.stats.aged_past_patience += 1;
        }
    }

    /// Candidate tier order for a tier-k request: own tier first, then
    /// (lazy promotion) tighter tiers nearest-first — or tighter tiers
    /// first under the eager-promotion ablation. Cached at construction.
    fn tier_order(&self, k: usize) -> &[usize] {
        &self.order[k]
    }

    /// The cached promotion order for tier `k`: [`Self::tier_order`]
    /// with the own tier stripped — the trailing `k` under eager
    /// promotion, the leading `k` otherwise (empty when no promotion
    /// feature is on, since the order is then just `[k]`).
    fn promo_order(&self, k: usize) -> &[usize] {
        let o = &self.order[k];
        if self.features.eager_promotion {
            &o[..o.len() - 1]
        } else {
            &o[1..]
        }
    }

    /// Pick the §4.3 load-gradient target in `tier` that passes
    /// `admit`: highest load first (or lowest when the load-gradient
    /// feature is ablated off).
    ///
    /// Default path: walk the cluster's load-ordered tier index with
    /// early exit at the first admission — descending `(batch, kv, id)`
    /// forward, or the same set reversed for the ablation — O(probed)
    /// per placement with no allocation and no sort. The reference
    /// modes reproduce the older per-placement costs bit-for-bit: the
    /// PR-4 indexed mode materializes the tier and sorts it (cached
    /// O(1) load reads underneath), scan mode does the same over the
    /// full-scan membership views with rescanning load accessors.
    fn pick_by_gradient(
        &self,
        ctx: &RouteCtx,
        model: ModelId,
        tier: usize,
        admit: impl Fn(&RouteCtx, usize) -> bool,
    ) -> Option<usize> {
        // Failure-domain steering: with an avoid-zone hint active (only
        // ever during victim re-placement after a kill, with `[chaos]
        // zones` set), prefer a target outside the blast radius — the
        // unmodified full walk is the fallback, so a fleet with
        // capacity only inside the avoided zone still places.
        if let Some(z) = self.avoid_zone {
            let found = self.pick_in_tier(ctx, model, tier, |c, id| {
                c.cluster.instances[id].domain.0 != z && admit(c, id)
            });
            if found.is_some() {
                return found;
            }
        }
        self.pick_in_tier(ctx, model, tier, admit)
    }

    /// The unhinted §4.3 walk behind [`Self::pick_by_gradient`] (which
    /// layers the avoid-zone pass on top).
    fn pick_in_tier(
        &self,
        ctx: &RouteCtx,
        model: ModelId,
        tier: usize,
        admit: impl Fn(&RouteCtx, usize) -> bool,
    ) -> Option<usize> {
        if ctx.cluster.is_scan_reference() || ctx.cluster.is_indexed_reference() {
            let prof = self.profile_for(ctx.profile, model);
            let mut scored: Vec<(u64, u64, usize)> = ctx
                .cluster
                .in_tier_of(model, tier)
                .map(|id| {
                    let est = load_estimate(&ctx.cluster.instances[id], ctx.requests, prof);
                    (est.batch, est.kv_now, id)
                })
                .collect();
            if self.features.load_gradient {
                scored.sort_unstable_by(|a, b| b.cmp(a)); // highest load first
            } else {
                scored.sort_unstable(); // least loaded first (ablation)
            }
            return scored
                .into_iter()
                .map(|(_, _, id)| id)
                .find(|&id| admit(ctx, id));
        }
        if self.features.load_gradient {
            ctx.cluster
                .tier_by_load_desc_of(model, tier)
                .find(|&id| admit(ctx, id))
        } else {
            ctx.cluster
                .tier_by_load_asc_of(model, tier)
                .find(|&id| admit(ctx, id))
        }
    }

    /// Try to place a decode-phase request on tier-k (with promotion).
    ///
    /// `relaxed` drops the per-request deadline check (§4.6) for
    /// requests that are already late: their own token is unavoidably
    /// delayed, but the steady-state TPOT check still protects the
    /// server's resident requests from being poisoned.
    fn place_decode(
        &self,
        now: TimeMs,
        req_idx: usize,
        relaxed: bool,
        tiers_to_try: &[usize],
        ctx: &mut RouteCtx,
    ) -> Option<usize> {
        let r = &ctx.requests[req_idx];
        let model = r.req.model;
        let kv_start = r.kv_now().max(r.req.prefill_len as u64);
        let next_deadline = if relaxed {
            TimeMs::MAX / 4
        } else {
            r.tracker.next_deadline()
        };
        let prof = self.profile_for(ctx.profile, model);
        for &tier in tiers_to_try {
            let tpot = self.tiers.tier(tier).tpot_ms;
            // No materialized candidate list: the ordered walk feeds
            // the admission check directly.
            let found = self.pick_by_gradient(ctx, model, tier, |c, id| {
                admission::admit_decode(
                    &c.cluster.instances[id],
                    c.requests,
                    prof,
                    tpot,
                    kv_start,
                    next_deadline,
                    now,
                    self.avg_decode_len,
                    self.features.wait_time_aware && !relaxed,
                )
            });
            if found.is_some() {
                return found;
            }
        }
        None
    }

    /// Try to place a fresh request on a coloc tier-k instance.
    /// `relaxed` as in [`Self::place_decode`]: the request's own TTFT is
    /// already lost, so only server-proting checks remain.
    fn place_coloc(
        &self,
        now: TimeMs,
        req_idx: usize,
        relaxed: bool,
        tiers_to_try: &[usize],
        ctx: &mut RouteCtx,
    ) -> Option<usize> {
        let r = &ctx.requests[req_idx];
        let model = r.req.model;
        let prefill_len = (r.req.prefill_len - r.prefill_done) as u64;
        let (ttft_deadline, next_token_deadline) = if relaxed {
            (TimeMs::MAX / 4, TimeMs::MAX / 4)
        } else {
            let t = r.ttft_deadline();
            (t, t + r.req.slo.tpot_ms)
        };
        let prof = self.profile_for(ctx.profile, model);
        for &tier in tiers_to_try {
            let tpot = self.tiers.tier(tier).tpot_ms;
            let found = self.pick_by_gradient(ctx, model, tier, |c, id| {
                admission::admit_coloc(
                    &c.cluster.instances[id],
                    c.requests,
                    prof,
                    tpot,
                    prefill_len,
                    ttft_deadline,
                    next_token_deadline,
                    now,
                    self.avg_decode_len,
                    PF_TOKEN_RATIO,
                    self.features.wait_time_aware && !relaxed,
                    self.features.continuous_chunk_prediction,
                )
            });
            if found.is_some() {
                return found;
            }
        }
        None
    }


    /// The §4.3/§4.4 placement ladder for a tier-k request:
    /// 1. own tier (load-gradient + admission);
    /// 2. grow the own tier (adopt a Pending instance / claim from the
    ///    best-effort pool) and place there;
    /// 3. lazy promotion: spill to tighter tiers *only when the own
    ///    tier cannot grow* (pool exhausted) — §4.4 "if and only if the
    ///    current cluster is full";
    /// 4. fail (caller pends the request).
    /// Under the eager-promotion ablation, step 3 runs before step 2.
    fn placement_ladder(
        &mut self,
        now: TimeMs,
        req_idx: usize,
        decode_phase: bool,
        ctx: &mut RouteCtx,
    ) -> Option<usize> {
        let k = ctx.requests[req_idx].tier;
        let model = ctx.requests[req_idx].req.model;
        if self.features.eager_promotion {
            if let Some(id) =
                self.place_in(now, req_idx, decode_phase, false, self.promo_order(k), ctx)
            {
                self.stats.placed_promoted += 1;
                return Some(id);
            }
        }
        if let Some(id) = self.place_in(now, req_idx, decode_phase, false, &[k], ctx) {
            self.stats.placed_direct += 1;
            return Some(id);
        }
        if self.scale_up(model, k, now, ctx).is_some() {
            if let Some(id) = self.place_in(now, req_idx, decode_phase, false, &[k], ctx) {
                self.stats.placed_direct += 1;
                return Some(id);
            }
        }
        if !self.features.eager_promotion {
            if let Some(id) =
                self.place_in(now, req_idx, decode_phase, false, self.promo_order(k), ctx)
            {
                self.stats.placed_promoted += 1;
                return Some(id);
            }
        }
        None
    }

    /// Phase dispatch for the ladder and the relaxed pending path: try
    /// `tiers` in order with the matching placement routine.
    fn place_in(
        &self,
        now: TimeMs,
        req_idx: usize,
        decode_phase: bool,
        relaxed: bool,
        tiers: &[usize],
        ctx: &mut RouteCtx,
    ) -> Option<usize> {
        if decode_phase {
            self.place_decode(now, req_idx, relaxed, tiers, ctx)
        } else {
            self.place_coloc(now, req_idx, relaxed, tiers, ctx)
        }
    }

    /// Scale up `model`'s tier `k`: claim from the model's BE pool, or
    /// adopt one of its Pending instances (§4.4). Returns the instance
    /// id if one was obtained. The hard placement constraint lives
    /// here too: a tier only ever grows by instances already serving
    /// the model (weight swaps are the autoscaler's job, not the
    /// router's).
    fn scale_up(
        &mut self,
        model: ModelId,
        k: usize,
        now: TimeMs,
        ctx: &mut RouteCtx,
    ) -> Option<usize> {
        // Prefer a Pending instance (it already holds promoted tier-k
        // requests — adopting avoids a cold start). The pending pool is
        // indexed: only actual Pending instances of the model are
        // visited.
        let pending_inst = ctx
            .cluster
            .pending_pool_of(model)
            .find(|&id| self.instance_hosts_tier(id, k, ctx));
        if let Some(id) = pending_inst {
            ctx.cluster.adopt_pending(id, k);
            self.stats.adoptions += 1;
            return Some(id);
        }
        let claimed = ctx.cluster.claim_for_tier_of(model, k, now);
        if claimed.is_some() {
            self.stats.claims += 1;
        }
        claimed
    }

    fn instance_hosts_tier(&self, id: usize, k: usize, ctx: &RouteCtx) -> bool {
        let inst = &ctx.cluster.instances[id];
        inst.running
            .iter()
            .map(|s| ctx.requests[s.req_idx].tier)
            .chain(inst.prefill_queue.iter().map(|j| ctx.requests[j.req_idx].tier))
            .chain(inst.decode_queue.iter().map(|&(r, _)| ctx.requests[r].tier))
            .any(|t| t == k)
    }

    /// Dispatch as many pending requests as possible; claim servers for
    /// tiers that stay blocked. Forced placement for requests whose
    /// deadline already passed (they can't be aborted — §3.6 — so they
    /// run on the least-loaded native-tier server and eat the miss).
    fn drain_pending(&mut self, now: TimeMs, ctx: &mut RouteCtx) {
        if self.pending_total == 0 {
            return; // O(1) fast path: nothing parked anywhere
        }
        let n_tiers = self.tiers.len();
        for q in 0..self.pending.len() {
            // Flat (model, tier) layout; in a single-model run `q` is
            // the tier index itself.
            let k = q % n_tiers;
            loop {
                let Some(&(dkey, skey, head)) = self.pending[q].first() else { break };
                let placed = self.placement_ladder(now, head.req_idx, head.decode_phase, ctx);
                let placed = match placed {
                    Some(id) => Some(id),
                    None => {
                        // Already-late requests (§3.6: they cannot be
                        // aborted) get relaxed admission: their own
                        // deadline check is moot, but the steady-state
                        // TPOT check still protects server residents.
                        let r = &ctx.requests[head.req_idx];
                        let deadline = if head.decode_phase {
                            r.tracker.next_deadline()
                        } else {
                            r.ttft_deadline()
                        };
                        if now >= deadline {
                            let relaxed = self.place_in(
                                now,
                                head.req_idx,
                                head.decode_phase,
                                true,
                                &self.order[k],
                                ctx,
                            );
                            match relaxed {
                                Some(id) => {
                                    self.stats.placed_relaxed += 1;
                                    Some(id)
                                }
                                // Liveness backstop: if even relaxed
                                // admission has failed for a long grace
                                // period, place on the least-loaded
                                // server no matter what.
                                None if now >= deadline + FORCED_GRACE_MS => {
                                    let model = ctx.requests[head.req_idx].req.model;
                                    let t = self.forced_target(model, k, ctx);
                                    if t.is_some() {
                                        self.stats.forced += 1;
                                    }
                                    t
                                }
                                None => None,
                            }
                        } else {
                            None
                        }
                    }
                };
                match placed {
                    Some(id) => {
                        self.pending[q].remove(&(dkey, skey, head));
                        self.pending_total -= 1;
                        self.note_dispatch(now, head.pended_at);
                        self.enqueue_on(id, head, now, ctx);
                    }
                    // Head blocked: EDF head-of-line per (model, tier)
                    // (FIFO head when the reference mode keys at 0).
                    None => break,
                }
            }
        }
    }

    /// Liveness fallback target: least-loaded instance in the request's
    /// own tier, else in a tighter tier, else in a Pending state, else
    /// claim anything from the pool, else the least-loaded serving
    /// instance of the right role cluster. Read-only and collect-free:
    /// each candidate view feeds the min-scan directly (same ascending
    /// id order as the old materialized lists, so ties resolve
    /// identically), and the pending step walks the cluster's ordered
    /// pending twin instead of min-scanning on the default path.
    fn forced_target(&self, model: ModelId, k: usize, ctx: &RouteCtx) -> Option<usize> {
        fn least_loaded(ctx: &RouteCtx, ids: impl Iterator<Item = usize>) -> Option<usize> {
            ids.min_by_key(|&id| {
                let i = &ctx.cluster.instances[id];
                (i.decode_batch_now(), i.queued_prefill_tokens(ctx.requests))
            })
        }
        // Every fallback stage is model-filtered: even the liveness
        // backstop may not cross the hard placement constraint (an
        // instance cannot run a model it hasn't loaded).
        for &tier in self.tier_order(k) {
            if let Some(id) = least_loaded(ctx, ctx.cluster.in_tier_of(model, tier)) {
                return Some(id);
            }
        }
        // Any pending-state instance (that still accepts work — the
        // elastic fleet may be draining some). Default path: the first
        // entry of the pending pool's ordered twin — ascending
        // `(batch, queued prefill, id)`, exactly the min-scan's pick
        // (`min_by_key` over the ascending-id view returns the
        // lexicographic minimum). Reference modes keep the min-scan.
        let pend = if ctx.cluster.is_scan_reference() || ctx.cluster.is_indexed_reference() {
            least_loaded(ctx, ctx.cluster.pending_pool_of(model))
        } else {
            ctx.cluster.pending_by_load_of(model).next()
        };
        if let Some(id) = pend {
            return Some(id);
        }
        // Anything serving the right role (looser tiers included).
        let role = match self.mode {
            ServingMode::PdDisaggregated => Role::Decode,
            ServingMode::Colocated => Role::Coloc,
        };
        if let Some(id) = least_loaded(
            ctx,
            ctx.cluster
                .with_role_of(model, role)
                .filter(|&id| ctx.cluster.assign_of(id) != TierAssign::BestEffort),
        ) {
            return Some(id);
        }
        least_loaded(ctx, ctx.cluster.with_role_of(model, role))
    }

    fn enqueue_on(&self, id: usize, p: Pending, now: TimeMs, ctx: &mut RouteCtx) {
        let kv_transfer_ms = ctx.kv_transfer_ms;
        if p.decode_phase {
            // The KV handoff costs `kv_transfer_ms` no matter how the
            // request got here: a pended dispatch pays the same delay
            // as the simulator's direct route_decode path.
            ctx.requests[p.req_idx].decode_instance = Some(id);
            ctx.cluster.instances[id].push_decode(
                p.req_idx,
                now + kv_transfer_ms,
                ctx.requests,
            );
        } else {
            let r = &ctx.requests[p.req_idx];
            let deadline = r.ttft_deadline();
            ctx.cluster.instances[id].push_prefill(
                crate::sim::PrefillJob {
                    req_idx: p.req_idx,
                    deadline,
                },
                ctx.requests,
            );
        }
        // Pended dispatch mutates instance load outside the simulator's
        // own sites: re-key here so the ordered indices never go stale.
        ctx.cluster.refresh_load(id);
        ctx.cluster.mark_kicked(id);
    }

    /// §4.3/§4.4 down-scaling sweep.
    fn autoscale_down(&mut self, now: TimeMs, inst: usize, ctx: &mut RouteCtx) {
        match ctx.cluster.assign_of(inst) {
            TierAssign::Tier(k) => {
                let i = &ctx.cluster.instances[inst];
                let q = self.pending_idx(i.model, k);
                if i.is_empty() {
                    if self.pending[q].is_empty() {
                        ctx.cluster.release(inst, now);
                        self.stats.releases += 1;
                    }
                } else if self.features.lazy_promotion && !self.instance_hosts_tier(inst, k, ctx)
                {
                    // Only promoted lower-tier requests remain (§4.4):
                    // move to the pending list.
                    ctx.cluster.mark_pending(inst);
                    self.stats.marked_pending += 1;
                }
            }
            TierAssign::Pending => {
                if ctx.cluster.instances[inst].is_empty() {
                    ctx.cluster.release(inst, now);
                    self.stats.releases += 1;
                }
            }
            _ => {}
        }
    }

    /// Simulate an instance's EDF prefill queue with `new_job` inserted:
    /// returns the new job's estimated finish time if *every* queued
    /// job (including those displaced by the EDF insert) still meets
    /// its own TTFT deadline, else None.
    ///
    /// Public for regression tests: the inserted job is identified by
    /// its queue *position*, never by `(deadline, rem)` equality — a
    /// queued job with the same pair must not stand in for it.
    pub fn prefill_queue_feasible(
        &self,
        now: TimeMs,
        inst: usize,
        new_rem: u64,
        new_deadline: TimeMs,
        ctx: &RouteCtx,
    ) -> Option<f64> {
        let i = &ctx.cluster.instances[inst];
        let prof = self.profile_for(ctx.profile, i.model);
        let wait = if self.features.wait_time_aware {
            i.wait_ms(now)
        } else {
            0
        };
        // (deadline, remaining tokens) in EDF order with the new job.
        // Each job's deadline is reduced by its own TPOT: finishing the
        // prefill exactly at TTFT leaves the decode placement zero
        // slack and the §4.6 wait-time check then rejects every loaded
        // server — one TPOT of headroom keeps token 1 schedulable.
        let mut jobs: Vec<(TimeMs, u64)> = i
            .prefill_queue
            .iter()
            .map(|j| {
                let r = &ctx.requests[j.req_idx];
                (
                    j.deadline.saturating_sub(r.req.slo.tpot_ms),
                    (r.req.prefill_len - r.prefill_done) as u64,
                )
            })
            .collect();
        let pos = jobs
            .iter()
            .position(|&(d, _)| d > new_deadline)
            .unwrap_or(jobs.len());
        jobs.insert(pos, (new_deadline, new_rem));

        // Per-chunk time estimate at the packed budget.
        let eff = (self.prefill_budget as f64 * PF_TOKEN_RATIO).ceil() as u64;
        let chunk_ms = prof.iter_ms(eff.max(1), self.prefill_budget);
        let ms_per_token = chunk_ms / self.prefill_budget as f64;
        let mut t = now as f64 + wait as f64;
        let mut new_finish = f64::INFINITY;
        for (i, (deadline, rem)) in jobs.into_iter().enumerate() {
            // Iteration-count overhead: each extra iteration pays the
            // fixed cost baked into chunk_ms via ms_per_token.
            t += rem as f64 * ms_per_token;
            if t > deadline as f64 {
                return None;
            }
            if i == pos {
                new_finish = t;
            }
        }
        Some(new_finish)
    }

    /// PD: route a fresh request to a prefill server — the highest-load
    /// server whose whole EDF queue (with this request inserted) still
    /// meets every TTFT (§4.2 + §4.3 + §4.7 "reroutes to other machines
    /// if PolyServe predicts a TTFT violation").
    fn place_prefill_pd(&self, now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> usize {
        // Failure-domain steering, same two-pass shape as
        // [`Self::pick_by_gradient`]: prefer prefill servers outside
        // the avoided zone, full cluster as fallback.
        if let Some(z) = self.avoid_zone {
            if let Some(id) = self.place_prefill_pd_pass(now, req_idx, Some(z), ctx) {
                return id;
            }
        }
        self.place_prefill_pd_pass(now, req_idx, None, ctx)
            .expect("PD cluster without prefill servers")
    }

    /// One scoring pass of [`Self::place_prefill_pd`], optionally
    /// skipping a failure zone (`None` = the unhinted full walk).
    fn place_prefill_pd_pass(
        &self,
        now: TimeMs,
        req_idx: usize,
        skip_zone: Option<u32>,
        ctx: &mut RouteCtx,
    ) -> Option<usize> {
        let r = &ctx.requests[req_idx];
        let model = r.req.model;
        let own_tokens = r.req.prefill_len as u64;
        let deadline = r.ttft_deadline().saturating_sub(r.req.slo.tpot_ms);
        // Collect-free: the role view feeds the scoring loop directly
        // (same ascending id order as the old materialized list). The
        // first candidate always seeds the fallback, so the old
        // `ids[0]` initialization is subsumed. Candidates come from the
        // request's model only — the hard placement constraint.
        let mut best_feasible: Option<(u64, usize)> = None; // (load, id)
        let mut best_fallback: Option<(f64, usize)> = None; // (finish/est, id)
        for id in ctx.cluster.with_role_of(model, Role::Prefill) {
            if skip_zone.is_some_and(|z| ctx.cluster.instances[id].domain.0 == z) {
                continue;
            }
            let queued = ctx.cluster.instances[id].queued_prefill_tokens(ctx.requests);
            let fallback_est = best_fallback.map_or(f64::INFINITY, |(e, _)| e);
            match self.prefill_queue_feasible(now, id, own_tokens, deadline, ctx) {
                Some(finish) => {
                    let better = match best_feasible {
                        Some((s, _)) => {
                            if self.features.load_gradient {
                                queued > s
                            } else {
                                queued < s
                            }
                        }
                        None => true,
                    };
                    if better {
                        best_feasible = Some((queued, id));
                    }
                    if finish < fallback_est {
                        best_fallback = Some((finish, id));
                    }
                }
                None => {
                    // Infeasible queue: fall back by queue length so an
                    // overloaded cluster still spreads.
                    let est = now as f64 + queued as f64;
                    if best_feasible.is_none() && est < fallback_est {
                        best_fallback = Some((est, id));
                    }
                }
            }
        }
        best_feasible
            .map(|(_, id)| id)
            .or_else(|| best_fallback.map(|(_, id)| id))
    }
}

impl Router for PolyServeRouter {
    fn set_avoid_zone(&mut self, zone: Option<u32>) {
        self.avoid_zone = zone;
    }

    fn route_new(&mut self, now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> Option<usize> {
        self.ensure_models(ctx);
        match self.mode {
            ServingMode::PdDisaggregated => Some(self.place_prefill_pd(now, req_idx, ctx)),
            ServingMode::Colocated => {
                if let Some(id) = self.placement_ladder(now, req_idx, false, ctx) {
                    return Some(id);
                }
                self.park(now, req_idx, false, ctx);
                None
            }
        }
    }

    fn route_decode(&mut self, now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> Option<usize> {
        // PD prefill→decode handoffs, and — in either serving mode —
        // decode requests evicted from a draining server (scale-in KV
        // migration) that need a surviving host.
        self.ensure_models(ctx);
        if let Some(id) = self.placement_ladder(now, req_idx, true, ctx) {
            return Some(id);
        }
        self.park(now, req_idx, true, ctx);
        None
    }

    fn chunk_budget(&mut self, now: TimeMs, inst: usize, ctx: &mut RouteCtx) -> u64 {
        let _ = now;
        let i = &ctx.cluster.instances[inst];
        match i.role {
            Role::Prefill => {
                // §4.7 dynamic chunking: if the head job's remainder is
                // under 2× the budget, take it all in one iteration (and
                // nothing else fills the gap — form_batch packs only up
                // to this budget).
                if !self.features.dynamic_chunking {
                    return self.prefill_budget;
                }
                // §4.7: when the head job's remainder is between 1× and
                // 2× the budget, take it all in one iteration *without
                // admitting new requests to fill the gap* (form_batch
                // packs only up to the returned budget, so the extended
                // chunk occupies it exactly). Smaller remainders pack
                // with other queued jobs at the normal budget.
                match i.prefill_queue.front() {
                    Some(job) => {
                        let r = &ctx.requests[job.req_idx];
                        let remaining = (r.req.prefill_len - r.prefill_done) as u64;
                        if remaining > self.prefill_budget
                            && remaining <= 2 * self.prefill_budget
                        {
                            remaining
                        } else {
                            self.prefill_budget
                        }
                    }
                    None => self.prefill_budget,
                }
            }
            Role::Decode => 0,
            Role::Coloc => {
                // TPOT-derived chunk for this instance's tier; Pending /
                // BE instances pace at the loosest tier.
                let tpot = match ctx.cluster.assign_of(inst) {
                    TierAssign::Tier(k) => self.tiers.tier(k).tpot_ms,
                    _ => self.tiers.tier(self.tiers.len() - 1).tpot_ms,
                };
                let prof = self.profile_for(ctx.profile, i.model);
                let est = load_estimate(i, ctx.requests, prof);
                admission::max_chunk_under(
                    prof,
                    tpot as f64,
                    est.batch,
                    est.kv_now,
                    PF_TOKEN_RATIO,
                )
            }
        }
    }

    fn on_iter_end(&mut self, now: TimeMs, inst: usize, ctx: &mut RouteCtx) {
        self.drain_pending(now, ctx);
        self.autoscale_down(now, inst, ctx);
    }

    fn on_tick(&mut self, now: TimeMs, ctx: &mut RouteCtx) {
        self.drain_pending(now, ctx);
        // Sweep: any tier instance that drained between its own
        // iterations (e.g. became empty via decode completions). Only
        // Tier/Pending-assigned instances can act here, so the sweep
        // visits exactly those (ascending id, like the old full loop —
        // every skipped instance was a no-op arm).
        for inst in ctx.cluster.assigned_ids() {
            self.autoscale_down(now, inst, ctx);
        }
    }

    fn name(&self) -> String {
        match self.mode {
            ServingMode::PdDisaggregated => "PD-PolyServe".into(),
            ServingMode::Colocated => "CO-PolyServe".into(),
        }
    }

    fn diagnostics(&self) -> String {
        format!("{:?}", self.stats)
    }

    /// The `[overload]` arrival-edge feasibility check: price the
    /// request against its model's profile table across its whole tier
    /// ladder (own tier + promotion order). Accept iff some serving
    /// instance passes the role-matched §4.5/§4.6 predictor
    /// ([`admission::feasible_at_arrival`]) — or the tier can still
    /// grow (an adoptable Pending instance or a claimable best-effort
    /// server), in which case the placement ladder will scale up and
    /// the request is not hopeless. Best-effort requests always pass:
    /// they have no deadline to protect.
    fn admit_at_arrival(&self, now: TimeMs, req_idx: usize, ctx: &RouteCtx) -> bool {
        let r = &ctx.requests[req_idx];
        if r.req.slo.is_best_effort() {
            return true;
        }
        let model = r.req.model;
        let k = r.tier;
        let prof = self.profile_for(ctx.profile, model);
        let prefill_len = (r.req.prefill_len - r.prefill_done) as u64;
        let ttft_deadline = r.ttft_deadline();
        let can_grow = |ctx: &RouteCtx| {
            ctx.cluster.pending_pool_of(model).next().is_some()
                || ctx.cluster.best_effort_pool_of(model).next().is_some()
        };
        match self.mode {
            ServingMode::Colocated => {
                for &tier in self.tier_order(k) {
                    let tpot = self.tiers.tier(tier).tpot_ms;
                    let ok = ctx.cluster.in_tier_of(model, tier).any(|id| {
                        admission::feasible_at_arrival(
                            &ctx.cluster.instances[id],
                            ctx.requests,
                            prof,
                            tpot,
                            prefill_len,
                            ttft_deadline,
                            ttft_deadline + r.req.slo.tpot_ms,
                            now,
                            self.avg_decode_len,
                            PF_TOKEN_RATIO,
                            self.prefill_budget,
                            self.features.wait_time_aware,
                            self.features.continuous_chunk_prediction,
                        )
                    });
                    if ok {
                        return true;
                    }
                }
                can_grow(ctx)
            }
            ServingMode::PdDisaggregated => {
                // Prefill side: some prefill server's whole EDF queue
                // (with this request inserted) still meets every TTFT.
                let deadline = ttft_deadline.saturating_sub(r.req.slo.tpot_ms);
                let prefill_ok = ctx.cluster.with_role_of(model, Role::Prefill).any(|id| {
                    self.prefill_queue_feasible(now, id, prefill_len, deadline, ctx)
                        .is_some()
                });
                if !prefill_ok {
                    return false;
                }
                // Decode side: after prefill the whole context is KV —
                // some ladder-tier server must admit that load (the
                // wait-time check is moot this far ahead of the
                // handoff), or the tier must still be growable.
                let kv_start = r.req.prefill_len as u64;
                for &tier in self.tier_order(k) {
                    let tpot = self.tiers.tier(tier).tpot_ms;
                    let ok = ctx.cluster.in_tier_of(model, tier).any(|id| {
                        admission::admit_decode(
                            &ctx.cluster.instances[id],
                            ctx.requests,
                            prof,
                            tpot,
                            kv_start,
                            ttft_deadline + r.req.slo.tpot_ms,
                            now,
                            self.avg_decode_len,
                            false,
                        )
                    });
                    if ok {
                        return true;
                    }
                }
                can_grow(ctx)
            }
        }
    }

    fn queue_aging(&self) -> Option<(u64, u64)> {
        Some((self.stats.aged_past_patience, self.stats.max_pend_ms))
    }
}
