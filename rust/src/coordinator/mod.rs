//! Routing policies — the paper's contribution (PolyServe) and the
//! §5.1 baselines, all behind one [`Router`] trait consumed by both the
//! discrete-event simulator and the live PJRT server.
//!
//! * [`polyserve`] — request binning, load-gradient routing, lazy
//!   promotion, fine-grained auto-scaling, profile-based batch
//!   formation, wait-time-aware scheduling, dynamic chunking and
//!   continuous chunked-prefill prediction (§4).
//! * [`baselines`] — Random, Minimal (lowest cycle-time), and the
//!   static-budget CO-Chunk scheduler.
//! * [`admission`] — the shared §4.5/§4.6 predictors: future-KV
//!   simulation, profile-table iteration-time estimates, wait-time-aware
//!   deadline checks.
//! * [`autoscaler`] — fleet-level elastic scaling: the §4.4
//!   load-gradient scaler, the reactive threshold baseline, and the
//!   predictive profile-driven planner (plus the TTFT-pressure signal
//!   for the elastic PD prefill tier).
//! * [`sizing`] — the shared fleet-sizing math (profile + Little's
//!   law) consumed by the predictive scaler and the bench harnesses.

pub mod admission;
pub mod autoscaler;
pub mod baselines;
pub mod polyserve;
pub mod sharded;
pub mod sizing;

pub use autoscaler::{
    make_autoscaler, make_autoscaler_with_models, migration_feasible, prefill_migration_feasible,
    scaling_role, ttft_pressure, Autoscaler, GradientAutoscaler, ModelMixPlanner,
    PredictiveAutoscaler, ScaleAction, ThresholdAutoscaler,
};
pub use baselines::{ChunkRouter, MinimalRouter, RandomRouter};
pub use polyserve::PolyServeRouter;
pub use sharded::ShardedRouter;

use crate::analysis::ServingMode;
use crate::config::{Policy, SimConfig};
use crate::profile::ProfileTable;
use crate::sim::{Cluster, SimRequest};
use crate::slo::TimeMs;

/// Mutable view the simulator hands to the router on every decision.
/// `'w` is the workload borrow carried by the request arena (the
/// [`SimRequest`]s borrow their immutable halves from the workload);
/// it outlives the view's own borrow `'a`.
pub struct RouteCtx<'a, 'w> {
    /// Current simulated time, ms.
    pub now: TimeMs,
    /// The fleet (mutable: routers claim/release/queue onto instances).
    pub cluster: &'a mut Cluster,
    /// Every request of the run, indexed by `req_idx`.
    pub requests: &'a mut [SimRequest<'w>],
    /// The profiling table — the router's only timing oracle (§4.5).
    pub profile: &'a ProfileTable,
    /// Serving architecture of this run.
    pub mode: ServingMode,
    /// Prefill→decode KV-handoff latency. Any decode placement the
    /// router enqueues itself (pended dispatch) must mark the handoff
    /// ready at `now + kv_transfer_ms`, exactly like the simulator's
    /// direct `route_decode` path — the transfer is paid either way.
    pub kv_transfer_ms: TimeMs,
}

/// A scheduling policy. All methods are called by the simulation loop
/// (or the live server) — never concurrently.
pub trait Router {
    /// A request arrived. Return the instance whose *prefill* queue it
    /// should join (PD: a prefill server; coloc: a coloc server), or
    /// `None` to hold it pending inside the policy (the policy must
    /// dispatch it later from `on_iter_end`/`on_tick` by pushing it onto
    /// an instance and calling `ctx.cluster.mark_kicked`).
    fn route_new(&mut self, now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> Option<usize>;

    /// PD only: `req_idx` finished prefill; pick its decode instance
    /// (or `None` to pend).
    fn route_decode(&mut self, now: TimeMs, req_idx: usize, ctx: &mut RouteCtx)
        -> Option<usize>;

    /// Prefill-token budget for the next iteration of `inst`
    /// (§2.4/§4.7 chunked prefill; PD prefill servers get large budgets,
    /// coloc budgets are TPOT-derived).
    fn chunk_budget(&mut self, now: TimeMs, inst: usize, ctx: &mut RouteCtx) -> u64;

    /// Called after `inst` finished an iteration (state updated).
    fn on_iter_end(&mut self, now: TimeMs, inst: usize, ctx: &mut RouteCtx);

    /// Periodic housekeeping (pending dispatch, auto-scaling sweeps).
    fn on_tick(&mut self, now: TimeMs, ctx: &mut RouteCtx);

    /// Human-readable name for reports.
    fn name(&self) -> String;

    /// Optional diagnostics line (scheduling-event counters).
    fn diagnostics(&self) -> String {
        String::new()
    }

    /// Arrival-edge admission gate (the `[overload]` layer): is
    /// `req_idx`'s SLO feasible right now? Consulted by the simulator
    /// only when `[overload] reject` is on; `false` sheds the request
    /// with a typed `Rejected` outcome before it ever reaches
    /// [`Router::route_new`]. The default accepts everything —
    /// baselines never shed.
    fn admit_at_arrival(&self, now: TimeMs, req_idx: usize, ctx: &RouteCtx) -> bool {
        let _ = (now, req_idx, ctx);
        true
    }

    /// Pending-queue aging diagnostics: `(dispatches whose pend
    /// exceeded the relaxed-admission patience, max observed pend ms)`.
    /// `None` for policies without a pending queue.
    fn queue_aging(&self) -> Option<(u64, u64)> {
        None
    }

    /// Failure-domain steering (the `[chaos]` zones layer): while set,
    /// placements should *prefer* instances outside `zone` — two-pass,
    /// never a hard filter; if only the avoided zone has capacity it is
    /// still used. The simulator brackets a failed instance's victim
    /// re-placements with the victim's zone and resets to `None`
    /// after. The default ignores the hint — baselines (and every run
    /// without a domain model) are untouched.
    fn set_avoid_zone(&mut self, zone: Option<u32>) {
        let _ = zone;
    }
}

/// Build the router described by a [`SimConfig`].
pub fn make_router(cfg: &SimConfig, avg_decode_len: f64) -> Box<dyn Router> {
    make_router_with_models(cfg, avg_decode_len, &[])
}

/// Build the router described by a [`SimConfig`], handing the PolyServe
/// policy one [`ProfileTable`] per deployed model (indexed by
/// `ModelId`). With zero or one profile every router falls back to the
/// run-wide `ctx.profile` and behaves exactly like [`make_router`];
/// baselines always use the run-wide table (their placement is
/// model-*constrained* but not model-*profiled*).
pub fn make_router_with_models(
    cfg: &SimConfig,
    avg_decode_len: f64,
    profiles: &[ProfileTable],
) -> Box<dyn Router> {
    match cfg.policy {
        Policy::PolyServe => {
            Box::new(PolyServeRouter::new(cfg, avg_decode_len).with_models(profiles.to_vec()))
        }
        Policy::Random => Box::new(RandomRouter::new(cfg.seed ^ 0x52_414E_44)),
        Policy::Minimal => Box::new(MinimalRouter::new()),
        Policy::Chunk => Box::new(ChunkRouter::new(cfg.chunk_budget)),
    }
}
