//! Sharded scheduling — the paper's §5.6 scale-out path: "PolyServe can
//! further scale by introducing more schedulers that manage independent
//! servers."
//!
//! [`ShardedRouter`] partitions the fleet into `n_shards` disjoint
//! server groups, each managed by an independent [`PolyServeRouter`].
//! Requests are assigned to shards by a cheap stateless hash of the
//! request id (so shards need no coordination — the paper's premise),
//! and every router-visible view is masked to the shard's instances.
//!
//! The masking works through [`TierAssign`]: instances outside the
//! shard are invisible to a shard's router because each shard router
//! only ever touches instances it has itself claimed from the pool, and
//! the pool view is filtered per shard (`shard_of_instance`). The
//! trade-off measured by `sec56_scheduler_efficiency` and the
//! `fig9`-style goodput check in `integration_policies`: per-placement
//! cost drops ~linearly with shard count, at a small goodput cost from
//! pool fragmentation.

use super::polyserve::PolyServeRouter;
use super::{RouteCtx, Router};
use crate::config::SimConfig;

use crate::slo::TimeMs;

/// Scale-out wrapper: statically partitions the fleet into independent
/// shards, each driven by its own inner PolyServe router.
pub struct ShardedRouter {
    shards: Vec<PolyServeRouter>,
    n_shards: usize,
    /// Cached instance → shard map (built on first use; the fleet's
    /// role layout is fixed for a run).
    shard_map: std::cell::RefCell<Vec<usize>>,
}

impl ShardedRouter {
    /// Build `n_shards` shards over the fleet described by `cfg`.
    pub fn new(cfg: &SimConfig, avg_decode_len: f64, n_shards: usize) -> ShardedRouter {
        let n_shards = n_shards.max(1);
        ShardedRouter {
            shards: (0..n_shards)
                .map(|_| PolyServeRouter::new(cfg, avg_decode_len))
                .collect(),
            n_shards,
            shard_map: std::cell::RefCell::new(Vec::new()),
        }
    }

    #[inline]
    fn shard_of_request(&self, req_idx: usize, ctx: &RouteCtx) -> usize {
        // Stable, stateless: hash the request id.
        let id = ctx.requests[req_idx].req.id;
        (id.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % self.n_shards
    }

    #[inline]
    fn shard_of_instance(&self, inst: usize, ctx: &RouteCtx) -> usize {
        // Instances are partitioned round-robin within each role so
        // every shard owns a proportional slice of prefill and decode
        // capacity. Built once and cached.
        {
            let map = self.shard_map.borrow();
            if let Some(&s) = map.get(inst) {
                return s;
            }
        }
        let mut map = self.shard_map.borrow_mut();
        if map.is_empty() {
            let mut per_role = [0usize; 3];
            let role_idx = |r: crate::sim::Role| match r {
                crate::sim::Role::Prefill => 0,
                crate::sim::Role::Decode => 1,
                crate::sim::Role::Coloc => 2,
            };
            *map = ctx
                .cluster
                .instances
                .iter()
                .map(|i| {
                    let rank = &mut per_role[role_idx(i.role)];
                    let s = *rank % self.n_shards;
                    *rank += 1;
                    s
                })
                .collect();
        }
        map[inst]
    }

    /// Run `f` with the cluster masked to shard `s`: instances outside
    /// the shard are temporarily re-roled so `with_role`/pool iteration
    /// skips them. (Mask/unmask is O(n) but branch-light; the §5.6
    /// bench includes it.)
    fn with_shard<T>(
        &mut self,
        s: usize,
        ctx: &mut RouteCtx,
        f: impl FnOnce(&mut PolyServeRouter, &mut RouteCtx) -> T,
    ) -> T {
        // Mask by flipping foreign BestEffort instances to Static so
        // claim_for_tier (pool scan) skips them; foreign tiered
        // instances are invisible anyway because each shard router only
        // routes to tiers it populated itself... except after Pending
        // adoption. To keep shards fully disjoint we additionally mask
        // foreign *empty* instances; loaded foreign instances belong to
        // the foreign shard's tiers and are filtered by the per-shard
        // tier bookkeeping below.
        // All mask writes go through `set_assign` so the cluster's
        // membership indices — including the load-ordered best-effort
        // twin, which re-keys on the instance's live counters at every
        // set entry — stay coherent with the temporary re-roles (the
        // BTreeSet pool restores to the same ascending order no matter
        // the unmask sequence).
        let mut masked: Vec<usize> = Vec::new();
        for inst in 0..ctx.cluster.instances.len() {
            if self.shard_of_instance(inst, ctx) != s
                && ctx.cluster.assign_of(inst) == crate::sim::TierAssign::BestEffort
            {
                ctx.cluster.set_assign(inst, crate::sim::TierAssign::Static);
                masked.push(inst);
            }
        }
        let out = f(&mut self.shards[s], ctx);
        for inst in masked {
            ctx.cluster.set_assign(inst, crate::sim::TierAssign::BestEffort);
        }
        out
    }
}

impl Router for ShardedRouter {
    fn route_new(&mut self, now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> Option<usize> {
        let s = self.shard_of_request(req_idx, ctx);
        self.with_shard(s, ctx, |r, ctx| r.route_new(now, req_idx, ctx))
    }

    fn route_decode(&mut self, now: TimeMs, req_idx: usize, ctx: &mut RouteCtx) -> Option<usize> {
        let s = self.shard_of_request(req_idx, ctx);
        self.with_shard(s, ctx, |r, ctx| r.route_decode(now, req_idx, ctx))
    }

    fn chunk_budget(&mut self, now: TimeMs, inst: usize, ctx: &mut RouteCtx) -> u64 {
        let s = self.shard_of_instance(inst, ctx);
        self.shards[s].chunk_budget(now, inst, ctx)
    }

    fn on_iter_end(&mut self, now: TimeMs, inst: usize, ctx: &mut RouteCtx) {
        let s = self.shard_of_instance(inst, ctx);
        self.with_shard(s, ctx, |r, ctx| r.on_iter_end(now, inst, ctx));
    }

    fn on_tick(&mut self, now: TimeMs, ctx: &mut RouteCtx) {
        for s in 0..self.n_shards {
            self.with_shard(s, ctx, |r, ctx| r.on_tick(now, ctx));
        }
    }

    fn name(&self) -> String {
        format!("PolyServe×{}", self.n_shards)
    }

    fn diagnostics(&self) -> String {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| format!("shard{i}: {}", s.diagnostics()))
            .collect::<Vec<_>>()
            .join(" | ")
    }

    fn set_avoid_zone(&mut self, zone: Option<u32>) {
        for s in &mut self.shards {
            s.set_avoid_zone(zone);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ServingMode;
    use crate::model::CostModel;
    use crate::profile::ProfileTable;
    use crate::sim::{Cluster, Role};
    use crate::slo::Slo;
    use crate::workload::Request;

    fn ctx_fixture(
        n: usize,
    ) -> (Cluster, Vec<crate::sim::SimRequest<'static>>, ProfileTable) {
        let cm = CostModel::h200_llama8b();
        let cluster = Cluster::build(ServingMode::PdDisaggregated, n, 0.25, 4, &cm, true);
        let reqs = (0..64)
            .map(|i| {
                // Leaked immutable half: the arena borrows, never clones.
                let req: &'static Request = Box::leak(Box::new(Request {
                    id: i,
                    arrival_ms: 0,
                    prefill_len: 100,
                    decode_len: 50,
                    slo: Slo::new(500, 50),
                    model: 0,
                }));
                let mut r = crate::sim::SimRequest::new(req, 2);
                r.prefill_done = 100;
                r.decoded = 1;
                r.first_token_ms = Some(1);
                r
            })
            .collect();
        (cluster, reqs, ProfileTable::from_cost_model(&cm))
    }

    #[test]
    fn requests_spread_across_shards() {
        let (mut cluster, mut reqs, profile) = ctx_fixture(8);
        let router = ShardedRouter::new(&SimConfig::default(), 300.0, 4);
        let mut ctx = RouteCtx {
            now: 0,
            cluster: &mut cluster,
            requests: &mut reqs,
            profile: &profile,
            mode: ServingMode::PdDisaggregated,
            kv_transfer_ms: 2,
        };
        let mut seen = [false; 4];
        for i in 0..64 {
            seen[router.shard_of_request(i, &ctx)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all shards receive requests");
        let _ = &mut ctx;
    }

    #[test]
    fn instances_partition_by_shard() {
        let (mut cluster, mut reqs, profile) = ctx_fixture(12);
        let router = ShardedRouter::new(&SimConfig::default(), 300.0, 3);
        let ctx = RouteCtx {
            now: 0,
            cluster: &mut cluster,
            requests: &mut reqs,
            profile: &profile,
            mode: ServingMode::PdDisaggregated,
            kv_transfer_ms: 2,
        };
        let mut per_shard = [0usize; 3];
        for inst in ctx.cluster.with_role(Role::Decode).collect::<Vec<_>>() {
            per_shard[router.shard_of_instance(inst, &ctx)] += 1;
        }
        // 9 decode instances across 3 shards → 3 each.
        assert_eq!(per_shard, [3, 3, 3]);
    }

    #[test]
    fn sharded_routing_places_requests() {
        let (mut cluster, mut reqs, profile) = ctx_fixture(8);
        let mut router = ShardedRouter::new(&SimConfig::default(), 300.0, 2);
        let mut ctx = RouteCtx {
            now: 0,
            cluster: &mut cluster,
            requests: &mut reqs,
            profile: &profile,
            mode: ServingMode::PdDisaggregated,
            kv_transfer_ms: 2,
        };
        let mut placed = 0;
        for i in 0..16 {
            if router.route_decode(0, i, &mut ctx).is_some() {
                placed += 1;
            }
        }
        assert!(placed >= 14, "placed {placed}/16");
        // Masking restored: pool view intact afterwards.
        assert!(ctx
            .cluster
            .assignments()
            .iter()
            .any(|a| *a == crate::sim::TierAssign::BestEffort));
    }
}
