//! Fleet-level autoscaling policies for the elastic cluster.
//!
//! The router's §4.3/§4.4 machinery already moves instances between
//! tiers and the best-effort pool *within* a fixed fleet; the
//! [`Autoscaler`] decides when the fleet itself should grow (provision
//! from the cloud, paying a cold-start delay) or shrink (drain and
//! retire a server). Two policies:
//!
//! * [`GradientAutoscaler`] — PolyServe's §4.4 story: routing to the
//!   highest-load-but-feasible server concentrates work, so the
//!   *lowest*-load server of an over-provisioned tier starves and can
//!   be retired once the rest of its tier absorbs its residents;
//!   conversely, when the tightest feasible server of some tier
//!   saturates and the best-effort reserve is exhausted, new capacity
//!   is provisioned.
//! * [`ThresholdAutoscaler`] — the classic reactive baseline: scale
//!   out above a fleet-utilization high-water mark, scale in below a
//!   low-water mark after a patience window.
//!
//! Policies only *propose* [`ScaleAction`]s; the simulator enforces
//! min/max fleet bounds and the provisioning delay (`sim::ElasticParams`).

use super::admission::{self, load_estimate};
use super::RouteCtx;
use crate::analysis::ServingMode;
use crate::config::{ScalerKind, SimConfig};
use crate::sim::{Lifecycle, Role};
use crate::slo::{TierSet, TimeMs};

/// A fleet-scaling decision (bounds-checked by the simulator).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// Add a cold-starting instance of `role`.
    Provision { role: Role },
    /// Drain instance `inst`. With `migrate` (and `[elastic]
    /// migration = "on"`) its decode residents are evicted and their KV
    /// moved to surviving servers; otherwise the drain waits for them
    /// to finish. Scalers set `migrate` from [`migration_feasible`] so
    /// a fleet without destination headroom falls back to wait-drain.
    Drain { inst: usize, migrate: bool },
}

/// Scale-in migration gate: can the surviving active fleet plausibly
/// absorb `inst`'s decode residents? Requires aggregate batch-slot
/// headroom for every resident and 2× KV headroom (residents keep
/// growing after the move). This only decides migrate-vs-wait; the
/// per-request admission checks at placement time remain the real
/// protection for destination residents.
pub fn migration_feasible(ctx: &RouteCtx, inst: usize) -> bool {
    // Same estimator for source and destinations, so the two sides of
    // the gate can never diverge. (The source estimate also counts any
    // queued-prefill KV, which stays put — a slightly conservative
    // overcount that only errs toward wait-drain.)
    let src = load_estimate(&ctx.cluster.instances[inst], ctx.requests, ctx.profile);
    if src.batch == 0 {
        return true; // nothing to move
    }
    let role = ctx.cluster.instances[inst].role;
    let mut batch_free = 0u64;
    let mut kv_free = 0u64;
    for i in &ctx.cluster.instances {
        if i.id == inst || i.role != role || !i.lifecycle.accepts_work() {
            continue;
        }
        let est = load_estimate(i, ctx.requests, ctx.profile);
        batch_free += ctx.profile.max_token_batch.saturating_sub(est.batch);
        kv_free += ctx.profile.kv_capacity_tokens.saturating_sub(est.kv_now);
    }
    batch_free >= src.batch && kv_free >= 2 * src.kv_now
}

/// A fleet-scaling policy, evaluated on every `ScaleEval` event.
pub trait Autoscaler {
    /// Inspect router-visible cluster state and propose scale actions.
    fn evaluate(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction>;

    /// Human-readable name for reports.
    fn name(&self) -> String;
}

/// The role the elastic layer scales: the PD prefill cluster is static,
/// everything else grows and shrinks.
pub fn scaling_role(mode: ServingMode) -> Role {
    match mode {
        ServingMode::PdDisaggregated => Role::Decode,
        ServingMode::Colocated => Role::Coloc,
    }
}

/// Arrived, unfinished requests resident on no instance — the demand
/// the router is holding in its pending queues (it cannot be read
/// directly; residency is reconstructed from instance queues).
fn unplaced_demand(ctx: &RouteCtx) -> usize {
    let mut placed = vec![false; ctx.requests.len()];
    for i in &ctx.cluster.instances {
        for j in &i.prefill_queue {
            placed[j.req_idx] = true;
        }
        for &(r, _) in &i.decode_queue {
            placed[r] = true;
        }
        for s in &i.running {
            placed[s.req_idx] = true;
        }
    }
    ctx.requests
        .iter()
        .enumerate()
        .filter(|(idx, r)| {
            r.req.arrival_ms <= ctx.now && r.finish_ms.is_none() && !placed[*idx]
        })
        .count()
}

/// How many *additional* requests `inst` could admit while keeping its
/// predicted iteration time under `SAFETY × tpot` — the per-server
/// headroom the gradient policy reasons about.
fn headroom_requests(ctx: &RouteCtx, inst: usize, tpot_ms: u64) -> u64 {
    let est = load_estimate(&ctx.cluster.instances[inst], ctx.requests, ctx.profile);
    let avg_kv = if est.batch > 0 { est.kv_now / est.batch } else { 0 };
    let limit = admission::SAFETY * tpot_ms as f64;
    let mut lo = 0u64;
    let mut hi = ctx.profile.max_token_batch.saturating_sub(est.batch);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        let kv = est.kv_now + mid * avg_kv.max(1);
        if kv <= ctx.profile.kv_capacity_tokens
            && ctx.profile.iter_ms(est.batch + mid, kv) < limit
        {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

// ------------------------------------------------------------- gradient

/// §4.4 load-gradient fleet scaler.
pub struct GradientAutoscaler {
    tiers: TierSet,
    /// Idle best-effort instances kept as claim-latency headroom.
    reserve: usize,
    /// Consecutive surplus evaluations required before draining.
    patience: u32,
    surplus_streak: u32,
}

impl GradientAutoscaler {
    pub fn new(tiers: TierSet) -> GradientAutoscaler {
        GradientAutoscaler {
            tiers,
            reserve: 1,
            patience: 3,
            surplus_streak: 0,
        }
    }

    /// A tier saturates when even its least-loaded member has no
    /// admission headroom left (§4.4 "the tightest feasible server").
    fn saturated_tiers(&self, ctx: &RouteCtx) -> usize {
        let mut saturated = 0;
        for k in 0..self.tiers.len() {
            let tpot = self.tiers.tier(k).tpot_ms;
            let ids: Vec<usize> = ctx.cluster.in_tier(k).collect();
            if !ids.is_empty() && ids.iter().all(|&id| headroom_requests(ctx, id, tpot) == 0) {
                saturated += 1;
            }
        }
        saturated
    }

    /// The §4.4 scale-in candidate: the lowest-load member of a tier
    /// whose remaining members can absorb its residents (with margin).
    fn tier_surplus_candidate(&self, ctx: &RouteCtx) -> Option<usize> {
        for k in 0..self.tiers.len() {
            let tpot = self.tiers.tier(k).tpot_ms;
            let ids: Vec<usize> = ctx.cluster.in_tier(k).collect();
            if ids.len() < 2 {
                continue;
            }
            let lowest = ids
                .iter()
                .copied()
                .min_by_key(|&id| {
                    let i = &ctx.cluster.instances[id];
                    (i.decode_batch_now(), i.queued_prefill_tokens(ctx.requests))
                })
                .expect("nonempty tier");
            let load = ctx.cluster.instances[lowest].decode_batch_now()
                + ctx.cluster.instances[lowest].prefill_queue.len() as u64;
            let others_headroom: u64 = ids
                .iter()
                .filter(|&&id| id != lowest)
                .map(|&id| headroom_requests(ctx, id, tpot))
                .sum();
            // 2× margin: absorbing the drained server's load must not
            // push the survivors to their own saturation edge.
            if others_headroom >= 2 * load.max(1) {
                return Some(lowest);
            }
        }
        None
    }
}

impl Autoscaler for GradientAutoscaler {
    fn evaluate(&mut self, _now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        let role = scaling_role(ctx.mode);
        // Reserve = *empty* best-effort instances. BE-assigned servers
        // can carry best-effort traffic without leaving the pool, and a
        // busy one is not claimable headroom.
        let be_idle = ctx
            .cluster
            .best_effort_pool()
            .filter(|&id| ctx.cluster.instances[id].is_empty())
            .count();

        // Scale out when the reserve is (nearly) gone and either a tier
        // is saturated or the router is visibly holding pending demand.
        let saturated = self.saturated_tiers(ctx);
        let pressure = if be_idle <= self.reserve { unplaced_demand(ctx) } else { 0 };
        if (saturated > 0 || pressure > 0) && be_idle <= self.reserve {
            self.surplus_streak = 0;
            let in_flight = ctx.cluster.provisioning_count(role);
            let want = saturated
                .max(pressure.div_ceil(8))
                .min(8)
                .saturating_sub(in_flight);
            return (0..want).map(|_| ScaleAction::Provision { role }).collect();
        }

        // Scale in, after `patience` consecutive surplus observations:
        // idle best-effort machines beyond the reserve first, then the
        // starved lowest-load member of an over-provisioned tier.
        let idle_be: Vec<usize> = ctx
            .cluster
            .best_effort_pool()
            .filter(|&id| {
                ctx.cluster.instances[id].is_empty() && ctx.cluster.instances[id].role == role
            })
            .collect();
        let surplus_be = idle_be.len().saturating_sub(self.reserve);
        let tier_candidate = self.tier_surplus_candidate(ctx);
        if surplus_be == 0 && tier_candidate.is_none() {
            self.surplus_streak = 0;
            return Vec::new();
        }
        self.surplus_streak += 1;
        if self.surplus_streak < self.patience {
            return Vec::new();
        }
        self.surplus_streak = 0;
        let mut actions: Vec<ScaleAction> = idle_be
            .into_iter()
            .rev() // newest first: LIFO keeps warm old servers
            .take(surplus_be)
            .map(|inst| ScaleAction::Drain { inst, migrate: true }) // idle: nothing to move
            .collect();
        if actions.is_empty() {
            if let Some(inst) = tier_candidate {
                let migrate = migration_feasible(ctx, inst);
                actions.push(ScaleAction::Drain { inst, migrate });
            }
        }
        actions
    }

    fn name(&self) -> String {
        "gradient".into()
    }
}

// ------------------------------------------------------------ threshold

/// Reactive utilization-threshold baseline scaler.
pub struct ThresholdAutoscaler {
    /// Scale out above this busy fraction.
    hi: f64,
    /// Scale in below this busy fraction (after `patience` evals).
    lo: f64,
    patience: u32,
    low_streak: u32,
    last_eval_ms: Option<TimeMs>,
    last_busy_ms: u64,
}

impl ThresholdAutoscaler {
    pub fn new(hi: f64, lo: f64) -> ThresholdAutoscaler {
        assert!(lo < hi, "scale-in threshold must be below scale-out");
        ThresholdAutoscaler {
            hi,
            lo,
            patience: 3,
            low_streak: 0,
            last_eval_ms: None,
            last_busy_ms: 0,
        }
    }

    /// Busy fraction of the scalable fleet since the last evaluation.
    /// Everything whose busy time lands in the numerator must count in
    /// the capacity denominator: drainers still burn iterations, and an
    /// instance that *retired inside the window* contributed busy time
    /// too — excluding either inflates util past the truth right after
    /// a scale-in and triggers an immediate re-provision oscillation.
    /// A retiree counts only up to its retirement, so a server gone
    /// early in the window doesn't deflate the surviving fleet's
    /// utilization either.
    fn utilization(&mut self, now: TimeMs, ctx: &RouteCtx, role: Role) -> Option<f64> {
        let busy: u64 = ctx
            .cluster
            .instances
            .iter()
            .filter(|i| i.role == role)
            .map(|i| i.busy_ms_total)
            .sum();
        let util = match self.last_eval_ms {
            Some(prev) if now > prev => {
                let serving =
                    (ctx.cluster.active_count(role) + ctx.cluster.draining_count(role)).max(1);
                // An instance that retired inside the window was
                // capacity only until its retirement.
                let retired_capacity_ms: u64 = ctx
                    .cluster
                    .instances
                    .iter()
                    .filter(|i| i.role == role)
                    .filter_map(|i| match i.lifecycle {
                        Lifecycle::Retired { at } if at > prev => Some(at - prev),
                        _ => None,
                    })
                    .sum();
                let window = (now - prev) * serving as u64 + retired_capacity_ms;
                Some((busy.saturating_sub(self.last_busy_ms)) as f64 / window as f64)
            }
            _ => None,
        };
        self.last_eval_ms = Some(now);
        self.last_busy_ms = busy;
        util
    }
}

impl Autoscaler for ThresholdAutoscaler {
    fn evaluate(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        let role = scaling_role(ctx.mode);
        let Some(util) = self.utilization(now, ctx, role) else {
            return Vec::new();
        };
        if util > self.hi {
            self.low_streak = 0;
            // Proportional step, 1 minimum: a deep overload closes
            // faster than one-at-a-time.
            let active = ctx.cluster.active_count(role);
            let want = (((util - self.hi) / self.hi) * active as f64).ceil() as usize;
            let in_flight = ctx.cluster.provisioning_count(role);
            let n = want.max(1).saturating_sub(in_flight);
            return (0..n).map(|_| ScaleAction::Provision { role }).collect();
        }
        if util < self.lo {
            self.low_streak += 1;
            if self.low_streak >= self.patience {
                self.low_streak = 0;
                // Drain the least-loaded active instance of the role.
                let target = ctx
                    .cluster
                    .with_role(role)
                    .min_by_key(|&id| {
                        let i = &ctx.cluster.instances[id];
                        (i.decode_batch_now(), i.queued_prefill_tokens(ctx.requests))
                    });
                if let Some(inst) = target {
                    let migrate = migration_feasible(ctx, inst);
                    return vec![ScaleAction::Drain { inst, migrate }];
                }
            }
            return Vec::new();
        }
        self.low_streak = 0;
        Vec::new()
    }

    fn name(&self) -> String {
        "threshold".into()
    }
}

/// Build the autoscaler requested by a [`SimConfig`] (`None` when the
/// fleet is fixed).
pub fn make_autoscaler(cfg: &SimConfig) -> Option<Box<dyn Autoscaler>> {
    if !cfg.elastic.enabled() {
        return None;
    }
    match cfg.elastic.scaler {
        ScalerKind::Gradient => Some(Box::new(GradientAutoscaler::new(cfg.tiers.clone()))),
        ScalerKind::Threshold => Some(Box::new(ThresholdAutoscaler::new(0.75, 0.35))),
        ScalerKind::Off => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;
    use crate::profile::ProfileTable;
    use crate::sim::{Cluster, SimRequest};

    fn ctx_parts() -> (Cluster, ProfileTable) {
        let cm = CostModel::h200_llama8b();
        let cluster = Cluster::build(ServingMode::Colocated, 6, 0.0, 4, &cm, true);
        (cluster, ProfileTable::from_cost_model(&cm))
    }

    #[test]
    fn gradient_drains_surplus_idle_pool_after_patience() {
        let (mut cluster, profile) = ctx_parts();
        let mut reqs: Vec<SimRequest> = Vec::new();
        let mut sc = GradientAutoscaler::new(TierSet::paper_default());
        // All 6 instances idle in the BE pool; reserve is 1 → 5 surplus.
        // The policy acts on the `patience`-th consecutive surplus eval.
        let mut actions = Vec::new();
        let evals = sc.patience as u64;
        for t in 0..evals {
            let mut ctx = RouteCtx {
                now: t * 1000,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::Colocated,
                kv_transfer_ms: 2,
            };
            actions = sc.evaluate(t * 1000, &mut ctx);
            if t + 1 < evals {
                assert!(actions.is_empty(), "drained before patience at t={t}");
            }
        }
        assert_eq!(actions.len(), 5);
        assert!(actions
            .iter()
            .all(|a| matches!(a, ScaleAction::Drain { .. })));
    }

    #[test]
    fn gradient_quiet_when_pool_has_reserve_and_no_tiers() {
        let (mut cluster, profile) = ctx_parts();
        let mut reqs: Vec<SimRequest> = Vec::new();
        // Shrink the pool to exactly the reserve: claim all but one.
        for _ in 0..5 {
            let id = cluster.claim_for_tier(3, 0).unwrap();
            // Tier members with nothing resident are "surplus" — avoid
            // that by immediately releasing them from the tier view.
            cluster.begin_drain(id, 0);
            cluster.retire_if_drained(id, 0);
        }
        let mut sc = GradientAutoscaler::new(TierSet::paper_default());
        for t in 0..5u64 {
            let mut ctx = RouteCtx {
                now: t,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::Colocated,
                kv_transfer_ms: 2,
            };
            assert!(sc.evaluate(t, &mut ctx).is_empty());
        }
    }

    #[test]
    fn threshold_scaler_needs_two_samples_then_reacts() {
        let (mut cluster, profile) = ctx_parts();
        let mut reqs: Vec<SimRequest> = Vec::new();
        let mut sc = ThresholdAutoscaler::new(0.75, 0.35);
        // First eval: no window yet.
        let a0 = {
            let mut ctx = RouteCtx {
                now: 1000,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::Colocated,
                kv_transfer_ms: 2,
            };
            sc.evaluate(1000, &mut ctx)
        };
        assert!(a0.is_empty());
        // Make the fleet look fully busy for the next window.
        for i in cluster.instances.iter_mut() {
            i.busy_ms_total += 1000;
        }
        let a1 = {
            let mut ctx = RouteCtx {
                now: 2000,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::Colocated,
                kv_transfer_ms: 2,
            };
            sc.evaluate(2000, &mut ctx)
        };
        assert!(
            a1.iter()
                .all(|a| matches!(a, ScaleAction::Provision { role: Role::Coloc })),
            "expected provisions, got {a1:?}"
        );
        assert!(!a1.is_empty());
        // Idle windows → drains after patience.
        let mut drained = false;
        for t in 3..10u64 {
            let mut ctx = RouteCtx {
                now: t * 1000,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::Colocated,
                kv_transfer_ms: 2,
            };
            let acts = sc.evaluate(t * 1000, &mut ctx);
            if acts
                .iter()
                .any(|a| matches!(a, ScaleAction::Drain { .. }))
            {
                drained = true;
                break;
            }
        }
        assert!(drained, "idle fleet never drained");
    }
}
