//! Fleet-level autoscaling policies for the elastic cluster.
//!
//! The router's §4.3/§4.4 machinery already moves instances between
//! tiers and the best-effort pool *within* a fixed fleet; the
//! [`Autoscaler`] decides when the fleet itself should grow (provision
//! from the cloud, paying a cold-start delay) or shrink (drain and
//! retire a server). Three policies:
//!
//! * [`GradientAutoscaler`] — PolyServe's §4.4 story: routing to the
//!   highest-load-but-feasible server concentrates work, so the
//!   *lowest*-load server of an over-provisioned tier starves and can
//!   be retired once the rest of its tier absorbs its residents;
//!   conversely, when the tightest feasible server of some tier
//!   saturates and the best-effort reserve is exhausted, new capacity
//!   is provisioned.
//! * [`ThresholdAutoscaler`] — the classic reactive baseline: scale
//!   out above a fleet-utilization high-water mark, scale in below a
//!   low-water mark after a patience window.
//! * [`PredictiveAutoscaler`] — profile-driven *planning* instead of
//!   reaction (the SLOs-Serve / SCORPIO direction): estimate the
//!   arrival-rate trend (windowed EWMA + linear slope over `ScaleEval`
//!   epochs), project it `provision_lead_ms` ahead, convert the
//!   projected rate into a required fleet via the shared
//!   [`sizing`](super::sizing) math, and provision *before* a diurnal
//!   ramp crests — so the cold-start delay is paid while the old
//!   capacity still suffices, not after it saturates.
//!
//! # Elastic prefill (PD)
//!
//! The PD prefill cluster stops being static when
//! `[elastic] prefill_elastic = "on"`: every policy then also consumes
//! the [`ttft_pressure`] signal — estimated prefill-queue drain time
//! over the queued jobs' mean TTFT headroom — and emits
//! `Provision`/`Drain` actions for [`Role::Prefill`] servers (the
//! predictive policy additionally sizes the prefill tier from projected
//! prompt-token demand). Prefill drains with `[elastic]
//! migration = "on"` re-route the drainer's queued prefill jobs to
//! surviving prefill servers instead of finishing them in place.
//!
//! Policies only *propose* [`ScaleAction`]s; the simulator enforces
//! per-role min/max fleet bounds and the provisioning delay
//! (`sim::ElasticParams`).

use super::admission::{self, load_estimate};
use super::sizing;
use super::RouteCtx;
use crate::analysis::ServingMode;
use crate::config::{ScalerKind, SimConfig};
use crate::metrics::{ChaosStats, RateSample};
use crate::model::ModelId;
use crate::profile::ProfileTable;
use crate::sim::{Lifecycle, Role};
use crate::slo::{TierSet, TimeMs};
use std::collections::VecDeque;

/// A fleet-scaling decision (bounds-checked by the simulator).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// Add a cold-starting instance of `role`.
    Provision {
        /// Role of the new instance (the scalable role, or
        /// `Role::Prefill` when the prefill tier is elastic).
        role: Role,
    },
    /// Drain instance `inst`. With `migrate` (and `[elastic]
    /// migration = "on"`) its residents are moved off — decode
    /// residents' KV streams to surviving servers, a prefill drainer's
    /// queued jobs are re-routed — otherwise the drain waits for them
    /// to finish. Scalers set `migrate` from [`migration_feasible`] /
    /// [`prefill_migration_feasible`] so a fleet without destination
    /// headroom falls back to wait-drain.
    Drain {
        /// Instance id to drain.
        inst: usize,
        /// Move residents out instead of waiting for them.
        migrate: bool,
    },
    /// Add a cold-starting instance of `role` loaded with `model` — the
    /// multi-model form of [`ScaleAction::Provision`] (which the
    /// simulator applies as `ProvisionModel { model: 0, .. }`, so
    /// single-model scalers keep emitting the short form and their
    /// action streams stay bit-identical).
    ProvisionModel {
        /// Registry id of the model the new instance serves.
        model: ModelId,
        /// Role of the new instance.
        role: Role,
    },
    /// Hot-swap instance `inst` to serve `model`: drain it (migrating
    /// its residents to same-model survivors when `[elastic]
    /// migration = "on"`), then pay the weight-reload delay
    /// (`[models] swap_delay_ms`) before it re-enters service under the
    /// new model. Cheaper than a cloud cold start when another model's
    /// sub-fleet has surplus capacity; the simulator refuses a swap
    /// that would empty the source model's sub-fleet.
    SwapModel {
        /// Instance id to re-purpose.
        inst: usize,
        /// Registry id of the model to load after the drain.
        model: ModelId,
    },
    /// Switch the chaos layer's spot/on-demand provisioning split.
    /// With `on_demand`, the `[chaos] spot_fraction` stride is *held*
    /// (every new instance provisions on-demand; the stride counter
    /// keeps advancing so lifting the hold resumes the original
    /// sequence). Emitted only by the chaos-adaptive predictive scaler
    /// when churn makes the discounted spot bill worse than on-demand;
    /// a no-op on runs without a chaos layer.
    SpotPolicy {
        /// `true` holds the spot stride; `false` restores it.
        on_demand: bool,
    },
}

/// Scale-in migration gate: can the surviving active fleet plausibly
/// absorb `inst`'s decode residents? Requires aggregate batch-slot
/// headroom for every resident and 2× KV headroom (residents keep
/// growing after the move). This only decides migrate-vs-wait; the
/// per-request admission checks at placement time remain the real
/// protection for destination residents.
pub fn migration_feasible(ctx: &RouteCtx, inst: usize) -> bool {
    // Same estimator for source and destinations, so the two sides of
    // the gate can never diverge. (The source estimate also counts any
    // queued-prefill KV, which stays put — a slightly conservative
    // overcount that only errs toward wait-drain.)
    let src = load_estimate(&ctx.cluster.instances[inst], ctx.requests, ctx.profile);
    if src.batch == 0 {
        return true; // nothing to move
    }
    let role = ctx.cluster.instances[inst].role;
    let model = ctx.cluster.instances[inst].model;
    let mut batch_free = 0u64;
    let mut kv_free = 0u64;
    // Role index + O(1) load estimates: the gate costs O(role size),
    // not O(fleet × batch). Destinations are same-model only (the hard
    // placement constraint: residents can only re-land on instances
    // already serving their model) and headroom is counted against each
    // destination's *own* capacity, so mixed-capacity fleets gate
    // correctly — for a single-model fleet both refinements are
    // identities.
    for id in ctx.cluster.with_role_of(model, role) {
        if id == inst {
            continue;
        }
        let dest = &ctx.cluster.instances[id];
        let est = load_estimate(dest, ctx.requests, ctx.profile);
        batch_free += dest.max_token_batch.saturating_sub(est.batch);
        kv_free += dest.kv_capacity.saturating_sub(est.kv_now);
    }
    batch_free >= src.batch && kv_free >= 2 * src.kv_now
}

/// Prefill scale-in migration gate: a prefill drainer's queued jobs
/// carry at most their partially-computed KV, so the only hard
/// requirement is a surviving active *same-model* prefill server to
/// requeue onto — the router's EDF-feasibility placement spreads them
/// from there. (Single-model fleets: identical to the any-survivor
/// check this gate used before the registry.)
pub fn prefill_migration_feasible(ctx: &RouteCtx, inst: usize) -> bool {
    let model = ctx.cluster.instances[inst].model;
    ctx.cluster.instances[inst].role == Role::Prefill
        && ctx.cluster.with_role_of(model, Role::Prefill).any(|id| id != inst)
}

/// A fleet-scaling policy, evaluated on every `ScaleEval` event.
pub trait Autoscaler {
    /// Inspect router-visible cluster state and propose scale actions.
    fn evaluate(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction>;

    /// Human-readable name for reports.
    fn name(&self) -> String;

    /// Drain the predicted-vs-observed arrival-rate series this policy
    /// recorded (empty for policies that don't predict); the simulator
    /// attaches it to `SimResult::fleet`.
    fn take_rate_series(&mut self) -> Vec<RateSample> {
        Vec::new()
    }

    /// Chaos telemetry feed: the simulator calls this immediately
    /// before [`Autoscaler::evaluate`] on every `ScaleEval` epoch of a
    /// chaos-enabled run, handing the cumulative [`ChaosStats`], the
    /// live spot-instance count, and the spot price currently in
    /// effect (the `[chaos] spot_price_schedule` step at `now`, or the
    /// flat `spot_price_frac`). Policies may fold it into their sizing
    /// (churn padding) or spot/on-demand split. The default ignores it
    /// — every scaler without an opt-in stays bit-identical.
    fn observe_chaos(
        &mut self,
        now: TimeMs,
        stats: &ChaosStats,
        spot_active: usize,
        spot_price: f64,
    ) {
        let _ = (now, stats, spot_active, spot_price);
    }
}

/// The *primary* role the elastic layer scales: decode servers under
/// PD-disaggregation, the coloc servers themselves under co-location.
/// The PD prefill cluster is a second, independently-bounded scaling
/// target — policies address it explicitly as [`Role::Prefill`] when
/// `prefill_elastic` is on, never through this function.
pub fn scaling_role(mode: ServingMode) -> Role {
    match mode {
        ServingMode::PdDisaggregated => Role::Decode,
        ServingMode::Colocated => Role::Coloc,
    }
}

/// Arrived, unfinished requests resident on no instance — the demand
/// the router is holding in its pending queues. O(1) off the cluster's
/// incremental arrival / finish / residency counters (maintained by
/// `note_arrival` / `note_finished` / `refresh_load` at every event);
/// the pre-PR reconstruction scan survives as
/// [`Cluster::unplaced_demand_scan`](crate::sim::Cluster::unplaced_demand_scan)
/// — the per-event debug-audit oracle and the path both reference modes
/// take (the scan *was* the per-epoch cost of both baselines).
fn unplaced_demand(ctx: &RouteCtx) -> usize {
    if ctx.cluster.is_scan_reference() || ctx.cluster.is_indexed_reference() {
        return ctx.cluster.unplaced_demand_scan(ctx.requests, ctx.now);
    }
    ctx.cluster.unplaced_demand()
}

/// The `k` least-loaded work-accepting instances of `role`, ordered by
/// `(decode_batch_now, queued_prefill_tokens)` with ascending-id ties —
/// exactly the prefix the old stable `sort_by_key` over the collected
/// role view produced, selected in O(role × k) (k ≤ [`MAX_DRAIN_STEP`])
/// with a k-slot buffer instead of an O(role log role) sort + collect
/// per drain epoch.
fn k_least_loaded(ctx: &RouteCtx, role: Role, k: usize) -> Vec<usize> {
    k_least_loaded_in(ctx, ctx.cluster.with_role(role), k)
}

/// [`k_least_loaded`] over an arbitrary candidate view (the multi-model
/// planner feeds per-model role views through the same k-slot buffer,
/// so donor selection and single-model drain selection share one
/// ordering definition).
fn k_least_loaded_in(
    ctx: &RouteCtx,
    ids: impl Iterator<Item = usize>,
    k: usize,
) -> Vec<usize> {
    if k == 0 {
        return Vec::new();
    }
    let mut best: Vec<((u64, u64), usize)> = Vec::with_capacity(k + 1);
    for id in ids {
        let i = &ctx.cluster.instances[id];
        let key = (i.decode_batch_now(), i.queued_prefill_tokens(ctx.requests));
        // Ascending-id iteration: comparing (key, id) reproduces the
        // stable sort's tie order bit-for-bit.
        let pos = best.partition_point(|&e| e <= (key, id));
        if pos < k {
            best.insert(pos, (key, id));
            best.truncate(k);
        }
    }
    best.into_iter().map(|(_, id)| id).collect()
}

/// How many *additional* requests `inst` could admit while keeping its
/// predicted iteration time under `SAFETY × tpot` — the per-server
/// headroom the gradient policy reasons about.
fn headroom_requests(ctx: &RouteCtx, inst: usize, tpot_ms: u64) -> u64 {
    let est = load_estimate(&ctx.cluster.instances[inst], ctx.requests, ctx.profile);
    let avg_kv = if est.batch > 0 { est.kv_now / est.batch } else { 0 };
    let limit = admission::SAFETY * tpot_ms as f64;
    let mut lo = 0u64;
    let mut hi = ctx.profile.max_token_batch.saturating_sub(est.batch);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        let kv = est.kv_now + mid * avg_kv.max(1);
        if kv <= ctx.profile.kv_capacity_tokens
            && ctx.profile.iter_ms(est.batch + mid, kv) < limit
        {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

// ------------------------------------------------------- TTFT pressure

/// Scale-out trigger for the prefill tier: provision when the queues
/// would take longer to drain than the queued jobs have TTFT headroom.
pub const PREFILL_PRESSURE_HI: f64 = 1.0;
/// Scale-in trigger for the prefill tier: drain (after patience) when
/// the queues clear in under a quarter of the available headroom.
pub const PREFILL_PRESSURE_LO: f64 = 0.25;
/// Chunk budget assumed by prefill throughput estimates — the same
/// constant the PolyServe router's prefill budget is built from
/// ([`sizing::DEFAULT_PREFILL_BUDGET`]), so the estimates track the
/// router's actual chunk rate by construction.
pub const PREFILL_SIZING_BUDGET: u64 = sizing::DEFAULT_PREFILL_BUDGET;

/// TTFT pressure on the PD prefill cluster: estimated time to drain all
/// queued prompt tokens at the active fleet's chunked-prefill
/// throughput, divided by the queued jobs' mean remaining TTFT headroom.
///
/// * `0.0` — no queued prefill work (or no prefill cluster: coloc mode).
/// * `< 1.0` — queues clear within the deadlines' headroom.
/// * `> 1.0` — TTFT violations are brewing; the prefill tier needs
///   capacity `≈ pressure ×` the current fleet.
/// * `∞` — queued work with *no* active prefill server (every one
///   draining/lost) — unconditional provisioning signal.
///
/// Queues on draining servers count toward demand but drainers don't
/// count as capacity: the estimate errs conservative during scale-in.
pub fn ttft_pressure(ctx: &RouteCtx, prefill_budget: u64) -> f64 {
    let mut queued_tokens = 0u64;
    let mut n_active = 0usize;
    let mut headroom_sum = 0.0f64;
    let mut jobs = 0usize;
    for i in &ctx.cluster.instances {
        if i.role != Role::Prefill || !i.lifecycle.is_live() {
            continue;
        }
        if i.lifecycle.accepts_work() {
            n_active += 1;
        }
        queued_tokens += i.queued_prefill_tokens(ctx.requests);
        for j in &i.prefill_queue {
            jobs += 1;
            headroom_sum += j.deadline.saturating_sub(ctx.now).max(1) as f64;
        }
    }
    if jobs == 0 {
        return 0.0;
    }
    if n_active == 0 {
        return f64::INFINITY;
    }
    let fleet_tokens_per_ms =
        sizing::prefill_tokens_per_ms(ctx.profile, prefill_budget) * n_active as f64;
    let drain_ms = queued_tokens as f64 / fleet_tokens_per_ms.max(1e-9);
    drain_ms / (headroom_sum / jobs as f64)
}

/// The shared prefill scale-in choice: drain the least-queued active
/// prefill server, migrating its queue if a survivor exists. Every
/// policy's prefill drain goes through here so the target selection
/// and feasibility gate can never diverge between scalers. In a
/// multi-model fleet a model's *last* prefill server is never a
/// candidate — draining it would strand that model's prefill stage.
fn prefill_drain_action(ctx: &RouteCtx) -> Option<ScaleAction> {
    let multi = ctx.cluster.num_models > 1;
    let inst = ctx
        .cluster
        .with_role(Role::Prefill)
        .filter(|&id| {
            !multi || {
                let m = ctx.cluster.instances[id].model;
                ctx.cluster.with_role_of(m, Role::Prefill).any(|o| o != id)
            }
        })
        .min_by_key(|&id| ctx.cluster.instances[id].queued_prefill_tokens(ctx.requests))?;
    let migrate = prefill_migration_feasible(ctx, inst);
    Some(ScaleAction::Drain { inst, migrate })
}

/// Shared prefill-tier reaction all three policies use when
/// `prefill_elastic` is on: provision the capacity shortfall implied
/// by TTFT pressure above [`PREFILL_PRESSURE_HI`] (pressure is demand
/// over *current* throughput, so the shortfall is
/// `(pressure − 1) × active`), drain the least-queued prefill server
/// after `patience` consecutive evaluations below
/// [`PREFILL_PRESSURE_LO`]. Bounds are enforced by the simulator.
fn prefill_pressure_actions(
    ctx: &RouteCtx,
    streak: &mut u32,
    patience: u32,
) -> Vec<ScaleAction> {
    let pressure = ttft_pressure(ctx, PREFILL_SIZING_BUDGET);
    let in_flight = ctx.cluster.provisioning_count(Role::Prefill);
    if pressure > PREFILL_PRESSURE_HI {
        *streak = 0;
        let active = ctx.cluster.active_count(Role::Prefill).max(1);
        let want = if pressure.is_finite() {
            (((pressure - 1.0) * active as f64).ceil() as usize).clamp(1, 4)
        } else {
            1
        }
        .saturating_sub(in_flight);
        return (0..want)
            .map(|_| ScaleAction::Provision { role: Role::Prefill })
            .collect();
    }
    if pressure < PREFILL_PRESSURE_LO && in_flight == 0 {
        *streak += 1;
        if *streak >= patience {
            *streak = 0;
            return prefill_drain_action(ctx).into_iter().collect();
        }
    } else {
        *streak = 0;
    }
    Vec::new()
}

// ------------------------------------------------------ model-mix plan

/// Shared multi-model fleet planner, attached to any of the three
/// autoscalers via [`make_autoscaler_with_models`].
///
/// When a registry holds more than one model, per-role fleet sizing
/// stops being one number: each model's sub-fleet must be sized against
/// *its own* profile table and arrival share, and capacity can move
/// between sub-fleets by hot-swapping weights instead of paying a cloud
/// cold start. The planner does exactly that, per `ScaleEval` epoch:
///
/// 1. Ingest arrivals since the last epoch (the same arrival-cursor
///    idiom as [`PredictiveAutoscaler`]) into per-model EWMA rates,
///    per-(model, tier) mix EWMAs and running length means.
/// 2. Size each model's sub-fleet with the shared
///    [`sizing::required_fleet`] math over that model's profile, plus
///    the per-model unplaced-demand backstop.
/// 3. Cover one model's shortfall from another's surplus first —
///    [`ScaleAction::SwapModel`] on the surplus model's least-loaded
///    instances (never its last one) — then cloud-provision the
///    remainder ([`ScaleAction::ProvisionModel`]) and, after a patience
///    window, drain any surplus no other model wants.
///
/// Attaching a planner replaces the host policy's single-model primary
/// sizing; elastic-prefill pressure reactions still run on top.
/// Single-model runs never construct one, so their decision streams
/// are bit-for-bit those of the underlying policy.
pub struct ModelMixPlanner {
    tiers: TierSet,
    profiles: Vec<ProfileTable>,
    patience: u32,
    /// Arrival-ingestion cursor into the (arrival-ordered) request list.
    cursor: usize,
    last_eval_ms: Option<TimeMs>,
    /// Per-model smoothed arrival rate (req/s) + its seeded flag.
    ewma_rps: Vec<f64>,
    rate_seeded: Vec<bool>,
    /// Per-model EWMA tier mix (each sums to ≈1 once seeded).
    tier_mix: Vec<Vec<f64>>,
    /// Per-model running workload-shape sums over ingested arrivals.
    n_seen: Vec<u64>,
    sum_prefill: Vec<f64>,
    sum_decode: Vec<f64>,
    drain_streak: Vec<u32>,
}

impl ModelMixPlanner {
    /// Build over one [`ProfileTable`] per registered model (≥ 2 — a
    /// single-model fleet has nothing to plan between).
    pub fn new(tiers: TierSet, profiles: Vec<ProfileTable>) -> ModelMixPlanner {
        assert!(profiles.len() >= 2, "model-mix planning needs >= 2 models");
        let m = profiles.len();
        let t = tiers.len();
        ModelMixPlanner {
            tiers,
            profiles,
            patience: 3,
            cursor: 0,
            last_eval_ms: None,
            ewma_rps: vec![0.0; m],
            rate_seeded: vec![false; m],
            tier_mix: vec![vec![0.0; t]; m],
            n_seen: vec![0; m],
            sum_prefill: vec![0.0; m],
            sum_decode: vec![0.0; m],
            drain_streak: vec![0; m],
        }
    }

    /// Ingest arrivals in `(prev, now]`; returns the per-model counts.
    fn ingest(&mut self, now: TimeMs, ctx: &RouteCtx) -> Vec<u64> {
        let m_n = self.profiles.len();
        let t_n = self.tiers.len();
        let mut counts = vec![0u64; m_n];
        let mut tier_counts = vec![vec![0u64; t_n]; m_n];
        while self.cursor < ctx.requests.len()
            && ctx.requests[self.cursor].req.arrival_ms <= now
        {
            let r = &ctx.requests[self.cursor];
            let m = r.req.model.min(m_n - 1);
            counts[m] += 1;
            if r.tier < t_n {
                tier_counts[m][r.tier] += 1;
            }
            self.n_seen[m] += 1;
            self.sum_prefill[m] += r.req.prefill_len as f64;
            self.sum_decode[m] += r.req.decode_len as f64;
            self.cursor += 1;
        }
        for m in 0..m_n {
            if counts[m] == 0 {
                continue;
            }
            // First ingestion for this model seeds the mix outright.
            let fresh = self.n_seen[m] == counts[m];
            let mut sum = 0.0;
            for (k, mix) in self.tier_mix[m].iter_mut().enumerate() {
                let frac = tier_counts[m][k] as f64 / counts[m] as f64;
                *mix = if fresh {
                    frac
                } else {
                    (1.0 - MIX_EWMA_ALPHA) * *mix + MIX_EWMA_ALPHA * frac
                };
                sum += *mix;
            }
            if sum > 0.0 {
                for mix in self.tier_mix[m].iter_mut() {
                    *mix /= sum;
                }
            }
        }
        counts
    }

    /// Required sub-fleet of model `m` at its current smoothed rate —
    /// the shared [`sizing::required_fleet`] math over the model's own
    /// profile table. Zero for a model with no traffic yet (its initial
    /// allocation is donor capacity).
    fn required_of(&self, mode: ServingMode, m: ModelId) -> usize {
        if self.n_seen[m] == 0 {
            return 0;
        }
        let avg_p = self.sum_prefill[m] / self.n_seen[m] as f64;
        let avg_d = (self.sum_decode[m] / self.n_seen[m] as f64).max(1.0);
        // Mean resident KV of a decode stream: full prompt + half the
        // output (the `p + d/2` idiom the predictive scaler uses).
        let kv_per_req = (avg_p + avg_d * 0.5) as u64;
        let rate = self.ewma_rps[m];
        let tier_rates: Vec<f64> = self.tier_mix[m].iter().map(|f| f * rate).collect();
        sizing::required_fleet(
            &self.profiles[m],
            mode,
            &self.tiers,
            &tier_rates,
            avg_p,
            avg_d,
            kv_per_req,
        )
    }

    /// One planning epoch (see the type docs for the three stages).
    pub fn evaluate(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        let counts = self.ingest(now, ctx);
        let Some(prev) = self.last_eval_ms.replace(now) else {
            return Vec::new(); // first epoch only anchors the window
        };
        if now <= prev {
            return Vec::new();
        }
        let dt_s = (now - prev) as f64 / 1000.0;
        let n_models = self.profiles.len();
        for m in 0..n_models {
            let observed = counts[m] as f64 / dt_s;
            self.ewma_rps[m] = if self.rate_seeded[m] {
                RATE_EWMA_ALPHA * observed + (1.0 - RATE_EWMA_ALPHA) * self.ewma_rps[m]
            } else {
                observed
            };
            self.rate_seeded[m] = self.rate_seeded[m] || counts[m] > 0;
        }

        let role = scaling_role(ctx.mode);
        let mut shortfall = vec![0usize; n_models];
        let mut surplus = vec![0usize; n_models];
        for m in 0..n_models {
            let mut required = self.required_of(ctx.mode, m);
            // Per-model reactive backstop: visible unplaced demand with
            // no idle instance of this model means the plan under-sized
            // — grow past it rather than strand requests.
            let saturated = ctx
                .cluster
                .with_role_of(m, role)
                .all(|id| !ctx.cluster.instances[id].is_empty());
            if saturated {
                let backlog = if ctx.cluster.is_scan_reference()
                    || ctx.cluster.is_indexed_reference()
                {
                    ctx.cluster.unplaced_demand_scan_of(m, ctx.requests, ctx.now)
                } else {
                    ctx.cluster.unplaced_demand_of(m)
                };
                if backlog > 0 {
                    required = required
                        .max(ctx.cluster.active_count_of(m, role) + backlog.div_ceil(8).min(4));
                }
            }
            let active = ctx.cluster.active_count_of(m, role);
            // Committed counts in-flight provisions *and* inbound swaps,
            // so a shortfall being serviced is not re-serviced.
            let committed = ctx.cluster.committed_count_of(m, role);
            if required > committed {
                self.drain_streak[m] = 0;
                shortfall[m] = required - committed;
            } else if required < active {
                surplus[m] = active - required;
            } else {
                self.drain_streak[m] = 0;
            }
        }

        let mut actions = Vec::new();
        // Donor lists: each surplus model's least-loaded active
        // instances, never its last survivor, bounded per epoch.
        let mut donors: Vec<Vec<usize>> = (0..n_models)
            .map(|m| {
                if surplus[m] == 0 {
                    return Vec::new();
                }
                let cap = surplus[m]
                    .min(ctx.cluster.active_count_of(m, role).saturating_sub(1))
                    .min(MAX_DRAIN_STEP);
                k_least_loaded_in(ctx, ctx.cluster.with_role_of(m, role), cap)
            })
            .collect();
        // Stage 1 — swaps: cover shortfall from surplus, cheapest first
        // (a swap re-uses a warm machine; only the weight reload is
        // paid).
        for a in 0..n_models {
            while shortfall[a] > 0 {
                let Some(b) = (0..n_models).find(|&b| b != a && !donors[b].is_empty())
                else {
                    break;
                };
                let inst = donors[b].remove(0);
                surplus[b] = surplus[b].saturating_sub(1);
                shortfall[a] -= 1;
                actions.push(ScaleAction::SwapModel { inst, model: a });
            }
        }
        // Stage 2 — cloud provisions for whatever shortfall no donor
        // covered, bounded like the predictive scaler's step.
        let mut budget = MAX_PROVISION_STEP;
        for (m, &want) in shortfall.iter().enumerate() {
            let take = want.min(budget);
            budget -= take;
            actions.extend((0..take).map(|_| ScaleAction::ProvisionModel { model: m, role }));
        }
        // Stage 3 — drain surplus nobody swapped away, after patience.
        for m in 0..n_models {
            if surplus[m] == 0 {
                continue;
            }
            self.drain_streak[m] += 1;
            if self.drain_streak[m] < self.patience {
                continue;
            }
            self.drain_streak[m] = 0;
            for (n, inst) in donors[m].drain(..).enumerate() {
                // Only the first drain of a batch may migrate (the gate
                // sees the pre-drain fleet; see the predictive scaler).
                let migrate = n == 0 && migration_feasible(ctx, inst);
                actions.push(ScaleAction::Drain { inst, migrate });
            }
        }
        actions
    }
}

// ------------------------------------------------------------- gradient

/// §4.4 load-gradient fleet scaler.
pub struct GradientAutoscaler {
    tiers: TierSet,
    /// Idle best-effort instances kept as claim-latency headroom.
    reserve: usize,
    /// Consecutive surplus evaluations required before draining.
    patience: u32,
    surplus_streak: u32,
    /// Also react to TTFT pressure on the PD prefill tier.
    prefill_elastic: bool,
    prefill_streak: u32,
    /// Multi-model planner; replaces the single-model primary sizing
    /// when present.
    planner: Option<ModelMixPlanner>,
}

impl GradientAutoscaler {
    /// Build with the default reserve (1 idle server) and patience (3
    /// evaluations); the prefill tier stays static unless
    /// [`Self::scale_prefill`] enables it.
    pub fn new(tiers: TierSet) -> GradientAutoscaler {
        GradientAutoscaler {
            tiers,
            reserve: 1,
            patience: 3,
            surplus_streak: 0,
            prefill_elastic: false,
            prefill_streak: 0,
            planner: None,
        }
    }

    /// Enable/disable elastic-prefill reactions ([`ttft_pressure`]).
    pub fn scale_prefill(mut self, enabled: bool) -> Self {
        self.prefill_elastic = enabled;
        self
    }

    /// Attach a multi-model planner (`None` leaves the single-model
    /// behaviour bit-for-bit unchanged).
    pub fn with_planner(mut self, planner: Option<ModelMixPlanner>) -> Self {
        self.planner = planner;
        self
    }

    /// A tier saturates when even its least-loaded member has no
    /// admission headroom left (§4.4 "the tightest feasible server").
    fn saturated_tiers(&self, ctx: &RouteCtx) -> usize {
        let mut saturated = 0;
        for k in 0..self.tiers.len() {
            let tpot = self.tiers.tier(k).tpot_ms;
            let ids: Vec<usize> = ctx.cluster.in_tier(k).collect();
            if !ids.is_empty() && ids.iter().all(|&id| headroom_requests(ctx, id, tpot) == 0) {
                saturated += 1;
            }
        }
        saturated
    }

    /// The §4.4 scale-in candidate: the lowest-load member of a tier
    /// whose remaining members can absorb its residents (with margin).
    fn tier_surplus_candidate(&self, ctx: &RouteCtx) -> Option<usize> {
        for k in 0..self.tiers.len() {
            let tpot = self.tiers.tier(k).tpot_ms;
            let ids: Vec<usize> = ctx.cluster.in_tier(k).collect();
            if ids.len() < 2 {
                continue;
            }
            let lowest = ids
                .iter()
                .copied()
                .min_by_key(|&id| {
                    let i = &ctx.cluster.instances[id];
                    (i.decode_batch_now(), i.queued_prefill_tokens(ctx.requests))
                })
                .expect("nonempty tier");
            let load = ctx.cluster.instances[lowest].decode_batch_now()
                + ctx.cluster.instances[lowest].prefill_queue.len() as u64;
            let others_headroom: u64 = ids
                .iter()
                .filter(|&&id| id != lowest)
                .map(|&id| headroom_requests(ctx, id, tpot))
                .sum();
            // 2× margin: absorbing the drained server's load must not
            // push the survivors to their own saturation edge.
            if others_headroom >= 2 * load.max(1) {
                return Some(lowest);
            }
        }
        None
    }

    /// The PR 1 §4.4 evaluation over the scalable role (decode/coloc);
    /// unchanged by the elastic-prefill extension.
    fn scale_primary(&mut self, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        let role = scaling_role(ctx.mode);
        // Reserve = *empty* best-effort instances. BE-assigned servers
        // can carry best-effort traffic without leaving the pool, and a
        // busy one is not claimable headroom.
        let be_idle = ctx
            .cluster
            .best_effort_pool()
            .filter(|&id| ctx.cluster.instances[id].is_empty())
            .count();

        // Scale out when the reserve is (nearly) gone and either a tier
        // is saturated or the router is visibly holding pending demand.
        let saturated = self.saturated_tiers(ctx);
        let pressure = if be_idle <= self.reserve { unplaced_demand(ctx) } else { 0 };
        if (saturated > 0 || pressure > 0) && be_idle <= self.reserve {
            self.surplus_streak = 0;
            let in_flight = ctx.cluster.provisioning_count(role);
            let want = saturated
                .max(pressure.div_ceil(8))
                .min(8)
                .saturating_sub(in_flight);
            return (0..want).map(|_| ScaleAction::Provision { role }).collect();
        }

        // Scale in, after `patience` consecutive surplus observations:
        // idle best-effort machines beyond the reserve first, then the
        // starved lowest-load member of an over-provisioned tier.
        let idle_be: Vec<usize> = ctx
            .cluster
            .best_effort_pool()
            .filter(|&id| {
                ctx.cluster.instances[id].is_empty() && ctx.cluster.instances[id].role == role
            })
            .collect();
        let surplus_be = idle_be.len().saturating_sub(self.reserve);
        let tier_candidate = self.tier_surplus_candidate(ctx);
        if surplus_be == 0 && tier_candidate.is_none() {
            self.surplus_streak = 0;
            return Vec::new();
        }
        self.surplus_streak += 1;
        if self.surplus_streak < self.patience {
            return Vec::new();
        }
        self.surplus_streak = 0;
        let mut actions: Vec<ScaleAction> = idle_be
            .into_iter()
            .rev() // newest first: LIFO keeps warm old servers
            .take(surplus_be)
            .map(|inst| ScaleAction::Drain { inst, migrate: true }) // idle: nothing to move
            .collect();
        if actions.is_empty() {
            if let Some(inst) = tier_candidate {
                let migrate = migration_feasible(ctx, inst);
                actions.push(ScaleAction::Drain { inst, migrate });
            }
        }
        actions
    }
}

impl Autoscaler for GradientAutoscaler {
    fn evaluate(&mut self, _now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        let mut actions = match self.planner.as_mut() {
            Some(p) => p.evaluate(_now, ctx),
            None => self.scale_primary(ctx),
        };
        if self.prefill_elastic {
            actions.extend(prefill_pressure_actions(ctx, &mut self.prefill_streak, self.patience));
        }
        actions
    }

    fn name(&self) -> String {
        "gradient".into()
    }
}

// ------------------------------------------------------------ threshold

/// Reactive utilization-threshold baseline scaler.
pub struct ThresholdAutoscaler {
    /// Scale out above this busy fraction.
    hi: f64,
    /// Scale in below this busy fraction (after `patience` evals).
    lo: f64,
    patience: u32,
    low_streak: u32,
    last_eval_ms: Option<TimeMs>,
    last_busy_ms: u64,
    /// Also react to TTFT pressure on the PD prefill tier.
    prefill_elastic: bool,
    prefill_streak: u32,
    /// Multi-model planner; replaces the single-model primary sizing
    /// when present.
    planner: Option<ModelMixPlanner>,
}

impl ThresholdAutoscaler {
    /// Build with high/low busy-fraction water marks (`lo < hi`); the
    /// prefill tier stays static unless [`Self::scale_prefill`] enables
    /// it.
    pub fn new(hi: f64, lo: f64) -> ThresholdAutoscaler {
        assert!(lo < hi, "scale-in threshold must be below scale-out");
        ThresholdAutoscaler {
            hi,
            lo,
            patience: 3,
            low_streak: 0,
            last_eval_ms: None,
            last_busy_ms: 0,
            prefill_elastic: false,
            prefill_streak: 0,
            planner: None,
        }
    }

    /// Enable/disable elastic-prefill reactions ([`ttft_pressure`]).
    pub fn scale_prefill(mut self, enabled: bool) -> Self {
        self.prefill_elastic = enabled;
        self
    }

    /// Attach a multi-model planner (`None` leaves the single-model
    /// behaviour bit-for-bit unchanged).
    pub fn with_planner(mut self, planner: Option<ModelMixPlanner>) -> Self {
        self.planner = planner;
        self
    }

    /// Busy fraction of the scalable fleet since the last evaluation.
    /// Everything whose busy time lands in the numerator must count in
    /// the capacity denominator: drainers still burn iterations, and an
    /// instance that *retired inside the window* contributed busy time
    /// too — excluding either inflates util past the truth right after
    /// a scale-in and triggers an immediate re-provision oscillation.
    /// A retiree counts only up to its retirement, so a server gone
    /// early in the window doesn't deflate the surviving fleet's
    /// utilization either.
    fn utilization(&mut self, now: TimeMs, ctx: &RouteCtx, role: Role) -> Option<f64> {
        let busy: u64 = ctx
            .cluster
            .instances
            .iter()
            .filter(|i| i.role == role)
            .map(|i| i.busy_ms_total)
            .sum();
        let util = match self.last_eval_ms {
            Some(prev) if now > prev => {
                let serving =
                    (ctx.cluster.active_count(role) + ctx.cluster.draining_count(role)).max(1);
                // An instance that retired inside the window was
                // capacity only until its retirement.
                let retired_capacity_ms: u64 = ctx
                    .cluster
                    .instances
                    .iter()
                    .filter(|i| i.role == role)
                    .filter_map(|i| match i.lifecycle {
                        Lifecycle::Retired { at } if at > prev => Some(at - prev),
                        _ => None,
                    })
                    .sum();
                let window = (now - prev) * serving as u64 + retired_capacity_ms;
                Some((busy.saturating_sub(self.last_busy_ms)) as f64 / window as f64)
            }
            _ => None,
        };
        self.last_eval_ms = Some(now);
        self.last_busy_ms = busy;
        util
    }

    /// The PR 1 utilization reaction over the scalable role (decode /
    /// coloc); unchanged by the elastic-prefill extension.
    fn scale_primary(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        let role = scaling_role(ctx.mode);
        let Some(util) = self.utilization(now, ctx, role) else {
            return Vec::new();
        };
        if util > self.hi {
            self.low_streak = 0;
            // Proportional step, 1 minimum: a deep overload closes
            // faster than one-at-a-time.
            let active = ctx.cluster.active_count(role);
            let want = (((util - self.hi) / self.hi) * active as f64).ceil() as usize;
            let in_flight = ctx.cluster.provisioning_count(role);
            let n = want.max(1).saturating_sub(in_flight);
            return (0..n).map(|_| ScaleAction::Provision { role }).collect();
        }
        if util < self.lo {
            self.low_streak += 1;
            if self.low_streak >= self.patience {
                self.low_streak = 0;
                // Drain the least-loaded active instance of the role.
                let target = ctx
                    .cluster
                    .with_role(role)
                    .min_by_key(|&id| {
                        let i = &ctx.cluster.instances[id];
                        (i.decode_batch_now(), i.queued_prefill_tokens(ctx.requests))
                    });
                if let Some(inst) = target {
                    let migrate = migration_feasible(ctx, inst);
                    return vec![ScaleAction::Drain { inst, migrate }];
                }
            }
            return Vec::new();
        }
        self.low_streak = 0;
        Vec::new()
    }
}

impl Autoscaler for ThresholdAutoscaler {
    fn evaluate(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        let mut actions = match self.planner.as_mut() {
            Some(p) => p.evaluate(now, ctx),
            None => self.scale_primary(now, ctx),
        };
        if self.prefill_elastic {
            actions.extend(prefill_pressure_actions(ctx, &mut self.prefill_streak, self.patience));
        }
        actions
    }

    fn name(&self) -> String {
        "threshold".into()
    }
}

// ----------------------------------------------------------- predictive

/// Smoothing factor for the arrival-rate EWMA (per `ScaleEval`).
const RATE_EWMA_ALPHA: f64 = 0.35;
/// Smoothing factor for the per-tier arrival-mix EWMA.
const MIX_EWMA_ALPHA: f64 = 0.3;
/// Rate-history window the linear trend is fitted over (samples).
const TREND_WINDOW: usize = 8;
/// Most instances provisioned (per role) in a single evaluation.
const MAX_PROVISION_STEP: usize = 8;
/// Most instances drained (primary role) in a single evaluation.
const MAX_DRAIN_STEP: usize = 2;
/// Bins a seasonal period is divided into for the per-bin rate EWMAs
/// of [`PredictiveAutoscaler::with_seasonal`].
const SEASON_BINS: usize = 16;
/// Smoothing factor for the chaos-adaptive kill-rate EWMA (per
/// `ScaleEval` epoch with fresh [`ChaosStats`]).
const KILL_EWMA_ALPHA: f64 = 0.35;
/// Billable work a spot preemption wastes, ms-equivalents: the cold
/// start of the replacement plus the victims' re-prefill. The
/// chaos-adaptive scaler prices churn as
/// `per-spot-instance kill rate (per ms) × CHURN_RECOVERY_MS` and adds
/// it to the spot price before comparing against on-demand.
const CHURN_RECOVERY_MS: f64 = 60_000.0;
/// Effective spot price (discounted rate + churn tax) above which the
/// chaos-adaptive scaler holds the spot stride and provisions
/// on-demand only.
const SPOT_POLICY_HI: f64 = 1.0;
/// Effective spot price below which a held stride is restored —
/// strictly under [`SPOT_POLICY_HI`] so the policy can't flap on a
/// boundary-hugging price curve.
const SPOT_POLICY_LO: f64 = 0.8;

/// Profile-driven predictive fleet scaler: provisions for the arrival
/// rate projected `provision_lead_ms` ahead instead of reacting to
/// saturation.
///
/// Per [`Autoscaler::evaluate`]:
/// 1. Ingest arrivals since the last epoch (a cursor over the
///    arrival-ordered request list) into a windowed rate sample,
///    per-tier mix EWMA, and running prompt/output-length means.
/// 2. Smooth the rate (EWMA) and fit a linear trend over the last
///    `TREND_WINDOW` epochs; project `rate(now + lead)` (clamped at
///    0).
/// 3. Convert the projected per-tier rates into a required fleet via
///    [`sizing::required_decode_fleet`] (PD) /
///    [`sizing::required_coloc_fleet`] (coloc) — the same math that
///    sizes the static bench baselines — plus a reactive backstop for
///    visible unplaced demand (model error never strands requests).
/// 4. Provision up to the shortfall vs *committed* capacity
///    (active + cold-starting) or, after a patience window, drain down
///    toward the requirement, least-loaded first.
/// 5. With `prefill_elastic`, size the PD prefill tier from projected
///    prompt-token demand ([`sizing::required_prefill_fleet`]) and the
///    [`ttft_pressure`] signal the reactive scalers also consume.
///
/// Every epoch records a [`RateSample`] (observed / smoothed /
/// projected rps) that lands on `SimResult::fleet.rates` for the
/// predicted-vs-actual series in benches and the CLI.
pub struct PredictiveAutoscaler {
    tiers: TierSet,
    /// Anticipation horizon: size for the rate projected this far ahead.
    lead_ms: u64,
    patience: u32,
    prefill_elastic: bool,
    /// Arrival-ingestion cursor into the (arrival-ordered) request list.
    cursor: usize,
    last_eval_ms: Option<TimeMs>,
    /// (epoch time, smoothed rps) history the trend is fitted over.
    history: VecDeque<(TimeMs, f64)>,
    ewma_rps: f64,
    seeded: bool,
    /// EWMA per-tier arrival mix (sums to ≈1 once seeded).
    tier_mix: Vec<f64>,
    /// Running workload-shape sums over all ingested arrivals.
    n_seen: u64,
    sum_prefill: f64,
    sum_decode: f64,
    drain_streak: u32,
    prefill_streak: u32,
    rates: Vec<RateSample>,
    /// Multi-model planner; replaces the single-model primary sizing
    /// when present.
    planner: Option<ModelMixPlanner>,
    /// Seasonal period for the per-bin rate EWMAs; `None` = no seasonal
    /// term (the pre-seasonal projection bit-for-bit).
    season_period_ms: Option<u64>,
    /// Per-bin smoothed observed rate over the seasonal period.
    season_rates: Vec<f64>,
    /// Which seasonal bins have been observed at least once.
    season_seeded: Vec<bool>,
    /// Pad the required fleet by a fraction of the active spot capacity
    /// (preemptible instances can vanish on a deadline).
    spot_aware: bool,
    /// `[chaos] adaptive`: consume [`ChaosStats`] online — pad the plan
    /// by expected imminent kills and steer the spot/on-demand split.
    chaos_adaptive: bool,
    /// Fleet-wide instance-kill EWMA, kills per ms (failures +
    /// deadline-expired preemptions, from the cumulative counters).
    kill_rate_per_ms: f64,
    /// Cumulative kill count at the last `observe_chaos`.
    last_kills: u64,
    /// Epoch time of the last `observe_chaos` (rate-window anchor).
    last_chaos_ms: Option<TimeMs>,
    /// Current spot-policy decision (`true` = hold the stride).
    spot_on_demand: bool,
    /// A [`ScaleAction::SpotPolicy`] flip awaiting emission by the next
    /// `evaluate`.
    spot_policy_dirty: bool,
}

impl PredictiveAutoscaler {
    /// Build for a tier set and anticipation horizon (typically the
    /// provisioning cold-start delay, so capacity lands exactly when
    /// the projected rate does).
    pub fn new(tiers: TierSet, lead_ms: u64) -> PredictiveAutoscaler {
        let n = tiers.len();
        PredictiveAutoscaler {
            tiers,
            lead_ms,
            patience: 3,
            prefill_elastic: false,
            cursor: 0,
            last_eval_ms: None,
            history: VecDeque::with_capacity(TREND_WINDOW + 1),
            ewma_rps: 0.0,
            seeded: false,
            tier_mix: vec![0.0; n],
            n_seen: 0,
            sum_prefill: 0.0,
            sum_decode: 0.0,
            drain_streak: 0,
            prefill_streak: 0,
            rates: Vec::new(),
            planner: None,
            season_period_ms: None,
            season_rates: vec![0.0; SEASON_BINS],
            season_seeded: vec![false; SEASON_BINS],
            spot_aware: false,
            chaos_adaptive: false,
            kill_rate_per_ms: 0.0,
            last_kills: 0,
            last_chaos_ms: None,
            spot_on_demand: false,
            spot_policy_dirty: false,
        }
    }

    /// Enable/disable predictive sizing of the PD prefill tier.
    pub fn scale_prefill(mut self, enabled: bool) -> Self {
        self.prefill_elastic = enabled;
        self
    }

    /// Attach a multi-model planner (`None` leaves the single-model
    /// behaviour bit-for-bit unchanged). With a planner the prefill
    /// tier falls back to the reactive [`ttft_pressure`] loop the other
    /// policies use — per-model prompt demand is what the planner
    /// already sizes the primary role from.
    pub fn with_planner(mut self, planner: Option<ModelMixPlanner>) -> Self {
        self.planner = planner;
        self
    }

    /// Enable a period-aware seasonal forecast term: the observed rate
    /// is also tracked in [`SEASON_BINS`] per-phase EWMAs over
    /// `period_ms`, and the projection is shifted by the historical
    /// rate difference between the bin the anticipation lead lands in
    /// and the current bin — recurring patterns (diurnal cycles,
    /// scheduled flash crowds) that the EWMA + linear-trend fit can
    /// only chase after the fact. `None` (the default) disables the
    /// term and reproduces the pre-seasonal projection bit-for-bit.
    pub fn with_seasonal(mut self, period_ms: Option<u64>) -> Self {
        self.season_period_ms = period_ms.filter(|p| *p >= SEASON_BINS as u64);
        self
    }

    /// Pad the required fleet by a quarter of the currently active spot
    /// capacity (rounded up): preemptible instances can vanish on a
    /// deadline, so the plan holds slack against reclamation. Off by
    /// default (bit-identical sizing).
    pub fn spot_aware(mut self, enabled: bool) -> Self {
        self.spot_aware = enabled;
        self
    }

    /// Enable chaos-adaptive provisioning (`[chaos] adaptive`): track a
    /// kill-rate EWMA from the [`ChaosStats`] feed, pad the required
    /// fleet by the kills expected inside the anticipation lead
    /// ([`sizing::churn_pad`]), and hold the spot stride
    /// ([`ScaleAction::SpotPolicy`]) while churn prices spot capacity
    /// above on-demand. Off by default — without the opt-in the
    /// telemetry feed is ignored and every decision stays bit-identical.
    pub fn chaos_adaptive(mut self, enabled: bool) -> Self {
        self.chaos_adaptive = enabled;
        self
    }

    /// Update the seasonal per-bin EWMA with this epoch's observation
    /// and return the forecast correction: the historical rate delta
    /// between the bin `now + lead` falls in and the current bin.
    /// `None` when the term is disabled, both times share a bin, or the
    /// target bin has never been observed.
    fn seasonal_delta(&mut self, now: TimeMs, observed_rps: f64) -> Option<f64> {
        let period = self.season_period_ms?;
        let bin_w = (period / SEASON_BINS as u64).max(1);
        let bin = ((now % period) / bin_w) as usize % SEASON_BINS;
        if self.season_seeded[bin] {
            self.season_rates[bin] = (1.0 - RATE_EWMA_ALPHA) * self.season_rates[bin]
                + RATE_EWMA_ALPHA * observed_rps;
        } else {
            self.season_rates[bin] = observed_rps;
            self.season_seeded[bin] = true;
        }
        let target = (((now + self.lead_ms) % period) / bin_w) as usize % SEASON_BINS;
        if target == bin || !self.season_seeded[target] {
            return None;
        }
        Some(self.season_rates[target] - self.season_rates[bin])
    }

    /// Least-squares slope (rps per ms) of the smoothed-rate history.
    fn trend_slope(&self) -> f64 {
        let n = self.history.len();
        if n < 2 {
            return 0.0;
        }
        let t0 = self.history.front().expect("n >= 2").0 as f64;
        let (mut st, mut sy, mut stt, mut sty) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for &(t, y) in &self.history {
            let x = t as f64 - t0;
            st += x;
            sy += y;
            stt += x * x;
            sty += x * y;
        }
        let nf = n as f64;
        let denom = nf * stt - st * st;
        if denom.abs() < 1e-9 {
            return 0.0;
        }
        (nf * sty - st * sy) / denom
    }

    /// Ingest arrivals in `(prev, now]`; returns the count.
    fn ingest_arrivals(&mut self, now: TimeMs, ctx: &RouteCtx) -> u64 {
        let mut new_n = 0u64;
        let mut tier_counts = vec![0u64; self.tier_mix.len()];
        while self.cursor < ctx.requests.len()
            && ctx.requests[self.cursor].req.arrival_ms <= now
        {
            let r = &ctx.requests[self.cursor];
            new_n += 1;
            if r.tier < tier_counts.len() {
                tier_counts[r.tier] += 1;
            }
            self.n_seen += 1;
            self.sum_prefill += r.req.prefill_len as f64;
            self.sum_decode += r.req.decode_len as f64;
            self.cursor += 1;
        }
        if new_n > 0 {
            let mut sum = 0.0;
            for (k, mix) in self.tier_mix.iter_mut().enumerate() {
                let frac = tier_counts[k] as f64 / new_n as f64;
                *mix = if self.seeded {
                    (1.0 - MIX_EWMA_ALPHA) * *mix + MIX_EWMA_ALPHA * frac
                } else {
                    frac
                };
                sum += *mix;
            }
            if sum > 0.0 {
                for mix in self.tier_mix.iter_mut() {
                    *mix /= sum;
                }
            }
        }
        new_n
    }
}

impl PredictiveAutoscaler {
    /// The single-model §4.4-predictive epoch (the pre-registry
    /// `evaluate` body, verbatim).
    fn scale_single(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        let new_n = self.ingest_arrivals(now, ctx);
        let Some(prev) = self.last_eval_ms.replace(now) else {
            // First epoch only anchors the window.
            return Vec::new();
        };
        if now <= prev {
            return Vec::new();
        }
        let dt_s = (now - prev) as f64 / 1000.0;
        let observed = new_n as f64 / dt_s;
        self.ewma_rps = if self.seeded {
            RATE_EWMA_ALPHA * observed + (1.0 - RATE_EWMA_ALPHA) * self.ewma_rps
        } else {
            observed
        };
        self.seeded = true;
        self.history.push_back((now, self.ewma_rps));
        while self.history.len() > TREND_WINDOW {
            self.history.pop_front();
        }
        let mut projected = (self.ewma_rps + self.trend_slope() * self.lead_ms as f64).max(0.0);
        // Seasonal correction: shift the projection by the recurring
        // phase-to-phase rate delta (no-op unless `with_seasonal`).
        if let Some(delta) = self.seasonal_delta(now, observed) {
            projected = (projected + delta).max(0.0);
        }
        self.rates.push(RateSample {
            t_ms: now,
            observed_rps: observed,
            smoothed_rps: self.ewma_rps,
            predicted_rps: projected,
        });
        if self.n_seen == 0 {
            return Vec::new();
        }

        let avg_p = self.sum_prefill / self.n_seen as f64;
        let avg_d = (self.sum_decode / self.n_seen as f64).max(1.0);
        // Mean resident KV of a decode stream: full prompt + half the
        // output (the same `p + d/2` idiom the analysis layer uses).
        let kv_per_req = (avg_p + avg_d * 0.5) as u64;
        let tier_rates: Vec<f64> = self.tier_mix.iter().map(|f| f * projected).collect();
        let role = scaling_role(ctx.mode);
        let mut required = match ctx.mode {
            ServingMode::PdDisaggregated => sizing::required_decode_fleet(
                ctx.profile,
                &self.tiers,
                &tier_rates,
                avg_d,
                kv_per_req,
            ),
            ServingMode::Colocated => sizing::required_coloc_fleet(
                ctx.profile,
                &self.tiers,
                &tier_rates,
                avg_p,
                avg_d,
                kv_per_req,
            ),
        };
        if self.spot_aware {
            // Reclamation slack: a quarter of the active spot capacity
            // (rounded up) can disappear on one grace window.
            let spot_active = ctx
                .cluster
                .instances
                .iter()
                .filter(|i| i.spot && i.role == role && i.lifecycle.accepts_work())
                .count();
            required += spot_active.div_ceil(4);
        }
        if self.chaos_adaptive {
            // Churn pad: capacity the observed kill rate is expected to
            // claim inside the anticipation lead must already be
            // cold-starting now, or every correlated kill re-opens the
            // provisioning-delay gap the lead exists to close.
            required += sizing::churn_pad(self.kill_rate_per_ms, self.lead_ms);
        }
        // Reactive backstop: visible unplaced demand means the model
        // under-sized (length misprediction, burst inside the window) —
        // grow past the plan rather than strand requests. The demand
        // read is O(1) off the incremental counter (the pre-PR O(total
        // requests) residency scan is the reference-mode path), and is
        // still gated on fleet stress (no scalable instance idle): with
        // an empty server available, capacity is not what's holding
        // demand back.
        let fleet_saturated = ctx
            .cluster
            .with_role(role)
            .all(|id| !ctx.cluster.instances[id].is_empty());
        if fleet_saturated {
            let backlog = unplaced_demand(ctx);
            if backlog > 0 {
                required =
                    required.max(ctx.cluster.active_count(role) + backlog.div_ceil(8).min(4));
            }
        }

        let mut actions = Vec::new();
        let active = ctx.cluster.active_count(role);
        let committed = ctx.cluster.committed_count(role);
        if required > committed {
            self.drain_streak = 0;
            let want = (required - committed).min(MAX_PROVISION_STEP);
            actions.extend((0..want).map(|_| ScaleAction::Provision { role }));
        } else if required < active {
            self.drain_streak += 1;
            if self.drain_streak >= self.patience {
                self.drain_streak = 0;
                let take = (active - required).min(MAX_DRAIN_STEP);
                for (n, inst) in k_least_loaded(ctx, role, take).into_iter().enumerate() {
                    // Only the first drain of a batch may migrate: the
                    // feasibility gate is evaluated against the
                    // *current* fleet, and a second simultaneous
                    // eviction would count the first drainee as a
                    // destination it no longer is. Later drains fall
                    // back to wait-drain (safe by construction).
                    let migrate = n == 0 && migration_feasible(ctx, inst);
                    actions.push(ScaleAction::Drain { inst, migrate });
                }
            }
        } else {
            self.drain_streak = 0;
        }

        if self.prefill_elastic && ctx.mode == ServingMode::PdDisaggregated {
            let planned = sizing::required_prefill_fleet(
                ctx.profile,
                projected,
                avg_p,
                PREFILL_SIZING_BUDGET,
            );
            let pressure = ttft_pressure(ctx, PREFILL_SIZING_BUDGET);
            let active_pf = ctx.cluster.active_count(Role::Prefill);
            let committed_pf = ctx.cluster.committed_count(Role::Prefill);
            // The plan sets the baseline; live TTFT pressure can only
            // raise it (a plan that lags a burst must not veto relief).
            let needed = if pressure > PREFILL_PRESSURE_HI {
                planned.max(active_pf + 1)
            } else {
                planned
            };
            if needed > committed_pf {
                self.prefill_streak = 0;
                actions.extend(
                    (0..(needed - committed_pf).min(4))
                        .map(|_| ScaleAction::Provision { role: Role::Prefill }),
                );
            } else if needed < active_pf && pressure < PREFILL_PRESSURE_LO {
                self.prefill_streak += 1;
                if self.prefill_streak >= self.patience {
                    self.prefill_streak = 0;
                    actions.extend(prefill_drain_action(ctx));
                }
            } else {
                self.prefill_streak = 0;
            }
        }
        actions
    }
}

impl Autoscaler for PredictiveAutoscaler {
    fn evaluate(&mut self, now: TimeMs, ctx: &mut RouteCtx) -> Vec<ScaleAction> {
        let mut actions = if let Some(p) = self.planner.as_mut() {
            let mut actions = p.evaluate(now, ctx);
            if self.prefill_elastic && ctx.mode == ServingMode::PdDisaggregated {
                actions.extend(prefill_pressure_actions(
                    ctx,
                    &mut self.prefill_streak,
                    self.patience,
                ));
            }
            actions
        } else {
            self.scale_single(now, ctx)
        };
        if self.spot_policy_dirty {
            self.spot_policy_dirty = false;
            actions.push(ScaleAction::SpotPolicy {
                on_demand: self.spot_on_demand,
            });
        }
        actions
    }

    fn name(&self) -> String {
        "predictive".into()
    }

    fn take_rate_series(&mut self) -> Vec<RateSample> {
        std::mem::take(&mut self.rates)
    }

    fn observe_chaos(
        &mut self,
        now: TimeMs,
        stats: &ChaosStats,
        spot_active: usize,
        spot_price: f64,
    ) {
        if !self.chaos_adaptive {
            return;
        }
        // Kill-rate EWMA off the cumulative hard-kill counter (explicit
        // schedules, MTBF draws, domain kills and blown preemption
        // deadlines all land in `failures`).
        let kills = stats.failures;
        if let Some(prev) = self.last_chaos_ms.replace(now) {
            if now > prev {
                let rate =
                    kills.saturating_sub(self.last_kills) as f64 / (now - prev) as f64;
                self.kill_rate_per_ms = KILL_EWMA_ALPHA * rate
                    + (1.0 - KILL_EWMA_ALPHA) * self.kill_rate_per_ms;
            }
        }
        self.last_kills = kills;
        // Spot/on-demand split: price churn as the per-spot-instance
        // kill rate times the wasted-work cost; when the discounted
        // rate plus that tax beats on-demand (1.0) the stride is held,
        // and restored only once the effective price falls back under
        // the hysteresis floor.
        let churn_tax =
            self.kill_rate_per_ms / spot_active.max(1) as f64 * CHURN_RECOVERY_MS;
        let effective = spot_price + churn_tax;
        let want = if self.spot_on_demand {
            effective >= SPOT_POLICY_LO
        } else {
            effective > SPOT_POLICY_HI
        };
        if want != self.spot_on_demand {
            self.spot_on_demand = want;
            self.spot_policy_dirty = true;
        }
    }
}

/// Build the autoscaler requested by a [`SimConfig`] (`None` when the
/// fleet is fixed). Elastic-prefill reactions are wired in only for PD
/// mode — co-location has no prefill cluster to scale.
pub fn make_autoscaler(cfg: &SimConfig) -> Option<Box<dyn Autoscaler>> {
    make_autoscaler_with_models(cfg, &[])
}

/// Multi-model form of [`make_autoscaler`]: with more than one profile
/// (one per registered model, model-id order) the chosen policy gets a
/// [`ModelMixPlanner`] attached and sizes each model's sub-fleet
/// separately, swapping capacity between sub-fleets when that is
/// cheaper than a cloud cold start. With zero or one profile this *is*
/// [`make_autoscaler`] — no planner, bit-identical decisions.
pub fn make_autoscaler_with_models(
    cfg: &SimConfig,
    profiles: &[ProfileTable],
) -> Option<Box<dyn Autoscaler>> {
    if !cfg.elastic.enabled() {
        return None;
    }
    let pf = cfg.elastic.prefill_elastic && cfg.mode == ServingMode::PdDisaggregated;
    let planner = (profiles.len() > 1)
        .then(|| ModelMixPlanner::new(cfg.tiers.clone(), profiles.to_vec()));
    match cfg.elastic.scaler {
        ScalerKind::Gradient => Some(Box::new(
            GradientAutoscaler::new(cfg.tiers.clone())
                .scale_prefill(pf)
                .with_planner(planner),
        )),
        ScalerKind::Threshold => Some(Box::new(
            ThresholdAutoscaler::new(0.75, 0.35)
                .scale_prefill(pf)
                .with_planner(planner),
        )),
        ScalerKind::Predictive => {
            let lead = cfg
                .elastic
                .provision_lead_ms
                .unwrap_or(cfg.elastic.provision_delay_ms);
            Some(Box::new(
                PredictiveAutoscaler::new(cfg.tiers.clone(), lead)
                    .scale_prefill(pf)
                    // Seasonal term engages only when the workload has a
                    // declared period to learn; spot awareness only when
                    // `[chaos]` actually provisions spot capacity.
                    .with_seasonal(cfg.diurnal.map(|d| (d.period_s * 1000.0) as u64))
                    .spot_aware(cfg.chaos.spot_fraction > 0.0)
                    .chaos_adaptive(cfg.chaos.adaptive)
                    .with_planner(planner),
            ))
        }
        ScalerKind::Off => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;
    use crate::profile::ProfileTable;
    use crate::sim::{Cluster, SimRequest};
    use crate::slo::Slo;
    use crate::workload::Request;

    fn ctx_parts() -> (Cluster, ProfileTable) {
        let cm = CostModel::h200_llama8b();
        let cluster = Cluster::build(ServingMode::Colocated, 6, 0.0, 4, &cm, true);
        (cluster, ProfileTable::from_cost_model(&cm))
    }

    /// A finished tier-`tier` request that arrived at `arrival_ms` —
    /// visible to the rate estimator, invisible to unplaced-demand.
    fn arrived_req(id: u64, arrival_ms: u64, tier: usize, tpot: u64) -> SimRequest<'static> {
        // Leaked immutable half: the arena borrows, never clones.
        let req: &'static Request = Box::leak(Box::new(Request {
            id,
            arrival_ms,
            prefill_len: 512,
            decode_len: 300,
            slo: Slo::new(1_000, tpot),
            model: 0,
        }));
        let mut r = SimRequest::new(req, tier);
        r.prefill_done = 512;
        r.decoded = 300;
        r.first_token_ms = Some(arrival_ms + 1);
        r.finish_ms = Some(arrival_ms + 2);
        r
    }

    /// An un-prefilled tier-`tier` request with an 8 k prompt — the
    /// queued-work fixture of the TTFT-pressure tests. The prompt
    /// length lives in the immutable borrowed half of the arena, so it
    /// is set at construction rather than mutated afterwards.
    fn unprefilled_req(id: u64, tier: usize, tpot: u64) -> SimRequest<'static> {
        let req: &'static Request = Box::leak(Box::new(Request {
            id,
            arrival_ms: 0,
            prefill_len: 8_000,
            decode_len: 300,
            slo: Slo::new(1_000, tpot),
            model: 0,
        }));
        SimRequest::new(req, tier)
    }

    #[test]
    fn gradient_drains_surplus_idle_pool_after_patience() {
        let (mut cluster, profile) = ctx_parts();
        let mut reqs: Vec<SimRequest> = Vec::new();
        let mut sc = GradientAutoscaler::new(TierSet::paper_default());
        // All 6 instances idle in the BE pool; reserve is 1 → 5 surplus.
        // The policy acts on the `patience`-th consecutive surplus eval.
        let mut actions = Vec::new();
        let evals = sc.patience as u64;
        for t in 0..evals {
            let mut ctx = RouteCtx {
                now: t * 1000,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::Colocated,
                kv_transfer_ms: 2,
            };
            actions = sc.evaluate(t * 1000, &mut ctx);
            if t + 1 < evals {
                assert!(actions.is_empty(), "drained before patience at t={t}");
            }
        }
        assert_eq!(actions.len(), 5);
        assert!(actions
            .iter()
            .all(|a| matches!(a, ScaleAction::Drain { .. })));
    }

    #[test]
    fn gradient_quiet_when_pool_has_reserve_and_no_tiers() {
        let (mut cluster, profile) = ctx_parts();
        let mut reqs: Vec<SimRequest> = Vec::new();
        // Shrink the pool to exactly the reserve: claim all but one.
        for _ in 0..5 {
            let id = cluster.claim_for_tier(3, 0).unwrap();
            // Tier members with nothing resident are "surplus" — avoid
            // that by immediately releasing them from the tier view.
            cluster.begin_drain(id, 0);
            cluster.retire_if_drained(id, 0);
        }
        let mut sc = GradientAutoscaler::new(TierSet::paper_default());
        for t in 0..5u64 {
            let mut ctx = RouteCtx {
                now: t,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::Colocated,
                kv_transfer_ms: 2,
            };
            assert!(sc.evaluate(t, &mut ctx).is_empty());
        }
    }

    #[test]
    fn threshold_scaler_needs_two_samples_then_reacts() {
        let (mut cluster, profile) = ctx_parts();
        let mut reqs: Vec<SimRequest> = Vec::new();
        let mut sc = ThresholdAutoscaler::new(0.75, 0.35);
        // First eval: no window yet.
        let a0 = {
            let mut ctx = RouteCtx {
                now: 1000,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::Colocated,
                kv_transfer_ms: 2,
            };
            sc.evaluate(1000, &mut ctx)
        };
        assert!(a0.is_empty());
        // Make the fleet look fully busy for the next window.
        for i in cluster.instances.iter_mut() {
            i.busy_ms_total += 1000;
        }
        let a1 = {
            let mut ctx = RouteCtx {
                now: 2000,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::Colocated,
                kv_transfer_ms: 2,
            };
            sc.evaluate(2000, &mut ctx)
        };
        assert!(
            a1.iter()
                .all(|a| matches!(a, ScaleAction::Provision { role: Role::Coloc })),
            "expected provisions, got {a1:?}"
        );
        assert!(!a1.is_empty());
        // Idle windows → drains after patience.
        let mut drained = false;
        for t in 3..10u64 {
            let mut ctx = RouteCtx {
                now: t * 1000,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::Colocated,
                kv_transfer_ms: 2,
            };
            let acts = sc.evaluate(t * 1000, &mut ctx);
            if acts
                .iter()
                .any(|a| matches!(a, ScaleAction::Drain { .. }))
            {
                drained = true;
                break;
            }
        }
        assert!(drained, "idle fleet never drained");
    }

    /// Property (1): at a constant arrival rate, the predictive scaler
    /// settles the fleet at exactly the shared static-sizing answer —
    /// provisioning up to it, then draining any surplus back down to it,
    /// then holding.
    #[test]
    fn predictive_converges_to_static_sizing_on_constant_rate() {
        let cm = CostModel::h200_llama8b();
        let profile = ProfileTable::from_cost_model(&cm);
        let tiers = TierSet::paper_default();
        // 40 req/s, all in the loosest (100 ms) tier, finished on
        // arrival so the rate estimator sees them but unplaced-demand
        // does not.
        let horizon_ms = 120_000u64;
        let mut reqs: Vec<SimRequest> = (0..(horizon_ms / 25))
            .map(|i| arrived_req(i, i * 25, 3, 100))
            .collect();
        let expected = sizing::required_decode_fleet(
            &profile,
            &tiers,
            &[0.0, 0.0, 0.0, 40.0],
            300.0,
            512 + 150,
        );
        assert!(expected >= 1);

        // Start from a 2-instance coloc fleet (sizing for coloc inflates
        // by the prefill share; compute the coloc expectation too).
        let expected_coloc = sizing::required_coloc_fleet(
            &profile,
            &tiers,
            &[0.0, 0.0, 0.0, 40.0],
            512.0,
            300.0,
            512 + 150,
        );
        let mut cluster = Cluster::build(ServingMode::Colocated, 2, 0.0, 4, &cm, true);
        let mut sc = PredictiveAutoscaler::new(tiers.clone(), 0);
        let mut now = 0u64;
        for _ in 0..60 {
            now += 1000;
            let actions = {
                let mut ctx = RouteCtx {
                    now,
                    cluster: &mut cluster,
                    requests: &mut reqs,
                    profile: &profile,
                    mode: ServingMode::Colocated,
                    kv_transfer_ms: 2,
                };
                sc.evaluate(now, &mut ctx)
            };
            // Apply: instant provisioning/retire keeps the test focused
            // on the *decision* sequence, not the sim mechanics.
            for a in actions {
                match a {
                    ScaleAction::Provision { role } => {
                        let id = cluster.provision(role, now, now);
                        cluster.mark_ready(id);
                    }
                    ScaleAction::Drain { inst, .. } => {
                        cluster.begin_drain(inst, now);
                        cluster.retire_if_drained(inst, now);
                    }
                    _ => {}
                }
            }
        }
        assert_eq!(
            cluster.active_count(Role::Coloc),
            expected_coloc,
            "constant 40 rps must converge to the static-sizing fleet"
        );
        // And from above: an over-provisioned fleet drains back to it.
        for _ in 0..5 {
            let id = cluster.provision(Role::Coloc, now, now);
            cluster.mark_ready(id);
        }
        for _ in 0..30 {
            now += 1000;
            let actions = {
                let mut ctx = RouteCtx {
                    now,
                    cluster: &mut cluster,
                    requests: &mut reqs,
                    profile: &profile,
                    mode: ServingMode::Colocated,
                    kv_transfer_ms: 2,
                };
                sc.evaluate(now, &mut ctx)
            };
            for a in actions {
                match a {
                    ScaleAction::Provision { role } => {
                        let id = cluster.provision(role, now, now);
                        cluster.mark_ready(id);
                    }
                    ScaleAction::Drain { inst, .. } => {
                        cluster.begin_drain(inst, now);
                        cluster.retire_if_drained(inst, now);
                    }
                    _ => {}
                }
            }
        }
        assert_eq!(
            cluster.active_count(Role::Coloc),
            expected_coloc,
            "surplus fleet must drain back to the static-sizing answer"
        );
        let series = sc.take_rate_series();
        assert!(!series.is_empty());
        let last = series.last().unwrap();
        assert!(
            (last.smoothed_rps - 40.0).abs() < 4.0,
            "EWMA must settle near the true rate, got {}",
            last.smoothed_rps
        );
        // Zero trend at constant rate: projection ≈ smoothed estimate.
        assert!((last.predicted_rps - last.smoothed_rps).abs() < 2.0);
    }

    /// The seasonal term learns a recurring square-wave demand pattern
    /// and shifts the projection *before* the regime switch, while
    /// within-regime projections stay uncorrected.
    #[test]
    fn seasonal_term_learns_recurring_pattern() {
        let mut sc =
            PredictiveAutoscaler::new(TierSet::paper_default(), 250).with_seasonal(Some(1_000));
        // Two periods of a square wave: 10 rps in each period's first
        // half, 90 rps in the second. Bin width 62 ms → every one of
        // the 16 bins is observed within the first period.
        for t in (0..2_000u64).step_by(62) {
            sc.seasonal_delta(t, if (t % 1_000) < 500 { 10.0 } else { 90.0 });
        }
        // Period start, lead lands in the same low regime: ~no shift.
        let d0 = sc.seasonal_delta(2_000, 10.0).unwrap_or(0.0);
        assert!(d0.abs() < 20.0, "within-regime delta {d0}");
        // Just before the mid-period switch the lead lands in the high
        // half: the correction pre-provisions for the jump.
        let d1 = sc.seasonal_delta(2_400, 10.0).expect("target bin seeded");
        assert!(d1 > 40.0, "pre-switch delta {d1}");
        // Disabled term: never a correction, state untouched.
        let mut off = PredictiveAutoscaler::new(TierSet::paper_default(), 250);
        assert_eq!(off.seasonal_delta(2_400, 10.0), None);
        assert!(off.season_seeded.iter().all(|s| !s));
    }

    /// Property (2): with `provision_lead_ms = 0` and a flat trend, the
    /// predictive policy moves in the same *direction* as the reactive
    /// threshold baseline — overload provisions, idle drains.
    #[test]
    fn predictive_zero_lead_matches_threshold_direction() {
        let cm = CostModel::h200_llama8b();
        let profile = ProfileTable::from_cost_model(&cm);
        let tiers = TierSet::paper_default();
        let direction = |actions: &[ScaleAction]| -> i32 {
            if actions.iter().any(|a| matches!(a, ScaleAction::Provision { .. })) {
                1
            } else if actions.iter().any(|a| matches!(a, ScaleAction::Drain { .. })) {
                -1
            } else {
                0
            }
        };

        // Overloaded phase: a heavy constant rate against 2 servers,
        // fully-busy windows. Both must provision.
        let mut reqs: Vec<SimRequest> = (0..4_000u64)
            .map(|i| arrived_req(i, i * 10, 3, 100)) // 100 rps
            .collect();
        let mut cl_p = Cluster::build(ServingMode::Colocated, 2, 0.0, 4, &cm, true);
        let mut cl_t = cl_p.clone();
        let mut pred = PredictiveAutoscaler::new(tiers.clone(), 0);
        let mut thr = ThresholdAutoscaler::new(0.75, 0.35);
        let mut dir_p = 0;
        let mut dir_t = 0;
        for step in 1..=6u64 {
            let now = step * 1000;
            for i in cl_t.instances.iter_mut() {
                i.busy_ms_total += 1000; // fully busy window
            }
            let ap = {
                let mut ctx = RouteCtx {
                    now,
                    cluster: &mut cl_p,
                    requests: &mut reqs,
                    profile: &profile,
                    mode: ServingMode::Colocated,
                    kv_transfer_ms: 2,
                };
                pred.evaluate(now, &mut ctx)
            };
            let at = {
                let mut ctx = RouteCtx {
                    now,
                    cluster: &mut cl_t,
                    requests: &mut reqs,
                    profile: &profile,
                    mode: ServingMode::Colocated,
                    kv_transfer_ms: 2,
                };
                thr.evaluate(now, &mut ctx)
            };
            if direction(&ap) != 0 {
                dir_p = direction(&ap);
            }
            if direction(&at) != 0 {
                dir_t = direction(&at);
            }
        }
        assert_eq!(dir_p, 1, "predictive must provision under overload");
        assert_eq!(dir_t, 1, "threshold must provision under overload");

        // Idle phase: no arrivals, idle windows, a 6-instance fleet.
        // Both must eventually drain.
        let mut reqs2: Vec<SimRequest> = vec![arrived_req(0, 0, 3, 100)];
        let mut cl_p = Cluster::build(ServingMode::Colocated, 6, 0.0, 4, &cm, true);
        let mut cl_t = cl_p.clone();
        let mut pred = PredictiveAutoscaler::new(tiers.clone(), 0);
        let mut thr = ThresholdAutoscaler::new(0.75, 0.35);
        let (mut dir_p, mut dir_t) = (0, 0);
        for step in 1..=8u64 {
            let now = step * 1000;
            let ap = {
                let mut ctx = RouteCtx {
                    now,
                    cluster: &mut cl_p,
                    requests: &mut reqs2,
                    profile: &profile,
                    mode: ServingMode::Colocated,
                    kv_transfer_ms: 2,
                };
                pred.evaluate(now, &mut ctx)
            };
            let at = {
                let mut ctx = RouteCtx {
                    now,
                    cluster: &mut cl_t,
                    requests: &mut reqs2,
                    profile: &profile,
                    mode: ServingMode::Colocated,
                    kv_transfer_ms: 2,
                };
                thr.evaluate(now, &mut ctx)
            };
            if direction(&ap) != 0 {
                dir_p = direction(&ap);
            }
            if direction(&at) != 0 {
                dir_t = direction(&at);
            }
        }
        assert_eq!(dir_p, -1, "predictive must drain an idle fleet");
        assert_eq!(dir_t, -1, "threshold must drain an idle fleet");
    }

    #[test]
    fn ttft_pressure_rises_with_queue_and_falls_with_fleet() {
        let cm = CostModel::h200_llama8b();
        let profile = ProfileTable::from_cost_model(&cm);
        let mut cluster = Cluster::build(ServingMode::PdDisaggregated, 6, 0.5, 4, &cm, true);
        // Unprefilled requests with tight TTFT headroom.
        let mut reqs: Vec<SimRequest> = (0..8u64).map(|i| unprefilled_req(i, 3, 100)).collect();
        let empty = {
            let ctx = RouteCtx {
                now: 0,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::PdDisaggregated,
                kv_transfer_ms: 2,
            };
            ttft_pressure(&ctx, PREFILL_SIZING_BUDGET)
        };
        assert_eq!(empty, 0.0, "no queued work ⇒ no pressure");
        // Queue everything on prefill server 0 with 500 ms of headroom.
        for i in 0..8usize {
            cluster.instances[0].push_prefill(
                crate::sim::PrefillJob {
                    req_idx: i,
                    deadline: 500,
                },
                &reqs,
            );
        }
        let loaded = {
            let ctx = RouteCtx {
                now: 0,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::PdDisaggregated,
                kv_transfer_ms: 2,
            };
            ttft_pressure(&ctx, PREFILL_SIZING_BUDGET)
        };
        assert!(loaded > PREFILL_PRESSURE_HI, "64k queued tokens vs 500 ms: {loaded}");
        // Doubling the active prefill fleet halves the pressure.
        let id = cluster.provision(Role::Prefill, 0, 0);
        cluster.mark_ready(id);
        let relieved = {
            let ctx = RouteCtx {
                now: 0,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::PdDisaggregated,
                kv_transfer_ms: 2,
            };
            ttft_pressure(&ctx, PREFILL_SIZING_BUDGET)
        };
        assert!(relieved < loaded, "more servers must relieve pressure");
    }

    #[test]
    fn prefill_pressure_provisions_and_drains_for_every_policy() {
        let cm = CostModel::h200_llama8b();
        let profile = ProfileTable::from_cost_model(&cm);
        // 3 prefill + 3 decode servers, heavy queue on server 0.
        let mut cluster = Cluster::build(ServingMode::PdDisaggregated, 6, 0.5, 4, &cm, true);
        let mut reqs: Vec<SimRequest> =
            (0..12u64).map(|i| unprefilled_req(i, 3, 100)).collect();
        for i in 0..12usize {
            cluster.instances[0].push_prefill(
                crate::sim::PrefillJob {
                    req_idx: i,
                    deadline: 400,
                },
                &reqs,
            );
        }
        let mut grad =
            GradientAutoscaler::new(TierSet::paper_default()).scale_prefill(true);
        let actions = {
            let mut ctx = RouteCtx {
                now: 0,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::PdDisaggregated,
                kv_transfer_ms: 2,
            };
            grad.evaluate(0, &mut ctx)
        };
        assert!(
            actions
                .iter()
                .any(|a| matches!(a, ScaleAction::Provision { role: Role::Prefill })),
            "pressure must provision prefill, got {actions:?}"
        );
        // Without the flag the same state proposes no prefill action
        // (bit-for-bit PR 2 gradient).
        let mut grad_off = GradientAutoscaler::new(TierSet::paper_default());
        let actions_off = {
            let mut ctx = RouteCtx {
                now: 0,
                cluster: &mut cluster,
                requests: &mut reqs,
                profile: &profile,
                mode: ServingMode::PdDisaggregated,
                kv_transfer_ms: 2,
            };
            grad_off.evaluate(0, &mut ctx)
        };
        assert!(
            !actions_off
                .iter()
                .any(|a| matches!(a, ScaleAction::Provision { role: Role::Prefill })
                    || matches!(a, ScaleAction::Drain { inst, .. } if cluster.instances[*inst].role == Role::Prefill)),
            "prefill_elastic off must never touch the prefill tier"
        );
        // Idle queues → drain a prefill server after patience.
        for i in cluster.instances.iter_mut() {
            i.clear_prefill_queue();
        }
        let mut drained = false;
        for t in 1..=5u64 {
            let actions = {
                let mut ctx = RouteCtx {
                    now: t * 1000,
                    cluster: &mut cluster,
                    requests: &mut reqs,
                    profile: &profile,
                    mode: ServingMode::PdDisaggregated,
                    kv_transfer_ms: 2,
                };
                grad.evaluate(t * 1000, &mut ctx)
            };
            if actions.iter().any(
                |a| matches!(a, ScaleAction::Drain { inst, .. } if cluster.instances[*inst].role == Role::Prefill),
            ) {
                drained = true;
                break;
            }
        }
        // An empty queue reads pressure 0.0 — below the LO mark.
        assert!(drained, "idle prefill tier never drained");
    }
}
