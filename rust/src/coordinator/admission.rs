//! Profile-based admission predictors (§4.5, §4.6).
//!
//! Everything here reads *only* router-visible state: the profile table
//! and public instance load (batch composition, KV occupancy, wait
//! time). Output lengths are unknown to the router — it predicts with
//! the workload's average decode length, exactly as the paper does
//! ("PolyServe simplifies the problem by just predicting the output
//! length using the average decode length", §4.5).

use crate::profile::ProfileTable;
use crate::sim::{Instance, Role, SimRequest};
use crate::slo::TimeMs;

/// Admission safety margin: predicted iteration times must stay under
/// `SAFETY × TPOT`. Absorbs profile-interpolation error, the 1 ms
/// simulator quantization and average-output-length underprediction —
/// without it a server admitted to exactly TPOT tips over and poisons
/// every resident request (see EXPERIMENTS.md §Perf for the sweep that
/// picked this value).
pub const SAFETY: f64 = 0.97;

/// Router-side estimate of a decode instance's load state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadEstimate {
    /// Decode requests resident (incl. in-flight handoffs).
    pub batch: u64,
    /// KV tokens resident now.
    pub kv_now: u64,
    /// Predicted iteration time at the current state, ms.
    pub iter_now_ms: f64,
}

/// Estimate `inst`'s router-visible load: decode batch, resident KV
/// (in-flight handoffs included), and predicted iteration time.
///
/// O(1): reads the instance's cached load counters (maintained at
/// every queue mutation) instead of rescanning residents — this is the
/// routing hot path, called once per candidate per placement. In
/// scan-reference mode the accessors recompute, reproducing the pre-PR
/// cost *and* values exactly. The `(batch, kv_now)` pair returned here
/// is byte-identical to `Instance::load_key`, the tuple the cluster's
/// load-ordered tier indices are keyed on — so an ordered walk visits
/// candidates in exactly the order sorting these estimates would.
pub fn load_estimate(inst: &Instance, requests: &[SimRequest], profile: &ProfileTable) -> LoadEstimate {
    let batch = inst.decode_batch_now();
    let kv_now = inst.kv_used(requests) + inst.handoff_kv(requests);
    LoadEstimate {
        batch,
        kv_now,
        iter_now_ms: profile.iter_ms(batch.max(1), kv_now),
    }
}

/// §4.5 future-KV simulation: peak KV if the instance's current decode
/// population plus one new request (with `new_kv_start` tokens already)
/// all grow to the predicted output length.
///
/// Each resident request `j` has `kv_j` tokens now and is predicted to
/// grow by `rem_j` more tokens; it then completes and frees its KV.
/// KV(t) = Σ_{j: rem_j ≥ t} (kv_j + t), maximized over iteration index
/// t at the completion points.
///
/// The remaining-length predictor is `max(avg_d − decoded, avg_d/2)`:
/// the paper predicts with the plain average, but the *resident*
/// population is length-biased (long-output requests accumulate — the
/// inspection paradox), so a request that has already decoded past the
/// average is still expected to produce ≈ half an average more. Without
/// this correction the peak-KV estimate is biased low on heavy-tailed
/// traces and servers get packed past their TPOT.
pub fn peak_kv_prediction(
    inst: &Instance,
    requests: &[SimRequest],
    new_kv_start: Option<u64>,
    avg_decode_len: f64,
) -> u64 {
    let mut pop: Vec<(u64, u64)> = Vec::with_capacity(inst.running.len() + 2); // (kv_now, rem)
    let rem_of = |decoded: f64| -> u64 {
        (avg_decode_len - decoded).max(avg_decode_len * 0.5).max(1.0) as u64
    };
    for slot in &inst.running {
        let r = &requests[slot.req_idx];
        pop.push((r.kv_now(), rem_of(r.decoded as f64)));
    }
    for &(req_idx, _) in &inst.decode_queue {
        let r = &requests[req_idx];
        pop.push((r.kv_now(), rem_of(r.decoded as f64)));
    }
    if let Some(kv0) = new_kv_start {
        pop.push((kv0, avg_decode_len.max(1.0) as u64));
    }
    if pop.is_empty() {
        return 0;
    }
    pop.sort_unstable_by_key(|&(_, rem)| rem);
    // Evaluate KV just before each completion time.
    let mut best = 0u64;
    let suffix_kv: Vec<u64> = {
        // suffix sums of kv_now for requests with rem ≥ t
        let mut s = vec![0u64; pop.len() + 1];
        for i in (0..pop.len()).rev() {
            s[i] = s[i + 1] + pop[i].0;
        }
        s
    };
    for i in 0..pop.len() {
        let t = pop[i].1; // completion time of request i (iterations)
        // requests j ≥ i are still resident at time t (rem_j ≥ t).
        let alive = (pop.len() - i) as u64;
        let kv_at_t = suffix_kv[i] + alive * t;
        best = best.max(kv_at_t);
    }
    best
}

/// O(B) upper bound on the peak KV: every resident (plus the optional
/// new request) grows to its full predicted remaining length with no
/// completions in between.
pub fn peak_kv_upper_bound(
    inst: &Instance,
    requests: &[SimRequest],
    new_kv_start: Option<u64>,
    avg_decode_len: f64,
) -> u64 {
    let rem_of = |decoded: f64| -> u64 {
        (avg_decode_len - decoded).max(avg_decode_len * 0.5).max(1.0) as u64
    };
    let mut total = 0u64;
    for slot in &inst.running {
        let r = &requests[slot.req_idx];
        total += r.kv_now() + rem_of(r.decoded as f64);
    }
    for &(req_idx, _) in &inst.decode_queue {
        let r = &requests[req_idx];
        total += r.kv_now() + rem_of(r.decoded as f64);
    }
    if let Some(kv0) = new_kv_start {
        total += kv0 + avg_decode_len.max(1.0) as u64;
    }
    total
}

/// §4.5 + §4.6 decode admission: can `inst` (serving `tier_tpot_ms`)
/// admit a new decode request with `new_kv_start` KV tokens, arriving
/// now with its next token due by `next_deadline`?
///
/// * Steady state (§4.5): predicted iteration time at (B+1, peak KV)
///   must stay under the server's TPOT.
/// * First token (§4.6): now + wait + first-iteration time must meet
///   the request's next DSLO deadline (skipped when `wait_aware` off).
pub fn admit_decode(
    inst: &Instance,
    requests: &[SimRequest],
    profile: &ProfileTable,
    tier_tpot_ms: u64,
    new_kv_start: u64,
    next_deadline: TimeMs,
    now: TimeMs,
    avg_decode_len: f64,
    wait_aware: bool,
) -> bool {
    let est = load_estimate(inst, requests, profile);
    let b_new = est.batch + 1;
    if b_new > profile.max_token_batch {
        return false;
    }
    // Fast path (hot: §5.6 measures this): the O(B) *upper bound* on
    // peak KV — every resident grows its full predicted remainder with
    // no completions — is conservative, so passing both checks with it
    // implies the exact peak passes too. Only near the feasibility edge
    // do we pay the exact O(B log B) simulation.
    let upper = peak_kv_upper_bound(inst, requests, Some(new_kv_start), avg_decode_len);
    let peak = if upper <= profile.kv_capacity_tokens
        && profile.iter_ms(b_new, upper) < SAFETY * tier_tpot_ms as f64
    {
        upper
    } else {
        let exact = peak_kv_prediction(inst, requests, Some(new_kv_start), avg_decode_len);
        if exact > profile.kv_capacity_tokens {
            return false;
        }
        if profile.iter_ms(b_new, exact) >= SAFETY * tier_tpot_ms as f64 {
            return false;
        }
        exact
    };
    let _ = peak;
    if wait_aware {
        // First-token deadline check with the wait for the current
        // iteration (§4.6).
        let wait = inst.wait_ms(now) as f64;
        let iter_first = profile.iter_ms(b_new, est.kv_now + new_kv_start);
        if now as f64 + wait + iter_first > next_deadline as f64 {
            return false;
        }
    }
    true
}

/// Largest prefill chunk `c` such that the predicted mixed-iteration
/// time stays under `tpot_ms` given the decode load (b_dc, kv). The
/// profile table's batch axis is decode-equivalent tokens, so the chunk
/// is weighted by `pf_token_ratio` (c_pf/c_dc from the cost model,
/// baked into the table generation).
pub fn max_chunk_under(
    profile: &ProfileTable,
    tpot_ms: f64,
    b_dc: u64,
    kv: u64,
    pf_token_ratio: f64,
) -> u64 {
    let mut lo = 0u64;
    let mut hi = profile.max_token_batch.saturating_sub(b_dc);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        let eff = b_dc + (mid as f64 * pf_token_ratio).ceil() as u64;
        let t = profile.iter_ms(eff.max(1), kv + mid);
        if t < tpot_ms {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

/// §4.7 co-location admission with continuous chunked-prefill
/// prediction: admit iff a chunk size exists that (a) keeps every
/// prefill iteration under the server TPOT *even at the KV state
/// predicted for the end of the prefill* and (b) completes the prompt
/// by the TTFT deadline, and (c) the post-prefill decode admission
/// holds.
#[allow(clippy::too_many_arguments)]
pub fn admit_coloc(
    inst: &Instance,
    requests: &[SimRequest],
    profile: &ProfileTable,
    tier_tpot_ms: u64,
    prefill_len: u64,
    ttft_deadline: TimeMs,
    next_token_deadline: TimeMs,
    now: TimeMs,
    avg_decode_len: f64,
    pf_token_ratio: f64,
    wait_aware: bool,
    continuous_prediction: bool,
) -> bool {
    let est = load_estimate(inst, requests, profile);
    // Queued prefill work ahead of us on this instance.
    let queued_pf = inst.queued_prefill_tokens(requests);

    // Chunk size from the *predicted end-of-prefill* KV state when
    // continuous prediction is on (§4.7); else the current state.
    let kv_for_chunk = if continuous_prediction {
        // During our prefill the decode population keeps decoding; KV
        // grows by ~b_dc per iteration. Bound with the peak prediction.
        peak_kv_prediction(inst, requests, None, avg_decode_len)
            .max(est.kv_now)
            + queued_pf
            + prefill_len
    } else {
        est.kv_now + queued_pf
    };
    let chunk = max_chunk_under(
        profile,
        SAFETY * tier_tpot_ms as f64,
        est.batch,
        kv_for_chunk,
        pf_token_ratio,
    );
    if chunk == 0 {
        return false;
    }
    // TTFT: wait + (queued + own prompt) prefilled at `chunk` per
    // TPOT-bounded iteration.
    let n_iters = (queued_pf + prefill_len).div_ceil(chunk);
    let wait = if wait_aware { inst.wait_ms(now) } else { 0 };
    let eff = est.batch + (chunk as f64 * pf_token_ratio).ceil() as u64;
    let iter_est = profile.iter_ms(eff.max(1), kv_for_chunk.min(profile.kv_capacity_tokens));
    let finish = now as f64 + wait as f64 + n_iters as f64 * iter_est;
    if finish > ttft_deadline as f64 {
        return false;
    }
    // Post-prefill: the request joins the decode population.
    admit_decode(
        inst,
        requests,
        profile,
        tier_tpot_ms,
        prefill_len,
        next_token_deadline.max(ttft_deadline),
        now,
        avg_decode_len,
        false, // wait handled above; steady-state check only
    )
}

/// Arrival-edge SLO feasibility (the `[overload]` admission gate): can
/// `inst` plausibly serve a *fresh* request under `tier_tpot_ms`
/// without breaking deadlines? One predicate per role:
///
/// * `Coloc` — the full §4.7 co-location admission (prefill backlog +
///   TTFT headroom + post-prefill decode admission): exactly the check
///   `place_coloc` runs, so an accepted request is immediately
///   placeable on this instance.
/// * `Prefill` (PD) — backlog drain time: the queued prefill tokens
///   plus this prompt, drained at the packed-budget rate, must finish
///   inside the TTFT headroom. Optimistic relative to the exact EDF
///   queue simulation the placement path runs — a backlog that fails
///   even this bound is provably infeasible.
/// * `Decode` (PD) — decode-slot availability: the steady-state §4.5
///   batch/KV/TPOT admission with the prompt's KV as the newcomer.
///
/// Rejection must be *provable*: the check mirrors the placement
/// admission rather than approximating it, so `[overload] reject`
/// sheds only requests the router could not have served here anyway.
#[allow(clippy::too_many_arguments)]
pub fn feasible_at_arrival(
    inst: &Instance,
    requests: &[SimRequest],
    profile: &ProfileTable,
    tier_tpot_ms: u64,
    prefill_len: u64,
    ttft_deadline: TimeMs,
    next_token_deadline: TimeMs,
    now: TimeMs,
    avg_decode_len: f64,
    pf_token_ratio: f64,
    prefill_budget: u64,
    wait_aware: bool,
    continuous_prediction: bool,
) -> bool {
    match inst.role {
        Role::Coloc => admit_coloc(
            inst,
            requests,
            profile,
            tier_tpot_ms,
            prefill_len,
            ttft_deadline,
            next_token_deadline,
            now,
            avg_decode_len,
            pf_token_ratio,
            wait_aware,
            continuous_prediction,
        ),
        Role::Prefill => {
            let wait = if wait_aware { inst.wait_ms(now) } else { 0 };
            let backlog = inst.queued_prefill_tokens(requests) + prefill_len;
            let eff = (prefill_budget as f64 * pf_token_ratio).ceil() as u64;
            let chunk_ms = profile.iter_ms(eff.max(1), prefill_budget);
            let ms_per_token = chunk_ms / prefill_budget.max(1) as f64;
            now as f64 + wait as f64 + backlog as f64 * ms_per_token
                <= ttft_deadline as f64
        }
        Role::Decode => admit_decode(
            inst,
            requests,
            profile,
            tier_tpot_ms,
            prefill_len,
            next_token_deadline,
            now,
            avg_decode_len,
            wait_aware,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;
    use crate::sim::instance::{Instance, Role};
    use crate::slo::Slo;
    use crate::workload::Request;

    fn profile() -> ProfileTable {
        ProfileTable::from_cost_model(&CostModel::h200_llama8b())
    }

    fn sim_req(id: u64, p: u32, decoded: u32) -> SimRequest<'static> {
        // Leak the immutable half: the arena borrows, never clones.
        let req: &'static Request = Box::leak(Box::new(Request {
            id,
            arrival_ms: 0,
            prefill_len: p,
            decode_len: 10_000,
            slo: Slo::new(1000, 50),
            model: 0,
        }));
        let mut r = SimRequest::new(req, 0);
        r.prefill_done = p;
        r.decoded = decoded;
        r.first_token_ms = Some(0);
        r.decode_instance = Some(0);
        r
    }

    fn loaded_instance(n: usize, p: u32, decoded: u32) -> (Instance, Vec<SimRequest<'static>>) {
        let cm = CostModel::h200_llama8b();
        let mut inst = Instance::new(0, Role::Decode, cm.kv_capacity_tokens, cm.max_token_batch);
        let mut reqs = Vec::new();
        for i in 0..n {
            reqs.push(sim_req(i as u64, p, decoded));
            inst.push_running(i, &reqs);
        }
        (inst, reqs)
    }

    #[test]
    fn peak_kv_grows_with_population() {
        let (inst, reqs) = loaded_instance(10, 1000, 10);
        let p1 = peak_kv_prediction(&inst, &reqs, None, 300.0);
        let (inst2, reqs2) = loaded_instance(20, 1000, 10);
        let p2 = peak_kv_prediction(&inst2, &reqs2, None, 300.0);
        assert!(p2 > p1);
        // Lower bound: current KV.
        assert!(p1 >= 10 * 1010);
        // Upper bound: everyone grows to full predicted length.
        assert!(p1 <= 10 * (1000 + 300));
    }

    #[test]
    fn peak_kv_empty_instance() {
        let cm = CostModel::h200_llama8b();
        let inst = Instance::new(0, Role::Decode, cm.kv_capacity_tokens, cm.max_token_batch);
        assert_eq!(peak_kv_prediction(&inst, &[], None, 100.0), 0);
        assert_eq!(peak_kv_prediction(&inst, &[], Some(500), 100.0), 600);
    }

    #[test]
    fn admit_decode_respects_tpot_tiers() {
        // ~100 requests at kv 3000 → iteration near 28 ms: fits 50 ms
        // tier, not 20 ms tier.
        let (inst, reqs) = loaded_instance(100, 2800, 100);
        let prof = profile();
        let ok_50 = admit_decode(&inst, &reqs, &prof, 50, 2800, u64::MAX >> 1, 0, 150.0, false);
        let ok_20 = admit_decode(&inst, &reqs, &prof, 20, 2800, u64::MAX >> 1, 0, 150.0, false);
        assert!(ok_50);
        assert!(!ok_20);
    }

    #[test]
    fn wait_time_awareness_rejects_tight_deadlines() {
        let (mut inst, reqs) = loaded_instance(10, 1000, 10);
        inst.iterating = true;
        inst.busy_until = 100; // 80 ms wait from now=20
        let prof = profile();
        // Next token due at t=60 < 100+iter → reject when wait-aware.
        let tight = admit_decode(&inst, &reqs, &prof, 100, 1000, 60, 20, 50.0, true);
        let loose = admit_decode(&inst, &reqs, &prof, 100, 1000, 500, 20, 50.0, true);
        let unaware = admit_decode(&inst, &reqs, &prof, 100, 1000, 60, 20, 50.0, false);
        assert!(!tight);
        assert!(loose);
        assert!(unaware);
    }

    #[test]
    fn admit_decode_rejects_kv_overflow() {
        // 300 requests each growing to ~3200 tokens ≈ 0.96M > 0.9M cap.
        let (inst, reqs) = loaded_instance(300, 3000, 10);
        let prof = profile();
        let ok = admit_decode(&inst, &reqs, &prof, 100, 3000, u64::MAX >> 1, 0, 210.0, false);
        assert!(!ok);
    }

    #[test]
    fn max_chunk_monotone_in_tpot() {
        let prof = profile();
        let c20 = max_chunk_under(&prof, 20.0, 10, 50_000, 0.25);
        let c50 = max_chunk_under(&prof, 50.0, 10, 50_000, 0.25);
        let c100 = max_chunk_under(&prof, 100.0, 10, 50_000, 0.25);
        assert!(c20 <= c50 && c50 <= c100, "{c20} {c50} {c100}");
        assert!(c100 > 0);
    }

    #[test]
    fn max_chunk_zero_when_decode_already_over() {
        let prof = profile();
        // 400-batch decode at 800k KV ≈ 85 ms ≫ 20 ms: no chunk fits.
        let c = max_chunk_under(&prof, 20.0, 400, 800_000, 0.25);
        assert_eq!(c, 0);
    }

    #[test]
    fn coloc_admission_needs_ttft_headroom() {
        let (inst, reqs) = loaded_instance(20, 500, 50);
        let prof = profile();
        // 8000-token prompt with 300 ms TTFT at 30 ms TPOT → impossible.
        let no = admit_coloc(&inst, &reqs, &prof, 30, 8000, 300, 330, 0, 150.0, 0.25, true, true);
        // Same prompt with 10 s TTFT → fine.
        let yes = admit_coloc(&inst, &reqs, &prof, 30, 8000, 10_000, 10_030, 0, 150.0, 0.25, true, true);
        assert!(!no);
        assert!(yes);
    }

    #[test]
    fn arrival_feasibility_dispatches_by_role() {
        let prof = profile();
        let cm = CostModel::h200_llama8b();
        // Empty coloc server: generous TTFT feasible, impossible TTFT not.
        let coloc = Instance::new(0, Role::Coloc, cm.kv_capacity_tokens, cm.max_token_batch);
        assert!(feasible_at_arrival(
            &coloc, &[], &prof, 50, 2_000, 10_000, 10_050, 0, 150.0, 0.25, 2_048, true, true,
        ));
        assert!(!feasible_at_arrival(
            &coloc, &[], &prof, 50, 8_000, 10, 60, 0, 150.0, 0.25, 2_048, true, true,
        ));
        // Prefill server: the backlog drain-time bound prices the
        // prompt itself too — a huge prompt can't drain by a tight TTFT.
        let pf = Instance::new(1, Role::Prefill, cm.kv_capacity_tokens, cm.max_token_batch);
        assert!(feasible_at_arrival(
            &pf, &[], &prof, 50, 2_000, 1_000, 1_050, 0, 150.0, 0.25, 2_048, true, true,
        ));
        assert!(!feasible_at_arrival(
            &pf, &[], &prof, 50, 400_000, 200, 250, 0, 150.0, 0.25, 2_048, true, true,
        ));
        // Decode: steady-state slot availability mirrors admit_decode.
        let (inst, reqs) = loaded_instance(100, 2800, 100);
        assert!(feasible_at_arrival(
            &inst, &reqs, &prof, 50, 2_800, u64::MAX >> 1, u64::MAX >> 1, 0, 150.0, 0.25,
            2_048, false, false,
        ));
        assert!(!feasible_at_arrival(
            &inst, &reqs, &prof, 20, 2_800, u64::MAX >> 1, u64::MAX >> 1, 0, 150.0, 0.25,
            2_048, false, false,
        ));
    }

    #[test]
    fn continuous_prediction_is_more_conservative() {
        // Near the feasibility edge, predicting end-of-prefill KV must
        // reject at least as often as the optimistic variant.
        let (inst, reqs) = loaded_instance(120, 2500, 20);
        let prof = profile();
        let mut flips = 0;
        for ttft in [400u64, 600, 800, 1200, 2000, 4000] {
            let optimistic = admit_coloc(&inst, &reqs, &prof, 30, 4000, ttft, ttft + 30, 0, 260.0, 0.25, true, false);
            let conservative = admit_coloc(&inst, &reqs, &prof, 30, 4000, ttft, ttft + 30, 0, 260.0, 0.25, true, true);
            assert!(
                !(conservative && !optimistic),
                "conservative admitted where optimistic rejected (ttft={ttft})"
            );
            if optimistic != conservative {
                flips += 1;
            }
        }
        let _ = flips; // edge flips are plausible but not required
    }
}
