//! Simulator-throughput benchmark: the first point of the repo's perf
//! trajectory (`BENCH_sim_perf.json` at the repo root).
//!
//! Sweeps large-fleet, high-rate scenarios and reports **simulated
//! events per second of wall clock** and wall clock per cell. Every
//! scenario runs twice — once on the indexed/cached hot path (this
//! PR) and once through the scan-based reference path
//! (`Experiment::scan_reference`), which restores the pre-PR
//! O(fleet × batch)-per-event membership scans and per-candidate
//! resident rescans (the dominant hot-path costs; the PR's satellite
//! micro-optimizations — pending short-circuit, sweep narrowing,
//! scratch reuse — stay active in both paths, so the reported ratio
//! is a *conservative floor* on the true pre-PR speedup). Both runs
//! simulate identical workload bytes, and a digest over every
//! per-request outcome is asserted equal between the two paths in
//! *all* modes: the optimization must be decision-identical, not just
//! fast.
//!
//! Scenarios fan out via `par_map`, but a scenario's indexed and scan
//! halves are timed back-to-back *inside one worker* — the ratio
//! never compares cells that ran under different pool contention.
//! The per-event debug audit is disabled in the timed runs — with it
//! the bench would measure the audit's own full scans
//! ([profile.bench] keeps debug-assertions on).
//!
//! `POLYSERVE_SMOKE=1` shrinks the sweep and hard-asserts the CI gate:
//! events/sec > 0 in every cell, every cell finishes all requests,
//! the digests match, and `BENCH_sim_perf.json` is emitted and parses.

use polyserve::analysis::ServingMode;
use polyserve::config::{DiurnalSpec, Policy, ScalerKind, SimConfig};
use polyserve::figures::Experiment;
use polyserve::sim::SimResult;
use polyserve::util::benchkit::{f, fmt_count, full_scale, smoke_scale, Bench};
use polyserve::util::json::Json;
use polyserve::util::threadpool::par_map;
use polyserve::workload::TraceKind;
use std::time::Instant;

#[derive(Clone, Copy)]
struct Scenario {
    name: &'static str,
    mode: ServingMode,
    instances: usize,
    requests: usize,
    /// Gradient-elastic diurnal cell (exercises ScaleEval, lifecycle
    /// churn, and migration on top of routing).
    elastic: bool,
}

#[derive(Clone, Copy)]
struct Cell {
    scenario: Scenario,
    /// true = pre-PR scan-based reference path.
    scan: bool,
}

struct CellOut {
    events: u64,
    wall_s: f64,
    sim_span_ms: u64,
    attain: f64,
    unfinished: usize,
    digest: u64,
}

/// FNV-1a over every per-request outcome plus the run totals: any
/// scheduling divergence between the indexed and scan paths flips it.
fn digest(res: &SimResult) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for o in &res.outcomes {
        mix(o.id);
        mix(o.first_token_ms.unwrap_or(u64::MAX));
        mix(o.finish_ms.unwrap_or(u64::MAX));
        mix(o.tokens);
        mix(o.attained as u64);
        mix(o.min_slack_ms as u64);
    }
    mix(res.sim_span_ms);
    mix(res.cost.instance_busy_ms);
    mix(res.cost.active_instance_ms);
    h
}

fn run_cell(c: &Cell) -> CellOut {
    let s = c.scenario;
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        mode: s.mode,
        policy: Policy::PolyServe,
        instances: s.instances,
        requests: s.requests,
        rate_frac_of_optimal: 0.75,
        seed: 2607,
        ..Default::default()
    };
    if s.elastic {
        cfg.diurnal = Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 300.0 });
        cfg.elastic.scaler = ScalerKind::Gradient;
        cfg.elastic.min_instances = (s.instances / 3).max(2);
        cfg.elastic.max_instances = s.instances + (s.instances / 4).max(1);
        cfg.elastic.provision_delay_ms = 10_000;
        cfg.elastic.scale_eval_ms = 1_000;
        cfg.elastic.migration = true;
    }
    // Experiment::prepare is deterministic in cfg, so the scan and
    // indexed halves of a pair simulate identical workload bytes.
    let mut exp = Experiment::prepare(&cfg);
    exp.scan_reference = c.scan;
    exp.debug_audit = false; // timing: don't measure the audit itself
    let t0 = Instant::now();
    let res = exp.run();
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    CellOut {
        events: res.events_processed,
        wall_s,
        sim_span_ms: res.sim_span_ms,
        attain: res.attainment.overall(),
        unfinished: res.unfinished,
        digest: digest(&res),
    }
}

fn main() {
    // Suite "sim" + table "perf" → results/sim_perf.csv.
    let mut bench = Bench::new("sim");
    let full = full_scale();
    let smoke = smoke_scale();
    let pd = ServingMode::PdDisaggregated;
    let co = ServingMode::Colocated;
    let cell = |name, mode, instances, requests, elastic| Scenario {
        name,
        mode,
        instances,
        requests,
        elastic,
    };
    let scenarios: Vec<Scenario> = if smoke {
        vec![
            cell("pd_smoke", pd, 10, 500, false),
            cell("co_elastic_smoke", co, 8, 400, true),
        ]
    } else if full {
        vec![
            cell("pd_large", pd, 96, 30_000, false),
            cell("co_large", co, 96, 30_000, false),
            cell("pd_xl", pd, 192, 40_000, false),
            cell("pd_elastic", pd, 64, 20_000, true),
        ]
    } else {
        vec![
            cell("pd_large", pd, 64, 6_000, false),
            cell("co_large", co, 64, 6_000, false),
            cell("pd_xl", pd, 160, 8_000, false),
            cell("pd_elastic", pd, 48, 5_000, true),
        ]
    };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // One par_map item per scenario; each worker times its indexed and
    // scan halves back-to-back so the pair shares identical pool
    // contention and the speedup ratio is reproducible.
    let pairs: Vec<(Scenario, CellOut, CellOut)> =
        par_map(scenarios.clone(), threads, move |_, scenario| {
            let indexed = run_cell(&Cell { scenario, scan: false });
            let scan = run_cell(&Cell { scenario, scan: true });
            (scenario, indexed, scan)
        });
    let results: Vec<(Cell, &CellOut)> = pairs
        .iter()
        .flat_map(|(s, indexed, scan)| {
            [
                (Cell { scenario: *s, scan: false }, indexed),
                (Cell { scenario: *s, scan: true }, scan),
            ]
        })
        .collect();

    let mut rows = Vec::new();
    for (c, r) in &results {
        rows.push(vec![
            c.scenario.name.to_string(),
            c.scenario.mode.name().to_string(),
            if c.scan { "scan" } else { "indexed" }.to_string(),
            c.scenario.instances.to_string(),
            c.scenario.requests.to_string(),
            r.events.to_string(),
            (r.sim_span_ms / 1000).to_string(),
            f(r.wall_s, 3),
            fmt_count(r.events as f64 / r.wall_s),
            f(r.attain, 3),
            r.unfinished.to_string(),
        ]);
    }
    bench.table(
        "perf",
        &[
            "scenario",
            "mode",
            "path",
            "instances",
            "requests",
            "events",
            "sim_span_s",
            "wall_s",
            "events_per_sec",
            "attain",
            "unfinished",
        ],
        &rows,
    );

    // Per-scenario speedup (indexed over scan) + decision-identity.
    let mut speedups: Vec<(&str, f64)> = Vec::new();
    for (s, idx, scan) in &pairs {
        assert_eq!(
            idx.digest, scan.digest,
            "{}: indexed path diverged from the scan reference — \
             the optimization changed a scheduling decision",
            s.name
        );
        assert_eq!(idx.events, scan.events, "{}: event count diverged", s.name);
        let speedup = (idx.events as f64 / idx.wall_s) / (scan.events as f64 / scan.wall_s);
        speedups.push((s.name, speedup));
        println!(
            "  {:<20} {:>8} events  indexed {:>10}/s  scan {:>10}/s  speedup {:.2}x",
            s.name,
            idx.events,
            fmt_count(idx.events as f64 / idx.wall_s),
            fmt_count(scan.events as f64 / scan.wall_s),
            speedup
        );
    }

    // Repo-root perf-trajectory artifact.
    let mut root = Json::obj();
    root.set("bench", Json::Str("sim_perf".into()));
    root.set("unit", Json::Str("simulated events per wall-clock second".into()));
    root.set("smoke", Json::Bool(smoke));
    root.set("full", Json::Bool(full));
    let mut cells_json = Vec::new();
    for (c, r) in &results {
        let mut o = Json::obj();
        o.set("scenario", Json::Str(c.scenario.name.into()))
            .set("mode", Json::Str(c.scenario.mode.name().into()))
            .set(
                "path",
                Json::Str(if c.scan { "scan" } else { "indexed" }.into()),
            )
            .set("instances", Json::Num(c.scenario.instances as f64))
            .set("requests", Json::Num(c.scenario.requests as f64))
            .set("events", Json::Num(r.events as f64))
            .set("sim_span_ms", Json::Num(r.sim_span_ms as f64))
            .set("wall_s", Json::Num(r.wall_s))
            .set("events_per_sec", Json::Num(r.events as f64 / r.wall_s))
            .set("attainment", Json::Num(r.attain))
            .set("unfinished", Json::Num(r.unfinished as f64));
        cells_json.push(o);
    }
    root.set("cells", Json::Arr(cells_json));
    let mut sp = Json::obj();
    for (name, x) in &speedups {
        sp.set(name, Json::Num(*x));
    }
    root.set("speedup_indexed_over_scan", sp);
    let payload = root.pretty() + "\n";
    std::fs::write("BENCH_sim_perf.json", &payload).expect("write BENCH_sim_perf.json");
    println!("  [json] wrote BENCH_sim_perf.json");

    // CI smoke gate: hard asserts, not just a CSV.
    if smoke {
        for (c, r) in &results {
            assert!(r.events > 0, "{}: no events simulated", c.scenario.name);
            assert!(r.wall_s > 0.0);
            assert_eq!(
                r.unfinished, 0,
                "{}/{}: cell left requests unfinished",
                c.scenario.name,
                if c.scan { "scan" } else { "indexed" }
            );
            assert!((0.0..=1.0).contains(&r.attain));
        }
        let parsed = Json::parse(&std::fs::read_to_string("BENCH_sim_perf.json").unwrap())
            .expect("emitted JSON must parse");
        assert_eq!(
            parsed.get("cells").and_then(|c| c.as_arr()).map(|a| a.len()),
            Some(results.len())
        );
        assert!(parsed.get("speedup_indexed_over_scan").is_some());
        println!("smoke invariants OK ({} cells)", results.len());
    }
    bench.finish();
}
