//! Simulator-throughput benchmark: the repo's perf trajectory
//! (`BENCH_sim_perf.json` at the repo root — this PR plants its third
//! point, the calendar-queue event engine).
//!
//! Sweeps large-fleet, high-rate scenarios and reports **simulated
//! events per second of wall clock** and wall clock per cell, over a
//! two-axis cell grid:
//!
//! * queue axis — `calendar` (this PR's event engine: bucketed timing
//!   wheel + overflow ring + cursor-fed arrivals) vs `heap` (the
//!   pre-PR-6 global binary heap, `Experiment::heap_reference`);
//! * index axis — `ordered` (PR-5 load-ordered tier walks + O(1)
//!   unplaced demand), `indexed` (PR-4 reference: id-indexed
//!   membership, materialize-and-sort per placement), `scan` (the
//!   pre-PR-4 reference: full-fleet membership + resident scans).
//!
//! The four acceptance scenarios (`pd_fixed` / `coloc_elastic` /
//! `pd_elastic` / `pd_nograd`) run the full 6-cell queue × index
//! matrix in **every** mode (smoke, default, full), and a digest over
//! every per-request outcome is asserted equal across all of a
//! scenario's cells unconditionally: each optimization layer must be
//! decision-identical, not just fast. The remaining perf scenarios —
//! including `pd_10x`, ≥10× the previously largest fleet and request
//! count — run the two queue cells, so `speedup_calendar_over_heap`
//! is reported for every scenario.
//!
//! Scenarios fan out via `par_map`, but one scenario's cells are timed
//! back-to-back *inside one worker* — a ratio never compares cells
//! that ran under different pool contention. The per-event debug audit
//! is disabled in the timed runs — with it the bench would measure the
//! audit's own scans ([profile.bench] keeps debug-assertions on).
//!
//! `POLYSERVE_SMOKE=1` shrinks the sweep and hard-asserts the CI gate:
//! events/sec > 0 in every cell, every cell finishes all requests, and
//! `BENCH_sim_perf.json` is emitted and parses. The digest-identity
//! marker line (`digest identity verified across N queue x index
//! cells`) prints in every mode *after* the assertions run; CI greps
//! for it, so the identity checks can never be silently skipped. CI
//! uploads `results/sim_perf.csv` and `BENCH_sim_perf.json` as build
//! artifacts.

use polyserve::analysis::ServingMode;
use polyserve::config::{DiurnalSpec, Policy, ScalerKind, SimConfig};
use polyserve::figures::Experiment;
use polyserve::sim::SimResult;
use polyserve::util::benchkit::{f, fmt_count, full_scale, smoke_scale, Bench};
use polyserve::util::json::Json;
use polyserve::util::threadpool::par_map;
use polyserve::workload::TraceKind;
use std::time::Instant;

#[derive(Clone, Copy)]
struct Scenario {
    name: &'static str,
    mode: ServingMode,
    instances: usize,
    requests: usize,
    /// Gradient-elastic diurnal cell (exercises ScaleEval, lifecycle
    /// churn, and migration on top of routing).
    elastic: bool,
    /// Run the full 6-cell queue × index matrix (acceptance scenarios);
    /// non-matrix scenarios run only the two queue cells.
    matrix: bool,
    /// `load_gradient = off` ablation (the ordered sets walked in
    /// reverse; the references sort ascending).
    nograd: bool,
}

/// Which event engine a cell runs on.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Queue {
    /// This PR: calendar queue + cursor-fed arrivals.
    Calendar,
    /// Pre-PR-6 reference: the global binary heap, arrivals pre-seeded.
    Heap,
}

impl Queue {
    fn name(self) -> &'static str {
        match self {
            Queue::Calendar => "calendar",
            Queue::Heap => "heap",
        }
    }
}

/// Which hot-path generation a cell's fleet views run on.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Path {
    /// PR-5: load-ordered tier walks + O(1) unplaced demand.
    Ordered,
    /// PR-4 reference: indexed membership + cached loads, sorted walks.
    Indexed,
    /// Pre-PR-4 reference: full membership + resident scans.
    Scan,
}

impl Path {
    fn name(self) -> &'static str {
        match self {
            Path::Ordered => "ordered",
            Path::Indexed => "indexed",
            Path::Scan => "scan",
        }
    }
}

/// Cell grid of a scenario. Index 0 is always the (calendar, ordered)
/// baseline every other cell is digest-compared against; matrix
/// scenarios append the remaining five queue × index combinations,
/// non-matrix ones only the heap twin of the baseline.
fn cells_for(s: &Scenario) -> Vec<(Queue, Path)> {
    if s.matrix {
        vec![
            (Queue::Calendar, Path::Ordered),
            (Queue::Calendar, Path::Indexed),
            (Queue::Calendar, Path::Scan),
            (Queue::Heap, Path::Ordered),
            (Queue::Heap, Path::Indexed),
            (Queue::Heap, Path::Scan),
        ]
    } else {
        vec![(Queue::Calendar, Path::Ordered), (Queue::Heap, Path::Ordered)]
    }
}

struct CellOut {
    events: u64,
    wall_s: f64,
    sim_span_ms: u64,
    attain: f64,
    unfinished: usize,
    digest: u64,
}

impl CellOut {
    fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall_s
    }
}

/// FNV-1a over every per-request outcome plus the run totals: any
/// scheduling divergence between two cells of a scenario flips it.
fn digest(res: &SimResult) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for o in &res.outcomes {
        mix(o.id);
        mix(o.first_token_ms.unwrap_or(u64::MAX));
        mix(o.finish_ms.unwrap_or(u64::MAX));
        mix(o.tokens);
        mix(o.attained as u64);
        mix(o.min_slack_ms as u64);
    }
    mix(res.sim_span_ms);
    mix(res.cost.instance_busy_ms);
    mix(res.cost.active_instance_ms);
    h
}

fn run_cell(s: &Scenario, queue: Queue, path: Path) -> CellOut {
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        mode: s.mode,
        policy: Policy::PolyServe,
        instances: s.instances,
        requests: s.requests,
        rate_frac_of_optimal: 0.75,
        seed: 2607,
        ..Default::default()
    };
    if s.nograd {
        cfg.features.load_gradient = false;
    }
    if s.elastic {
        cfg.diurnal = Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 300.0 });
        cfg.elastic.scaler = ScalerKind::Gradient;
        cfg.elastic.min_instances = (s.instances / 3).max(2);
        cfg.elastic.max_instances = s.instances + (s.instances / 4).max(1);
        cfg.elastic.provision_delay_ms = 10_000;
        cfg.elastic.scale_eval_ms = 1_000;
        cfg.elastic.migration = true;
    }
    // Experiment::prepare is deterministic in cfg, so every cell of a
    // scenario simulates identical workload bytes.
    let mut exp = Experiment::prepare(&cfg);
    exp.heap_reference = queue == Queue::Heap;
    exp.scan_reference = path == Path::Scan;
    exp.indexed_reference = path == Path::Indexed;
    exp.debug_audit = false; // timing: don't measure the audit itself
    let t0 = Instant::now();
    let res = exp.run();
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    CellOut {
        events: res.events_processed,
        wall_s,
        sim_span_ms: res.sim_span_ms,
        attain: res.attainment.overall(),
        unfinished: res.unfinished,
        digest: digest(&res),
    }
}

fn main() {
    // Suite "sim" + table "perf" → results/sim_perf.csv.
    let mut bench = Bench::new("sim");
    let full = full_scale();
    let smoke = smoke_scale();
    let pd = ServingMode::PdDisaggregated;
    let co = ServingMode::Colocated;
    let cell = |name, mode, instances, requests, elastic, matrix, nograd| Scenario {
        name,
        mode,
        instances,
        requests,
        elastic,
        matrix,
        nograd,
    };
    // The four acceptance scenarios run the 6-cell matrix in EVERY
    // mode; the trailing perf scenarios scale with the mode and run
    // the calendar/heap pair only. `pd_10x` is ≥10× the previously
    // largest fleet and request count of its mode.
    let mut scenarios: Vec<Scenario> = if smoke {
        vec![
            cell("pd_fixed", pd, 10, 400, false, true, false),
            cell("coloc_elastic", co, 8, 400, true, true, false),
            cell("pd_elastic", pd, 8, 400, true, true, false),
            cell("pd_nograd", pd, 10, 400, false, true, true),
        ]
    } else if full {
        vec![
            cell("pd_fixed", pd, 64, 10_000, false, true, false),
            cell("coloc_elastic", co, 48, 8_000, true, true, false),
            cell("pd_elastic", pd, 48, 8_000, true, true, false),
            cell("pd_nograd", pd, 64, 10_000, false, true, true),
        ]
    } else {
        vec![
            cell("pd_fixed", pd, 32, 3_000, false, true, false),
            cell("coloc_elastic", co, 24, 2_000, true, true, false),
            cell("pd_elastic", pd, 24, 2_000, true, true, false),
            cell("pd_nograd", pd, 32, 3_000, false, true, true),
        ]
    };
    if full {
        scenarios.extend([
            cell("pd_large", pd, 96, 30_000, false, false, false),
            cell("co_large", co, 96, 30_000, false, false, false),
            cell("pd_xl", pd, 192, 40_000, false, false, false),
            cell("pd_elastic_xl", pd, 64, 20_000, true, false, false),
            cell("pd_10x", pd, 1_920, 400_000, false, false, false),
        ]);
    } else if !smoke {
        scenarios.extend([
            cell("pd_large", pd, 64, 6_000, false, false, false),
            cell("co_large", co, 64, 6_000, false, false, false),
            cell("pd_xl", pd, 160, 8_000, false, false, false),
            cell("pd_elastic_xl", pd, 48, 5_000, true, false, false),
            cell("pd_10x", pd, 1_600, 80_000, false, false, false),
        ]);
    }
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // One par_map item per scenario; each worker times its cells
    // back-to-back so a scenario's grid shares identical pool
    // contention and the speedup ratios are reproducible.
    let runs: Vec<(Scenario, Vec<((Queue, Path), CellOut)>)> =
        par_map(scenarios, threads, move |_, scenario| {
            let outs = cells_for(&scenario)
                .into_iter()
                .map(|(q, p)| ((q, p), run_cell(&scenario, q, p)))
                .collect();
            (scenario, outs)
        });
    let results: Vec<(Scenario, Queue, Path, &CellOut)> = runs
        .iter()
        .flat_map(|(s, outs)| {
            outs.iter().map(|(cell, o)| (*s, cell.0, cell.1, o)).collect::<Vec<_>>()
        })
        .collect();

    let mut rows = Vec::new();
    for (s, q, p, r) in &results {
        rows.push(vec![
            s.name.to_string(),
            s.mode.name().to_string(),
            q.name().to_string(),
            p.name().to_string(),
            s.instances.to_string(),
            s.requests.to_string(),
            r.events.to_string(),
            (r.sim_span_ms / 1000).to_string(),
            f(r.wall_s, 3),
            fmt_count(r.events_per_sec()),
            f(r.attain, 3),
            r.unfinished.to_string(),
        ]);
    }
    bench.table(
        "perf",
        &[
            "scenario",
            "mode",
            "queue",
            "path",
            "instances",
            "requests",
            "events",
            "sim_span_s",
            "wall_s",
            "events_per_sec",
            "attain",
            "unfinished",
        ],
        &rows,
    );

    // Decision identity: every cell of a scenario must reproduce the
    // (calendar, ordered) baseline bit-for-bit. Asserted in every mode
    // (smoke, default, full) — never skipped.
    let mut identity_cells = 0usize;
    for (s, outs) in &runs {
        let (_, baseline) = &outs[0];
        for ((q, p), r) in &outs[1..] {
            assert_eq!(
                baseline.digest,
                r.digest,
                "{}: calendar+ordered diverged from {}+{} — \
                 an optimization changed a scheduling decision",
                s.name,
                q.name(),
                p.name()
            );
            assert_eq!(
                baseline.events,
                r.events,
                "{}: event count diverged vs {}+{}",
                s.name,
                q.name(),
                p.name()
            );
            identity_cells += 1;
        }
    }
    // CI greps for this exact marker; it prints only after the asserts
    // above have all passed.
    println!("digest identity verified across {identity_cells} queue x index cells");

    // Per-scenario speedups. The calendar/heap ratio exists for every
    // scenario; the index-axis ratios only where the matrix ran.
    let find = |outs: &[((Queue, Path), CellOut)], q: Queue, p: Path| -> Option<f64> {
        outs.iter()
            .find(|((oq, op), _)| *oq == q && *op == p)
            .map(|(_, o)| o.events_per_sec())
    };
    let mut sp_calendar_heap: Vec<(&str, f64)> = Vec::new();
    let mut sp_ordered_scan: Vec<(&str, f64)> = Vec::new();
    let mut sp_ordered_indexed: Vec<(&str, f64)> = Vec::new();
    let mut sp_indexed_scan: Vec<(&str, f64)> = Vec::new();
    for (s, outs) in &runs {
        let cal = find(outs, Queue::Calendar, Path::Ordered).expect("baseline cell");
        let heap = find(outs, Queue::Heap, Path::Ordered).expect("heap twin");
        sp_calendar_heap.push((s.name, cal / heap));
        println!(
            "  {:<16} calendar {:>10}/s  heap {:>10}/s  cal/heap {:.2}x",
            s.name,
            fmt_count(cal),
            fmt_count(heap),
            cal / heap,
        );
        if let (Some(idx), Some(scan)) = (
            find(outs, Queue::Calendar, Path::Indexed),
            find(outs, Queue::Calendar, Path::Scan),
        ) {
            sp_ordered_scan.push((s.name, cal / scan));
            sp_ordered_indexed.push((s.name, cal / idx));
            sp_indexed_scan.push((s.name, idx / scan));
        }
    }

    // Repo-root perf-trajectory artifact (third point: calendar cells).
    let mut root = Json::obj();
    root.set("bench", Json::Str("sim_perf".into()));
    root.set("unit", Json::Str("simulated events per wall-clock second".into()));
    root.set("smoke", Json::Bool(smoke));
    root.set("full", Json::Bool(full));
    let mut cells_json = Vec::new();
    for (s, q, p, r) in &results {
        let mut o = Json::obj();
        o.set("scenario", Json::Str(s.name.into()))
            .set("mode", Json::Str(s.mode.name().into()))
            .set("queue", Json::Str(q.name().into()))
            .set("path", Json::Str(p.name().into()))
            .set("instances", Json::Num(s.instances as f64))
            .set("requests", Json::Num(s.requests as f64))
            .set("events", Json::Num(r.events as f64))
            .set("sim_span_ms", Json::Num(r.sim_span_ms as f64))
            .set("wall_s", Json::Num(r.wall_s))
            .set("events_per_sec", Json::Num(r.events_per_sec()))
            .set("attainment", Json::Num(r.attain))
            .set("unfinished", Json::Num(r.unfinished as f64));
        cells_json.push(o);
    }
    root.set("cells", Json::Arr(cells_json));
    for (label, sps) in [
        ("speedup_calendar_over_heap", &sp_calendar_heap),
        ("speedup_ordered_over_scan", &sp_ordered_scan),
        ("speedup_ordered_over_indexed", &sp_ordered_indexed),
        ("speedup_indexed_over_scan", &sp_indexed_scan),
    ] {
        let mut sp = Json::obj();
        for (name, x) in sps {
            sp.set(name, Json::Num(*x));
        }
        root.set(label, sp);
    }
    let payload = root.pretty() + "\n";
    std::fs::write("BENCH_sim_perf.json", &payload).expect("write BENCH_sim_perf.json");
    println!("  [json] wrote BENCH_sim_perf.json");

    // CI smoke gate: hard asserts, not just a CSV.
    if smoke {
        for (s, q, p, r) in &results {
            assert!(r.events > 0, "{}: no events simulated", s.name);
            assert!(r.wall_s > 0.0);
            assert_eq!(
                r.unfinished,
                0,
                "{}/{}/{}: cell left requests unfinished",
                s.name,
                q.name(),
                p.name()
            );
            assert!((0.0..=1.0).contains(&r.attain));
        }
        let parsed = Json::parse(&std::fs::read_to_string("BENCH_sim_perf.json").unwrap())
            .expect("emitted JSON must parse");
        assert_eq!(
            parsed.get("cells").and_then(|c| c.as_arr()).map(|a| a.len()),
            Some(results.len())
        );
        for key in [
            "speedup_calendar_over_heap",
            "speedup_ordered_over_scan",
            "speedup_ordered_over_indexed",
            "speedup_indexed_over_scan",
        ] {
            assert!(parsed.get(key).is_some(), "missing {key}");
        }
        println!("smoke invariants OK ({} cells)", results.len());
    }
    bench.finish();
}
