//! Simulator-throughput benchmark: the repo's perf trajectory
//! (`BENCH_sim_perf.json` at the repo root — this PR plants its second
//! point, the load-ordered fleet indices).
//!
//! Sweeps large-fleet, high-rate scenarios and reports **simulated
//! events per second of wall clock** and wall clock per cell. Every
//! scenario runs three times:
//!
//! * `ordered` — this PR's hot path: load-ordered tier walks (no
//!   per-placement sort or collect) + O(1) unplaced demand;
//! * `indexed` — the PR-4 reference (`Experiment::indexed_reference`):
//!   id-indexed membership and cached O(1) load counters, but a
//!   materialize-and-sort per placement and scan-reconstructed
//!   unplaced demand;
//! * `scan` — the pre-PR-4 reference (`Experiment::scan_reference`):
//!   full-fleet membership scans and per-candidate resident rescans.
//!
//! All three simulate identical workload bytes, and a digest over every
//! per-request outcome is asserted equal across all three paths in
//! *all* modes (not just smoke): each optimization layer must be
//! decision-identical, not just fast. The satellite micro-optimizations
//! (pending short-circuit, sweep narrowing, scratch reuse, cached tier
//! orders, k-least drain selection) stay active in every path, so the
//! reported ratios are conservative floors on the true historical
//! speedups.
//!
//! Scenarios fan out via `par_map`, but a scenario's three halves are
//! timed back-to-back *inside one worker* — a ratio never compares
//! cells that ran under different pool contention. The per-event debug
//! audit is disabled in the timed runs — with it the bench would
//! measure the audit's own full scans ([profile.bench] keeps
//! debug-assertions on).
//!
//! `POLYSERVE_SMOKE=1` shrinks the sweep and hard-asserts the CI gate:
//! events/sec > 0 in every cell, every cell finishes all requests,
//! the three digests match, and `BENCH_sim_perf.json` is emitted and
//! parses. CI uploads `results/sim_perf.csv` as a build artifact.

use polyserve::analysis::ServingMode;
use polyserve::config::{DiurnalSpec, Policy, ScalerKind, SimConfig};
use polyserve::figures::Experiment;
use polyserve::sim::SimResult;
use polyserve::util::benchkit::{f, fmt_count, full_scale, smoke_scale, Bench};
use polyserve::util::json::Json;
use polyserve::util::threadpool::par_map;
use polyserve::workload::TraceKind;
use std::time::Instant;

#[derive(Clone, Copy)]
struct Scenario {
    name: &'static str,
    mode: ServingMode,
    instances: usize,
    requests: usize,
    /// Gradient-elastic diurnal cell (exercises ScaleEval, lifecycle
    /// churn, and migration on top of routing).
    elastic: bool,
}

/// Which hot-path generation a cell runs on.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Path {
    /// This PR: load-ordered tier walks + O(1) unplaced demand.
    Ordered,
    /// PR-4 reference: indexed membership + cached loads, sorted walks.
    Indexed,
    /// Pre-PR-4 reference: full membership + resident scans.
    Scan,
}

impl Path {
    const ALL: [Path; 3] = [Path::Ordered, Path::Indexed, Path::Scan];

    fn name(self) -> &'static str {
        match self {
            Path::Ordered => "ordered",
            Path::Indexed => "indexed",
            Path::Scan => "scan",
        }
    }
}

struct CellOut {
    events: u64,
    wall_s: f64,
    sim_span_ms: u64,
    attain: f64,
    unfinished: usize,
    digest: u64,
}

impl CellOut {
    fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall_s
    }
}

/// FNV-1a over every per-request outcome plus the run totals: any
/// scheduling divergence between the three paths flips it.
fn digest(res: &SimResult) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for o in &res.outcomes {
        mix(o.id);
        mix(o.first_token_ms.unwrap_or(u64::MAX));
        mix(o.finish_ms.unwrap_or(u64::MAX));
        mix(o.tokens);
        mix(o.attained as u64);
        mix(o.min_slack_ms as u64);
    }
    mix(res.sim_span_ms);
    mix(res.cost.instance_busy_ms);
    mix(res.cost.active_instance_ms);
    h
}

fn run_cell(s: &Scenario, path: Path) -> CellOut {
    let mut cfg = SimConfig {
        trace: TraceKind::ShareGpt,
        mode: s.mode,
        policy: Policy::PolyServe,
        instances: s.instances,
        requests: s.requests,
        rate_frac_of_optimal: 0.75,
        seed: 2607,
        ..Default::default()
    };
    if s.elastic {
        cfg.diurnal = Some(DiurnalSpec { peak_to_trough: 3.0, period_s: 300.0 });
        cfg.elastic.scaler = ScalerKind::Gradient;
        cfg.elastic.min_instances = (s.instances / 3).max(2);
        cfg.elastic.max_instances = s.instances + (s.instances / 4).max(1);
        cfg.elastic.provision_delay_ms = 10_000;
        cfg.elastic.scale_eval_ms = 1_000;
        cfg.elastic.migration = true;
    }
    // Experiment::prepare is deterministic in cfg, so the three path
    // cells of a scenario simulate identical workload bytes.
    let mut exp = Experiment::prepare(&cfg);
    exp.scan_reference = path == Path::Scan;
    exp.indexed_reference = path == Path::Indexed;
    exp.debug_audit = false; // timing: don't measure the audit itself
    let t0 = Instant::now();
    let res = exp.run();
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    CellOut {
        events: res.events_processed,
        wall_s,
        sim_span_ms: res.sim_span_ms,
        attain: res.attainment.overall(),
        unfinished: res.unfinished,
        digest: digest(&res),
    }
}

fn main() {
    // Suite "sim" + table "perf" → results/sim_perf.csv.
    let mut bench = Bench::new("sim");
    let full = full_scale();
    let smoke = smoke_scale();
    let pd = ServingMode::PdDisaggregated;
    let co = ServingMode::Colocated;
    let cell = |name, mode, instances, requests, elastic| Scenario {
        name,
        mode,
        instances,
        requests,
        elastic,
    };
    let scenarios: Vec<Scenario> = if smoke {
        vec![
            cell("pd_smoke", pd, 10, 500, false),
            cell("co_elastic_smoke", co, 8, 400, true),
        ]
    } else if full {
        vec![
            cell("pd_large", pd, 96, 30_000, false),
            cell("co_large", co, 96, 30_000, false),
            cell("pd_xl", pd, 192, 40_000, false),
            cell("pd_elastic", pd, 64, 20_000, true),
        ]
    } else {
        vec![
            cell("pd_large", pd, 64, 6_000, false),
            cell("co_large", co, 64, 6_000, false),
            cell("pd_xl", pd, 160, 8_000, false),
            cell("pd_elastic", pd, 48, 5_000, true),
        ]
    };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // One par_map item per scenario; each worker times its three path
    // cells back-to-back so the triple shares identical pool contention
    // and the speedup ratios are reproducible.
    let triples: Vec<(Scenario, [CellOut; 3])> =
        par_map(scenarios.clone(), threads, move |_, scenario| {
            let outs = Path::ALL.map(|p| run_cell(&scenario, p));
            (scenario, outs)
        });
    let results: Vec<(Scenario, Path, &CellOut)> = triples
        .iter()
        .flat_map(|(s, outs)| {
            Path::ALL
                .iter()
                .zip(outs.iter())
                .map(|(&p, o)| (*s, p, o))
                .collect::<Vec<_>>()
        })
        .collect();

    let mut rows = Vec::new();
    for (s, p, r) in &results {
        rows.push(vec![
            s.name.to_string(),
            s.mode.name().to_string(),
            p.name().to_string(),
            s.instances.to_string(),
            s.requests.to_string(),
            r.events.to_string(),
            (r.sim_span_ms / 1000).to_string(),
            f(r.wall_s, 3),
            fmt_count(r.events_per_sec()),
            f(r.attain, 3),
            r.unfinished.to_string(),
        ]);
    }
    bench.table(
        "perf",
        &[
            "scenario",
            "mode",
            "path",
            "instances",
            "requests",
            "events",
            "sim_span_s",
            "wall_s",
            "events_per_sec",
            "attain",
            "unfinished",
        ],
        &rows,
    );

    // Per-scenario speedups + decision-identity across all three paths.
    let mut sp_ordered_scan: Vec<(&str, f64)> = Vec::new();
    let mut sp_ordered_indexed: Vec<(&str, f64)> = Vec::new();
    let mut sp_indexed_scan: Vec<(&str, f64)> = Vec::new();
    for (s, [ordered, indexed, scan]) in &triples {
        for (other, r) in [("indexed", indexed), ("scan", scan)] {
            assert_eq!(
                ordered.digest, r.digest,
                "{}: ordered path diverged from the {other} reference — \
                 the optimization changed a scheduling decision",
                s.name
            );
            assert_eq!(
                ordered.events, r.events,
                "{}: event count diverged vs {other}",
                s.name
            );
        }
        sp_ordered_scan.push((s.name, ordered.events_per_sec() / scan.events_per_sec()));
        sp_ordered_indexed
            .push((s.name, ordered.events_per_sec() / indexed.events_per_sec()));
        sp_indexed_scan.push((s.name, indexed.events_per_sec() / scan.events_per_sec()));
        println!(
            "  {:<20} {:>8} events  ordered {:>10}/s  indexed {:>10}/s  scan {:>10}/s  \
             ord/scan {:.2}x  ord/idx {:.2}x",
            s.name,
            ordered.events,
            fmt_count(ordered.events_per_sec()),
            fmt_count(indexed.events_per_sec()),
            fmt_count(scan.events_per_sec()),
            ordered.events_per_sec() / scan.events_per_sec(),
            ordered.events_per_sec() / indexed.events_per_sec(),
        );
    }

    // Repo-root perf-trajectory artifact (second point: ordered cells).
    let mut root = Json::obj();
    root.set("bench", Json::Str("sim_perf".into()));
    root.set("unit", Json::Str("simulated events per wall-clock second".into()));
    root.set("smoke", Json::Bool(smoke));
    root.set("full", Json::Bool(full));
    let mut cells_json = Vec::new();
    for (s, p, r) in &results {
        let mut o = Json::obj();
        o.set("scenario", Json::Str(s.name.into()))
            .set("mode", Json::Str(s.mode.name().into()))
            .set("path", Json::Str(p.name().into()))
            .set("instances", Json::Num(s.instances as f64))
            .set("requests", Json::Num(s.requests as f64))
            .set("events", Json::Num(r.events as f64))
            .set("sim_span_ms", Json::Num(r.sim_span_ms as f64))
            .set("wall_s", Json::Num(r.wall_s))
            .set("events_per_sec", Json::Num(r.events_per_sec()))
            .set("attainment", Json::Num(r.attain))
            .set("unfinished", Json::Num(r.unfinished as f64));
        cells_json.push(o);
    }
    root.set("cells", Json::Arr(cells_json));
    for (label, sps) in [
        ("speedup_ordered_over_scan", &sp_ordered_scan),
        ("speedup_ordered_over_indexed", &sp_ordered_indexed),
        ("speedup_indexed_over_scan", &sp_indexed_scan),
    ] {
        let mut sp = Json::obj();
        for (name, x) in sps {
            sp.set(name, Json::Num(*x));
        }
        root.set(label, sp);
    }
    let payload = root.pretty() + "\n";
    std::fs::write("BENCH_sim_perf.json", &payload).expect("write BENCH_sim_perf.json");
    println!("  [json] wrote BENCH_sim_perf.json");

    // CI smoke gate: hard asserts, not just a CSV.
    if smoke {
        for (s, p, r) in &results {
            assert!(r.events > 0, "{}: no events simulated", s.name);
            assert!(r.wall_s > 0.0);
            assert_eq!(
                r.unfinished,
                0,
                "{}/{}: cell left requests unfinished",
                s.name,
                p.name()
            );
            assert!((0.0..=1.0).contains(&r.attain));
        }
        let parsed = Json::parse(&std::fs::read_to_string("BENCH_sim_perf.json").unwrap())
            .expect("emitted JSON must parse");
        assert_eq!(
            parsed.get("cells").and_then(|c| c.as_arr()).map(|a| a.len()),
            Some(results.len())
        );
        for key in [
            "speedup_ordered_over_scan",
            "speedup_ordered_over_indexed",
            "speedup_indexed_over_scan",
        ] {
            assert!(parsed.get(key).is_some(), "missing {key}");
        }
        println!("smoke invariants OK ({} cells)", results.len());
    }
    bench.finish();
}
