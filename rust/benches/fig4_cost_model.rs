//! Fig 4 reproduction: per-request serving cost vs TPOT, co-location
//! (solid in the paper) vs PD-disaggregation (dashed), TTFT = 700 ms.
//!
//! Two regimes are printed: the H200-realistic KV capacity (900k
//! tokens) and the unbounded-KV regime the paper's figure implicitly
//! assumes (its co-location batch sizes exceed single-GPU KV capacity —
//! see EXPERIMENTS.md).

use polyserve::analysis::fig4_cost_series;
use polyserve::model::CostModel;
use polyserve::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::new("fig4");
    let tpots = [20.0, 30.0, 40.0, 50.0, 75.0, 100.0, 150.0];
    let configs = [(512u64, 512u64), (1000, 1000), (1000, 4000), (4000, 1000), (4000, 4000)];
    for (label, cm, ttft) in [
        ("C=900k tokens (H200), TTFT=700ms", CostModel::h200_llama8b(), 700.0),
        (
            "unbounded KV (paper's implicit regime), TTFT=2000ms",
            CostModel::h200_llama8b().with_unbounded_kv(),
            2000.0,
        ),
    ] {
        let mut rows = Vec::new();
        for &(p, d) in &configs {
            for pt in fig4_cost_series(&cm, p, d, ttft, &tpots) {
                rows.push(vec![
                    format!("({p},{d})"),
                    format!("{:.0}", pt.tpot_ms),
                    fmt(pt.cost_coloc_s),
                    fmt(pt.cost_pd_s),
                    if pt.cost_coloc_s < pt.cost_pd_s { "CO" } else { "PD" }.to_string(),
                ]);
            }
        }
        bench.table(
            &format!("Fig 4: cost inst*s/request — {label}"),
            &["(p,d)", "TPOT_ms", "cost_CO", "cost_PD", "cheaper"],
            &rows,
        );
    }
    bench.finish();
}

fn fmt(x: f64) -> String {
    if x.is_finite() { format!("{x:.3}") } else { "inf".into() }
}
