//! Fig 7 reproduction: burstiness — uniform lengths (input [1,8192],
//! output [1,2048]); the TPOT-tier mix inverts halfway through the run
//! (10/20/30/40% → 40/30/20/10%). PolyServe's fine-grained autoscaling
//! should absorb the shift (paper: 1.33× PD / 1.36× CO at 90%).

use polyserve::analysis::ServingMode;
use polyserve::config::{Policy, SimConfig};
use polyserve::figures::Experiment;
use polyserve::metrics::AttainmentCurve;
use polyserve::slo::TierDistribution;
use polyserve::util::benchkit::{f, full_scale, Bench};
use polyserve::util::rng::Rng;
use polyserve::util::threadpool::par_map;
use polyserve::workload::{Request, TraceKind, Workload};

/// Build the §5.3 workload: first half paper-default mix, second half
/// inverted, uniform lengths.
fn burst_workload(n: usize, rate_rps: f64, seed: u64) -> Workload {
    let mut rng = Rng::new(seed);
    let d1 = TierDistribution::paper_default();
    let d2 = TierDistribution::paper_inverted();
    let mut t = 0.0f64;
    let mut requests = Vec::with_capacity(n);
    for id in 0..n {
        t += rng.exp(rate_rps) * 1000.0;
        let dist = if id < n / 2 { &d1 } else { &d2 };
        requests.push(Request {
            id: id as u64,
            arrival_ms: t as u64,
            prefill_len: rng.range_u64(1, 8192) as u32,
            decode_len: rng.range_u64(1, 2048) as u32,
            slo: dist.sample(&mut rng),
            model: 0,
        });
    }
    Workload { requests }
}

fn main() {
    let mut bench = Bench::new("fig7");
    let full = full_scale();
    let n = if full { 300_000 } else { 6_000 };
    let fracs = [0.2, 0.35, 0.5, 0.65, 0.8, 0.95, 1.1];
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    struct Cell {
        mode: ServingMode,
        policy: Policy,
        frac: f64,
    }
    let mut cells = Vec::new();
    for mode in [ServingMode::PdDisaggregated, ServingMode::Colocated] {
        for policy in [Policy::PolyServe, Policy::Random, Policy::Minimal, Policy::Chunk] {
            if policy == Policy::Chunk && mode == ServingMode::PdDisaggregated {
                continue;
            }
            for &frac in &fracs {
                cells.push(Cell { mode, policy, frac });
            }
        }
    }
    let results = par_map(cells, threads, move |_, c| {
        let cfg = SimConfig {
            trace: TraceKind::Uniform4096x1024, // placeholder, workload overridden
            mode: c.mode,
            policy: c.policy,
            requests: n,
            rate_frac_of_optimal: c.frac,
            ..Default::default()
        };
        let mut exp = Experiment::prepare(&cfg);
        // Replace the trace workload with the burst workload at the
        // same rate.
        exp.workload = burst_workload(n, exp.rate_rps, cfg.seed);
        let res = exp.run();
        (c.mode, c.policy, exp.rate_rps, res.attainment.overall())
    });

    let mut rows = Vec::new();
    for mode in [ServingMode::PdDisaggregated, ServingMode::Colocated] {
        let mut goodputs: Vec<(Policy, f64)> = Vec::new();
        for policy in [Policy::PolyServe, Policy::Random, Policy::Minimal, Policy::Chunk] {
            let mut curve = AttainmentCurve::default();
            for (m, p, rate, att) in &results {
                if *m == mode && *p == policy {
                    curve.push(*rate, *att);
                    rows.push(vec![
                        mode.name().into(),
                        policy.label(mode),
                        f(*rate, 1),
                        f(*att, 3),
                    ]);
                }
            }
            if let Some(g) = curve.goodput_at(0.9) {
                goodputs.push((policy, g));
            }
        }
        if let Some(ps) = goodputs.iter().find(|(p, _)| *p == Policy::PolyServe) {
            let best = goodputs
                .iter()
                .filter(|(p, _)| *p != Policy::PolyServe)
                .map(|(_, g)| *g)
                .fold(0.0, f64::max);
            let gain = if best > 0.0 {
                f(ps.1 / best, 2)
            } else {
                "inf (baselines never reach 90%)".into()
            };
            rows.push(vec![mode.name().into(), "GAIN".into(), f(ps.1, 1), gain]);
        }
    }
    bench.table(
        "Fig 7: burst (tier-mix inversion) attainment; paper gains 1.33x PD / 1.36x CO",
        &["mode", "policy", "rate_rps", "attain_or_gain"],
        &rows,
    );
    bench.finish();
}
